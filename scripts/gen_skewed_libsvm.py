#!/usr/bin/env python3
"""Regenerate `rust/testdata/skewed.libsvm`, the straggler fixture.

The file is a small LIBSVM classification set whose stored non-zeros
are deliberately concentrated in a head block of dense rows: under a
row-balanced contiguous partition the first shard owns almost all of
the nnz (and therefore almost all of the local-step work), which is
exactly the skew `--balance nnz` (DESIGN.md §16) is designed to
repair.  The distributed-smoke CI job and the `--balance nnz` parity
tests in `rust/tests/balance.rs` read the checked-in copy; the bench
`dadm_round_skewed_balance` in `rust/benches/perf_hotpath.rs` uses the
same head/tail shape (generated in-process at larger n).

Deterministic by construction — a fixed-seed Mersenne generator and
3-decimal values — so re-running this script reproduces the checked-in
bytes exactly.  Regenerate with:

    python3 scripts/gen_skewed_libsvm.py
"""

import random
from pathlib import Path

OUT = Path(__file__).resolve().parent.parent / "rust" / "testdata" / "skewed.libsvm"

SEED = 0xDAD5
N = 160  # rows
DIM = 64  # 1-based feature indices 1..=DIM
HEAD = 24  # dense head rows
HEAD_NNZ = (40, 56)  # nnz range for head rows
TAIL_NNZ = (1, 4)  # nnz range for tail rows


def main() -> None:
    rng = random.Random(SEED)
    lines = []
    for i in range(N):
        lo, hi = HEAD_NNZ if i < HEAD else TAIL_NNZ
        nnz = rng.randint(lo, min(hi, DIM))
        indices = sorted(rng.sample(range(1, DIM + 1), nnz))
        label = rng.choice((-1, 1))
        feats = " ".join(
            # :g-style trim keeps the file byte-stable and small.
            f"{j}:{round(rng.uniform(-4.0, 4.0), 3):g}"
            for j in indices
        )
        lines.append(f"{label} {feats}")
    OUT.write_text("\n".join(lines) + "\n")
    head_nnz = sum(line.count(":") for line in lines[:HEAD])
    total_nnz = sum(line.count(":") for line in lines)
    print(
        f"wrote {OUT} — {N} rows, {total_nnz} nnz, "
        f"head {HEAD} rows hold {100 * head_nnz / total_nnz:.0f}% of nnz"
    )


if __name__ == "__main__":
    main()
