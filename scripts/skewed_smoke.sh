#!/usr/bin/env bash
# Straggler-repair smoke (DESIGN.md §16), run by the distributed-smoke
# CI job:
#
#   1. solve the checked-in skewed-nnz LIBSVM fixture (dense head rows
#      hoard ~3/4 of the stored non-zeros) on Cluster::Serial with
#      --balance nnz,
#   2. solve the same problem under --cluster tcp with 4 real
#      `dadm worker` processes — the nnz-balanced row ranges ship
#      explicitly in the specs and each worker sub-splits its shard
#      with the same split_nnz formula,
#   3. assert the two trace CSVs agree bit for bit on every modeled
#      column (the first eight fields, round..comm_secs; wall_secs and
#      the step_min/mean/max_secs + imbalance straggler telemetry are
#      real elapsed time and are stripped).
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${DADM_BIN:-target/release/dadm}
FIXTURE=rust/testdata/skewed.libsvm
MACHINES=4
WORK=$(mktemp -d)
cleanup() {
    # The coordinator shuts workers down; the kill is a safety net for
    # early-exit failures.
    kill "${PIDS[@]:-}" 2>/dev/null || true
    rm -rf "$WORK"
}
trap cleanup EXIT
PIDS=()

# One flag set for both runs: only the backend differs.
COMMON=(--method dadm --loss svm --lambda 1e-3 --machines "$MACHINES"
    --sp 0.5 --eps 1e-12 --max-passes 6 --seed 7 --balance nnz
    --local-threads 2)

echo "== skewed fixture, serial, --balance nnz =="
"$BIN" --dataset "$FIXTURE" "${COMMON[@]}"
mv target/dadm_trace.csv "$WORK/serial.csv"

echo "== skewed fixture, --cluster tcp ($MACHINES worker processes), --balance nnz =="
"$BIN" --dataset "$FIXTURE" "${COMMON[@]}" \
    --cluster tcp --tcp-listen 127.0.0.1:0 >"$WORK/coord.log" 2>&1 &
COORD=$!
PIDS+=("$COORD")

# The coordinator binds an ephemeral port and prints it; wait for the
# line, then connect the fleet.
ADDR=""
for _ in $(seq 100); do
    ADDR=$(sed -n 's/^coordinator listening on \([0-9.:]*\);.*/\1/p' \
        "$WORK/coord.log" 2>/dev/null | head -n 1)
    [ -n "$ADDR" ] && break
    sleep 0.1
done
[ -n "$ADDR" ] || {
    echo "coordinator never announced its address:"
    cat "$WORK/coord.log"
    exit 1
}
for _ in $(seq "$MACHINES"); do
    "$BIN" worker --connect "$ADDR" &
    PIDS+=("$!")
done
wait "$COORD"
cat "$WORK/coord.log"
mv target/dadm_trace.csv "$WORK/tcp.csv"

echo "== trace parity (modeled columns) =="
cut -d, -f1-8 "$WORK/serial.csv" >"$WORK/serial.math.csv"
cut -d, -f1-8 "$WORK/tcp.csv" >"$WORK/tcp.math.csv"
if ! diff -u "$WORK/serial.math.csv" "$WORK/tcp.math.csv"; then
    echo "FAIL: nnz-balanced TCP trace diverged from the serial trace"
    exit 1
fi
ROUNDS=$(($(wc -l <"$WORK/serial.csv") - 1))
echo "skewed-smoke OK: $ROUNDS rounds bit-identical (serial vs tcp, --balance nnz)"
