#!/usr/bin/env python3
"""Diff a fresh BENCH_*.json against the committed baseline.

Usage:
    bench_diff.py BASELINE.json FRESH.json [--threshold=1.25]

Both files are BenchTable JSON artifacts ({"bench", "meta", "header",
"rows"}). Rows are keyed by (bench, config) — the first two columns —
and the third column is the median time as emitted by `fmt_secs`
(e.g. "1.5µs", "2.30ms", "0.123s", "40.0ns"). The gate FAILS (exit 1)
when any row present in both files regresses past the threshold
(fresh > baseline * threshold, default 1.25 = the 25% budget), or when
fewer than half of the baseline's timed rows could be matched (which
means the bench configs drifted and the baseline needs a refresh).

Rows whose median is not a time (e.g. "skipped") are ignored. Baseline
rows missing from the fresh run count toward the match-coverage check;
fresh rows missing from the baseline FAIL the gate outright (a new
bench landed without a seeded baseline row — every timed row must be
covered). Speedups are reported, never required.

The committed baseline may be *seeded* (meta.provenance starts with
"seeded"): conservative upper bounds written before the first CI
artifact existed. Refresh it by copying a bench-smoke artifact's
BENCH_perf_hotpath.json rows into BENCH_baseline.json (keep the meta
block, update provenance) — the gate tightens automatically.
"""

import json
import re
import sys

TIME_RE = re.compile(r"^([0-9]+(?:\.[0-9]+)?)(ns|µs|us|ms|s)$")
SCALE = {"ns": 1e-9, "µs": 1e-6, "us": 1e-6, "ms": 1e-3, "s": 1.0}


def parse_secs(cell):
    m = TIME_RE.match(cell.strip())
    if not m:
        return None
    return float(m.group(1)) * SCALE[m.group(2)]


def load_rows(path):
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    rows = {}
    for row in doc.get("rows", []):
        if len(row) < 3:
            continue
        secs = parse_secs(row[2])
        if secs is not None:
            rows[(row[0], row[1])] = secs
    return doc, rows


def main(argv):
    args, threshold = [], 1.25
    for a in argv[1:]:
        if a.startswith("--threshold="):
            threshold = float(a.split("=", 1)[1])
        elif a.startswith("--"):
            print(f"unknown flag {a} (use --threshold=X)")
            return 2
        else:
            args.append(a)
    if len(args) != 2:
        print(__doc__)
        return 2
    base_doc, base = load_rows(args[0])
    _, fresh = load_rows(args[1])

    provenance = base_doc.get("meta", {}).get("provenance", "")
    if provenance.startswith("seeded"):
        print(
            f"note: baseline is seeded with conservative upper bounds "
            f"({provenance}); refresh it from a CI bench artifact to tighten the gate"
        )

    regressions, matched, table = [], 0, []
    for key in sorted(base):
        bench, config = key
        if key not in fresh:
            print(f"MISSING  {bench} [{config}]: not in fresh run")
            continue
        matched += 1
        b, f = base[key], fresh[key]
        ratio = f / b if b > 0 else float("inf")
        status = "ok"
        if ratio > threshold:
            status = "REGRESSED"
            regressions.append((bench, config, b, f, ratio))
        table.append((status, bench, config, b, f, ratio))
    # Per-row delta table — printed on success as well as failure, so a
    # green CI run still shows where the time went (slowest-relative
    # rows first; negative delta = faster than baseline).
    if table:
        table.sort(key=lambda r: -r[5])
        name_w = max(len(f"{r[1]} [{r[2]}]") for r in table)
        print(f"{'':>9}  {'row':<{name_w}}  {'base':>10}  {'fresh':>10}  {'ratio':>7}  {'delta':>8}")
        for status, bench, config, b, f, ratio in table:
            name = f"{bench} [{config}]"
            delta = 100.0 * (f - b) / b if b > 0 else float("inf")
            print(
                f"{status:>9}  {name:<{name_w}}  {b * 1e3:>8.3f}ms  "
                f"{f * 1e3:>8.3f}ms  {ratio:>6.2f}x  {delta:>+7.1f}%"
            )
    uncovered = sorted(set(fresh) - set(base))
    for key in uncovered:
        print(f"NEW      {key[0]} [{key[1]}]: {fresh[key] * 1e3:.3f}ms (uncovered: no baseline row)")

    if regressions:
        print(f"\nFAIL: {len(regressions)} row(s) regressed past {threshold:.2f}x:")
        for bench, config, b, f, ratio in regressions:
            print(f"  {bench} [{config}]: {b * 1e3:.3f}ms -> {f * 1e3:.3f}ms ({ratio:.2f}x)")
        return 1
    if uncovered:
        print(f"\nFAIL: {len(uncovered)} fresh row(s) have no baseline coverage:")
        for bench, config in uncovered:
            print(f"  {bench} [{config}]")
        print("seed them in BENCH_baseline.json (conservative ceiling) so the gate covers them")
        return 1
    if not base:
        print("FAIL: baseline has no timed rows")
        return 1
    if matched * 2 < len(base):
        print(
            f"\nFAIL: only {matched}/{len(base)} baseline rows matched — bench "
            f"configs drifted; refresh BENCH_baseline.json from the artifact"
        )
        return 1
    print(f"\nPASS: {matched}/{len(base)} rows within {threshold:.2f}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
