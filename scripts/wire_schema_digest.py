#!/usr/bin/env python3
"""Wire-schema fingerprint for rust/src/comm/wire.rs — Python port.

A line-for-line port of the normalization in
rust/tools/dadm-lint/src/schema.rs (and the token scanner in
src/lexer.rs), for environments without a Rust toolchain. Both
implementations must produce identical digests over wire.rs; the
dadm-lint `real_tree_lints_clean` test pins the Rust side to the
committed rust/src/comm/wire.schema, and CI runs `dadm-lint -- check`
on every push, so any divergence between the two ports fails loudly.

Usage:
    python3 scripts/wire_schema_digest.py            # print version/digest
    python3 scripts/wire_schema_digest.py --write    # regenerate wire.schema
"""

import sys
from pathlib import Path

TRACKED_ITEMS = {
    "WIRE_MAGIC",
    "WIRE_VERSION",
    "MAX_FRAME_LEN",
    "FRAME_HEADER_BYTES",
    "WireLoss",
    "WireReg",
    "WireSolver",
    "DataSpec",
    "ProblemSpec",
    "WireBroadcast",
    "BroadcastRef",
    "EvalOp",
    "StepFlags",
    "Frame",
}
TRACKED_PREFIXES = ("TAG_", "STEP_FLAG_")


def tracked(name):
    return name in TRACKED_ITEMS or name.startswith(TRACKED_PREFIXES)


def is_ident_start(c):
    return ("a" <= c <= "z") or ("A" <= c <= "Z") or c == "_"


def is_ident_continue(c):
    return is_ident_start(c) or ("0" <= c <= "9")


def lex(src):
    """Token texts, mirroring lexer.rs exactly (comments dropped).

    Each token is (text, kind) with kind in {"ident", "punct", "lit"} —
    the schema path only needs text plus punct identification.
    """
    toks = []
    i = 0
    n = len(src)

    def peek(a):
        j = i + a
        return src[j] if j < n else "\0"

    while i < n:
        c = src[i]
        if c.isspace():
            i += 1
            continue
        # Line comments (waivers are irrelevant here — dropped).
        if c == "/" and peek(1) == "/":
            while i < n and src[i] != "\n":
                i += 1
            continue
        # Nested block comments.
        if c == "/" and peek(1) == "*":
            i += 2
            depth = 1
            while i < n and depth > 0:
                if src[i] == "/" and peek(1) == "*":
                    i += 2
                    depth += 1
                elif src[i] == "*" and peek(1) == "/":
                    i += 2
                    depth -= 1
                else:
                    i += 1
            continue
        # Raw strings / byte strings / raw identifiers.
        if c in ("r", "b"):
            if c == "b" and peek(1) == "r":
                prefix_len, has_b, has_r = 2, True, True
            elif c == "b":
                prefix_len, has_b, has_r = 1, True, False
            else:
                prefix_len, has_b, has_r = 1, False, True
            j = prefix_len
            nh = 0
            if has_r:
                while peek(j) == "#":
                    j += 1
                    nh += 1
            if peek(j) == '"':
                start = i
                i += prefix_len + nh + 1  # prefix, hashes, opening quote
                while i < n:
                    ch = src[i]
                    i += 1
                    if nh == 0:
                        if ch == "\\":
                            i += 1
                        elif ch == '"':
                            break
                    elif ch == '"':
                        seen = 0
                        while seen < nh and peek(0) == "#":
                            i += 1
                            seen += 1
                        if seen == nh:
                            break
                toks.append((src[start:i], "lit"))
                continue
            if has_b and not has_r and peek(1) == "'":
                start = i
                i += 2
                while i < n:
                    ch = src[i]
                    i += 1
                    if ch == "\\":
                        i += 1
                    elif ch == "'":
                        break
                toks.append((src[start:i], "lit"))
                continue
            if has_r and not has_b and peek(1) == "#" and is_ident_start(peek(2)):
                start = i
                i += 2
                while i < n and is_ident_continue(src[i]):
                    i += 1
                toks.append((src[start:i], "ident"))
                continue
            # Fall through: plain identifier starting with r/b.
        if is_ident_start(c):
            start = i
            while i < n and is_ident_continue(src[i]):
                i += 1
            toks.append((src[start:i], "ident"))
            continue
        if "0" <= c <= "9":
            # Never consumes `.` — `0..n` and `1.5` split, as in lexer.rs.
            start = i
            while i < n and is_ident_continue(src[i]):
                i += 1
            toks.append((src[start:i], "lit"))
            continue
        if c == '"':
            start = i
            i += 1
            while i < n:
                ch = src[i]
                i += 1
                if ch == "\\":
                    i += 1
                elif ch == '"':
                    break
            toks.append((src[start:i], "lit"))
            continue
        if c == "'":
            if is_ident_start(peek(1)) and peek(2) != "'":
                start = i
                i += 1
                while i < n and is_ident_continue(src[i]):
                    i += 1
                toks.append((src[start:i], "lit"))
                continue
            start = i
            i += 1
            while i < n:
                ch = src[i]
                i += 1
                if ch == "\\":
                    i += 1
                elif ch == "'":
                    break
            toks.append((src[start:i], "lit"))
            continue
        toks.append((c, "punct"))
        i += 1
    return toks


def is_punct(toks, i, c):
    return 0 <= i < len(toks) and toks[i][1] == "punct" and toks[i][0] == c


def ident_at(toks, i):
    if 0 <= i < len(toks) and toks[i][1] == "ident":
        return toks[i][0]
    return None


def item_span_end(toks, i, kw):
    # Depth counts []/() too: `const WIRE_MAGIC: [u8; 4] = ...;` has a
    # `;` inside the array type. Only `}` closes a struct/enum body;
    # `const` items always run to their `;`.
    brace_bodied = kw != "const"
    depth = 0
    j = i
    while j < len(toks):
        if is_punct(toks, j, "{") or is_punct(toks, j, "[") or is_punct(toks, j, "("):
            depth += 1
        elif is_punct(toks, j, "}"):
            depth = max(depth - 1, 0)
            if depth == 0 and brace_bodied:
                return j + 1
        elif is_punct(toks, j, "]") or is_punct(toks, j, ")"):
            depth = max(depth - 1, 0)
        elif is_punct(toks, j, ";") and depth == 0:
            return j + 1
        j += 1
    return len(toks)


def normalize(toks):
    parts = []
    i = 0
    while i < len(toks):
        if is_punct(toks, i, "#") and is_punct(toks, i + 1, "["):
            depth = 1
            j = i + 2
            while j < len(toks) and depth > 0:
                if is_punct(toks, j, "["):
                    depth += 1
                elif is_punct(toks, j, "]"):
                    depth -= 1
                j += 1
            i = j
            continue
        parts.append(toks[i][0])
        i += 1
    return " ".join(parts)


def extract_items(toks):
    items = []
    depth = 0
    i = 0
    while i < len(toks):
        if is_punct(toks, i, "{"):
            depth += 1
        elif is_punct(toks, i, "}"):
            depth = max(depth - 1, 0)
        elif depth == 0:
            kw = ident_at(toks, i)
            if kw in ("const", "struct", "enum"):
                name = ident_at(toks, i + 1)
                if name is not None and tracked(name):
                    end = item_span_end(toks, i, kw)
                    items.append((name, normalize(toks[i:end])))
                    i = end
                    continue
        i += 1
    items.sort()
    return items


def fnv1a64(data):
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def fingerprint(src):
    items = extract_items(lex(src))
    version = None
    for name, norm in items:
        if name == "WIRE_VERSION":
            parts = norm.split(" ")
            version = int(parts[parts.index("=") + 1])
    if version is None:
        raise SystemExit("wire.rs has no top-level WIRE_VERSION const")
    joined = "\n".join(f"{name} := {norm}" for name, norm in items)
    return version, format(fnv1a64(joined.encode("utf-8")), "016x")


def main():
    root = Path(__file__).resolve().parent.parent
    wire = root / "rust" / "src" / "comm" / "wire.rs"
    version, digest = fingerprint(wire.read_text())
    if "--write" in sys.argv[1:]:
        schema = root / "rust" / "src" / "comm" / "wire.schema"
        schema.write_text(
            "# Wire-schema fingerprint for rust/src/comm/wire.rs (DESIGN.md §12.4).\n"
            "# FNV-1a 64 over the normalized frame-item token streams; fails the\n"
            "# `wire-schema` lint when frame definitions drift without a\n"
            "# WIRE_VERSION bump. Regenerate: cargo run -p dadm-lint -- schema --update\n"
            f"version = {version}\n"
            f"digest = {digest}\n"
        )
        print(f"wrote {schema} (digest {digest})")
    else:
        print(f"version = {version}")
        print(f"digest = {digest}")


if __name__ == "__main__":
    main()
