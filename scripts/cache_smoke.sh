#!/usr/bin/env bash
# Out-of-core cache smoke (DESIGN.md §15), run by the distributed-smoke
# CI job:
#
#   1. compile the checked-in LIBSVM fixture into a binary CSR cache,
#   2. solve from the text parse on Cluster::Serial (contiguous
#      partition — the cache's implied scheme),
#   3. solve from the mmapped cache under --cluster tcp with real
#      `dadm worker` processes (each worker maps its own shard row
#      range; no training rows cross the wire),
#   4. assert the two trace CSVs agree bit for bit on every modeled
#      column (the first eight fields, round..comm_secs; wall_secs and
#      the step_min/mean/max_secs + imbalance straggler telemetry are
#      real elapsed time and are stripped — the same projection the
#      in-process parity test
#      `cli::tests::cache_solve_is_bit_identical_to_text_solve` uses).
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${DADM_BIN:-target/release/dadm}
FIXTURE=rust/testdata/smoke.libsvm
MACHINES=4
WORK=$(mktemp -d)
cleanup() {
    # The coordinator shuts workers down; the kill is a safety net for
    # early-exit failures.
    kill "${PIDS[@]:-}" 2>/dev/null || true
    rm -rf "$WORK"
}
trap cleanup EXIT
PIDS=()

echo "== compile-cache =="
"$BIN" compile-cache "$FIXTURE" "$WORK/smoke.dadmcache"

# One flag set for both runs: only the data source and backend differ.
COMMON=(--method dadm --loss svm --lambda 1e-3 --machines "$MACHINES"
    --sp 0.5 --eps 1e-12 --max-passes 6 --seed 7 --partition contiguous)

echo "== text parse, serial =="
"$BIN" --dataset "$FIXTURE" "${COMMON[@]}"
mv target/dadm_trace.csv "$WORK/text.csv"

echo "== mmap cache, --cluster tcp ($MACHINES worker processes) =="
"$BIN" --cache "$WORK/smoke.dadmcache" "${COMMON[@]}" \
    --cluster tcp --tcp-listen 127.0.0.1:0 >"$WORK/coord.log" 2>&1 &
COORD=$!
PIDS+=("$COORD")

# The coordinator binds an ephemeral port and prints it; wait for the
# line, then connect the fleet.
ADDR=""
for _ in $(seq 100); do
    ADDR=$(sed -n 's/^coordinator listening on \([0-9.:]*\);.*/\1/p' \
        "$WORK/coord.log" 2>/dev/null | head -n 1)
    [ -n "$ADDR" ] && break
    sleep 0.1
done
[ -n "$ADDR" ] || {
    echo "coordinator never announced its address:"
    cat "$WORK/coord.log"
    exit 1
}
for _ in $(seq "$MACHINES"); do
    "$BIN" worker --connect "$ADDR" &
    PIDS+=("$!")
done
wait "$COORD"
cat "$WORK/coord.log"
mv target/dadm_trace.csv "$WORK/cache.csv"

echo "== trace parity (modeled columns) =="
cut -d, -f1-8 "$WORK/text.csv" >"$WORK/text.math.csv"
cut -d, -f1-8 "$WORK/cache.csv" >"$WORK/cache.math.csv"
if ! diff -u "$WORK/text.math.csv" "$WORK/cache.math.csv"; then
    echo "FAIL: cache-backed TCP trace diverged from the text-parsed serial trace"
    exit 1
fi
ROUNDS=$(($(wc -l <"$WORK/text.csv") - 1))
echo "cache-smoke OK: $ROUNDS rounds bit-identical (text/serial vs cache/tcp)"
