//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The build environment ships neither the `xla` crate nor the
//! `xla_extension` shared library, so this stub provides the exact API
//! surface `dadm::runtime` compiles against while reporting the runtime
//! as unavailable at the first constructor ([`PjRtClient::cpu`]). Every
//! consumer in the workspace already degrades gracefully on that error:
//! tests and benches print a skip notice, and the native Rust solvers
//! carry the solve.
//!
//! To enable the real PJRT path, point the workspace's `xla` path
//! dependency at an `xla-rs` checkout (plus `xla_extension` on the
//! library path); no source changes are needed.

use std::fmt;

/// Stub error: always "PJRT unavailable".
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT runtime unavailable: the `xla` dependency is the in-tree stub \
         (vendor/xla); point Cargo.toml at a real xla-rs checkout to enable \
         the AOT artifact path"
            .to_string(),
    )
}

/// Stub of the PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    /// Always fails: the stub has no PJRT backend.
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    /// Platform name (diagnostics only; unreachable through `cpu()`).
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Always fails in the stub.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Stub of a compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Always fails in the stub.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Stub of a device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Always fails in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Stub of a host literal.
#[derive(Default)]
pub struct Literal;

impl Literal {
    /// Construct a rank-1 literal (contents are discarded by the stub).
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Always fails in the stub.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    /// Always fails in the stub.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    /// Always fails in the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Stub of a parsed HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable())
    }
}

/// Stub of an XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a proto (no-op in the stub).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = match PjRtClient::cpu() {
            Err(e) => e,
            Ok(_) => panic!("stub client must not construct"),
        };
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }
}
