//! In-tree, offline shim of the `anyhow` API surface this workspace uses.
//!
//! The build environment has no crates.io access, so this crate provides
//! the subset of `anyhow` the code relies on with identical names and
//! call-site semantics: [`Result`], [`Error`], the [`anyhow!`]/[`bail!`]/
//! [`ensure!`] macros, and the [`Context`] extension trait for both
//! `Result` and `Option`. Swapping the Cargo path dependency for the real
//! `anyhow` requires no source changes.

use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with a defaultable error type, exactly like
/// the real crate's alias (so `collect::<Result<_>>()` works).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error: an outermost message plus the chain of
/// causes it wrapped. Like `anyhow::Error`, it deliberately does *not*
/// implement `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` conversion coherent.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(self, context: C) -> Self {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur.msg.as_str())
        })
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, matching anyhow.
            let mut first = true;
            for msg in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&str> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Preserve the source chain as messages.
        let mut chain: Vec<String> = Vec::new();
        chain.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            err = Some(match err {
                None => Error::msg(msg),
                Some(inner) => inner.context(msg),
            });
        }
        err.expect("chain is non-empty")
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, mirroring `anyhow::Context`.
pub trait Context<T, E> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    /// Wrap the error (or `None`) with a lazily-built context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built from the arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<f64> {
        let x: f64 = s.parse().context("not a float")?;
        ensure!(x > 0.0, "x must be positive, got {x}");
        Ok(x)
    }

    #[test]
    fn ok_path() {
        assert_eq!(parse("2.5").unwrap(), 2.5);
    }

    #[test]
    fn context_wraps_std_errors() {
        let err = parse("nope").unwrap_err();
        assert_eq!(format!("{err}"), "not a float");
        assert!(format!("{err:#}").contains("not a float"));
        assert!(format!("{err:#}").contains("invalid float"));
    }

    #[test]
    fn ensure_formats_message() {
        let err = parse("-1").unwrap_err();
        assert_eq!(format!("{err}"), "x must be positive, got -1");
    }

    #[test]
    fn bail_and_option_context() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged: {}", 7);
            }
            let v: Option<u32> = None;
            let v = v.with_context(|| format!("missing {}", "value"))?;
            Ok(v)
        }
        assert_eq!(format!("{}", f(true).unwrap_err()), "flagged: 7");
        assert_eq!(format!("{}", f(false).unwrap_err()), "missing value");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let inner: Result<()> = Err(anyhow!("root cause"));
        let err = inner.context("outer layer").unwrap_err();
        let dbg = format!("{err:?}");
        assert!(dbg.contains("outer layer"));
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("root cause"));
    }
}
