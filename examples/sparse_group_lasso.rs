//! Sparse group lasso via the g/h split (paper §6).
//!
//! Regularization `λ₃/2·‖w‖² + λ₂‖w‖₁ + λ₁·Σ_G‖w_G‖₂`: putting the group
//! norm into `h` keeps every *local* dual update in closed form (elastic
//! net only), while the group prox runs once per (rare) global
//! synchronization — exactly the computational argument §6 makes.
//!
//! ```bash
//! cargo run --release --example sparse_group_lasso
//! ```

use dadm::comm::CostModel;
use dadm::coordinator::{DadmOptions, Problem};
use dadm::data::{Dataset, Partition, SparseMatrix};
use dadm::loss::Squared;
use dadm::reg::{ElasticNet, GroupLasso};
use dadm::solver::ProxSdca;
use dadm::utils::Rng;

/// Regression data whose ground truth lives on the first half of the
/// groups — the setting where group sparsity should shine.
fn group_sparse_regression(n: usize, d: usize, group_size: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let active_dims = d / 2; // first half of the groups carry signal
    let w_star: Vec<f64> = (0..d)
        .map(|j| if j < active_dims { rng.normal() } else { 0.0 })
        .collect();
    let mut rows = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let x: Vec<f64> = (0..d).map(|_| rng.normal() / (d as f64).sqrt()).collect();
        y.push(x.iter().zip(&w_star).map(|(a, b)| a * b).sum::<f64>() + 0.05 * rng.normal());
        rows.push(x);
    }
    let _ = group_size;
    Dataset {
        x: SparseMatrix::from_dense(&rows),
        y,
        name: "group-sparse-reg".into(),
    }
}

fn main() -> anyhow::Result<()> {
    let d = 32;
    let group_size = 4;
    let data = group_sparse_regression(600, d, group_size, 11);
    let part = Partition::balanced(data.n(), 4, 11);
    let lambda = 1e-3; // λ₃ (strong convexity)
    let l1 = 2e-3; // λ₂/λ₃ scaled into g
    let group_weight = 1.2; // λ₁ in h — strong enough to zero the noise groups

    let opts = DadmOptions {
        sp: 1.0,
        cost: CostModel::free(),
        ..Default::default()
    };

    // Without group norm (plain elastic net).
    let mut en_only = Problem::new(&data, &part)
        .loss(Squared)
        .reg(ElasticNet::new(l1 / lambda))
        .lambda(lambda)
        .build_dadm(ProxSdca, opts.clone());
    let r_en = en_only.solve(1e-8, 800);

    // With the group norm assigned to h (the §6 split).
    let mut sgl = Problem::new(&data, &part)
        .loss(Squared)
        .reg(ElasticNet::new(l1 / lambda))
        .extra_reg(GroupLasso::contiguous(d, group_size, group_weight))
        .lambda(lambda)
        .build_dadm(ProxSdca, opts);
    let r_sgl = sgl.solve(1e-8, 800);

    let group_pattern = |w: &[f64]| -> Vec<bool> {
        (0..d / group_size)
            .map(|g| {
                w[g * group_size..(g + 1) * group_size]
                    .iter()
                    .any(|&x| x != 0.0)
            })
            .collect()
    };

    let en_groups = group_pattern(&r_en.w).iter().filter(|&&b| b).count();
    let sgl_groups = group_pattern(&r_sgl.w).iter().filter(|&&b| b).count();
    println!("elastic net only : gap {:.2e}, {} communications, {} / {} groups active",
        r_en.normalized_gap(), r_en.rounds, en_groups, d / group_size);
    println!("sparse group lasso: gap {:.2e}, {} communications, {} / {} groups active",
        r_sgl.normalized_gap(), r_sgl.rounds, sgl_groups, d / group_size);
    println!(
        "\ngroup sparsity induced: {}",
        if sgl_groups < en_groups { "yes ✓" } else { "no (weight too small)" }
    );
    anyhow::ensure!(r_sgl.converged, "sparse group lasso solve did not converge");
    Ok(())
}
