//! Loopback distributed smoke test: one coordinator + four real worker
//! **processes** on 127.0.0.1 (the CI `distributed-smoke` job's entry
//! point, also runnable locally):
//!
//! ```text
//! cargo run --release --example distributed_smoke
//! ```
//!
//! The binary re-executes itself in worker mode (`worker --connect
//! HOST:PORT`), so no separate worker binary is needed. The coordinator
//! assigns a synthetic problem by *seed* — training data never crosses
//! the wire — runs DADM over the TCP backend and over `Cluster::Serial`,
//! and fails (non-zero exit) if the final duality gaps diverge beyond
//! 1e-9 or the round counts differ.
//!
//! `--compress f32|i16` instead runs the quantized-delta wire check
//! (gap within 10× of exact, DeltaReply bytes below the codec's bound);
//! `--overlap` runs the double-buffered-rounds check (barrier collapse
//! plus convergence). See DESIGN.md §13.

use anyhow::{bail, Context, Result};
use dadm::comm::sparse::DeltaCodec;
use dadm::comm::tcp::{run_worker, synthetic_specs, TcpClusterBuilder, TcpHandle};
use dadm::comm::wire::{WireLoss, WireSolver};
use dadm::comm::{Cluster, CostModel};
use dadm::coordinator::{Dadm, DadmOptions, Problem, SolveReport};
use dadm::data::synthetic::SyntheticSpec;
use dadm::data::{Dataset, Partition};
use dadm::loss::SmoothHinge;
use dadm::reg::{ElasticNet, Zero};
use dadm::solver::ProxSdca;
use std::process::{Child, Command, Stdio};

const MACHINES: usize = 4;
const PART_SEED: u64 = 31;
const RNG_SEED: u64 = 0x51107E;
const SP: f64 = 0.25;
const EPS: f64 = 1e-5;
const MAX_ROUNDS: usize = 60;
const GAP_TOLERANCE: f64 = 1e-9;

fn spec() -> SyntheticSpec {
    SyntheticSpec {
        name: "distributed-smoke".into(),
        n: 600,
        d: 64,
        density: 0.3,
        signal_density: 0.4,
        noise: 0.1,
        seed: 0x5E_ED,
    }
}

fn solve(
    data: &Dataset,
    part: &Partition,
    cluster: Cluster,
    local_threads: usize,
) -> SolveReport {
    build_dadm(data, part, cluster, local_threads, DeltaCodec::F64, false).solve(EPS, MAX_ROUNDS)
}

/// Build a smoke-configured coordinator with an explicit codec and
/// engine mode (the `--compress` / `--overlap` runs).
fn build_dadm(
    data: &Dataset,
    part: &Partition,
    cluster: Cluster,
    local_threads: usize,
    compress: DeltaCodec,
    overlap: bool,
) -> Dadm<SmoothHinge, ElasticNet, Zero, ProxSdca> {
    Problem::new(data, part)
        .loss(SmoothHinge::default())
        .reg(ElasticNet::new(0.1))
        .lambda(1e-2)
        .build_dadm(
            ProxSdca,
            DadmOptions {
                sp: SP,
                cluster,
                cost: CostModel::default(),
                seed: RNG_SEED,
                gap_every: 1,
                sparse_comm: true,
                local_threads,
                conj_resum_every: 64,
                compress,
                overlap,
            },
        )
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // Worker mode: this same binary, re-executed by the coordinator.
    // (The sub-solver count T arrives in the AssignPartition spec, so
    // worker processes need no flag of their own.)
    if args.first().map(String::as_str) == Some("worker") {
        let addr = match args.get(1).map(String::as_str) {
            Some("--connect") => args.get(2).context("worker: missing address")?,
            _ => bail!("usage: distributed_smoke worker --connect HOST:PORT"),
        };
        return run_worker(addr);
    }

    // Coordinator flags: `--local-threads T` runs every worker process
    // with T concurrent sub-shard solvers; `--compress f32|i16` runs the
    // quantized-delta wire check instead of the exact-parity checks;
    // `--overlap` runs the double-buffered-rounds check (the CI
    // distributed-smoke job exercises T = 2, `--compress i16` and
    // `--overlap` on every push).
    let mut local_threads = 1usize;
    let mut compress = DeltaCodec::F64;
    let mut overlap = false;
    let mut it = args.iter();
    while let Some(k) = it.next() {
        match k.as_str() {
            "--local-threads" => {
                local_threads = it
                    .next()
                    .context("missing value for --local-threads")?
                    .parse()
                    .context("parsing --local-threads")?;
                if local_threads == 0 {
                    bail!("the smoke harness needs an explicit --local-threads ≥ 1");
                }
            }
            "--compress" => {
                let v = it.next().context("missing value for --compress")?;
                compress = DeltaCodec::parse(v)
                    .with_context(|| format!("--compress must be f64, f32 or i16, got `{v}`"))?;
            }
            "--overlap" => {
                overlap = true;
            }
            other => bail!(
                "unknown flag `{other}` (usage: distributed_smoke \
                 [--local-threads T] [--compress f64|f32|i16] [--overlap])"
            ),
        }
    }

    // --- Coordinator ---
    let builder = TcpClusterBuilder::bind("127.0.0.1:0")?;
    let addr = builder.local_addr()?.to_string();
    let exe = std::env::current_exe().context("locating own binary")?;
    println!(
        "coordinator on {addr}; spawning {MACHINES} worker processes \
         (local-threads = {local_threads})"
    );
    let mut children: Vec<Child> = (0..MACHINES)
        .map(|_| {
            Command::new(&exe)
                .args(["worker", "--connect", &addr])
                .stdin(Stdio::null())
                .spawn()
                .context("spawning worker process")
        })
        .collect::<Result<_>>()?;

    let outcome = (|| -> Result<()> {
        let mut cluster = builder.accept(MACHINES)?;
        let problem = spec();
        cluster.assign(synthetic_specs(
            &problem,
            MACHINES,
            PART_SEED,
            RNG_SEED,
            SP,
            WireLoss::SmoothHinge(SmoothHinge::default()),
            WireSolver::ProxSdca,
            local_threads,
        ))?;
        let handle = TcpHandle::new(cluster);

        let data = problem.generate();
        let part = Partition::balanced(data.n(), MACHINES, PART_SEED);

        // Re-assigning resets the worker fleet's dual state between
        // independently measured runs.
        let reassign = |handle: &TcpHandle| -> Result<()> {
            handle.with(|c| {
                c.assign(synthetic_specs(
                    &problem,
                    MACHINES,
                    PART_SEED,
                    RNG_SEED,
                    SP,
                    WireLoss::SmoothHinge(SmoothHinge::default()),
                    WireSolver::ProxSdca,
                    local_threads,
                ))
            })
        };

        if compress != DeltaCodec::F64 {
            // --- Quantized-delta wire check (DESIGN.md §13): at an equal
            // round budget the lossy codec must stay within 10× of the
            // exact run's final gap (error feedback at work) while its
            // DeltaReply payloads shrink below the codec's bound. ---
            let rounds = 20usize;
            let measured = |codec: DeltaCodec| -> Result<(SolveReport, u64)> {
                reassign(&handle)?;
                let before = handle.stats().delta_reply_bytes;
                let mut dadm = build_dadm(
                    &data,
                    &part,
                    Cluster::Tcp(handle.clone()),
                    local_threads,
                    codec,
                    false,
                );
                let report = dadm.solve(0.0, rounds);
                Ok((report, handle.stats().delta_reply_bytes - before))
            };
            let (exact, exact_bytes) = measured(DeltaCodec::F64)?;
            let (lossy, lossy_bytes) = measured(compress)?;
            let (gap_exact, gap_lossy) = (exact.normalized_gap(), lossy.normalized_gap());
            let ratio = lossy_bytes as f64 / exact_bytes as f64;
            println!(
                "compress {}: DeltaReply {lossy_bytes} B vs exact {exact_bytes} B \
                 (ratio {ratio:.3}); gaps {gap_lossy:.3e} vs {gap_exact:.3e}",
                compress.name()
            );
            if !gap_lossy.is_finite() || gap_lossy > gap_exact * 10.0 {
                bail!(
                    "{} gap {gap_lossy:.3e} drifted past 10× the exact {gap_exact:.3e}",
                    compress.name()
                );
            }
            let limit = match compress {
                DeltaCodec::I16 => 0.5,
                _ => 0.75,
            };
            if ratio >= limit {
                bail!(
                    "{} DeltaReply bytes did not shrink: ratio {ratio:.3} ≥ {limit}",
                    compress.name()
                );
            }
            handle.with(|c| c.shutdown());
            return Ok(());
        }

        if overlap {
            // --- Double-buffered rounds (DESIGN.md §13): same round
            // budget with pipelined issue/complete halves — the
            // per-round barrier collapses (the counter pins the overlap
            // schedule) and the solve still converges. ---
            let rounds = 30usize;
            reassign(&handle)?;
            let mut seq = build_dadm(
                &data,
                &part,
                Cluster::Tcp(handle.clone()),
                local_threads,
                DeltaCodec::F64,
                false,
            );
            let seq_report = seq.solve(0.0, rounds);
            let seq_barriers = seq.barriers();
            reassign(&handle)?;
            let mut ovl = build_dadm(
                &data,
                &part,
                Cluster::Tcp(handle.clone()),
                local_threads,
                DeltaCodec::F64,
                true,
            );
            let ovl_report = ovl.solve(0.0, rounds);
            let ovl_barriers = ovl.barriers();
            let (gap_seq, gap_ovl) = (seq_report.normalized_gap(), ovl_report.normalized_gap());
            println!(
                "overlap: rounds {} vs {} sequential, barriers {ovl_barriers} vs \
                 {seq_barriers}, gaps {gap_ovl:.3e} vs {gap_seq:.3e}",
                ovl_report.rounds, seq_report.rounds
            );
            if ovl_report.rounds != seq_report.rounds {
                bail!(
                    "overlap round count diverged: {} vs {}",
                    ovl_report.rounds,
                    seq_report.rounds
                );
            }
            if !gap_ovl.is_finite() || gap_ovl > gap_seq * 10.0 {
                bail!("overlapped gap {gap_ovl:.3e} drifted past 10× sequential {gap_seq:.3e}");
            }
            if ovl_barriers >= seq_barriers {
                bail!(
                    "overlap did not collapse barriers: {ovl_barriers} vs \
                     sequential {seq_barriers}"
                );
            }
            handle.with(|c| c.shutdown());
            return Ok(());
        }

        let tcp = solve(&data, &part, Cluster::Tcp(handle.clone()), local_threads);
        let serial = solve(&data, &part, Cluster::Serial, local_threads);

        let gap_tcp = tcp.normalized_gap();
        let gap_serial = serial.normalized_gap();
        let diff = (gap_tcp - gap_serial).abs();
        let stats = handle.stats();
        println!(
            "tcp:    rounds={} gap={gap_tcp:.3e} (wire: {} B sent, {} B received, {} frames)",
            tcp.rounds, stats.bytes_sent, stats.bytes_received, stats.frames_sent
        );
        println!("serial: rounds={} gap={gap_serial:.3e}", serial.rounds);

        if tcp.rounds != serial.rounds {
            bail!("round counts diverged: tcp {} vs serial {}", tcp.rounds, serial.rounds);
        }
        if diff.is_nan() || diff > GAP_TOLERANCE {
            bail!("duality gaps diverged by {diff:.3e} (> {GAP_TOLERANCE:.0e})");
        }
        if stats.bytes_sent == 0 || stats.bytes_received == 0 {
            bail!("no wire traffic recorded");
        }

        // --- Fused-gap wire check (DESIGN.md §11): a --gap-every 1 run
        // with fused telemetry must move strictly fewer bytes than the
        // legacy LossSumAt pattern, which re-ships the 8·d-byte iterate
        // to every worker for each gap evaluation. Re-assigning resets
        // the worker fleet's dual state between the two measurements. ---
        let wire_rounds = 10usize;
        reassign(&handle)?;
        let before = handle.stats().total_bytes();
        let fused = |cluster: Cluster| -> SolveReport {
            build_dadm(&data, &part, cluster, local_threads, DeltaCodec::F64, false)
                .solve(0.0, wire_rounds) // eps 0: run all rounds, record each
        };
        let fused_report = fused(Cluster::Tcp(handle.clone()));
        let fused_bytes = handle.stats().total_bytes() - before;

        reassign(&handle)?;
        let before = handle.stats().total_bytes();
        let mut legacy = build_dadm(
            &data,
            &part,
            Cluster::Tcp(handle.clone()),
            local_threads,
            DeltaCodec::F64,
            false,
        );
        legacy.resync();
        let _ = legacy.gap();
        let mut legacy_last_gap = f64::NAN;
        for _ in 0..wire_rounds {
            legacy.round();
            // The pre-fusion wire pattern: ship the iterate for the
            // primal sum, then the dual.
            let w = legacy.w().to_vec();
            let loss_sum = legacy.loss_sum_at(&w);
            let lambda_n = 1e-2 * data.n() as f64;
            let primal = loss_sum
                + lambda_n * dadm::Regularizer::value(&ElasticNet::new(0.1), &w);
            legacy_last_gap = primal - legacy.dual();
        }
        let legacy_bytes = handle.stats().total_bytes() - before;

        let fused_last = fused_report.trace.last().expect("trace");
        let fused_last_gap = fused_last.gap();
        println!(
            "fused gap wire: {fused_bytes} B over {wire_rounds} rounds vs legacy \
             LossSumAt {legacy_bytes} B (final gaps {fused_last_gap:.6e} / {legacy_last_gap:.6e})"
        );
        if (fused_last_gap - legacy_last_gap).abs() > GAP_TOLERANCE {
            bail!(
                "fused vs legacy gap traces diverged: {fused_last_gap:.6e} vs {legacy_last_gap:.6e}"
            );
        }
        let w_payload = (wire_rounds * MACHINES * 8 * data.dim()) as u64;
        if fused_bytes + w_payload / 2 > legacy_bytes {
            bail!(
                "fused telemetry did not shrink the eval wire: {fused_bytes} B vs \
                 legacy {legacy_bytes} B (w payload ≈ {w_payload} B)"
            );
        }

        handle.with(|c| c.shutdown());
        Ok(())
    })();

    // Reap workers whatever happened above.
    for child in &mut children {
        if outcome.is_ok() {
            let status = child.wait().context("waiting for worker")?;
            if !status.success() {
                bail!("worker exited with {status}");
            }
        } else {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
    outcome?;
    println!("distributed smoke PASS: gap diff ≤ {GAP_TOLERANCE:.0e}, bit-identical iterates");
    Ok(())
}
