//! End-to-end driver: the full three-layer stack on a real small workload.
//!
//! Proves all layers compose: the **L1/L2** AOT artifacts (Pallas kernel
//! inside a JAX local step, lowered to HLO text by `make artifacts`) are
//! loaded by the **runtime** (PJRT CPU client) and driven by the **L3**
//! coordinator as the local solver of a distributed logistic-regression
//! solve on an rcv1-style sparse workload — Python never runs. The same
//! solve is repeated with the native Rust solver and both the iterates
//! and the headline metric (duality gap vs communications) are compared.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use dadm::comm::CostModel;
use dadm::coordinator::{DadmOptions, Problem};
use dadm::data::synthetic::SyntheticSpec;
use dadm::data::Partition;
use dadm::loss::{Loss, SmoothHinge};
use dadm::reg::ElasticNet;
use dadm::runtime::XlaLocalStep;
use dadm::solver::TheoremStep;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    // Workload: rcv1-analogue at small scale, but with d matching the AOT
    // artifact shape (XLA programs are shape-static).
    const DIM: usize = 256;
    const BATCH: usize = 128;
    let data = SyntheticSpec {
        name: "synth-rcv1-e2e".into(),
        n: 8_192,
        d: DIM,
        density: 0.05,
        signal_density: 0.1,
        noise: 0.05,
        seed: 0xE2E,
    }
    .generate();
    let machines = 8;
    let part = Partition::balanced(data.n(), machines, 0xE2E);
    let (lambda, mu) = (3e-2, 1e-6); // well-conditioned: the Theorem-6 step is conservative
    let loss = SmoothHinge::default();
    let sp = BATCH as f64 / (data.n() as f64 / machines as f64); // M_ℓ = artifact batch
    let opts = DadmOptions {
        sp,
        cost: CostModel::default(),
        gap_every: 5,
        ..Default::default()
    };
    println!(
        "== end-to-end: n={} d={} m={machines} M_ℓ={BATCH} λ={lambda} μ={mu} ==",
        data.n(),
        data.dim()
    );

    // --- Native Rust Theorem-6 local step ---
    let t0 = Instant::now();
    let mut native = Problem::new(&data, &part)
        .loss(loss)
        .reg(ElasticNet::new(mu / lambda))
        .lambda(lambda)
        .build_dadm(
            TheoremStep {
                radius: data.max_row_norm_sq(),
            },
            opts.clone(),
        );
    let r_native = native.solve(1e-2, 1500);
    let native_secs = t0.elapsed().as_secs_f64();
    println!(
        "native  : gap {:.3e} in {} comms, {:.1} passes, {:.2}s wall",
        r_native.normalized_gap(),
        r_native.rounds,
        r_native.passes,
        native_secs
    );

    // --- XLA (AOT Pallas/JAX artifact via PJRT) local step ---
    let xla_step = match XlaLocalStep::new(loss.name(), BATCH, DIM, data.max_row_norm_sq()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!(
                "SKIP: XLA artifacts unavailable ({e:#}).\nRun `make artifacts` first."
            );
            return Ok(());
        }
    };
    let t0 = Instant::now();
    let mut xla = Problem::new(&data, &part)
        .loss(loss)
        .reg(ElasticNet::new(mu / lambda))
        .lambda(lambda)
        .build_dadm(xla_step, opts);
    let r_xla = xla.solve(1e-2, 1500);
    let xla_secs = t0.elapsed().as_secs_f64();
    println!(
        "xla/pjrt: gap {:.3e} in {} comms, {:.1} passes, {:.2}s wall",
        r_xla.normalized_gap(),
        r_xla.rounds,
        r_xla.passes,
        xla_secs
    );

    // --- Cross-check: both backends must agree on the final predictor ---
    let max_diff = r_native
        .w
        .iter()
        .zip(&r_xla.w)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("max |w_native − w_xla| = {max_diff:.3e} (f32 artifact vs f64 native)");
    anyhow::ensure!(
        r_native.converged && r_xla.converged,
        "a backend failed to converge"
    );
    anyhow::ensure!(max_diff < 1e-2, "backends diverged: {max_diff}");
    println!("end_to_end OK — all three layers compose.");
    Ok(())
}
