//! Quickstart: solve an L2-L1 regularized SVM with DADM and Acc-DADM on a
//! small synthetic dataset across 4 simulated machines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dadm::comm::CostModel;
use dadm::config::ExperimentConfig;
use dadm::coordinator::{AccDadmOptions, DadmOptions, Problem};
use dadm::data::Partition;
use dadm::loss::SmoothHinge;
use dadm::reg::ElasticNet;
use dadm::solver::ProxSdca;

fn main() -> anyhow::Result<()> {
    // A small learnable binary classification problem.
    let cfg = ExperimentConfig {
        dataset: "tiny".into(),
        ..Default::default()
    };
    let data = cfg.load_dataset()?;
    let (lambda, mu) = (1e-4, 1e-5);
    let machines = 4;
    let part = Partition::balanced(data.n(), machines, 42);
    println!(
        "dataset: n={} d={} density={:.3} machines={machines} λ={lambda} μ={mu}",
        data.n(),
        data.dim(),
        data.density()
    );

    let opts = DadmOptions {
        sp: 0.5,
        cost: CostModel::default(),
        ..Default::default()
    };

    // Plain DADM (≡ CoCoA+ here: h = 0, balanced partitions).
    let mut plain = Problem::new(&data, &part)
        .loss(SmoothHinge::default())
        .reg(ElasticNet::new(mu / lambda))
        .lambda(lambda)
        .build_dadm(ProxSdca, opts.clone());
    let r1 = plain.solve(1e-4, 400);
    println!(
        "DADM/CoCoA+ : gap {:.3e} in {} communications ({:.1} passes)",
        r1.normalized_gap(),
        r1.rounds,
        r1.passes
    );

    // Acc-DADM (Algorithm 3, ν = 0 practical variant).
    let mut acc = Problem::new(&data, &part)
        .loss(SmoothHinge::default())
        .lambda(lambda)
        .l1(mu)
        .build_acc_dadm(
            ProxSdca,
            AccDadmOptions {
                dadm: opts,
                ..Default::default()
            },
        );
    let r2 = acc.solve(1e-4, 400);
    println!(
        "Acc-DADM    : gap {:.3e} in {} communications ({:.1} passes, {} stages)",
        r2.normalized_gap(),
        r2.rounds,
        r2.passes,
        acc.stages()
    );

    // Inspect the learned predictor.
    let nnz = r2.w.iter().filter(|&&w| w != 0.0).count();
    println!("predictor: {} / {} non-zero weights (L1 at work)", nnz, r2.w.len());
    Ok(())
}
