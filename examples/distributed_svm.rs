//! Paper-style distributed SVM experiment (the §10 protocol at reduced
//! scale): rcv1-analogue data on m = 8 machines, λ sweep, CoCoA+ vs
//! Acc-DADM, duality gap vs communications and modeled time.
//!
//! ```bash
//! cargo run --release --example distributed_svm [-- scale]
//! ```

use dadm::comm::CostModel;
use dadm::coordinator::{AccDadmOptions, DadmOptions, Problem};
use dadm::data::synthetic::SyntheticSpec;
use dadm::data::Partition;
use dadm::loss::SmoothHinge;
use dadm::reg::ElasticNet;
use dadm::solver::ProxSdca;

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(6e-3);
    let data = SyntheticSpec::rcv1(scale).generate();
    let machines = 8;
    let (mu, sp) = (1e-5, 0.2);
    let eps = 1e-3;
    let part = Partition::balanced(data.n(), machines, 7);
    println!(
        "== distributed SVM on {} (n={}, d={}, nnz/row≈{:.1}) m={machines} sp={sp} ==",
        data.name,
        data.n(),
        data.dim(),
        data.density() * data.dim() as f64
    );
    println!(
        "{:>9}  {:>12}  {:>10}  {:>10}  {:>12}",
        "lambda", "method", "comms", "passes", "final gap"
    );

    // λ grid matched to the paper's by λn (see DESIGN.md §5).
    let grid = dadm::experiments::lambda_grid(data.n());
    for &lambda in &grid {
        let max_rounds = (100.0 / sp) as usize;
        let opts = DadmOptions {
            sp,
            cost: CostModel::default(),
            gap_every: 5,
            ..Default::default()
        };

        let mut cocoa = Problem::new(&data, &part)
            .loss(SmoothHinge::default())
            .reg(ElasticNet::new(mu / lambda))
            .lambda(lambda)
            .build_dadm(ProxSdca, opts.clone());
        let r = cocoa.solve(eps, max_rounds);
        println!(
            "{lambda:>9.0e}  {:>12}  {:>10}  {:>10.1}  {:>12.3e}",
            "CoCoA+", r.rounds, r.passes, r.normalized_gap()
        );

        let mut acc = Problem::new(&data, &part)
            .loss(SmoothHinge::default())
            .lambda(lambda)
            .l1(mu)
            .build_acc_dadm(
                ProxSdca,
                AccDadmOptions {
                    dadm: opts,
                    ..Default::default()
                },
            );
        let r = acc.solve(eps, max_rounds);
        println!(
            "{lambda:>9.0e}  {:>12}  {:>10}  {:>10.1}  {:>12.3e}",
            "Acc-DADM", r.rounds, r.passes, r.normalized_gap()
        );
    }
    println!("\nExpected shape (paper Figs 2-3): as λ shrinks, CoCoA+ needs many");
    println!("more communications while Acc-DADM stays fast.");
    Ok(())
}
