"""L2 model tests: shapes, the fused regularizer variant, and the AOT
lowering path (HLO text emission)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels import ref


def test_local_step_shapes():
    fn = model.local_step("smooth_hinge", tile=16)
    m, d = 8, 16
    rng = np.random.default_rng(0)
    out = fn(
        rng.normal(size=(m, d)).astype(np.float32),
        np.ones(m, np.float32),
        np.zeros(m, np.float32),
        rng.normal(size=d).astype(np.float32),
        np.float32(0.5),
    )
    assert out[0].shape == (m,)
    assert out[1].shape == (d,)
    assert str(out[0].dtype) == "float32"


def test_soft_threshold_matches_numpy():
    v = np.array([2.0, -2.0, 0.5, -0.5, 0.0], np.float32)
    got = np.asarray(model.soft_threshold(v, 1.0))
    np.testing.assert_allclose(got, [1.0, -1.0, 0.0, 0.0, 0.0])


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), tau=st.floats(0.0, 0.5))
def test_fused_equals_manual_composition(seed, tau):
    rng = np.random.default_rng(seed)
    m, d = 8, 24
    x = rng.normal(size=(m, d)).astype(np.float32)
    y = np.sign(rng.normal(size=m)).astype(np.float32)
    y[y == 0] = 1.0
    alpha = np.zeros(m, np.float32)
    v_tilde = rng.normal(size=d).astype(np.float32)
    shift = rng.normal(size=d).astype(np.float32) * 0.1
    fused = model.local_step_fused("logistic", tile=8)
    a1, dv1 = fused(x, y, alpha, v_tilde, shift, np.float32(tau), np.float32(0.6))
    w = np.asarray(model.soft_threshold(v_tilde + shift, tau))
    a2, dv2 = ref.local_step_ref("logistic", x, y, alpha, w, 0.6)
    np.testing.assert_allclose(a1, a2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dv1, dv2, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("loss", model.LOSSES)
def test_aot_lowering_emits_valid_hlo_text(loss):
    text = aot.lower_one(loss, 8, 16)
    assert "HloModule" in text
    # The entry computation must take the 5 runtime inputs and return a
    # 2-tuple (alpha_new, dv).
    assert "f32[8,16]" in text  # X
    assert "(f32[8]" in text or "f32[8]" in text
    assert len(text) > 1000


def test_aot_shapes_cover_runtime_contract():
    # The Rust runtime hard-codes these shapes in its tests/examples.
    assert (8, 16) in aot.SHAPES
    assert (128, 256) in aot.SHAPES
