"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes (via value ranges), losses, tile sizes,
and step scales; assert_allclose against ``ref.local_step_ref`` is THE
correctness signal for Layer 1.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.minibatch_update import local_step_pallas

RTOL, ATOL = 1e-4, 1e-5


def make_case(seed, m, d):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, d)).astype(np.float32)
    y = np.sign(rng.normal(size=m)).astype(np.float32)
    y[y == 0] = 1.0
    alpha = (rng.uniform(0, 1, size=m) * y).astype(np.float32)
    w = rng.normal(size=d).astype(np.float32)
    return x, y, alpha, w


@pytest.mark.parametrize("loss", ref.LOSSES)
def test_matches_ref_basic(loss):
    x, y, alpha, w = make_case(0, 16, 32)
    a1, dv1 = local_step_pallas(x, y, alpha, w, 0.5, loss=loss, tile=16)
    a2, dv2 = ref.local_step_ref(loss, x, y, alpha, w, 0.5)
    np.testing.assert_allclose(a1, a2, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(dv1, dv2, rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(1, 24),
    d=st.integers(1, 48),
    loss=st.sampled_from(ref.LOSSES),
    s=st.floats(0.0, 1.0),
)
def test_matches_ref_hypothesis(seed, m, d, loss, s):
    x, y, alpha, w = make_case(seed, m, d)
    a1, dv1 = local_step_pallas(x, y, alpha, w, s, loss=loss, tile=16)
    a2, dv2 = ref.local_step_ref(loss, x, y, alpha, w, s)
    np.testing.assert_allclose(a1, a2, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(dv1), np.asarray(dv2), rtol=1e-3, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    tile=st.sampled_from([4, 8, 16, 64, 256]),
)
def test_tile_size_invariance(seed, tile):
    """The d-tiling is an implementation detail: results must not depend
    on it (this is what validates the two-phase grid schedule)."""
    x, y, alpha, w = make_case(seed, 12, 40)
    base_a, base_dv = ref.local_step_ref("smooth_hinge", x, y, alpha, w, 0.7)
    a, dv = local_step_pallas(x, y, alpha, w, 0.7, loss="smooth_hinge", tile=tile)
    np.testing.assert_allclose(a, base_a, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(dv, base_dv, rtol=1e-3, atol=1e-4)


def test_zero_rows_are_noops():
    """Zero-padding safety: x = 0, y = 0, alpha = 0 rows must produce
    d_alpha = 0 for every loss (the Rust chunking path relies on this)."""
    m, d = 8, 16
    x = np.zeros((m, d), np.float32)
    y = np.zeros(m, np.float32)
    alpha = np.zeros(m, np.float32)
    w = np.ones(d, np.float32)
    for loss in ref.LOSSES:
        a, dv = local_step_pallas(x, y, alpha, w, 0.9, loss=loss, tile=8)
        np.testing.assert_array_equal(np.asarray(a), 0.0)
        np.testing.assert_array_equal(np.asarray(dv), 0.0)


def test_s_zero_is_identity():
    x, y, alpha, w = make_case(3, 8, 8)
    a, dv = local_step_pallas(x, y, alpha, w, 0.0, loss="logistic", tile=8)
    np.testing.assert_allclose(a, alpha, rtol=0, atol=0)
    np.testing.assert_array_equal(np.asarray(dv), 0.0)


def test_dual_feasibility_preserved_smooth_hinge():
    """s in [0,1] keeps y*alpha in [0,1] (convex combination with the
    feasible direction)."""
    rng = np.random.default_rng(7)
    m, d = 32, 16
    x = rng.normal(size=(m, d)).astype(np.float32)
    y = np.sign(rng.normal(size=m)).astype(np.float32)
    y[y == 0] = 1.0
    alpha = (rng.uniform(0, 1, size=m) * y).astype(np.float32)
    w = rng.normal(size=d).astype(np.float32)
    for s in [0.1, 0.5, 1.0]:
        a, _ = local_step_pallas(x, y, alpha, w, s, loss="smooth_hinge", tile=16)
        ya = y * np.asarray(a)
        assert (ya >= -1e-6).all() and (ya <= 1 + 1e-6).all()


def test_rejects_unknown_loss():
    x, y, alpha, w = make_case(0, 4, 4)
    with pytest.raises(ValueError):
        local_step_pallas(x, y, alpha, w, 0.5, loss="nope")
