"""Layer-2 JAX model: the DADM local step as a jittable computation.

Composes the Layer-1 Pallas kernel (`kernels.minibatch_update`) into the
functions that get AOT-lowered for the Rust coordinator:

* ``local_step(loss)`` — the batched Theorem-6 update the Rust runtime
  drives: inputs ``(X_b, y_b, alpha_b, w, s)``, outputs
  ``(alpha_new, dv_raw)``.  The regularizer side (``w = grad g*(v~)``,
  exact f64, including the Acc-DADM shift) stays in Rust — see
  DESIGN.md SS2 for the division of labor.

* ``local_step_fused(loss)`` — the fully-fused variant that also applies
  the elastic-net soft-threshold ``w = soft_threshold(v~ + shift, tau)``
  inside the graph: inputs ``(X_b, y_b, alpha_b, v_tilde, shift, tau, s)``.
  Exercised by the model tests and available for an all-XLA deployment;
  XLA fuses the threshold into the first GEMV so the marginal cost is nil.

Python here is build-time only: ``aot.py`` lowers these once to HLO text
and the Rust binary never imports Python again.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.minibatch_update import local_step_pallas

LOSSES = ref.LOSSES


def local_step(loss, gamma=1.0, tile=256):
    """The (X, y, alpha, w, s) -> (alpha_new, dv_raw) local step."""

    @jax.jit
    def fn(x, y, alpha, w, s):
        alpha_new, dv = local_step_pallas(
            x, y, alpha, w, s, loss=loss, gamma=gamma, tile=tile
        )
        return (alpha_new, dv)

    return fn


def soft_threshold(v, tau):
    """Elementwise sign(v) * max(|v| - tau, 0) — grad g* of the elastic net."""
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - tau, 0.0)


def local_step_fused(loss, gamma=1.0, tile=256):
    """Variant that computes w from (v_tilde, shift, tau) in-graph."""

    @jax.jit
    def fn(x, y, alpha, v_tilde, shift, tau, s):
        w = soft_threshold(v_tilde + shift, tau)
        alpha_new, dv = local_step_pallas(
            x, y, alpha, w, s, loss=loss, gamma=gamma, tile=tile
        )
        return (alpha_new, dv)

    return fn


@functools.lru_cache(maxsize=None)
def example_args(m, d):
    """ShapeDtypeStructs for lowering at shape (m, d)."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((m, d), f32),  # X
        jax.ShapeDtypeStruct((m,), f32),    # y
        jax.ShapeDtypeStruct((m,), f32),    # alpha
        jax.ShapeDtypeStruct((d,), f32),    # w
        jax.ShapeDtypeStruct((), f32),      # s
    )
