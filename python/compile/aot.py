"""AOT lowering: JAX/Pallas local steps -> HLO text artifacts.

Emits ``artifacts/local_step_<loss>_<M>x<d>.hlo.txt`` for every loss in
the zoo at the shapes the Rust runtime uses (a small test shape and the
default production shape).

HLO **text** is the interchange format, NOT ``lowered.compile()`` or a
serialized ``HloModuleProto``: jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
``xla`` 0.1.6 crate links) rejects; the text parser reassigns ids and
round-trips cleanly.  Lowered with ``return_tuple=True`` and unwrapped
with ``to_tuple()`` on the Rust side.  See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (see Makefile).
"""

import argparse
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import model

# (M, d) shapes baked into artifacts: test shape + production shape.
SHAPES = [(8, 16), (128, 256)]


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(loss, m, d):
    fn = model.local_step(loss, tile=min(256, d))
    lowered = jax.jit(fn).lower(*model.example_args(m, d))
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--losses", nargs="*", default=list(model.LOSSES))
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    for loss in args.losses:
        for m, d in SHAPES:
            text = lower_one(loss, m, d)
            path = out_dir / f"local_step_{loss}_{m}x{d}.hlo.txt"
            path.write_text(text)
            print(f"wrote {path} ({len(text)} chars)")
    # Stamp file lets `make` skip regeneration when inputs are unchanged.
    (out_dir / ".stamp").write_text("ok\n")


if __name__ == "__main__":
    main()
