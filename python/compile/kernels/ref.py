"""Pure-jnp reference oracle for the local-step kernel.

This is the CORE correctness signal for Layer 1: the Pallas kernel in
``minibatch_update.py`` must match these functions to float32 tolerance
on every shape/dtype hypothesis sweeps throw at it.

Semantics (matching ``rust/src/solver/theorem_step.rs`` and
``rust/src/runtime/local_step.rs``):

    u      = X_b @ w                       scores, (M,)
    u_dir  = -phi'(u, y)                   Theorem-6 direction, (M,)
    d_alpha= s * (u_dir - alpha)           scaled dual step, (M,)
    out    = (alpha + d_alpha, X_b.T @ d_alpha)

Losses: smooth_hinge (gamma=1), logistic, hinge, squared — the same zoo
as ``rust/src/loss``.
"""

import jax.numpy as jnp

LOSSES = ("smooth_hinge", "logistic", "hinge", "squared")


def grad_phi(name, u, y, gamma=1.0):
    """Subgradient phi'(u) for each loss (same conventions as rust/src/loss)."""
    if name == "smooth_hinge":
        z = y * u
        # 0 if z >= 1; -y if z <= 1-gamma; -y(1-z)/gamma otherwise
        mid = -y * (1.0 - z) / gamma
        return jnp.where(z >= 1.0, 0.0, jnp.where(z <= 1.0 - gamma, -y, mid))
    if name == "logistic":
        # -y * sigmoid(-y u), computed stably
        z = y * u
        return -y * (0.5 * (1.0 - jnp.tanh(0.5 * z)))
    if name == "hinge":
        return jnp.where(y * u < 1.0, -y, 0.0)
    if name == "squared":
        return 2.0 * (u - y)
    raise ValueError(f"unknown loss {name}")


def local_step_ref(name, x, y, alpha, w, s, gamma=1.0):
    """Reference batched Theorem-6 local step.

    Args:
      name:  loss name.
      x:     (M, d) mini-batch design block.
      y:     (M,) labels.
      alpha: (M,) current dual variables.
      w:     (d,) primal point  (= grad g*(v_tilde), computed by Rust).
      s:     scalar step scale in [0, 1].
      gamma: smooth-hinge smoothing parameter.

    Returns:
      (alpha_new (M,), delta_v_raw (d,)) with delta_v_raw = X^T d_alpha
      (unscaled; the Rust side divides by lambda*n_l).
    """
    u = x @ w
    u_dir = -grad_phi(name, u, y, gamma)
    d_alpha = s * (u_dir - alpha)
    alpha_new = alpha + d_alpha
    delta_v_raw = x.T @ d_alpha
    return alpha_new, delta_v_raw
