"""Layer-1 Pallas kernel: the batched Theorem-6 mini-batch dual update.

The paper's local-step hot spot is, per machine and per round,

    u       = X_b @ w            (forward scores over the mini-batch)
    d_alpha = s * (-phi'(u,y) - alpha)
    dv_raw  = X_b^T @ d_alpha    (rank-M update of the dual combination)

i.e. two GEMVs against the same (M, d) mini-batch block plus an
elementwise dual maximizer. On TPU the schedule that matters is HBM->VMEM
streaming of X: this kernel tiles the feature dimension into (M, d_blk)
blocks and runs a TWO-PHASE sequential grid

    phase 0, tile j:  u += X[:, j] @ w[j]          (accumulate scores)
    phase 1, tile 0:  d_alpha = s*(dir(u) - alpha) (once, from scratch)
    phase 1, tile j:  dv[j] = X[:, j]^T @ d_alpha

so each X tile is fetched from HBM exactly twice (once per phase) and
everything else lives in VMEM scratch — the TPU translation of the
paper's "one pass over the mini-batch per round" CPU loop (DESIGN.md
SS2/SS8).  MUST run with interpret=True on CPU: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_TILE = 256


def _dual_direction(name, u, y, gamma):
    """-phi'(u, y): the Theorem-6 feasible dual point, elementwise."""
    return -ref.grad_phi(name, u, y, gamma)


def _kernel(x_ref, y_ref, alpha_ref, w_ref, s_ref, alpha_out_ref, dv_ref,
            u_acc, d_alpha, *, loss, gamma, n_tiles):
    phase = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(jnp.logical_and(phase == 0, j == 0))
    def _init():
        u_acc[...] = jnp.zeros_like(u_acc)

    @pl.when(phase == 0)
    def _accumulate_scores():
        # u += X[:, tile] @ w[tile]  — MXU-shaped (M, d_blk) x (d_blk,)
        u_acc[...] += x_ref[...] @ w_ref[...]

    @pl.when(jnp.logical_and(phase == 1, j == 0))
    def _dual_step():
        u = u_acc[...]
        y = y_ref[...]
        alpha = alpha_ref[...]
        s = s_ref[0]
        direction = _dual_direction(loss, u, y, gamma)
        d_alpha[...] = s * (direction - alpha)
        alpha_out_ref[...] = alpha + d_alpha[...]

    @pl.when(phase == 1)
    def _transpose_update():
        # dv[tile] = X[:, tile]^T @ d_alpha
        dv_ref[...] = x_ref[...].T @ d_alpha[...]

    del n_tiles  # encoded in the grid


@functools.partial(jax.jit, static_argnames=("loss", "gamma", "tile"))
def local_step_pallas(x, y, alpha, w, s, *, loss, gamma=1.0, tile=DEFAULT_TILE):
    """Batched Theorem-6 local step as a Pallas kernel.

    Args:
      x:     (M, d) float32 mini-batch block.
      y:     (M,) labels.
      alpha: (M,) dual variables.
      w:     (d,) primal point.
      s:     scalar step size (0-d array or python float).
      loss:  one of ``ref.LOSSES``.
      gamma: smooth-hinge gamma.
      tile:  feature-tile width (d is zero-padded to a multiple).

    Returns:
      (alpha_new (M,), dv_raw (d,)).
    """
    if loss not in ref.LOSSES:
        raise ValueError(f"unknown loss {loss!r}")
    m, d = x.shape
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    y = y.astype(jnp.float32)
    alpha = alpha.astype(jnp.float32)
    s_arr = jnp.asarray(s, jnp.float32).reshape((1,))

    d_blk = min(tile, d)
    pad = (-d) % d_blk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        w = jnp.pad(w, (0, pad))
    d_padded = d + pad
    n_tiles = d_padded // d_blk

    kernel = functools.partial(_kernel, loss=loss, gamma=gamma, n_tiles=n_tiles)
    alpha_new, dv = pl.pallas_call(
        kernel,
        grid=(2, n_tiles),
        in_specs=[
            pl.BlockSpec((m, d_blk), lambda p, j: (0, j)),  # X tile
            pl.BlockSpec((m,), lambda p, j: (0,)),          # y
            pl.BlockSpec((m,), lambda p, j: (0,)),          # alpha
            pl.BlockSpec((d_blk,), lambda p, j: (j,)),      # w tile
            pl.BlockSpec((1,), lambda p, j: (0,)),          # s
        ],
        out_specs=[
            pl.BlockSpec((m,), lambda p, j: (0,)),          # alpha_new
            pl.BlockSpec((d_blk,), lambda p, j: (j,)),      # dv tile
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((d_padded,), jnp.float32),
        ],
        # u accumulator and d_alpha persist in scratch across the grid
        # (VMEM on real TPU; MemorySpace.ANY keeps interpret-mode happy).
        scratch_shapes=[
            pl.MemorySpace.ANY((m,), jnp.float32),
            pl.MemorySpace.ANY((m,), jnp.float32),
        ],
        interpret=True,  # CPU path; real TPU would lower to Mosaic
    )(x, y, alpha, w, s_arr)
    return alpha_new, dv[:d]
