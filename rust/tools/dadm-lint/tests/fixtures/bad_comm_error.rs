// Fixture: every `comm-error` pattern the rule must catch when linted
// under a virtual comm/ path. The transport's failure surface is the
// typed `CommError` (comm/error.rs); `anyhow` erases the failure class
// the fault-tolerance paths match on. Not compiled.

use anyhow::{anyhow, bail, Context, Result};

pub fn recv_step(ok: bool) -> Result<u32> {
    if !ok {
        bail!("worker hung up");
    }
    Err(anyhow!("still stringly")).context("collect")
}

#[cfg(test)]
mod tests {
    // Test code inside comm/ may use anyhow like the rest of the repo.
    use anyhow::Result;

    #[test]
    fn exempt_inside_cfg_test() -> Result<()> {
        Ok(())
    }
}
