// Fixture: findings covered by well-formed waivers — lints clean, and
// every waiver is consumed (no stale-waiver warnings). Not compiled.

// dadm-lint: allow(total-decoding) — fixture: caller guarantees Some
pub fn guarded(x: Option<u8>) -> u8 {
    x.expect("guarded by caller")
}

pub fn timed() -> f64 {
    // dadm-lint: allow(wall-clock) — fixture: telemetry only, never control flow
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}
