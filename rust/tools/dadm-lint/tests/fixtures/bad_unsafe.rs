// Fixture: trips `unsafe-code` for any file not on
// unsafe_allowlist.txt. Not compiled.

pub fn read_first(xs: &[f64]) -> f64 {
    unsafe { *xs.get_unchecked(0) }
}
