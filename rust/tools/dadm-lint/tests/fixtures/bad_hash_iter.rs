// Fixture: trips `hash-iter` when linted under a determinism-scoped
// virtual path (solver/, comm/, coordinator/, runtime/). Not compiled.

use std::collections::HashMap;

pub fn histogram(xs: &[u32]) -> HashMap<u32, usize> {
    let mut h = HashMap::new();
    for &x in xs {
        *h.entry(x).or_insert(0usize) += 1;
    }
    h
}
