// Fixture: trips `naive-reduction` in aggregation code — float
// accumulation outside tree_sum/tree_allreduce_delta. Not compiled.

pub fn merge(parts: &[f64]) -> f64 {
    parts.iter().sum()
}

pub fn merge_turbofish(parts: &[f64]) -> f64 {
    parts.iter().copied().sum::<f64>()
}
