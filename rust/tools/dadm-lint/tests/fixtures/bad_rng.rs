// Fixture: trips `rng-construction` outside the blessed
// fork-discipline sites. Not compiled.

pub fn fresh_stream(seed: u64) -> Rng {
    Rng::new(seed)
}

pub fn resume(state: [u64; 4]) -> Rng {
    Rng::from_state(state)
}

pub fn reseed(r: &mut SomeRng, s: u64) {
    r.seed_from_u64(s);
}
