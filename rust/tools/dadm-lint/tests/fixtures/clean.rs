// Fixture: near-miss corpus — none of this may trip any rule, even
// under the strictest virtual path (comm/wire.rs). Not compiled.

use std::collections::BTreeMap;

/// Rule patterns inside strings and comments must not count:
/// HashMap, panic!, Instant::now(), .unwrap(), xs.iter().sum().
pub fn describe() -> String {
    let s = "HashMap panic! Instant::now() .unwrap() xs.iter().sum()";
    s.to_string()
}

pub fn recover<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

pub fn tallies(xs: &[u32]) -> BTreeMap<u32, usize> {
    let mut m = BTreeMap::new();
    for &x in xs {
        *m.entry(x).or_insert(0usize) += 1;
    }
    m
}

pub fn array_forms(n: usize) -> [u8; 4] {
    let a = [1u8, 2, 3, 4];
    let _ = 0..n;
    a
}

pub fn checksum(xs: &[u64]) -> u64 {
    xs.iter().fold(0u64, |acc, &x| acc.wrapping_add(x))
}
