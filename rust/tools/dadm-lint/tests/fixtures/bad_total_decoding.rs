// Fixture: every `total-decoding` pattern the rule must catch when
// linted under the virtual path comm/wire.rs. Not compiled.

pub fn decode(buf: &[u8]) -> u8 {
    let tag = buf[0];
    let n = u32::from_le_bytes(buf[1..5].try_into().unwrap());
    if n > 10 {
        panic!("frame too large");
    }
    let body = buf.get(5).expect("truncated frame");
    match tag {
        0 => *body,
        _ => unreachable!("unknown tag"),
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn exempt_inside_cfg_test() {
        let v: Result<u8, ()> = Ok(1);
        v.unwrap();
    }
}
