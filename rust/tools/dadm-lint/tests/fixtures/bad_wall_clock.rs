// Fixture: trips `wall-clock` outside the metrics/driver allowlist.
// Not compiled.

use std::time::Instant;

pub fn stamp() -> f64 {
    let t0 = Instant::now();
    let wall = std::time::SystemTime::now();
    drop(wall);
    t0.elapsed().as_secs_f64()
}
