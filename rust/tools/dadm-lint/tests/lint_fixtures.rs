//! Fixture-corpus tests: every rule family has at least one snippet
//! that trips it, the near-miss corpus stays clean, the real
//! `rust/src/**` tree lints clean, and the wire-schema fingerprint
//! flips when a frame struct is edited without a `WIRE_VERSION` bump.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use dadm_lint::rules::{FileLint, Rule};
use dadm_lint::{find_root, lint_source, run_check, schema};
use std::path::PathBuf;

const BAD_HASH_ITER: &str = include_str!("fixtures/bad_hash_iter.rs");
const BAD_RNG: &str = include_str!("fixtures/bad_rng.rs");
const BAD_WALL_CLOCK: &str = include_str!("fixtures/bad_wall_clock.rs");
const BAD_REDUCTION: &str = include_str!("fixtures/bad_reduction.rs");
const BAD_TOTAL_DECODING: &str = include_str!("fixtures/bad_total_decoding.rs");
const BAD_UNSAFE: &str = include_str!("fixtures/bad_unsafe.rs");
const BAD_COMM_ERROR: &str = include_str!("fixtures/bad_comm_error.rs");
const CLEAN: &str = include_str!("fixtures/clean.rs");
const WAIVED: &str = include_str!("fixtures/waived.rs");

/// Field injected into `StepFlags` by the schema-mutation tests.
const PROBE_FIELD: &str = "pub struct StepFlags {\n    pub schema_probe: u64,";

fn lint(rel: &str, src: &str) -> FileLint {
    lint_source(rel, src, &[])
}

fn active_rules(fl: &FileLint) -> Vec<Rule> {
    fl.findings.iter().filter(|f| !f.waived).map(|f| f.rule).collect()
}

#[test]
fn hash_iter_fixture_trips() {
    let rules = active_rules(&lint("solver/fixture.rs", BAD_HASH_ITER));
    assert!(rules.contains(&Rule::HashIter), "{rules:?}");
    // Out of the determinism scope the same source is fine.
    assert!(active_rules(&lint("data/fixture.rs", BAD_HASH_ITER)).is_empty());
}

#[test]
fn rng_construction_fixture_trips() {
    let rules = active_rules(&lint("coordinator/fixture.rs", BAD_RNG));
    let hits = rules.iter().filter(|r| **r == Rule::RngConstruction).count();
    // Rng::new, Rng::from_state, and seed_from_u64 must each be caught.
    assert_eq!(hits, 3, "{rules:?}");
    assert!(active_rules(&lint("solver/worker.rs", BAD_RNG)).is_empty());
}

#[test]
fn wall_clock_fixture_trips() {
    let rules = active_rules(&lint("comm/fixture.rs", BAD_WALL_CLOCK));
    assert!(rules.contains(&Rule::WallClock), "{rules:?}");
    assert!(active_rules(&lint("comm/pool.rs", BAD_WALL_CLOCK)).is_empty());
}

#[test]
fn naive_reduction_fixture_trips() {
    let rules = active_rules(&lint("comm/fixture.rs", BAD_REDUCTION));
    let hits = rules.iter().filter(|r| **r == Rule::NaiveReduction).count();
    // Plain `.sum()` and turbofish `.sum::<f64>()` both count.
    assert_eq!(hits, 2, "{rules:?}");
    assert!(active_rules(&lint("comm/allreduce.rs", BAD_REDUCTION)).is_empty());
}

#[test]
fn total_decoding_fixture_trips() {
    let fl = lint("comm/wire.rs", BAD_TOTAL_DECODING);
    let rules = active_rules(&fl);
    // Two indexings, unwrap, expect, panic!, unreachable! — and nothing
    // from the #[cfg(test)] module at the bottom of the fixture.
    assert_eq!(rules.len(), 6, "{:?}", fl.findings);
    assert!(rules.iter().all(|r| *r == Rule::TotalDecoding));
}

#[test]
fn unsafe_fixture_trips_unless_allowlisted() {
    let rules = active_rules(&lint("solver/fixture.rs", BAD_UNSAFE));
    assert_eq!(rules, vec![Rule::UnsafeCode]);
    let allow = ["solver/fixture.rs".to_string()];
    let fl = lint_source("solver/fixture.rs", BAD_UNSAFE, &allow);
    assert!(active_rules(&fl).is_empty());
}

#[test]
fn comm_error_fixture_trips_only_inside_comm() {
    let fl = lint("comm/fixture.rs", BAD_COMM_ERROR);
    let rules = active_rules(&fl);
    // The use-import (`anyhow` + the braced `anyhow` macro name) and the
    // `anyhow!(..)` construction — and nothing from the #[cfg(test)]
    // module at the bottom.
    let hits = rules.iter().filter(|r| **r == Rule::CommErrorBoundary).count();
    assert_eq!(hits, 3, "{:?}", fl.findings);
    // Outside comm/ anyhow is the repo's normal application error type.
    assert!(active_rules(&lint("coordinator/fixture.rs", BAD_COMM_ERROR)).is_empty());
}

#[test]
fn clean_fixture_passes_under_strictest_path() {
    let fl = lint("comm/wire.rs", CLEAN);
    assert!(fl.findings.is_empty(), "{:?}", fl.findings);
    assert!(fl.unused_waivers.is_empty());
}

#[test]
fn waived_fixture_is_clean_with_no_stale_waivers() {
    let fl = lint("comm/cluster.rs", WAIVED);
    assert!(active_rules(&fl).is_empty(), "{:?}", fl.findings);
    let waived: Vec<_> = fl.findings.iter().filter(|f| f.waived).collect();
    assert_eq!(waived.len(), 2, "{:?}", fl.findings);
    assert!(waived.iter().all(|f| f.waiver_reason.is_some()));
    assert!(fl.unused_waivers.is_empty(), "{:?}", fl.unused_waivers);
}

fn repo_root() -> PathBuf {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    find_root(&manifest).expect("repo root above dadm-lint crate")
}

#[test]
fn real_tree_lints_clean() {
    let report = run_check(&repo_root()).unwrap();
    assert!(report.files_checked > 20, "walked only {} files", report.files_checked);
    let msgs: Vec<String> = report
        .violations
        .iter()
        .map(|f| format!("{}:{} [{}] {}", f.file, f.line, f.rule.slug(), f.message))
        .collect();
    assert!(report.ok(), "real tree has violations:\n{}", msgs.join("\n"));
    // The audited waivers in comm/ must all be live (none stale).
    assert!(!report.waived.is_empty());
    let stale: Vec<String> = report
        .unused_waivers
        .iter()
        .map(|(file, w)| format!("{}:{} allow({})", file, w.line, w.rule.slug()))
        .collect();
    assert!(stale.is_empty(), "stale waivers:\n{}", stale.join("\n"));
}

/// A scratch repo tree holding a copy of the real `wire.rs` (and
/// optionally `wire.schema`), so schema mutations never touch the repo.
fn scratch_tree(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dadm-lint-{}-{}", tag, std::process::id()));
    if dir.exists() {
        std::fs::remove_dir_all(&dir).unwrap();
    }
    std::fs::create_dir_all(dir.join("rust/src/comm")).unwrap();
    dir
}

fn real_wire_src() -> String {
    std::fs::read_to_string(repo_root().join("rust/src/comm/wire.rs")).unwrap()
}

#[test]
fn schema_check_matches_committed_file_and_flips_on_mutation() {
    let root = scratch_tree("flip");
    let wire = root.join("rust/src/comm/wire.rs");
    let src = real_wire_src();
    std::fs::write(&wire, &src).unwrap();

    // Missing schema file is a violation, not a pass.
    assert!(schema::check(&root).unwrap().is_some());

    // Bootstrap, then the unmodified tree passes.
    schema::update(&root, true).unwrap();
    assert_eq!(schema::check(&root).unwrap(), None);

    // Editing a frame struct without bumping WIRE_VERSION fails.
    let marker = "pub struct StepFlags {";
    assert!(src.contains(marker), "wire.rs layout changed; update this test");
    let mutated = src.replace(marker, PROBE_FIELD);
    std::fs::write(&wire, &mutated).unwrap();
    let msg = schema::check(&root).unwrap().expect("mutation must be flagged");
    assert!(msg.contains("WIRE_VERSION"), "{msg}");

    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn schema_update_refuses_same_version_drift_without_force() {
    let root = scratch_tree("refuse");
    let wire = root.join("rust/src/comm/wire.rs");
    let src = real_wire_src();
    std::fs::write(&wire, &src).unwrap();
    schema::update(&root, true).unwrap();

    // Drift at the same version: update must refuse without --force.
    let marker = "pub struct StepFlags {";
    let mutated = src.replace(marker, PROBE_FIELD);
    std::fs::write(&wire, &mutated).unwrap();
    assert!(schema::update(&root, false).is_err());

    // Bump WIRE_VERSION too: check flags the stale file, update accepts
    // without force, and the tree then passes.
    let version_marker = "pub const WIRE_VERSION: u16 = ";
    assert!(src.contains(version_marker), "wire.rs layout changed; update this test");
    let old = schema::fingerprint(&src).unwrap().version;
    let bumped = mutated.replace(
        &format!("{version_marker}{old};"),
        &format!("{version_marker}{};", old + 1),
    );
    assert_ne!(bumped, mutated, "version bump replace had no effect");
    std::fs::write(&wire, &bumped).unwrap();
    let msg = schema::check(&root).unwrap().expect("stale schema file must be flagged");
    assert!(msg.contains("regenerate"), "{msg}");
    schema::update(&root, false).unwrap();
    assert_eq!(schema::check(&root).unwrap(), None);

    std::fs::remove_dir_all(&root).unwrap();
}
