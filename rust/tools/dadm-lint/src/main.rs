//! CLI for the invariant analyzer.
//!
//! ```text
//! cargo run -p dadm-lint -- check [--root <repo>]
//! cargo run -p dadm-lint -- schema [--update [--force]] [--root <repo>]
//! ```
//!
//! `check` exits 0 when every invariant holds (unused waivers only
//! warn), 1 on violations, 2 on usage or I/O errors. `schema` prints
//! the current fingerprint, or regenerates `rust/src/comm/wire.schema`
//! with `--update` (refusing same-version digest drift unless
//! `--force`).

use anyhow::{bail, Result};
use dadm_lint::{find_root, run_check, schema, Report};
use std::path::PathBuf;
use std::process::ExitCode;

struct Args {
    command: String,
    root: Option<PathBuf>,
    update: bool,
    force: bool,
}

fn parse_args() -> Result<Args> {
    let mut args = Args {
        command: String::new(),
        root: None,
        update: false,
        force: false,
    };
    let mut it = std::env::args().skip(1);
    match it.next() {
        Some(c) if c == "check" || c == "schema" => args.command = c,
        Some(c) => bail!("unknown command `{c}` (expected `check` or `schema`)"),
        None => bail!("usage: dadm-lint <check|schema> [--root <repo>] [--update] [--force]"),
    }
    while let Some(flag) = it.next() {
        match flag.as_str() {
            "--root" => match it.next() {
                Some(p) => args.root = Some(PathBuf::from(p)),
                None => bail!("--root requires a path"),
            },
            "--update" => args.update = true,
            "--force" => args.force = true,
            other => bail!("unknown flag `{other}`"),
        }
    }
    if args.command != "schema" && (args.update || args.force) {
        bail!("--update/--force only apply to the `schema` command");
    }
    Ok(args)
}

/// Resolve the repo root: explicit `--root`, else walk up from the
/// current directory, else walk up from this crate's manifest (covers
/// `cargo run -p dadm-lint` from an unrelated working directory).
fn resolve_root(explicit: Option<PathBuf>) -> Result<PathBuf> {
    if let Some(r) = explicit {
        if !r.join("rust").join("src").join("lib.rs").is_file() {
            bail!("--root {} does not contain rust/src/lib.rs", r.display());
        }
        return Ok(r);
    }
    if let Ok(cwd) = std::env::current_dir() {
        if let Some(r) = find_root(&cwd) {
            return Ok(r);
        }
    }
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    if let Some(r) = find_root(&manifest) {
        return Ok(r);
    }
    bail!("could not locate the repo root (no rust/src/lib.rs above cwd); pass --root")
}

fn print_report(report: &Report) {
    for f in &report.violations {
        if f.line == 0 {
            println!("error[{}]: {}: {}", f.rule.slug(), f.file, f.message);
        } else {
            println!("error[{}]: {}:{}: {}", f.rule.slug(), f.file, f.line, f.message);
        }
    }
    for (file, w) in &report.unused_waivers {
        println!(
            "warning[stale-waiver]: {}:{}: allow({}) matched no finding — remove it",
            file,
            w.line,
            w.rule.slug()
        );
    }
    println!(
        "dadm-lint: {} files checked, {} violations, {} waived ({} stale waivers)",
        report.files_checked,
        report.violations.len(),
        report.waived.len(),
        report.unused_waivers.len()
    );
    if !report.waived.is_empty() {
        println!("waiver inventory:");
        for f in &report.waived {
            let reason = f.waiver_reason.as_deref().unwrap_or("");
            println!("  {}:{} [{}] {}", f.file, f.line, f.rule.slug(), reason);
        }
    }
}

fn run() -> Result<bool> {
    let args = parse_args()?;
    let root = resolve_root(args.root)?;
    match args.command.as_str() {
        "check" => {
            let report = run_check(&root)?;
            print_report(&report);
            Ok(report.ok())
        }
        _ => {
            if args.update {
                let digest = schema::update(&root, args.force)?;
                println!("wrote rust/src/comm/wire.schema (digest {digest})");
            } else {
                let wire = root.join("rust").join("src").join("comm").join("wire.rs");
                let fp = schema::fingerprint(&std::fs::read_to_string(wire)?)?;
                println!("version = {}\ndigest = {}", fp.version, fp.digest);
            }
            Ok(true)
        }
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::from(1),
        Err(e) => {
            eprintln!("dadm-lint: {e:#}");
            ExitCode::from(2)
        }
    }
}
