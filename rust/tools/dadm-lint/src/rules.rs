//! The invariant catalog (DESIGN.md §12): token-pattern rules over the
//! `rust/src/**` tree, each waivable inline with
//! `// dadm-lint: allow(<rule>) — <reason>` on the offending line or
//! within the three preceding lines (so an interposed `#[allow(...)]`
//! attribute does not break the association). A waiver with an empty
//! reason does not waive — justifications are part of the contract.

use crate::lexer::{ident_at, is_punct, test_regions, Lexed, Tok, TokKind};

/// The rule families `dadm-lint check` enforces.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rule {
    /// No `HashMap`/`HashSet` in determinism-scoped paths.
    HashIter,
    /// RNG construction only in the blessed fork-discipline sites.
    RngConstruction,
    /// No wall-clock reads outside the metrics/driver allowlist.
    WallClock,
    /// Cross-machine float accumulation only via the blessed reductions.
    NaiveReduction,
    /// No panic paths (and, in `wire.rs`, no slice indexing) in `comm/`.
    TotalDecoding,
    /// Committed wire-schema fingerprint must match the source.
    WireSchema,
    /// `unsafe` only in files on the explicit allowlist.
    UnsafeCode,
    /// No `anyhow` error construction inside `comm/` — the transport
    /// speaks typed [`CommError`]s so callers can match on failure
    /// classes (disconnect vs timeout vs fault) instead of strings.
    ///
    /// [`CommError`]: ../../../src/comm/error.rs
    CommErrorBoundary,
}

impl Rule {
    /// Every rule, in report order.
    pub const ALL: [Rule; 8] = [
        Rule::HashIter,
        Rule::RngConstruction,
        Rule::WallClock,
        Rule::NaiveReduction,
        Rule::TotalDecoding,
        Rule::WireSchema,
        Rule::UnsafeCode,
        Rule::CommErrorBoundary,
    ];

    /// The slug used in waiver comments and report lines.
    pub fn slug(self) -> &'static str {
        match self {
            Rule::HashIter => "hash-iter",
            Rule::RngConstruction => "rng-construction",
            Rule::WallClock => "wall-clock",
            Rule::NaiveReduction => "naive-reduction",
            Rule::TotalDecoding => "total-decoding",
            Rule::WireSchema => "wire-schema",
            Rule::UnsafeCode => "unsafe-code",
            Rule::CommErrorBoundary => "comm-error",
        }
    }

    /// Inverse of [`Rule::slug`].
    pub fn from_slug(s: &str) -> Option<Rule> {
        Rule::ALL.into_iter().find(|r| r.slug() == s)
    }
}

/// One rule violation (possibly waived).
#[derive(Clone, Debug)]
pub struct Finding {
    /// Path relative to `rust/src`, forward slashes.
    pub file: String,
    /// 1-based source line.
    pub line: usize,
    /// The violated rule.
    pub rule: Rule,
    /// Human-readable description.
    pub message: String,
    /// Set when an inline waiver covers this finding.
    pub waived: bool,
    /// The waiver's justification, when waived.
    pub waiver_reason: Option<String>,
}

/// A parsed `dadm-lint: allow(<rule>) — <reason>` comment.
#[derive(Clone, Debug)]
pub struct Waiver {
    /// 1-based line the comment sits on.
    pub line: usize,
    /// The rule it waives.
    pub rule: Rule,
    /// Justification text (non-empty by construction).
    pub reason: String,
    /// Set once a finding consumed it.
    pub used: bool,
}

/// Result of linting one file.
#[derive(Debug, Default)]
pub struct FileLint {
    /// All findings, waived or not.
    pub findings: Vec<Finding>,
    /// Waivers that matched no finding (stale — reported as warnings).
    pub unused_waivers: Vec<Waiver>,
}

/// Directories (relative to `rust/src`) whose math paths must be
/// deterministic: the scope of `hash-iter`, `rng-construction`, and
/// `wall-clock`.
const DETERMINISM_DIRS: [&str; 4] = ["solver/", "comm/", "coordinator/", "runtime/"];

/// Files allowed to construct RNGs: the fork-discipline helpers that
/// derive per-machine streams (`utils/rng.rs` is outside the scoped
/// dirs and needs no entry).
const RNG_ALLOWED_FILES: [&str; 1] = ["solver/worker.rs"];

/// Files allowed to read the wall clock: the driver's wall-time capture
/// and the pool's compute-timing core — both feed *reported* cost-model
/// telemetry, never control flow.
const WALL_CLOCK_ALLOWED_FILES: [&str; 2] = ["runtime/engine.rs", "comm/pool.rs"];

/// The blessed reduction implementations themselves.
const REDUCTION_BLESSED_FILES: [&str; 2] = ["comm/allreduce.rs", "comm/sparse.rs"];

/// Identifiers that precede `[` without forming an index expression.
const NON_INDEX_KEYWORDS: [&str; 16] = [
    "return", "in", "if", "else", "match", "break", "loop", "while", "for", "as", "mut", "ref",
    "move", "box", "dyn", "where",
];

fn in_determinism_scope(rel: &str) -> bool {
    DETERMINISM_DIRS.iter().any(|d| rel.starts_with(d))
}

/// Lint one file's token stream against every token rule (the
/// `wire-schema` rule is file-set-level and handled by [`crate::schema`]).
/// `rel` is the path relative to `rust/src`; `unsafe_allowlist` holds
/// such relative paths where `unsafe` is permitted.
pub fn lint_tokens(rel: &str, lexed: &Lexed, unsafe_allowlist: &[String]) -> FileLint {
    let toks = &lexed.toks;
    let regions = test_regions(toks);
    let in_test = |i: usize| regions.iter().any(|&(s, e)| i >= s && i < e);
    let mut raw: Vec<Finding> = Vec::new();
    let mut push = |line: usize, rule: Rule, message: String| {
        raw.push(Finding {
            file: rel.to_string(),
            line,
            rule,
            message,
            waived: false,
            waiver_reason: None,
        });
    };

    let determinism = in_determinism_scope(rel);
    let rng_allowed = RNG_ALLOWED_FILES.contains(&rel);
    let clock_allowed = WALL_CLOCK_ALLOWED_FILES.contains(&rel);
    let in_comm = rel.starts_with("comm/");
    let reduction_scoped = in_comm && !REDUCTION_BLESSED_FILES.contains(&rel);
    let unsafe_allowed = unsafe_allowlist.iter().any(|p| p == rel);

    for i in 0..toks.len() {
        if in_test(i) {
            continue;
        }
        let line = toks[i].line;

        if determinism {
            if let Some(id) = ident_at(toks, i) {
                if id == "HashMap" || id == "HashSet" {
                    push(
                        line,
                        Rule::HashIter,
                        format!(
                            "`{id}` in a determinism-scoped path: iteration order is \
                             unspecified; use a Vec/BTreeMap or waive if never iterated"
                        ),
                    );
                }
            }
            if !rng_allowed {
                if ident_at(toks, i) == Some("Rng")
                    && is_punct(toks, i + 1, ':')
                    && is_punct(toks, i + 2, ':')
                {
                    if let Some(m) = ident_at(toks, i + 3) {
                        if m == "new" || m == "from_state" {
                            push(
                                line,
                                Rule::RngConstruction,
                                format!(
                                    "raw RNG construction `Rng::{m}` outside the blessed \
                                     fork-discipline sites (solver::machine_rng/machine_rngs)"
                                ),
                            );
                        }
                    }
                }
                if ident_at(toks, i) == Some("seed_from_u64") {
                    push(
                        line,
                        Rule::RngConstruction,
                        "`seed_from_u64` outside the blessed fork-discipline sites".to_string(),
                    );
                }
            }
            if !clock_allowed {
                if ident_at(toks, i) == Some("Instant")
                    && is_punct(toks, i + 1, ':')
                    && is_punct(toks, i + 2, ':')
                    && ident_at(toks, i + 3) == Some("now")
                {
                    push(
                        line,
                        Rule::WallClock,
                        "`Instant::now` outside the metrics/driver wall-clock allowlist"
                            .to_string(),
                    );
                }
                if ident_at(toks, i) == Some("SystemTime") {
                    push(
                        line,
                        Rule::WallClock,
                        "`SystemTime` outside the metrics/driver wall-clock allowlist".to_string(),
                    );
                }
            }
        }

        if reduction_scoped
            && is_punct(toks, i, '.')
            && ident_at(toks, i + 1) == Some("sum")
            && (is_punct(toks, i + 2, '(') || is_punct(toks, i + 2, ':'))
        {
            push(
                line,
                Rule::NaiveReduction,
                "naive `.sum()` in aggregation code: cross-machine float accumulation \
                 must go through tree_sum/tree_allreduce_delta"
                    .to_string(),
            );
        }

        if in_comm {
            if is_punct(toks, i, '.') && is_punct(toks, i + 2, '(') {
                if let Some(m) = ident_at(toks, i + 1) {
                    if m == "unwrap" || m == "expect" {
                        push(
                            line,
                            Rule::TotalDecoding,
                            format!("`.{m}(...)` in non-test communication code"),
                        );
                    }
                }
            }
            if is_punct(toks, i + 1, '!') {
                if let Some(m) = ident_at(toks, i) {
                    if matches!(m, "panic" | "unreachable" | "todo" | "unimplemented") {
                        push(
                            line,
                            Rule::TotalDecoding,
                            format!("`{m}!` in non-test communication code"),
                        );
                    }
                }
            }
            if rel == "comm/wire.rs" && is_punct(toks, i, '[') && i > 0 {
                let prev = &toks[i - 1];
                let indexes = match prev.kind {
                    TokKind::Ident => !NON_INDEX_KEYWORDS.contains(&prev.text.as_str()),
                    TokKind::Punct => prev.text == ")" || prev.text == "]",
                    TokKind::Literal => false,
                };
                if indexes {
                    push(
                        line,
                        Rule::TotalDecoding,
                        "slice indexing in wire.rs: decode must be total — use \
                         `Dec::take`/`le_bytes` or iterator forms"
                            .to_string(),
                    );
                }
            }
        }

        if in_comm && ident_at(toks, i) == Some("anyhow") {
            push(
                line,
                Rule::CommErrorBoundary,
                "`anyhow` inside comm/: the transport's error surface is the typed                  `CommError` (comm/error.rs) — map failures onto its variants instead"
                    .to_string(),
            );
        }

        if !unsafe_allowed && ident_at(toks, i) == Some("unsafe") {
            push(
                line,
                Rule::UnsafeCode,
                "`unsafe` outside rust/tools/dadm-lint/unsafe_allowlist.txt".to_string(),
            );
        }
    }

    apply_waivers(raw, &lexed.comments)
}

/// Parse waivers out of the line comments and match them to findings.
fn apply_waivers(mut findings: Vec<Finding>, comments: &[(usize, String)]) -> FileLint {
    let mut waivers: Vec<Waiver> = comments
        .iter()
        .filter_map(|(line, text)| parse_waiver(*line, text))
        .collect();
    for f in &mut findings {
        for w in &mut waivers {
            let window = f.line.saturating_sub(3)..=f.line;
            if w.rule == f.rule && window.contains(&w.line) {
                f.waived = true;
                f.waiver_reason = Some(w.reason.clone());
                w.used = true;
                break;
            }
        }
    }
    FileLint {
        findings,
        unused_waivers: waivers.into_iter().filter(|w| !w.used).collect(),
    }
}

/// Parse one comment's text as a waiver, if it is one. Requires a
/// non-empty reason after the `allow(...)` clause (separators `—`, `-`,
/// `:` are stripped).
fn parse_waiver(line: usize, text: &str) -> Option<Waiver> {
    let rest = text.split("dadm-lint:").nth(1)?.trim_start();
    let rest = rest.strip_prefix("allow(")?;
    let close = rest.find(')')?;
    let rule = Rule::from_slug(rest.get(..close)?.trim())?;
    let reason: String = rest
        .get(close + 1..)?
        .trim_matches(|c: char| c.is_whitespace() || c == '—' || c == '-' || c == '–' || c == ':')
        .to_string();
    if reason.is_empty() {
        return None;
    }
    Some(Waiver {
        line,
        rule,
        reason,
        used: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn lint(rel: &str, src: &str) -> FileLint {
        lint_tokens(rel, &lex(src), &[])
    }

    fn rules_of(fl: &FileLint) -> Vec<Rule> {
        fl.findings.iter().filter(|f| !f.waived).map(|f| f.rule).collect()
    }

    #[test]
    fn hash_iter_scoped_to_determinism_dirs() {
        let src = "use std::collections::HashMap;";
        assert_eq!(rules_of(&lint("solver/x.rs", src)), vec![Rule::HashIter]);
        assert!(rules_of(&lint("data/x.rs", src)).is_empty());
    }

    #[test]
    fn rng_construction_blessed_files_pass() {
        let src = "let r = Rng::new(seed);";
        assert_eq!(
            rules_of(&lint("coordinator/x.rs", src)),
            vec![Rule::RngConstruction]
        );
        assert!(rules_of(&lint("solver/worker.rs", src)).is_empty());
        assert!(rules_of(&lint("data/partition.rs", src)).is_empty());
    }

    #[test]
    fn wall_clock_allowlist() {
        let src = "let t0 = Instant::now();";
        assert_eq!(rules_of(&lint("comm/cluster.rs", src)), vec![Rule::WallClock]);
        assert!(rules_of(&lint("comm/pool.rs", src)).is_empty());
        assert!(rules_of(&lint("runtime/engine.rs", src)).is_empty());
    }

    #[test]
    fn instant_mention_without_now_is_fine() {
        assert!(rules_of(&lint("comm/cluster.rs", "use std::time::Instant;")).is_empty());
    }

    #[test]
    fn naive_reduction_excludes_blessed_files() {
        let src = "let s: f64 = xs.iter().sum();";
        assert_eq!(
            rules_of(&lint("comm/cluster.rs", src)),
            vec![Rule::NaiveReduction]
        );
        assert!(rules_of(&lint("comm/allreduce.rs", src)).is_empty());
        assert!(rules_of(&lint("solver/x.rs", src)).is_empty());
    }

    #[test]
    fn turbofish_sum_is_flagged() {
        let fl = lint("comm/tcp.rs", "let s = xs.iter().sum::<f64>();");
        assert!(rules_of(&fl).contains(&Rule::NaiveReduction));
    }

    #[test]
    fn total_decoding_panics_and_indexing() {
        let fl = lint("comm/wire.rs", "fn f(b: &[u8]) -> u8 { b[0] }");
        assert_eq!(rules_of(&fl), vec![Rule::TotalDecoding]);
        let src = "fn f() { x.unwrap(); y.expect(\"z\"); panic!(\"q\"); }";
        assert_eq!(rules_of(&lint("comm/tcp.rs", src)).len(), 3);
        // Indexing is wire.rs-only; other comm files index guarded buffers.
        assert!(rules_of(&lint("comm/tcp.rs", "fn f(b: &[u8]) -> u8 { b[0] }")).is_empty());
    }

    #[test]
    fn array_literals_and_attributes_are_not_indexing() {
        let src = "#[derive(Clone)]\nstruct S;\nfn f() -> [u8; 4] { let a = [0u8; 4]; a }";
        assert!(rules_of(&lint("comm/wire.rs", src)).is_empty());
    }

    #[test]
    fn unwrap_or_else_is_not_unwrap() {
        let src = "fn f() { x.lock().unwrap_or_else(std::sync::PoisonError::into_inner); }";
        assert!(rules_of(&lint("comm/tcp.rs", src)).is_empty());
    }

    #[test]
    fn cfg_test_code_is_exempt() {
        let src = "#[cfg(test)]\nmod tests { fn t() { x.unwrap(); panic!(\"boom\"); } }";
        assert!(rules_of(&lint("comm/wire.rs", src)).is_empty());
    }

    #[test]
    fn unsafe_respects_allowlist() {
        let src = "unsafe impl Send for X {}";
        assert_eq!(rules_of(&lint("runtime/x.rs", src)), vec![Rule::UnsafeCode]);
        let fl = lint_tokens("runtime/x.rs", &lex(src), &["runtime/x.rs".to_string()]);
        assert!(rules_of(&fl).is_empty());
    }

    #[test]
    fn waiver_same_line_and_above() {
        let src = concat!(
            "// dadm-lint: allow(total-decoding) — guarded by construction\n",
            "fn f() { x.unwrap(); }",
        );
        let fl = lint("comm/tcp.rs", src);
        assert_eq!(fl.findings.len(), 1);
        assert!(fl.findings[0].waived);
        assert!(fl.unused_waivers.is_empty());

        let src = "fn f() { x.unwrap() } // dadm-lint: allow(total-decoding) - same line";
        assert!(rules_of(&lint("comm/tcp.rs", src)).is_empty());
    }

    #[test]
    fn waiver_reaches_past_interposed_attribute() {
        let src = concat!(
            "// dadm-lint: allow(total-decoding) — unreachable by guard\n",
            "#[allow(clippy::expect_used)]\n",
            "let v = x.expect(\"y\");",
        );
        assert!(rules_of(&lint("comm/tcp.rs", src)).is_empty());
    }

    #[test]
    fn waiver_without_reason_does_not_waive() {
        let src = "// dadm-lint: allow(total-decoding)\nfn f() { x.unwrap(); }";
        assert_eq!(rules_of(&lint("comm/tcp.rs", src)), vec![Rule::TotalDecoding]);
    }

    #[test]
    fn wrong_rule_waiver_does_not_waive_and_reports_unused() {
        let src = "// dadm-lint: allow(hash-iter) — wrong rule\nfn f() { x.unwrap(); }";
        let fl = lint("comm/tcp.rs", src);
        assert_eq!(rules_of(&fl), vec![Rule::TotalDecoding]);
        assert_eq!(fl.unused_waivers.len(), 1);
    }

    #[test]
    fn comm_error_boundary_flags_anyhow_in_comm() {
        let src = "use anyhow::{bail, Result};";
        assert_eq!(
            rules_of(&lint("comm/tcp.rs", src)),
            vec![Rule::CommErrorBoundary]
        );
        let src = "fn f() -> anyhow::Result<()> { Err(anyhow::anyhow!(\"x\")) }";
        assert_eq!(rules_of(&lint("comm/cluster.rs", src)).len(), 3);
        // Outside comm/ anyhow is the normal application error type.
        assert!(rules_of(&lint("coordinator/dadm.rs", "use anyhow::Result;")).is_empty());
        // Test code inside comm/ is exempt like the other comm rules.
        let src = "#[cfg(test)]\nmod tests { use anyhow::Result; }";
        assert!(rules_of(&lint("comm/tcp.rs", src)).is_empty());
    }

    #[test]
    fn slugs_roundtrip() {
        for r in Rule::ALL {
            assert_eq!(Rule::from_slug(r.slug()), Some(r));
        }
        assert_eq!(Rule::from_slug("nope"), None);
    }
}
