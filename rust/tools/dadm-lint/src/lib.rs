//! dadm-lint — the repo's invariant analyzer (DESIGN.md §12).
//!
//! A hand-rolled token walker (no syn, no proc-macro machinery — the
//! only dependency is the vendored `anyhow` shim) that enforces the
//! determinism, total-decoding, blessed-reduction, wire-schema,
//! comm-error-boundary, and unsafe-audit invariants over `rust/src/**`. Run as
//! `cargo run -p dadm-lint -- check` from anywhere in the repo; CI runs
//! it on every push (`lint-invariants` job).
//!
//! The crate is a library plus a thin CLI so the fixture corpus under
//! `tests/` can drive [`rules::lint_tokens`] and [`schema`] directly.

pub mod lexer;
pub mod rules;
pub mod schema;

use anyhow::{Context, Result};
use rules::{FileLint, Finding, Rule, Waiver};
use std::path::{Path, PathBuf};

/// Aggregated result of a full `check` run over a repo tree.
#[derive(Debug, Default)]
pub struct Report {
    /// Number of `.rs` files linted under `rust/src`.
    pub files_checked: usize,
    /// Unwaived violations — any entry here fails the run.
    pub violations: Vec<Finding>,
    /// Waived findings, kept for the waiver inventory.
    pub waived: Vec<Finding>,
    /// Waiver comments that matched no finding (stale).
    pub unused_waivers: Vec<(String, Waiver)>,
}

impl Report {
    /// Does the run pass? Unused waivers warn but do not fail.
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Read the `unsafe` allowlist (paths relative to `rust/src`, `#`
/// comments and blank lines ignored). A missing file means an empty
/// allowlist — absence must fail closed, not open.
fn read_unsafe_allowlist(root: &Path) -> Vec<String> {
    let path = root
        .join("rust")
        .join("tools")
        .join("dadm-lint")
        .join("unsafe_allowlist.txt");
    let Ok(text) = std::fs::read_to_string(path) else {
        return Vec::new();
    };
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect()
}

/// Collect every `.rs` file under `dir`, depth-first, sorted by path at
/// each level so the walk order (and thus the report order) is
/// deterministic across filesystems.
fn walk_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading directory {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            walk_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint one file's source text as if it lived at `rel` (relative to
/// `rust/src`, forward slashes). Exposed for the fixture tests, which
/// lint corpus snippets under virtual paths.
pub fn lint_source(rel: &str, src: &str, unsafe_allowlist: &[String]) -> FileLint {
    rules::lint_tokens(rel, &lexer::lex(src), unsafe_allowlist)
}

/// Run the full check over the repo tree at `root` (the directory
/// containing `rust/src`).
pub fn run_check(root: &Path) -> Result<Report> {
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    walk_rs_files(&src_root, &mut files)?;
    let allowlist = read_unsafe_allowlist(root);

    let mut report = Report::default();
    for path in &files {
        let rel_path = path.strip_prefix(&src_root).unwrap_or(path);
        let rel = rel_path.to_string_lossy().replace('\\', "/");
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let fl = lint_source(&rel, &src, &allowlist);
        report.files_checked += 1;
        for f in fl.findings {
            if f.waived {
                report.waived.push(f);
            } else {
                report.violations.push(f);
            }
        }
        for w in fl.unused_waivers {
            report.unused_waivers.push((rel.clone(), w));
        }
    }

    if let Some(msg) = schema::check(root)? {
        report.violations.push(Finding {
            file: "comm/wire.rs".to_string(),
            line: 0,
            rule: Rule::WireSchema,
            message: msg,
            waived: false,
            waiver_reason: None,
        });
    }
    Ok(report)
}

/// Locate the repo root: walk up from `start` looking for
/// `rust/src/lib.rs`. Lets the binary run from any subdirectory.
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut dir = Some(start);
    while let Some(d) = dir {
        if d.join("rust").join("src").join("lib.rs").is_file() {
            return Some(d.to_path_buf());
        }
        dir = d.parent();
    }
    None
}
