//! A hand-rolled Rust token scanner — just enough lexical structure for
//! the invariant rules: identifiers, punctuation, and literals, with
//! comments and strings fully delimited so rule patterns can never match
//! inside them. Line comments are kept (per line) because they carry the
//! `dadm-lint: allow(...)` waivers; everything else about comments is
//! discarded.
//!
//! The scanner is deliberately *not* a full Rust lexer: it does not
//! classify keywords, does not parse numeric suffixes precisely, and
//! treats a float literal as `digits . digits` (three tokens). All that
//! matters is that (a) token boundaries are correct for the patterns the
//! rules match, and (b) the normalization is stable — the wire-schema
//! fingerprint hashes these token streams, so any lexer change that
//! alters token text for `wire.rs` items requires regenerating
//! `rust/src/comm/wire.schema`.

/// Token classes — coarse on purpose (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`[A-Za-z_][A-Za-z0-9_]*`, raw `r#ident`).
    Ident,
    /// Single punctuation character.
    Punct,
    /// String/char/byte/numeric literal or lifetime, verbatim text.
    Literal,
}

/// One token with its source line (1-based).
#[derive(Clone, Debug)]
pub struct Tok {
    /// Verbatim source text of the token.
    pub text: String,
    /// Coarse class.
    pub kind: TokKind,
    /// 1-based source line the token starts on.
    pub line: usize,
}

/// A scanned file: the token stream plus line comments by line number.
#[derive(Debug, Default)]
pub struct Lexed {
    /// All non-comment tokens in source order.
    pub toks: Vec<Tok>,
    /// `(line, comment_text)` for every `//` comment (text excludes the
    /// leading slashes), in source order.
    pub comments: Vec<(usize, String)>,
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

struct Scanner {
    chars: Vec<char>,
    i: usize,
    line: usize,
}

impl Scanner {
    fn peek(&self, ahead: usize) -> char {
        self.chars.get(self.i + ahead).copied().unwrap_or('\0')
    }

    fn bump(&mut self) -> char {
        let c = self.peek(0);
        if c == '\n' {
            self.line += 1;
        }
        self.i += 1;
        c
    }

    fn eof(&self) -> bool {
        self.i >= self.chars.len()
    }

    /// Consume a run of `#` characters, returning the count.
    fn hashes(&mut self) -> usize {
        let mut n = 0;
        while self.peek(0) == '#' {
            self.bump();
            n += 1;
        }
        n
    }

    /// Consume a (possibly raw) string body starting at the opening
    /// quote; `raw_hashes > 0` means raw-string rules (no escapes,
    /// terminated by `"` + that many `#`).
    fn string_body(&mut self, out: &mut String, raw_hashes: usize) {
        out.push(self.bump()); // opening quote
        while !self.eof() {
            let c = self.bump();
            out.push(c);
            if raw_hashes == 0 {
                if c == '\\' {
                    out.push(self.bump());
                } else if c == '"' {
                    return;
                }
            } else if c == '"' {
                let mut seen = 0;
                while seen < raw_hashes && self.peek(0) == '#' {
                    out.push(self.bump());
                    seen += 1;
                }
                if seen == raw_hashes {
                    return;
                }
            }
        }
    }
}

/// Scan `src` into a [`Lexed`] token stream. Total: any input produces
/// some tokenization (unterminated literals run to end of file).
pub fn lex(src: &str) -> Lexed {
    let mut s = Scanner {
        chars: src.chars().collect(),
        i: 0,
        line: 1,
    };
    let mut out = Lexed::default();
    while !s.eof() {
        let c = s.peek(0);
        let line = s.line;
        if c.is_whitespace() {
            s.bump();
            continue;
        }
        // Comments.
        if c == '/' && s.peek(1) == '/' {
            s.bump();
            s.bump();
            let mut text = String::new();
            while !s.eof() && s.peek(0) != '\n' {
                text.push(s.bump());
            }
            out.comments.push((line, text));
            continue;
        }
        if c == '/' && s.peek(1) == '*' {
            s.bump();
            s.bump();
            let mut depth = 1usize;
            while !s.eof() && depth > 0 {
                if s.peek(0) == '/' && s.peek(1) == '*' {
                    s.bump();
                    s.bump();
                    depth += 1;
                } else if s.peek(0) == '*' && s.peek(1) == '/' {
                    s.bump();
                    s.bump();
                    depth -= 1;
                } else {
                    s.bump();
                }
            }
            continue;
        }
        // Raw strings / byte strings / raw identifiers: r"..", r#".."#,
        // b"..", br#".."#, b'..', r#ident.
        if c == 'r' || c == 'b' {
            let (prefix_len, has_b, has_r) = if c == 'b' && s.peek(1) == 'r' {
                (2, true, true)
            } else if c == 'b' {
                (1, true, false)
            } else {
                (1, false, true)
            };
            let mut j = prefix_len;
            let mut nh = 0;
            if has_r {
                while s.peek(j) == '#' {
                    j += 1;
                    nh += 1;
                }
            }
            if s.peek(j) == '"' {
                let mut text = String::new();
                for _ in 0..prefix_len {
                    text.push(s.bump());
                }
                for _ in 0..nh {
                    text.push(s.bump());
                }
                s.string_body(&mut text, nh);
                out.toks.push(Tok {
                    text,
                    kind: TokKind::Literal,
                    line,
                });
                continue;
            }
            if has_b && !has_r && s.peek(1) == '\'' {
                // Byte char literal b'x'.
                let mut text = String::new();
                text.push(s.bump());
                text.push(s.bump());
                while !s.eof() {
                    let ch = s.bump();
                    text.push(ch);
                    if ch == '\\' {
                        text.push(s.bump());
                    } else if ch == '\'' {
                        break;
                    }
                }
                out.toks.push(Tok {
                    text,
                    kind: TokKind::Literal,
                    line,
                });
                continue;
            }
            if has_r && !has_b && s.peek(1) == '#' && is_ident_start(s.peek(2)) {
                // Raw identifier r#ident.
                let mut text = String::new();
                text.push(s.bump());
                s.hashes();
                text.push('#');
                while is_ident_continue(s.peek(0)) {
                    text.push(s.bump());
                }
                out.toks.push(Tok {
                    text,
                    kind: TokKind::Ident,
                    line,
                });
                continue;
            }
            // Fall through: plain identifier starting with r/b.
        }
        if is_ident_start(c) {
            let mut text = String::new();
            while is_ident_continue(s.peek(0)) {
                text.push(s.bump());
            }
            out.toks.push(Tok {
                text,
                kind: TokKind::Ident,
                line,
            });
            continue;
        }
        if c.is_ascii_digit() {
            // Digits, underscores, and alphanumeric suffixes (0xFF, 1u32)
            // — but never `.`, so `0..n` and `1.5` split cleanly.
            let mut text = String::new();
            while is_ident_continue(s.peek(0)) {
                text.push(s.bump());
            }
            out.toks.push(Tok {
                text,
                kind: TokKind::Literal,
                line,
            });
            continue;
        }
        if c == '"' {
            let mut text = String::new();
            s.string_body(&mut text, 0);
            out.toks.push(Tok {
                text,
                kind: TokKind::Literal,
                line,
            });
            continue;
        }
        if c == '\'' {
            // Lifetime (`'a`) vs char literal (`'x'`, `'\n'`): a
            // lifetime is `'` + ident run *not* closed by another `'`.
            if is_ident_start(s.peek(1)) && s.peek(2) != '\'' {
                let mut text = String::new();
                text.push(s.bump());
                while is_ident_continue(s.peek(0)) {
                    text.push(s.bump());
                }
                out.toks.push(Tok {
                    text,
                    kind: TokKind::Literal,
                    line,
                });
                continue;
            }
            let mut text = String::new();
            text.push(s.bump());
            while !s.eof() {
                let ch = s.bump();
                text.push(ch);
                if ch == '\\' {
                    text.push(s.bump());
                } else if ch == '\'' {
                    break;
                }
            }
            out.toks.push(Tok {
                text,
                kind: TokKind::Literal,
                line,
            });
            continue;
        }
        // Everything else: one punctuation character per token.
        let mut text = String::new();
        text.push(s.bump());
        out.toks.push(Tok {
            text,
            kind: TokKind::Punct,
            line,
        });
    }
    out
}

/// Is token `i` the punctuation character `c`?
pub fn is_punct(toks: &[Tok], i: usize, c: char) -> bool {
    toks.get(i)
        .map(|t| t.kind == TokKind::Punct && t.text.chars().next() == Some(c))
        .unwrap_or(false)
}

/// The identifier text at token `i`, if it is an identifier.
pub fn ident_at(toks: &[Tok], i: usize) -> Option<&str> {
    toks.get(i).and_then(|t| {
        if t.kind == TokKind::Ident {
            Some(t.text.as_str())
        } else {
            None
        }
    })
}

/// Token-index ranges `[start, end)` covered by `#[test]` / `#[cfg(test)]`
/// items — attributes included. Rules skip findings inside these ranges,
/// which is what makes "non-`#[cfg(test)]` code" a lexical notion the
/// linter can enforce.
pub fn test_regions(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if is_punct(toks, i, '#') && is_punct(toks, i + 1, '[') {
            let attr_start = i;
            let mut any_test = false;
            while is_punct(toks, i, '#') && is_punct(toks, i + 1, '[') {
                let (idents, after) = attr_span(toks, i);
                if attr_marks_test(&idents) {
                    any_test = true;
                }
                i = after;
            }
            if any_test {
                let end = item_end(toks, i);
                regions.push((attr_start, end));
                i = end;
            }
        } else {
            i += 1;
        }
    }
    regions
}

/// From token `i` at `#` of an outer attribute, return the identifier
/// texts inside the attribute and the index just past its closing `]`.
fn attr_span(toks: &[Tok], i: usize) -> (Vec<String>, usize) {
    let mut idents = Vec::new();
    let mut j = i + 2; // past `#[`
    let mut depth = 1usize;
    while j < toks.len() && depth > 0 {
        if is_punct(toks, j, '[') {
            depth += 1;
        } else if is_punct(toks, j, ']') {
            depth -= 1;
        } else if let Some(id) = ident_at(toks, j) {
            idents.push(id.to_string());
        }
        j += 1;
    }
    (idents, j)
}

/// Does an attribute's identifier list mark a test item? `#[test]`
/// exactly, or `#[cfg(...)]` with `test` anywhere in the predicate
/// (covers `cfg(test)` and `cfg(all(test, ...))`; `cfg_attr` does not
/// count — it gates an attribute, not the item's compilation).
fn attr_marks_test(idents: &[String]) -> bool {
    match idents.first().map(String::as_str) {
        Some("test") => idents.len() == 1,
        Some("cfg") => idents.iter().skip(1).any(|s| s == "test"),
        _ => false,
    }
}

/// Index just past the end of the item starting at token `i`: the first
/// top-level `;`, or the `}` matching the first top-level `{`.
fn item_end(toks: &[Tok], i: usize) -> usize {
    let mut depth = 0usize;
    let mut j = i;
    while j < toks.len() {
        if is_punct(toks, j, '{') {
            depth += 1;
        } else if is_punct(toks, j, '}') {
            depth = depth.saturating_sub(1);
            if depth == 0 {
                return j + 1;
            }
        } else if is_punct(toks, j, ';') && depth == 0 {
            return j + 1;
        }
        j += 1;
    }
    toks.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn idents_puncts_and_numbers() {
        assert_eq!(
            texts("let x2 = a_b + 0x1F;"),
            vec!["let", "x2", "=", "a_b", "+", "0x1F", ";"]
        );
    }

    #[test]
    fn ranges_and_floats_split_on_dot() {
        assert_eq!(texts("0..n"), vec!["0", ".", ".", "n"]);
        assert_eq!(texts("1.5"), vec!["1", ".", "5"]);
    }

    #[test]
    fn strings_hide_their_contents() {
        let toks = texts(r#"f("panic! .unwrap() HashMap")"#);
        assert_eq!(toks[0], "f");
        assert_eq!(toks[2], r#""panic! .unwrap() HashMap""#);
        assert_eq!(toks.len(), 4);
    }

    #[test]
    fn raw_and_byte_strings() {
        assert_eq!(texts(r##"r#"a "quoted" b"#"##).len(), 1);
        assert_eq!(texts(r#"b"DADM""#).len(), 1);
        assert_eq!(texts("b'\\n'").len(), 1);
    }

    #[test]
    fn comments_are_captured_not_tokenized() {
        let l = lex("a // dadm-lint: allow(x) — y\nb /* panic! */ c");
        assert_eq!(l.toks.len(), 3);
        assert_eq!(l.comments.len(), 1);
        assert_eq!(l.comments[0].0, 1);
        assert!(l.comments[0].1.contains("allow(x)"));
    }

    #[test]
    fn nested_block_comments() {
        assert_eq!(texts("a /* x /* y */ z */ b"), vec!["a", "b"]);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        assert_eq!(texts("&'a str"), vec!["&", "'a", "str"]);
        assert_eq!(texts("'x'"), vec!["'x'"]);
        assert_eq!(texts("'\\n'"), vec!["'\\n'"]);
    }

    #[test]
    fn line_numbers_advance() {
        let l = lex("a\nb\n\nc");
        let lines: Vec<usize> = l.toks.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 4]);
    }

    #[test]
    fn cfg_test_region_covers_module() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests { fn a() { x.unwrap(); } }\nfn tail() {}";
        let l = lex(src);
        let regions = test_regions(&l.toks);
        assert_eq!(regions.len(), 1);
        let (s, e) = regions[0];
        // The region starts at `#` and ends after the closing `}`.
        assert_eq!(l.toks[s].text, "#");
        assert_eq!(l.toks[e].text, "fn");
        assert_eq!(l.toks[e + 1].text, "tail");
    }

    #[test]
    fn test_attribute_with_allow_chain() {
        let src = "#[test]\n#[allow(dead_code)]\nfn t() { a.unwrap(); }\nfn live() {}";
        let l = lex(src);
        let regions = test_regions(&l.toks);
        assert_eq!(regions.len(), 1);
        let (_, e) = regions[0];
        assert_eq!(l.toks[e + 1].text, "live");
    }

    #[test]
    fn cfg_attr_is_not_a_test_marker() {
        let src = "#[cfg_attr(test, allow(dead_code))]\nfn live() { x.unwrap(); }";
        let l = lex(src);
        assert!(test_regions(&l.toks).is_empty());
    }
}
