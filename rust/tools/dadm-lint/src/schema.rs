//! Wire-schema fingerprinting (DESIGN.md §12.4).
//!
//! The frame-format-bearing items of `rust/src/comm/wire.rs` — the
//! protocol constants, tag/flag constants, and the frame payload
//! structs/enums — are extracted from the token stream, normalized
//! (attributes stripped, tokens joined by single spaces, items sorted by
//! name), and hashed with FNV-1a 64. The digest and the `WIRE_VERSION`
//! it was computed at are committed as `rust/src/comm/wire.schema`; the
//! `wire-schema` rule fails whenever the digest drifts at an unchanged
//! version — i.e. someone edited a frame definition without bumping
//! `WIRE_VERSION` — or when the version changed without regenerating the
//! file. Regenerate with `cargo run -p dadm-lint -- schema --update`.
//!
//! `scripts/wire_schema_digest.py` is a line-for-line port of the
//! normalization (for toolchain-free environments); the
//! `real_tree_lints_clean` test pins the two implementations to the same
//! committed digest.

use crate::lexer::{ident_at, is_punct, lex, Tok};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Named items whose definitions are part of the wire contract.
const TRACKED_ITEMS: [&str; 14] = [
    "WIRE_MAGIC",
    "WIRE_VERSION",
    "MAX_FRAME_LEN",
    "FRAME_HEADER_BYTES",
    "WireLoss",
    "WireReg",
    "WireSolver",
    "DataSpec",
    "ProblemSpec",
    "WireBroadcast",
    "BroadcastRef",
    "EvalOp",
    "StepFlags",
    "Frame",
];

/// Const-name prefixes that are part of the wire contract (frame tags
/// and flag bits).
const TRACKED_PREFIXES: [&str; 2] = ["TAG_", "STEP_FLAG_"];

fn tracked(name: &str) -> bool {
    TRACKED_ITEMS.contains(&name) || TRACKED_PREFIXES.iter().any(|p| name.starts_with(p))
}

/// The fingerprint of a `wire.rs` source text: the `WIRE_VERSION` value
/// and the normalized-item digest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Fingerprint {
    /// `WIRE_VERSION` as written in the source.
    pub version: u16,
    /// FNV-1a 64 digest, 16 lowercase hex digits.
    pub digest: String,
}

/// FNV-1a 64-bit.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Extract `(name, normalized_tokens)` for every tracked top-level item.
fn extract_items(toks: &[Tok]) -> Vec<(String, String)> {
    let mut items = Vec::new();
    let mut depth = 0usize;
    let mut i = 0;
    while i < toks.len() {
        if is_punct(toks, i, '{') {
            depth += 1;
        } else if is_punct(toks, i, '}') {
            depth = depth.saturating_sub(1);
        } else if depth == 0 {
            if let Some(kw) = ident_at(toks, i) {
                if matches!(kw, "const" | "struct" | "enum") {
                    if let Some(name) = ident_at(toks, i + 1) {
                        if tracked(name) {
                            let end = item_span_end(toks, i, kw);
                            items.push((name.to_string(), normalize(&toks[i..end])));
                            i = end;
                            continue;
                        }
                    }
                }
            }
        }
        i += 1;
    }
    items.sort();
    items
}

/// End (exclusive) of the item starting at keyword token `i`: consts
/// and unit/tuple structs end at the first top-level `;`, brace-bodied
/// structs/enums at their closing `}`. Depth counts `[`/`(` too —
/// `const WIRE_MAGIC: [u8; 4] = ...;` has a `;` inside the array type
/// that must not end the item — and only a `}` can close a struct/enum
/// body (`const` items keep going to their `;` even after a block
/// initializer's `}`).
fn item_span_end(toks: &[Tok], i: usize, kw: &str) -> usize {
    let brace_bodied = kw != "const";
    let mut depth = 0usize;
    let mut j = i;
    while j < toks.len() {
        if is_punct(toks, j, '{') || is_punct(toks, j, '[') || is_punct(toks, j, '(') {
            depth += 1;
        } else if is_punct(toks, j, '}') {
            depth = depth.saturating_sub(1);
            if depth == 0 && brace_bodied {
                return j + 1;
            }
        } else if is_punct(toks, j, ']') || is_punct(toks, j, ')') {
            depth = depth.saturating_sub(1);
        } else if is_punct(toks, j, ';') && depth == 0 {
            return j + 1;
        }
        j += 1;
    }
    toks.len()
}

/// Join an item's tokens with single spaces, dropping `#[...]`
/// attribute sequences (derives and field attributes are not part of
/// the wire format).
fn normalize(toks: &[Tok]) -> String {
    let mut parts: Vec<&str> = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if is_punct(toks, i, '#') && is_punct(toks, i + 1, '[') {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < toks.len() && depth > 0 {
                if is_punct(toks, j, '[') {
                    depth += 1;
                } else if is_punct(toks, j, ']') {
                    depth -= 1;
                }
                j += 1;
            }
            i = j;
            continue;
        }
        parts.push(&toks[i].text);
        i += 1;
    }
    parts.join(" ")
}

/// Compute the fingerprint of a `wire.rs` source text.
pub fn fingerprint(src: &str) -> Result<Fingerprint> {
    let lexed = lex(src);
    let items = extract_items(&lexed.toks);
    let version_item = items
        .iter()
        .find(|(name, _)| name == "WIRE_VERSION")
        .context("wire.rs has no top-level WIRE_VERSION const")?;
    let version = parse_version(&version_item.1)?;
    let joined: Vec<String> = items
        .iter()
        .map(|(name, norm)| format!("{name} := {norm}"))
        .collect();
    let digest = format!("{:016x}", fnv1a64(joined.join("\n").as_bytes()));
    Ok(Fingerprint { version, digest })
}

/// Pull the numeric value out of the normalized
/// `const WIRE_VERSION : u16 = <n> ;` token string.
fn parse_version(normalized: &str) -> Result<u16> {
    let mut after_eq = false;
    for tok in normalized.split(' ') {
        if after_eq {
            return tok
                .parse::<u16>()
                .with_context(|| format!("non-numeric WIRE_VERSION value `{tok}`"));
        }
        if tok == "=" {
            after_eq = true;
        }
    }
    bail!("WIRE_VERSION const has no `=` initializer")
}

/// The committed fingerprint parsed from `wire.schema`.
fn parse_schema_file(text: &str) -> Result<Fingerprint> {
    let mut version: Option<u16> = None;
    let mut digest: Option<String> = None;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some((key, value)) = line.split_once('=') {
            let value = value.trim();
            match key.trim() {
                "version" => {
                    version = Some(value.parse::<u16>().context("bad `version` in wire.schema")?);
                }
                "digest" => digest = Some(value.to_string()),
                other => bail!("unknown wire.schema key `{other}`"),
            }
        } else {
            bail!("malformed wire.schema line `{line}`");
        }
    }
    Ok(Fingerprint {
        version: version.context("wire.schema missing `version`")?,
        digest: digest.context("wire.schema missing `digest`")?,
    })
}

fn wire_rs(root: &Path) -> std::path::PathBuf {
    root.join("rust").join("src").join("comm").join("wire.rs")
}

fn wire_schema(root: &Path) -> std::path::PathBuf {
    root.join("rust").join("src").join("comm").join("wire.schema")
}

/// Run the `wire-schema` rule over the tree at `root`. `Ok(None)` is a
/// pass; `Ok(Some(msg))` is a rule violation; `Err` is an I/O or parse
/// failure of the inputs themselves.
pub fn check(root: &Path) -> Result<Option<String>> {
    let src = std::fs::read_to_string(wire_rs(root))
        .with_context(|| format!("reading {}", wire_rs(root).display()))?;
    let current = fingerprint(&src)?;
    let schema_path = wire_schema(root);
    let committed = match std::fs::read_to_string(&schema_path) {
        Ok(text) => parse_schema_file(&text)?,
        Err(_) => {
            return Ok(Some(format!(
                "missing {}: run `cargo run -p dadm-lint -- schema --update`",
                schema_path.display()
            )))
        }
    };
    if current.version != committed.version {
        return Ok(Some(format!(
            "WIRE_VERSION is {} but wire.schema records {}: regenerate with \
             `cargo run -p dadm-lint -- schema --update`",
            current.version, committed.version
        )));
    }
    if current.digest != committed.digest {
        return Ok(Some(format!(
            "wire schema drifted without a WIRE_VERSION bump (digest {} != committed {}): \
             bump WIRE_VERSION in wire.rs and regenerate wire.schema",
            current.digest, committed.digest
        )));
    }
    Ok(None)
}

/// Regenerate `wire.schema`. Refuses to update when the digest drifted
/// at an unchanged `WIRE_VERSION` (that is exactly the mistake the rule
/// exists to catch) unless `force` is set for bootstrap or
/// cosmetic-normalization cases.
pub fn update(root: &Path, force: bool) -> Result<String> {
    let src = std::fs::read_to_string(wire_rs(root))
        .with_context(|| format!("reading {}", wire_rs(root).display()))?;
    let current = fingerprint(&src)?;
    let schema_path = wire_schema(root);
    if !force {
        if let Ok(text) = std::fs::read_to_string(&schema_path) {
            let committed = parse_schema_file(&text)?;
            if committed.version == current.version && committed.digest != current.digest {
                bail!(
                    "refusing to update: frame definitions changed but WIRE_VERSION is \
                     still {} — bump it in wire.rs first (or pass --force for a \
                     cosmetic-only normalization change)",
                    current.version
                );
            }
        }
    }
    let contents = format!(
        "# Wire-schema fingerprint for rust/src/comm/wire.rs (DESIGN.md §12.4).\n\
         # FNV-1a 64 over the normalized frame-item token streams; fails the\n\
         # `wire-schema` lint when frame definitions drift without a\n\
         # WIRE_VERSION bump. Regenerate: cargo run -p dadm-lint -- schema --update\n\
         version = {}\n\
         digest = {}\n",
        current.version, current.digest
    );
    std::fs::write(&schema_path, &contents)
        .with_context(|| format!("writing {}", schema_path.display()))?;
    Ok(current.digest)
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI_WIRE: &str = r#"
//! Mini wire module.
pub const WIRE_MAGIC: [u8; 4] = *b"DADM";
pub const WIRE_VERSION: u16 = 3;
const TAG_HELLO: u8 = 0;
const HELPER: u8 = 9; // untracked
#[derive(Clone, Debug)]
pub struct StepFlags {
    pub eval_loss: bool,
}
pub enum Frame {
    Hello { magic: [u8; 4], version: u16 },
    Ack,
}
fn le_array<const N: usize>(c: &[u8]) {}
#[cfg(test)]
mod tests {
    pub const TAG_FAKE: u8 = 99;
}
"#;

    #[test]
    fn fingerprint_is_stable_under_comments_and_whitespace() {
        let a = fingerprint(MINI_WIRE).unwrap();
        let b = fingerprint(&MINI_WIRE.replace("// untracked", "// changed comment")).unwrap();
        let spaced = MINI_WIRE.replace("pub eval_loss: bool,", "pub eval_loss:   bool,");
        let c = fingerprint(&spaced).unwrap();
        assert_eq!(a, b);
        assert_eq!(a, c);
        assert_eq!(a.version, 3);
    }

    #[test]
    fn fingerprint_changes_on_frame_edit() {
        let a = fingerprint(MINI_WIRE).unwrap();
        let edited = MINI_WIRE.replace(
            "pub eval_loss: bool,",
            "pub eval_loss: bool,\n    pub extra: u64,",
        );
        let b = fingerprint(&edited).unwrap();
        assert_ne!(a.digest, b.digest);
        let c = fingerprint(&MINI_WIRE.replace("Ack,", "Ack, Nack,")).unwrap();
        assert_ne!(a.digest, c.digest);
    }

    #[test]
    fn untracked_and_test_items_do_not_count() {
        let a = fingerprint(MINI_WIRE).unwrap();
        let helper = MINI_WIRE.replace("const HELPER: u8 = 9;", "const HELPER: u8 = 10;");
        let b = fingerprint(&helper).unwrap();
        let fake = MINI_WIRE.replace("TAG_FAKE: u8 = 99", "TAG_FAKE: u8 = 98");
        let c = fingerprint(&fake).unwrap();
        assert_eq!(a.digest, b.digest);
        assert_eq!(a.digest, c.digest);
    }

    #[test]
    fn version_bump_changes_digest_and_version() {
        let edited = MINI_WIRE.replace(
            "pub const WIRE_VERSION: u16 = 3;",
            "pub const WIRE_VERSION: u16 = 4;",
        );
        let b = fingerprint(&edited).unwrap();
        assert_eq!(b.version, 4);
        assert_ne!(b.digest, fingerprint(MINI_WIRE).unwrap().digest);
    }

    #[test]
    fn const_with_array_type_spans_to_real_semicolon() {
        // The `;` inside `[u8; 4]` must not end the WIRE_MAGIC item:
        // its *value* is part of the fingerprint.
        let a = fingerprint(MINI_WIRE).unwrap();
        let b = fingerprint(&MINI_WIRE.replace("*b\"DADM\"", "*b\"XXXX\"")).unwrap();
        assert_ne!(a.digest, b.digest);
    }

    #[test]
    fn const_generic_is_not_an_item() {
        // `fn le_array<const N: usize>` contains a depth-0 `const`
        // keyword; the name filter must ignore it.
        assert!(fingerprint(MINI_WIRE).is_ok());
    }

    #[test]
    fn schema_file_roundtrip() {
        let fp = parse_schema_file("# c\nversion = 3\ndigest = 00ff\n").unwrap();
        assert_eq!(fp.version, 3);
        assert_eq!(fp.digest, "00ff");
        assert!(parse_schema_file("version = 3").is_err()); // missing digest
        assert!(parse_schema_file("bogus line").is_err());
    }
}
