//! Tcp-vs-Serial parity over **real worker processes**.
//!
//! These tests spawn actual `dadm worker --connect …` children (the
//! binary cargo builds for this package), drive them from an in-test
//! coordinator over 127.0.0.1, and pin the distributed solve to the
//! serial one **bit for bit**: same rounds, same passes, same primal and
//! dual objectives, same modeled comm seconds. Only wall-clock-derived
//! fields (compute seconds, wall seconds) may differ between backends.

use dadm::comm::tcp::{synthetic_specs, TcpClusterBuilder, TcpHandle};
use dadm::comm::wire::{WireLoss, WireSolver};
use dadm::comm::{Cluster, CostModel};
use dadm::coordinator::{Dadm, DadmOptions, Problem, SolveReport};
use dadm::data::synthetic::SyntheticSpec;
use dadm::data::{Dataset, Partition};
use dadm::loss::SmoothHinge;
use dadm::reg::{ElasticNet, Zero};
use dadm::solver::ProxSdca;
use std::process::{Child, Command, Stdio};

const MACHINES: usize = 4;
const PART_SEED: u64 = 11;
const RNG_SEED: u64 = 0xDAD_A;
const SP: f64 = 0.2;

/// Kills any still-running children on drop so a failing assertion
/// never leaks worker processes into the CI runner.
struct WorkerFleet(Vec<Child>);

impl WorkerFleet {
    fn spawn(addr: &str, m: usize) -> Self {
        WorkerFleet(
            (0..m)
                .map(|_| {
                    Command::new(env!("CARGO_BIN_EXE_dadm"))
                        .args(["worker", "--connect", addr])
                        .stdin(Stdio::null())
                        .spawn()
                        .expect("spawning dadm worker process")
                })
                .collect(),
        )
    }

    /// Wait for every worker to exit and assert clean status.
    fn join(mut self) {
        for child in &mut self.0 {
            let status = child.wait().expect("waiting for worker");
            assert!(status.success(), "worker exited with {status}");
        }
        self.0.clear();
    }
}

impl Drop for WorkerFleet {
    fn drop(&mut self) {
        for child in &mut self.0 {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

fn problem_spec() -> SyntheticSpec {
    SyntheticSpec {
        name: "tcp-parity".into(),
        n: 320,
        d: 48,
        density: 0.25,
        signal_density: 0.4,
        noise: 0.1,
        seed: 0xBEEF,
    }
}

fn build_dadm_t(
    data: &Dataset,
    part: &Partition,
    cluster: Cluster,
    local_threads: usize,
) -> Dadm<SmoothHinge, ElasticNet, Zero, ProxSdca> {
    Problem::new(data, part)
        .loss(SmoothHinge::default())
        .reg(ElasticNet::new(0.1))
        .lambda(1e-2)
        .build_dadm(
            ProxSdca,
            DadmOptions {
                sp: SP,
                cluster,
                cost: CostModel::default(),
                seed: RNG_SEED,
                gap_every: 1,
                sparse_comm: true,
                local_threads,
                conj_resum_every: 64,
                ..Default::default()
            },
        )
}

fn build_dadm(
    data: &Dataset,
    part: &Partition,
    cluster: Cluster,
) -> Dadm<SmoothHinge, ElasticNet, Zero, ProxSdca> {
    build_dadm_t(data, part, cluster, 1)
}

/// Start a loopback coordinator + child-process fleet, assigned and
/// ready to solve with `local_threads` sub-solvers per worker process.
fn connected_fleet_t(spec: &SyntheticSpec, local_threads: usize) -> (TcpHandle, WorkerFleet) {
    let builder = TcpClusterBuilder::bind("127.0.0.1:0").expect("bind");
    let addr = builder.local_addr().expect("local addr").to_string();
    let fleet = WorkerFleet::spawn(&addr, MACHINES);
    let mut cluster = builder.accept(MACHINES).expect("accepting workers");
    cluster
        .assign(synthetic_specs(
            spec,
            MACHINES,
            PART_SEED,
            RNG_SEED,
            SP,
            WireLoss::SmoothHinge(SmoothHinge::default()),
            WireSolver::ProxSdca,
            local_threads,
        ))
        .expect("assigning partitions");
    (TcpHandle::new(cluster), fleet)
}

fn connected_fleet(spec: &SyntheticSpec) -> (TcpHandle, WorkerFleet) {
    connected_fleet_t(spec, 1)
}

fn assert_traces_bit_identical(serial: &SolveReport, tcp: &SolveReport) {
    assert_eq!(serial.converged, tcp.converged);
    assert_eq!(serial.rounds, tcp.rounds);
    assert_eq!(
        serial.trace.rounds.len(),
        tcp.trace.rounds.len(),
        "trace lengths differ"
    );
    for (s, t) in serial.trace.rounds.iter().zip(&tcp.trace.rounds) {
        assert_eq!(s.round, t.round);
        assert_eq!(
            s.passes.to_bits(),
            t.passes.to_bits(),
            "passes diverged at round {}",
            s.round
        );
        assert_eq!(
            s.primal.to_bits(),
            t.primal.to_bits(),
            "primal diverged at round {}: {} vs {}",
            s.round,
            s.primal,
            t.primal
        );
        assert_eq!(
            s.dual.to_bits(),
            t.dual.to_bits(),
            "dual diverged at round {}: {} vs {}",
            s.round,
            s.dual,
            t.dual
        );
        // Modeled comm time is deterministic (message sizes, not wall
        // clock) and must also match exactly; compute/wall are measured
        // and excluded.
        assert_eq!(
            s.comm_secs.to_bits(),
            t.comm_secs.to_bits(),
            "modeled comm diverged at round {}",
            s.round
        );
    }
    assert_eq!(serial.w, tcp.w, "final iterates differ");
}

#[test]
fn tcp_solve_matches_serial_trace_bit_for_bit() {
    let spec = problem_spec();
    let data = spec.generate();
    let part = Partition::balanced(data.n(), MACHINES, PART_SEED);

    let mut serial = build_dadm(&data, &part, Cluster::Serial);
    let serial_report = serial.solve(1e-6, 40);

    let (handle, fleet) = connected_fleet(&spec);
    let mut tcp = build_dadm(&data, &part, Cluster::Tcp(handle.clone()));
    let bytes_before = tcp.wire_bytes();
    let tcp_report = tcp.solve(1e-6, 40);
    let bytes_after = tcp.wire_bytes();

    assert_traces_bit_identical(&serial_report, &tcp_report);

    // Actual wire traffic was recorded — and it is substantial: at
    // minimum one LocalStep + one DeltaReply frame per worker per round.
    assert!(bytes_before > 0, "assignment produced no traffic");
    let min_frames = (tcp_report.rounds * MACHINES * 2) as u64;
    assert!(
        bytes_after - bytes_before >= min_frames * 5,
        "wire bytes implausibly low: {}",
        bytes_after - bytes_before
    );

    // Orderly teardown: Shutdown frames, workers exit 0.
    handle.with(|c| c.shutdown());
    drop(tcp);
    drop(handle);
    fleet.join();
}

#[test]
fn multithreaded_workers_match_serial_and_flat_trace_bit_for_bit() {
    // Real `dadm worker` child processes each running T = 2 concurrent
    // sub-shard solvers: the trace must be bit-identical to the nested
    // in-process Serial solve, and both to a flat m·T = 8-machine Serial
    // solve over the split partition (n = 320 is divisible by 8, so the
    // split partition equals the flat balanced one — DESIGN.md §10).
    let spec = problem_spec();
    let data = spec.generate();
    let part = Partition::balanced(data.n(), MACHINES, PART_SEED);

    let mut serial = build_dadm_t(&data, &part, Cluster::Serial, 2);
    let serial_report = serial.solve(1e-6, 30);

    let flat_part = Partition::balanced(data.n(), MACHINES * 2, PART_SEED);
    let mut flat = build_dadm_t(&data, &flat_part, Cluster::Serial, 1);
    let flat_report = flat.solve(1e-6, 30);
    // Flat comm accounting differs (8 wire participants vs 4), so
    // compare the math fields + iterate, not comm seconds.
    assert_eq!(serial_report.rounds, flat_report.rounds);
    assert_eq!(serial_report.primal.to_bits(), flat_report.primal.to_bits());
    assert_eq!(serial_report.dual.to_bits(), flat_report.dual.to_bits());
    assert_eq!(serial_report.w, flat_report.w, "nested vs flat iterates differ");

    let (handle, fleet) = connected_fleet_t(&spec, 2);
    let mut tcp = build_dadm_t(&data, &part, Cluster::Tcp(handle.clone()), 2);
    let tcp_report = tcp.solve(1e-6, 30);
    assert_traces_bit_identical(&serial_report, &tcp_report);

    handle.with(|c| c.shutdown());
    drop(tcp);
    drop(handle);
    fleet.join();
}

#[test]
fn wire_bytes_grow_round_by_round_and_track_messages() {
    let spec = problem_spec();
    let data = spec.generate();
    let part = Partition::balanced(data.n(), MACHINES, PART_SEED);

    let (handle, fleet) = connected_fleet(&spec);
    let mut tcp = build_dadm(&data, &part, Cluster::Tcp(handle.clone()));
    tcp.resync();
    let mut last = tcp.wire_bytes();
    assert!(last > 0, "resync moved no bytes");
    for round in 0..5 {
        tcp.round();
        let now = tcp.wire_bytes();
        // Every round must move at least the per-worker frame headers in
        // both directions (request + reply).
        assert!(
            now >= last + (MACHINES as u64) * 2 * 5,
            "round {round} moved too few bytes: {last} -> {now}"
        );
        last = now;
    }
    let stats = handle.stats();
    assert_eq!(stats.frames_sent, stats.frames_received, "unbalanced round trips");

    handle.with(|c| c.shutdown());
    drop(tcp);
    drop(handle);
    fleet.join();
}
