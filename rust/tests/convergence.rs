//! Integration: end-to-end convergence properties of the full stack on
//! problems with independently-known answers.

use dadm::comm::{Cluster, CostModel};
use dadm::coordinator::{AccDadm, AccDadmOptions, Dadm, DadmOptions, Problem};
use dadm::data::synthetic::{tiny_classification, tiny_regression};
use dadm::data::{Dataset, Partition};
use dadm::loss::{Logistic, Loss, SmoothHinge, Squared};
use dadm::reg::{ElasticNet, ExtraReg, GroupLasso, Regularizer, Zero};
use dadm::solver::{LocalSolver, ProxSdca};
use dadm::utils::math::soft_threshold;

fn opts(sp: f64) -> DadmOptions {
    DadmOptions {
        sp,
        cost: CostModel::free(),
        cluster: Cluster::Serial,
        ..Default::default()
    }
}

/// Positional convenience over the [`Problem`] builder — the only
/// construction path — for this file's repetitive setups.
#[allow(clippy::too_many_arguments)]
fn build_dadm<L, R, H, S>(
    data: &Dataset,
    part: &Partition,
    loss: L,
    reg: R,
    h: H,
    lambda: f64,
    solver: S,
    opts: DadmOptions,
) -> Dadm<L, R, H, S>
where
    L: Loss,
    R: Regularizer,
    H: ExtraReg,
    S: LocalSolver,
{
    Problem::new(data, part)
        .loss(loss)
        .reg(reg)
        .extra_reg(h)
        .lambda(lambda)
        .build_dadm(solver, opts)
}

#[allow(clippy::too_many_arguments)]
fn build_acc<L, H, S>(
    data: &Dataset,
    part: &Partition,
    loss: L,
    h: H,
    lambda: f64,
    mu: f64,
    solver: S,
    opts: AccDadmOptions,
) -> AccDadm<L, H, S>
where
    L: Loss,
    H: ExtraReg,
    S: LocalSolver,
{
    Problem::new(data, part)
        .loss(loss)
        .extra_reg(h)
        .lambda(lambda)
        .l1(mu)
        .build_acc_dadm(solver, opts)
}

/// Lasso-style problem with orthogonal-ish design: the optimal w of
/// `min Σ(x_iᵀw − y_i)² + (λn/2)‖w‖² + μn‖w‖₁` must satisfy the
/// first-order condition `2Xᵀ(Xw − y) + λn·w + μn·∂‖w‖₁ ∋ 0`.
#[test]
fn elastic_net_regression_kkt() {
    let data = tiny_regression(120, 6, 0.02, 41);
    let part = Partition::balanced(120, 3, 41);
    let (lambda, mu) = (0.02, 0.01);
    let mut dadm = build_dadm(
        &data,
        &part,
        Squared,
        ElasticNet::new(mu / lambda),
        Zero,
        lambda,
        ProxSdca,
        opts(1.0),
    );
    let r = dadm.solve(1e-11, 3000);
    assert!(r.converged, "gap {}", r.normalized_gap());
    let n = data.n() as f64;
    let resid: Vec<f64> = data
        .x
        .matvec(&r.w)
        .iter()
        .zip(&data.y)
        .map(|(p, y)| p - y)
        .collect();
    let grad_smooth = data.x.matvec_t(&resid);
    for j in 0..data.dim() {
        let g = 2.0 * grad_smooth[j] + lambda * n * r.w[j];
        if r.w[j] != 0.0 {
            let kkt = g + mu * n * r.w[j].signum();
            assert!(kkt.abs() < 2e-2 * n, "KKT violated at {j}: {kkt}");
        } else {
            assert!(g.abs() <= mu * n * (1.0 + 1e-2), "|∂| bound violated at {j}: {g}");
        }
    }
}

/// m = 1 DADM with sp = 1/n_ℓ is plain sequential ProxSDCA — it must
/// converge on logistic regression to the same optimum as full-batch.
#[test]
fn single_machine_reduces_to_sdca() {
    let data = tiny_classification(150, 5, 42);
    let part1 = Partition::balanced(150, 1, 42);
    let mut sdca = build_dadm(
        &data,
        &part1,
        Logistic,
        ElasticNet::new(0.01),
        Zero,
        1e-2,
        ProxSdca,
        opts(1.0),
    );
    let r1 = sdca.solve(1e-8, 2000);
    assert!(r1.converged);

    let part4 = Partition::balanced(150, 4, 42);
    let mut multi = build_dadm(
        &data,
        &part4,
        Logistic,
        ElasticNet::new(0.01),
        Zero,
        1e-2,
        ProxSdca,
        opts(1.0),
    );
    let r4 = multi.solve(1e-8, 2000);
    assert!(r4.converged);
    // Same optimum regardless of the machine count.
    for (a, b) in r1.w.iter().zip(&r4.w) {
        assert!((a - b).abs() < 1e-3, "m=1 vs m=4 optima differ: {a} vs {b}");
    }
}

/// The sparse-group-lasso split (§6): solving with the group norm in `h`
/// must satisfy the combined KKT conditions at the optimum.
#[test]
fn group_lasso_solve_is_group_sparse() {
    // Ground truth supported on the first two of four groups; the noise
    // groups must be zeroed by a moderate group weight.
    use dadm::data::SparseMatrix;
    use dadm::utils::Rng;
    let d = 12;
    let n = 200;
    let mut rng = Rng::new(43);
    let w_star: Vec<f64> = (0..d)
        .map(|j| if j < 6 { 1.0 + 0.2 * rng.normal() } else { 0.0 })
        .collect();
    let mut rows = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let x: Vec<f64> = (0..d).map(|_| rng.normal() / (d as f64).sqrt()).collect();
        y.push(
            x.iter().zip(&w_star).map(|(a, b)| a * b).sum::<f64>() + 0.02 * rng.normal(),
        );
        rows.push(x);
    }
    let data = Dataset {
        x: SparseMatrix::from_dense(&rows),
        y,
        name: "group-sparse".into(),
    };
    let part = Partition::balanced(200, 2, 43);
    let lambda = 0.05;
    let h = GroupLasso::contiguous(d, 3, 2.0);
    let mut dadm = build_dadm(
        &data,
        &part,
        Squared,
        ElasticNet::new(0.01),
        h,
        lambda,
        ProxSdca,
        opts(1.0),
    );
    let r = dadm.solve(1e-10, 4000);
    assert!(r.converged, "gap {}", r.normalized_gap());
    // With a strong group weight at least one full group must be zeroed,
    // while the fit remains sane (some groups survive).
    let groups: Vec<bool> = (0..d / 3)
        .map(|g| r.w[g * 3..(g + 1) * 3].iter().any(|&x| x != 0.0))
        .collect();
    assert!(groups.iter().any(|&b| !b), "no group zeroed: {groups:?}");
    assert!(groups.iter().any(|&b| b), "all groups zeroed");
}

/// Acc-DADM and DADM must agree on the optimum (not just both converge).
#[test]
fn acc_and_plain_reach_same_optimum() {
    let data = tiny_classification(200, 6, 44);
    let part = Partition::balanced(200, 4, 44);
    let (lambda, mu) = (1e-3, 1e-4);
    let mut plain = build_dadm(
        &data,
        &part,
        SmoothHinge::default(),
        ElasticNet::new(mu / lambda),
        Zero,
        lambda,
        ProxSdca,
        opts(1.0),
    );
    let r_plain = plain.solve(1e-8, 3000);
    let mut acc = build_acc(
        &data,
        &part,
        SmoothHinge::default(),
        Zero,
        lambda,
        mu,
        ProxSdca,
        AccDadmOptions {
            dadm: opts(1.0),
            ..Default::default()
        },
    );
    let r_acc = acc.solve(1e-8, 3000);
    assert!(r_plain.converged && r_acc.converged);
    for (a, b) in r_plain.w.iter().zip(&r_acc.w) {
        assert!((a - b).abs() < 1e-3, "optima differ: {a} vs {b}");
    }
}

/// The final predictor respects the L1 geometry: w = soft_threshold of
/// the dual combination (the Prop-4 structure).
#[test]
fn solution_has_soft_threshold_structure() {
    let data = tiny_classification(120, 8, 45);
    let part = Partition::balanced(120, 3, 45);
    let (lambda, mu) = (1e-3, 5e-4);
    let tau = mu / lambda;
    let mut dadm = build_dadm(
        &data,
        &part,
        SmoothHinge::default(),
        ElasticNet::new(tau),
        Zero,
        lambda,
        ProxSdca,
        opts(0.5),
    );
    let r = dadm.solve(1e-7, 3000);
    assert!(r.converged);
    let st = soft_threshold(dadm.v(), tau);
    for (a, b) in r.w.iter().zip(&st) {
        assert!((a - b).abs() < 1e-12, "w != soft_threshold(v): {a} vs {b}");
    }
}

/// Mini-batch sp < 1 converges to the same answer as sp = 1.
#[test]
fn minibatch_and_fullbatch_same_optimum() {
    let data = tiny_classification(160, 5, 46);
    let part = Partition::balanced(160, 4, 46);
    let solve = |sp: f64| {
        let mut dadm = build_dadm(
            &data,
            &part,
            Logistic,
            ElasticNet::new(0.0),
            Zero,
            1e-2,
            ProxSdca,
            opts(sp),
        );
        dadm.solve(1e-9, 5000)
    };
    let full = solve(1.0);
    let mini = solve(0.1);
    assert!(full.converged && mini.converged);
    for (a, b) in full.w.iter().zip(&mini.w) {
        assert!((a - b).abs() < 1e-3);
    }
}
