//! Integration: the sparse Δv/Δṽ pipeline (DESIGN.md §7) against its
//! dense reference, and the persistent worker-pool backend against
//! serial execution.
//!
//! * The sparse-aware tree allreduce must reproduce the dense tree
//!   reduction within fp tolerance for any mix of message forms, machine
//!   counts, and densities.
//! * A full DADM solve is backend- and message-form-invariant: the pool
//!   backend (`Cluster::Threads`) must match `Cluster::Serial` exactly,
//!   and the `sparse_comm` cost accounting must never change iterates.

use dadm::comm::allreduce::tree_allreduce;
use dadm::comm::sparse::{tree_allreduce_delta, Delta, SparseDelta};
use dadm::comm::{Cluster, CostModel};
use dadm::coordinator::{Dadm, DadmOptions, Problem};
use dadm::data::synthetic::SyntheticSpec;
use dadm::data::{Dataset, Partition};
use dadm::loss::SmoothHinge;
use dadm::reg::{ElasticNet, Zero};
use dadm::solver::ProxSdca;
use dadm::testing::prop::for_each_case;

#[test]
fn prop_sparse_allreduce_matches_dense() {
    for_each_case(0xA11D, 80, |g| {
        let m = g.usize_in(1, 24);
        let d = g.usize_in(1, 80);
        let density = g.f64_in(0.0, 1.0);
        let dense: Vec<Vec<f64>> = (0..m)
            .map(|_| {
                (0..d)
                    .map(|_| {
                        if g.bool(density) {
                            g.f64_in(-10.0, 10.0)
                        } else {
                            0.0
                        }
                    })
                    .collect()
            })
            .collect();
        let weights = g.vec_f64(m, 0.0, 1.0);
        let want = tree_allreduce(&dense, &weights);
        // Random mix of message forms per machine, as the real pipeline
        // produces (dense epochs next to sparse mini-batches).
        let messages: Vec<Delta> = dense
            .iter()
            .map(|v| {
                if g.bool(0.5) {
                    Delta::Dense(v.clone())
                } else {
                    Delta::Sparse(SparseDelta::from_dense(v))
                }
            })
            .collect();
        let (total, max_elems) = tree_allreduce_delta(messages, &weights);
        // The reported largest tree message is at least every leaf's size
        // and never exceeds the dense vector.
        assert!(max_elems <= d.max(1));
        let got = total.into_dense();
        assert_eq!(got.len(), d);
        for j in 0..d {
            assert!(
                (got[j] - want[j]).abs() < 1e-9,
                "coordinate {j}: sparse tree {} vs dense tree {}",
                got[j],
                want[j]
            );
        }
    });
}

fn rcv1ish(n: usize, d: usize, seed: u64) -> Dataset {
    SyntheticSpec {
        name: "sparse-pipeline".into(),
        n,
        d,
        density: 0.02,
        signal_density: 0.1,
        noise: 0.05,
        seed,
    }
    .generate()
}

fn build(
    data: &Dataset,
    part: &Partition,
    cluster: Cluster,
    sp: f64,
) -> Dadm<SmoothHinge, ElasticNet, Zero, ProxSdca> {
    Problem::new(data, part)
        .loss(SmoothHinge::default())
        .reg(ElasticNet::new(0.1))
        .lambda(1e-3)
        .build_dadm(
            ProxSdca,
            DadmOptions {
                sp,
                cluster,
                cost: CostModel::free(),
                ..Default::default()
            },
        )
}

#[test]
fn pool_backend_matches_serial_solve() {
    // Mini-batch regime on sparse data: every round exchanges sparse
    // Δv/Δṽ messages, and the pool backend must reproduce the serial
    // backend bit for bit (identical mini-batch draws, identical
    // machine-ordered reduction).
    let data = rcv1ish(400, 512, 31);
    let part = Partition::balanced(400, 4, 31);
    let mut serial = build(&data, &part, Cluster::Serial, 0.1);
    let mut pooled = build(&data, &part, Cluster::Threads, 0.1);
    serial.resync();
    pooled.resync();
    for _ in 0..12 {
        serial.round();
        pooled.round();
    }
    for (a, b) in serial.w().iter().zip(pooled.w()) {
        assert!((a - b).abs() < 1e-12, "backends diverge: {a} vs {b}");
    }
    assert!((serial.gap() - pooled.gap()).abs() < 1e-9);
    serial.check_v_invariant().unwrap();
    pooled.check_v_invariant().unwrap();
}

#[test]
fn pool_backend_full_solve_converges() {
    let data = rcv1ish(300, 256, 32);
    let part = Partition::balanced(300, 3, 32);
    let mut dadm = build(&data, &part, Cluster::Threads, 1.0);
    let report = dadm.solve(1e-5, 400);
    assert!(report.converged, "gap = {}", report.normalized_gap());
    dadm.check_v_invariant().unwrap();
}

#[test]
fn prop_v_invariant_holds_under_sparse_aggregation() {
    // The coordinator's v is built exclusively from sparse-aware tree
    // reductions of worker messages; it must always equal the full
    // recompute Σ_ℓ X_ℓᵀ α_ℓ / (λn) regardless of sp, m, and data shape.
    for_each_case(0x51AB, 6, |g| {
        let n = g.usize_in(80, 200);
        let m = g.usize_in(1, 5);
        let d = g.usize_in(32, 256);
        let data = rcv1ish(n, d, g.rng().next_u64());
        let part = Partition::balanced(n, m, 3);
        let sp = *g.choose(&[0.05, 0.3, 1.0]);
        let mut dadm = build(&data, &part, Cluster::Serial, sp);
        dadm.resync();
        for _ in 0..5 {
            dadm.round();
        }
        dadm.check_v_invariant().unwrap();
        assert!(dadm.gap() >= -1e-8);
    });
}

#[test]
fn sparse_comm_accounting_reflects_message_sizes() {
    // On a sparse workload the charged comm time must drop when the cost
    // model charges actual message sizes, while the iterates stay
    // bit-identical (the flag never touches the data path).
    let data = rcv1ish(400, 1024, 33);
    let part = Partition::balanced(400, 4, 33);
    let run = |sparse_comm: bool| {
        let mut dadm = Problem::new(&data, &part)
            .loss(SmoothHinge::default())
            .reg(ElasticNet::new(0.1))
            .lambda(1e-3)
            .build_dadm(
                ProxSdca,
                DadmOptions {
                    sp: 0.05,
                    sparse_comm,
                    ..DadmOptions::default() // default (non-free) cost model
                },
            );
        dadm.resync();
        for _ in 0..6 {
            dadm.round();
        }
        (dadm.w().to_vec(), dadm.modeled_secs().1)
    };
    let (w_dense, t_dense) = run(false);
    let (w_sparse, t_sparse) = run(true);
    assert_eq!(w_dense, w_sparse, "cost accounting must not change math");
    assert!(
        t_sparse < t_dense,
        "sparse messages not cheaper: {t_sparse} vs {t_dense}"
    );
}
