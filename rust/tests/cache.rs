//! Out-of-core cache integration (DESIGN.md §15): a solve served from
//! the mmap-backed binary CSR cache must be **bit-identical** to the
//! same solve over the text-parsed dataset — on Serial, Threads, and
//! the TCP loopback backend where workers mmap their own contiguous
//! shard row ranges (`DataSpec::Cache`) instead of receiving rows over
//! the wire. Both paths read the same LIBSVM text exactly once, so any
//! divergence is a cache-layer bug, not a parsing tolerance.

use dadm::comm::tcp::{cache_specs, serve, TcpClusterBuilder, TcpHandle};
use dadm::comm::wire::{WireLoss, WireSolver};
use dadm::comm::{Cluster, CostModel};
use dadm::coordinator::{Dadm, DadmOptions, Problem};
use dadm::data::synthetic::tiny_classification;
use dadm::data::{cache, libsvm, Balance, CsrCache, Dataset, Partition};
use dadm::loss::SmoothHinge;
use dadm::reg::{ElasticNet, Zero};
use dadm::solver::ProxSdca;
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::thread::JoinHandle;

const MACHINES: usize = 4;
const RNG_SEED: u64 = 0xDAD_A;
const SP: f64 = 0.25;

static UNIQ: AtomicU64 = AtomicU64::new(0);

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "dadm_cache_it_{tag}_{}_{}",
        std::process::id(),
        UNIQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Removes the fixture files on drop so failing assertions don't litter
/// the runner's temp dir.
struct Fixture {
    text: PathBuf,
    bin: PathBuf,
}

impl Fixture {
    /// Write `data` as LIBSVM text and compile it into a binary cache.
    fn build(tag: &str, data: &Dataset) -> Fixture {
        let text = tmp(&format!("{tag}_txt"));
        let mut buf = Vec::new();
        libsvm::write(data, &mut buf).expect("serialize libsvm");
        std::fs::write(&text, &buf).expect("write text fixture");
        let bin = tmp(&format!("{tag}_bin"));
        cache::compile(&text, &bin).expect("compile cache");
        Fixture { text, bin }
    }

    fn open(&self) -> CsrCache {
        CsrCache::open(&self.bin).expect("open cache")
    }
}

impl Drop for Fixture {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.text);
        let _ = std::fs::remove_file(&self.bin);
    }
}

fn build_dadm(
    data: &Dataset,
    part: &Partition,
    cluster: Cluster,
) -> Dadm<SmoothHinge, ElasticNet, Zero, ProxSdca> {
    Problem::new(data, part)
        .loss(SmoothHinge::default())
        .reg(ElasticNet::new(0.1))
        .lambda(1e-2)
        .build_dadm(
            ProxSdca,
            DadmOptions {
                sp: SP,
                cluster,
                cost: CostModel::default(),
                seed: RNG_SEED,
                gap_every: 1,
                sparse_comm: true,
                ..Default::default()
            },
        )
}

/// The deterministic math fields of a trace (wall-clock-derived fields
/// are excluded from bit-equality claims).
fn math_fields(report: &dadm::SolveReport) -> Vec<(usize, u64, u64, u64)> {
    report
        .trace
        .rounds
        .iter()
        .map(|r| {
            (
                r.round,
                r.passes.to_bits(),
                r.primal.to_bits(),
                r.dual.to_bits(),
            )
        })
        .collect()
}

/// Spawn `m` in-process loopback workers (the thread-hosted twin of
/// real `dadm worker` processes; the child-process cache variant lives
/// in `rust/tests/chaos.rs`).
fn loopback(m: usize) -> (TcpHandle, Vec<JoinHandle<()>>) {
    let builder = TcpClusterBuilder::bind("127.0.0.1:0").unwrap();
    let addr = builder.local_addr().unwrap();
    let threads: Vec<_> = (0..m)
        .map(|_| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).expect("worker connect");
                serve(stream).expect("worker serve");
            })
        })
        .collect();
    let cluster = builder.accept(m).unwrap();
    (TcpHandle::new(cluster), threads)
}

fn join_workers(handle: TcpHandle, threads: Vec<JoinHandle<()>>) {
    handle.with(|c| c.shutdown());
    drop(handle);
    for t in threads {
        t.join().expect("worker thread panicked");
    }
}

#[test]
fn cache_dataset_equals_text_parse_exactly() {
    let data = tiny_classification(180, 12, 0xCAC4E);
    let fx = Fixture::build("roundtrip", &data);
    let text = libsvm::load(&fx.text).expect("parse text");
    let mapped = fx.open().dataset().expect("decode cache");
    assert_eq!(text.n(), mapped.n());
    assert_eq!(text.dim(), mapped.dim());
    for i in 0..text.n() {
        assert_eq!(text.y[i].to_bits(), mapped.y[i].to_bits(), "label {i}");
        let (a, b) = (text.x.row(i), mapped.x.row(i));
        assert_eq!(a.indices, b.indices, "row {i} indices");
        for (x, y) in a.values.iter().zip(b.values) {
            assert_eq!(x.to_bits(), y.to_bits(), "row {i} values");
        }
    }
}

#[test]
fn cache_solve_matches_text_solve_on_serial_and_threads() {
    let data = tiny_classification(240, 10, 0xCAC4E + 1);
    let fx = Fixture::build("inproc", &data);
    let text = libsvm::load(&fx.text).expect("parse text");
    let mapped = fx.open().dataset().expect("decode cache");
    let part = Partition::contiguous(text.n(), MACHINES);
    for cluster in [Cluster::Serial, Cluster::Threads] {
        let text_report = build_dadm(&text, &part, cluster.clone()).solve(1e-6, 30);
        let cache_report = build_dadm(&mapped, &part, cluster.clone()).solve(1e-6, 30);
        assert_eq!(text_report.converged, cache_report.converged);
        assert_eq!(
            math_fields(&text_report),
            math_fields(&cache_report),
            "trace diverged on {cluster:?}"
        );
        assert_eq!(
            text_report.w, cache_report.w,
            "iterates diverged on {cluster:?}"
        );
    }
}

#[test]
fn cache_solve_over_tcp_matches_text_serial_bit_for_bit() {
    // The acceptance pin: workers mmap their own shard ranges from the
    // cache file (zero rows on the wire) and the trajectory must match
    // the in-process text-parsed Serial solve bit for bit, round by
    // round — w, v, and gap.
    let data = tiny_classification(200, 8, 0xCAC4E + 2);
    let fx = Fixture::build("tcp", &data);
    let text = libsvm::load(&fx.text).expect("parse text");
    let cache = fx.open();
    let part = Partition::contiguous(text.n(), MACHINES);

    let (handle, threads) = loopback(MACHINES);
    handle
        .with(|c| {
            c.assign(cache_specs(
                &cache,
                fx.bin.to_str().expect("utf-8 temp path"),
                MACHINES,
                RNG_SEED,
                SP,
                WireLoss::SmoothHinge(SmoothHinge::default()),
                WireSolver::ProxSdca,
                1,
                Balance::Rows,
            ))
        })
        .unwrap();
    let mut serial = build_dadm(&text, &part, Cluster::Serial);
    let mut tcp = build_dadm(&text, &part, Cluster::Tcp(handle.clone()));
    serial.resync();
    tcp.resync();
    for round in 0..8 {
        serial.round();
        tcp.round();
        assert_eq!(serial.w(), tcp.w(), "w diverged at round {round}");
        assert_eq!(serial.v(), tcp.v(), "v diverged at round {round}");
        assert_eq!(
            serial.gap().to_bits(),
            tcp.gap().to_bits(),
            "gap diverged at round {round}"
        );
    }
    drop(tcp);
    join_workers(handle, threads);
}
