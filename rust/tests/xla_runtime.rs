//! Integration: the AOT JAX/Pallas artifacts executed through PJRT must
//! agree with the native Rust Theorem-6 implementation.
//!
//! These tests exercise the full L1→L2→runtime→L3 chain and skip with a
//! notice when `artifacts/` has not been built (`make artifacts`).

use dadm::comm::CostModel;
use dadm::coordinator::{DadmOptions, Problem};
use dadm::data::synthetic::SyntheticSpec;
use dadm::data::Partition;
use dadm::loss::{Hinge, Logistic, Loss, SmoothHinge, Squared};
use dadm::reg::ElasticNet;
use dadm::runtime::{ArtifactSpec, XlaLocalStep, XlaRuntime};
use dadm::solver::{LocalSolver, TheoremStep, WorkerState};
use dadm::utils::Rng;

fn artifacts_available() -> bool {
    match XlaRuntime::cpu() {
        Ok(rt) => rt.available(&ArtifactSpec {
            loss: "smooth_hinge".into(),
            batch: 8,
            dim: 16,
        }),
        Err(_) => false,
    }
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

fn setup(n: usize, d: usize, seed: u64) -> WorkerState {
    let data = SyntheticSpec {
        name: "xla-test".into(),
        n,
        d,
        density: 0.3,
        signal_density: 0.5,
        noise: 0.1,
        seed,
    }
    .generate();
    let part = Partition::balanced(n, 1, seed);
    WorkerState::from_partition(&data, &part, 0)
}

fn check_against_native<L: Loss + Clone>(loss: L, batch_rows: usize, dim: usize) {
    let mut native_ws = setup(64, dim, 9);
    let mut xla_ws = native_ws.clone();
    // Put some state into play: nonzero w via a synced v_tilde.
    let reg = ElasticNet::new(0.05);
    let mut seed = Rng::new(3);
    let v: Vec<f64> = (0..dim).map(|_| seed.normal() * 0.2).collect();
    native_ws.set_v_tilde(&v, &reg);
    xla_ws.set_v_tilde(&v, &reg);

    let lambda_n_l = 0.01 * native_ws.n_l() as f64;
    let batch: Vec<usize> = (0..native_ws.n_l()).step_by(2).collect();
    let mut rng_a = Rng::new(1);
    let mut rng_b = Rng::new(1);

    let native = TheoremStep { radius: 1.0 };
    let dv_native = native
        .local_step(&mut native_ws, &batch, &loss, &reg, lambda_n_l, &mut rng_a)
        .into_dense();

    let xla = XlaLocalStep::new(loss.name(), batch_rows, dim, 1.0).expect("artifact load");
    let dv_xla = xla
        .local_step(&mut xla_ws, &batch, &loss, &reg, lambda_n_l, &mut rng_b)
        .into_dense();

    for (i, (a, b)) in native_ws.alpha.iter().zip(&xla_ws.alpha).enumerate() {
        assert!(
            (a - b).abs() < 1e-4,
            "{}: alpha[{i}] native {a} vs xla {b}",
            loss.name()
        );
    }
    for (j, (a, b)) in dv_native.iter().zip(&dv_xla).enumerate() {
        assert!(
            (a - b).abs() < 1e-4 * (1.0 + a.abs()),
            "{}: dv[{j}] native {a} vs xla {b}",
            loss.name()
        );
    }
}

#[test]
fn xla_matches_native_smooth_hinge() {
    require_artifacts!();
    check_against_native(SmoothHinge::default(), 8, 16);
}

#[test]
fn xla_matches_native_logistic() {
    require_artifacts!();
    check_against_native(Logistic, 8, 16);
}

#[test]
fn xla_matches_native_hinge() {
    require_artifacts!();
    check_against_native(Hinge, 8, 16);
}

#[test]
fn xla_matches_native_squared() {
    require_artifacts!();
    check_against_native(Squared, 8, 16);
}

#[test]
fn xla_production_shape_matches_native() {
    require_artifacts!();
    check_against_native(SmoothHinge::default(), 128, 256);
}

#[test]
fn chunking_handles_odd_batches() {
    require_artifacts!();
    // Batch of 13 through an M=8 artifact: 2 chunks with padding.
    let loss = SmoothHinge::default();
    let reg = ElasticNet::new(0.0);
    let mut a = setup(40, 16, 11);
    let mut b = a.clone();
    let batch: Vec<usize> = (0..13).collect();
    let mut r1 = Rng::new(2);
    let mut r2 = Rng::new(2);
    let native = TheoremStep { radius: 1.0 };
    // Native semantics use the FULL batch size in s; the chunked XLA path
    // passes the full batch length too, so both see identical s.
    let dv_n = native
        .local_step(&mut a, &batch, &loss, &reg, 0.4, &mut r1)
        .into_dense();
    let xla = XlaLocalStep::new(loss.name(), 8, 16, 1.0).unwrap();
    let dv_x = xla
        .local_step(&mut b, &batch, &loss, &reg, 0.4, &mut r2)
        .into_dense();
    for (x, y) in dv_n.iter().zip(&dv_x) {
        assert!((x - y).abs() < 1e-4);
    }
    for (x, y) in a.alpha.iter().zip(&b.alpha) {
        assert!((x - y).abs() < 1e-4);
    }
}

#[test]
fn full_dadm_solve_through_pjrt() {
    require_artifacts!();
    // End-to-end: a distributed DADM solve whose every local step runs
    // through the AOT artifact.
    let data = SyntheticSpec {
        name: "xla-e2e".into(),
        n: 512,
        d: 16,
        density: 0.5,
        signal_density: 0.5,
        noise: 0.05,
        seed: 21,
    }
    .generate();
    let part = Partition::balanced(data.n(), 4, 21);
    let loss = SmoothHinge::default();
    let step = XlaLocalStep::new(loss.name(), 8, 16, data.max_row_norm_sq()).unwrap();
    let mut dadm = Problem::new(&data, &part)
        .loss(loss)
        .reg(ElasticNet::new(0.1))
        .lambda(1e-2)
        .build_dadm(
            step,
            DadmOptions {
                sp: 8.0 / 128.0, // M_ℓ = artifact batch
                cost: CostModel::free(),
                ..Default::default()
            },
        );
    let report = dadm.solve(1e-4, 2000);
    assert!(
        report.converged,
        "PJRT-backed DADM failed to converge: gap {}",
        report.normalized_gap()
    );
}
