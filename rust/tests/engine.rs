//! Engine-level integration tests: Driver-vs-legacy-loop parity,
//! bit-exact checkpoint resumption through the engine's snapshot hook,
//! and concurrent pool-backed solves time-sharing the global workers.

use dadm::comm::{Cluster, CostModel};
use dadm::coordinator::Checkpoint;
use dadm::data::synthetic::tiny_classification;
use dadm::data::{Dataset, Partition};
use dadm::loss::SmoothHinge;
use dadm::reg::{ElasticNet, Zero};
use dadm::solver::ProxSdca;
use dadm::{Dadm, DadmOptions, Driver, Problem};

type TestDadm = Dadm<SmoothHinge, ElasticNet, Zero, ProxSdca>;

fn build(
    data: &Dataset,
    part: &Partition,
    cluster: Cluster,
    sp: f64,
    gap_every: usize,
) -> TestDadm {
    Problem::new(data, part)
        .loss(SmoothHinge::default())
        .reg(ElasticNet::new(0.1))
        .lambda(1e-3)
        .build_dadm(
            ProxSdca,
            DadmOptions {
                sp,
                cluster,
                cost: CostModel::free(),
                gap_every,
                ..Default::default()
            },
        )
}

/// The math fields of a trace record (cumulative modeled/wall seconds
/// are measured, not derived, so bit-equality claims exclude them).
fn math_fields(report: &dadm::SolveReport) -> Vec<(usize, f64, f64, f64)> {
    report
        .trace
        .rounds
        .iter()
        .map(|r| (r.round, r.passes, r.primal, r.dual))
        .collect()
}

/// Verbatim replica of the pre-engine `Dadm::solve` loop, written
/// against the public API: the engine-driven solve must reproduce its
/// records and final iterate bit for bit.
fn legacy_dadm_solve(
    dadm: &mut TestDadm,
    eps: f64,
    max_rounds: usize,
    gap_every: usize,
) -> (Vec<(usize, f64, f64, f64)>, Vec<f64>, bool) {
    let n = dadm.n() as f64;
    let mut records = Vec::new();
    dadm.resync();
    let record = |d: &mut TestDadm, records: &mut Vec<(usize, f64, f64, f64)>| {
        let primal = d.primal();
        let dual = d.dual();
        records.push((d.rounds(), d.passes(), primal, dual));
        primal - dual
    };
    let mut gap = record(dadm, &mut records);
    let mut converged = gap / n <= eps;
    let mut rounds_done = 0usize;
    while !converged && rounds_done < max_rounds {
        dadm.round();
        rounds_done += 1;
        if rounds_done % gap_every == 0 || rounds_done == max_rounds {
            gap = record(dadm, &mut records);
            converged = gap / n <= eps;
        }
    }
    (records, dadm.w().to_vec(), converged)
}

#[test]
fn driver_matches_legacy_dadm_loop_bit_for_bit() {
    let data = tiny_classification(260, 7, 91);
    let part = Partition::balanced(260, 4, 91);
    // A converging run and a capped run, at an off-cadence gap_every.
    for (eps, max_rounds) in [(1e-5, 500usize), (1e-14, 17)] {
        let gap_every = 3;
        let mut engine = build(&data, &part, Cluster::Serial, 0.3, gap_every);
        let report = engine.solve(eps, max_rounds);
        let mut legacy = build(&data, &part, Cluster::Serial, 0.3, gap_every);
        let (want_records, want_w, want_converged) =
            legacy_dadm_solve(&mut legacy, eps, max_rounds, gap_every);
        assert_eq!(report.converged, want_converged);
        // Record values are bit-identical to the eager three-barrier
        // loop in both cases — the fused protocol changes *when* a
        // record's sums are gathered (piggybacked on the next round's
        // leg, DESIGN.md §11), never what they are.
        assert_eq!(math_fields(&report), want_records);
        if want_converged {
            // Lagged stopping: the record for round T completes during
            // round T+1, so the engine ran exactly one more plain round
            // than the eager loop before noticing — the trace still ends
            // at the converged record, and replaying that one round on
            // the legacy instance reproduces the engine's final iterate
            // bit for bit.
            let t = want_records.last().unwrap().0;
            assert_eq!(report.rounds, t + 1, "overrun must be exactly one round");
            legacy.round();
            assert_eq!(report.w, legacy.w(), "overrun round diverged");
        } else {
            assert_eq!(report.rounds, max_rounds);
            assert_eq!(report.w, want_w, "final iterates diverge");
        }
    }
}

#[test]
fn checkpoint_resume_reproduces_trace_bit_for_bit() {
    let dir = std::env::temp_dir().join("dadm-engine-resume");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resume.ck");

    let data = tiny_classification(200, 6, 92);
    let part = Partition::balanced(200, 3, 92);

    // Reference: 10 uninterrupted rounds, recorded every round.
    let mut full = build(&data, &part, Cluster::Serial, 0.2, 1);
    let full_report = Driver::new(0.0, 10).solve(&mut full);

    // Interrupted: 5 rounds with the engine snapshotting at round 5…
    let mut first = build(&data, &part, Cluster::Serial, 0.2, 1);
    let _ = Driver::new(0.0, 5)
        .with_checkpoint(path.clone(), 5)
        .solve(&mut first);
    let ck = Checkpoint::load_file(&path).unwrap();
    assert_eq!(ck.rounds, 5);
    assert!(ck.rng.is_some(), "v2 snapshots carry the RNG streams");

    // …then a fresh instance restored from disk runs the back half.
    let mut resumed = build(&data, &part, Cluster::Serial, 0.2, 1);
    resumed.restore(&ck).unwrap();
    let resumed_report = Driver::new(0.0, 5).solve(&mut resumed);

    // The resumed trace (initial record at round 5, then 6..10) must
    // equal the tail of the uninterrupted trace bit for bit: the
    // snapshot carries the mini-batch RNG streams and the broadcast is
    // value-setting, so worker replicas cannot drift.
    let full_fields = math_fields(&full_report);
    let resumed_fields = math_fields(&resumed_report);
    let tail: Vec<_> = full_fields
        .iter()
        .filter(|(round, ..)| *round >= 5)
        .copied()
        .collect();
    assert_eq!(resumed_fields, tail, "resumed trajectory diverged");
    assert_eq!(resumed_report.w, full_report.w);
    std::fs::remove_file(&path).ok();
}

#[test]
fn concurrent_pool_solves_do_not_corrupt_state() {
    // Two solves on different datasets, running simultaneously on the
    // process-global worker pool, must each reproduce their serial
    // counterpart bit for bit (jobs time-share workers FIFO; per-machine
    // state must never leak across solves).
    let data_a = tiny_classification(300, 8, 101);
    let part_a = Partition::balanced(300, 4, 101);
    let data_b = tiny_classification(240, 5, 202);
    let part_b = Partition::balanced(240, 3, 202);

    let run = |data: &Dataset, part: &Partition, cluster: Cluster| {
        let mut d = build(data, part, cluster, 0.25, 1);
        d.resync();
        for _ in 0..15 {
            d.round();
        }
        d.check_v_invariant().unwrap();
        (d.w().to_vec(), d.gap())
    };

    let (serial_a, serial_b) = (
        run(&data_a, &part_a, Cluster::Serial),
        run(&data_b, &part_b, Cluster::Serial),
    );
    let (pooled_a, pooled_b) = std::thread::scope(|s| {
        let ha = s.spawn(|| run(&data_a, &part_a, Cluster::Threads));
        let hb = s.spawn(|| run(&data_b, &part_b, Cluster::Threads));
        (ha.join().unwrap(), hb.join().unwrap())
    });

    assert_eq!(serial_a.0, pooled_a.0, "solve A corrupted under sharing");
    assert_eq!(serial_b.0, pooled_b.0, "solve B corrupted under sharing");
    assert!((serial_a.1 - pooled_a.1).abs() < 1e-9);
    assert!((serial_b.1 - pooled_b.1).abs() < 1e-9);
}
