//! Integration: edge cases and failure injection across the stack.

use dadm::comm::CostModel;
use dadm::coordinator::{AccDadm, AccDadmOptions, Dadm, DadmOptions, Problem};
use dadm::data::synthetic::tiny_classification;
use dadm::data::{Dataset, Partition, SparseMatrix};
use dadm::loss::{Logistic, Loss, SmoothHinge};
use dadm::reg::{ElasticNet, ExtraReg, Regularizer, Zero};
use dadm::solver::{LocalSolver, ProxSdca};

fn opts(sp: f64) -> DadmOptions {
    DadmOptions {
        sp,
        cost: CostModel::free(),
        ..Default::default()
    }
}

/// Positional convenience over the [`Problem`] builder — the only
/// construction path — for this file's repetitive setups.
#[allow(clippy::too_many_arguments)]
fn build_dadm<L, R, H, S>(
    data: &Dataset,
    part: &Partition,
    loss: L,
    reg: R,
    h: H,
    lambda: f64,
    solver: S,
    opts: DadmOptions,
) -> Dadm<L, R, H, S>
where
    L: Loss,
    R: Regularizer,
    H: ExtraReg,
    S: LocalSolver,
{
    Problem::new(data, part)
        .loss(loss)
        .reg(reg)
        .extra_reg(h)
        .lambda(lambda)
        .build_dadm(solver, opts)
}

#[allow(clippy::too_many_arguments)]
fn build_acc<L, H, S>(
    data: &Dataset,
    part: &Partition,
    loss: L,
    h: H,
    lambda: f64,
    mu: f64,
    solver: S,
    opts: AccDadmOptions,
) -> AccDadm<L, H, S>
where
    L: Loss,
    H: ExtraReg,
    S: LocalSolver,
{
    Problem::new(data, part)
        .loss(loss)
        .extra_reg(h)
        .lambda(lambda)
        .l1(mu)
        .build_acc_dadm(solver, opts)
}

/// One example per machine — the most extreme partition.
#[test]
fn one_example_per_machine() {
    let data = tiny_classification(8, 3, 61);
    let part = Partition::balanced(8, 8, 61);
    let mut dadm = build_dadm(
        &data,
        &part,
        SmoothHinge::default(),
        ElasticNet::new(0.0),
        Zero,
        0.1,
        ProxSdca,
        opts(1.0),
    );
    let r = dadm.solve(1e-6, 500);
    assert!(r.converged, "gap {}", r.normalized_gap());
}

/// All labels identical: the optimum is a large-margin one-class
/// predictor; the solver must still converge (no division blowups).
#[test]
fn degenerate_single_class() {
    let mut data = tiny_classification(60, 4, 62);
    for y in &mut data.y {
        *y = 1.0;
    }
    let part = Partition::balanced(60, 3, 62);
    let mut dadm = build_dadm(
        &data,
        &part,
        Logistic,
        ElasticNet::new(0.01),
        Zero,
        1e-2,
        ProxSdca,
        opts(0.5),
    );
    let r = dadm.solve(1e-6, 1000);
    assert!(r.converged);
    // The predictor must score the positive class positively on average.
    let preds = data.x.matvec(&r.w);
    let mean: f64 = preds.iter().sum::<f64>() / preds.len() as f64;
    assert!(mean > 0.0);
}

/// Rows that are entirely zero contribute nothing but must not crash or
/// corrupt the duals.
#[test]
fn zero_feature_rows() {
    let rows = vec![
        vec![1.0, 0.0],
        vec![0.0, 0.0], // empty row
        vec![0.0, 1.0],
        vec![0.0, 0.0], // empty row
        vec![0.5, 0.5],
        vec![-0.5, 0.5],
    ];
    let data = Dataset {
        x: SparseMatrix::from_dense(&rows),
        y: vec![1.0, 1.0, -1.0, -1.0, 1.0, -1.0],
        name: "zeros".into(),
    };
    let part = Partition::balanced(6, 2, 63);
    let mut dadm = build_dadm(
        &data,
        &part,
        SmoothHinge::default(),
        ElasticNet::new(0.0),
        Zero,
        0.1,
        ProxSdca,
        opts(1.0),
    );
    let r = dadm.solve(1e-8, 500);
    assert!(r.converged, "gap {}", r.normalized_gap());
    // Empty rows contribute nothing to v but their dual term must reach
    // its own maximizer (α = y for the smooth hinge at u = 0), otherwise
    // the gap keeps a φ(0) floor.
    for ws in dadm.machine_states() {
        for i in 0..ws.n_l() {
            if ws.x.row(i).nnz() == 0 {
                assert!((ws.alpha[i] - ws.y[i]).abs() < 1e-6, "α = {}", ws.alpha[i]);
            }
        }
    }
}

/// Extreme regularization: huge λ drives w → 0; the gap must still hit
/// machine precision quickly.
#[test]
fn huge_lambda_zero_solution() {
    let data = tiny_classification(50, 4, 64);
    let part = Partition::balanced(50, 2, 64);
    let mut dadm = build_dadm(
        &data,
        &part,
        SmoothHinge::default(),
        ElasticNet::new(10.0), // heavy L1 too
        Zero,
        100.0,
        ProxSdca,
        opts(1.0),
    );
    let r = dadm.solve(1e-10, 100);
    assert!(r.converged);
    assert!(r.w.iter().all(|&w| w.abs() < 1e-6), "{:?}", r.w);
}

/// Tiny λ with a round cap: must not panic, must report not-converged
/// honestly, and the trace must stay finite.
#[test]
fn tiny_lambda_capped_run_is_sane() {
    let data = tiny_classification(80, 4, 65);
    let part = Partition::balanced(80, 4, 65);
    let mut acc = build_acc(
        &data,
        &part,
        SmoothHinge::default(),
        Zero,
        1e-12,
        1e-9,
        ProxSdca,
        AccDadmOptions {
            dadm: opts(0.5),
            ..Default::default()
        },
    );
    let r = acc.solve(1e-9, 20);
    assert!(!r.converged);
    assert!(r.rounds <= 21);
    for rec in &r.trace.rounds {
        assert!(rec.primal.is_finite() && rec.dual.is_finite());
        assert!(rec.gap() >= -1e-6);
    }
}

/// Unbalanced (round-robin with uneven n) partitions: weights n_ℓ/n must
/// keep the v bookkeeping exact.
#[test]
fn unbalanced_partition_bookkeeping() {
    let data = tiny_classification(101, 4, 66); // 101 % 4 != 0
    let part = Partition::balanced(101, 4, 66);
    let mut dadm = build_dadm(
        &data,
        &part,
        Logistic,
        ElasticNet::new(0.05),
        Zero,
        1e-2,
        ProxSdca,
        opts(0.3),
    );
    dadm.resync();
    for _ in 0..5 {
        dadm.round();
    }
    dadm.check_v_invariant().unwrap();
}

/// The solve must be exactly reproducible for a fixed seed and diverge
/// for different seeds (mini-batch draws actually depend on the seed).
#[test]
fn determinism_and_seed_sensitivity() {
    let data = tiny_classification(90, 5, 67);
    let part = Partition::balanced(90, 3, 67);
    let run = |seed: u64| {
        let mut dadm = build_dadm(
            &data,
            &part,
            SmoothHinge::default(),
            ElasticNet::new(0.1),
            Zero,
            1e-3,
            ProxSdca,
            DadmOptions {
                sp: 0.2,
                seed,
                cost: CostModel::free(),
                ..Default::default()
            },
        );
        dadm.resync();
        for _ in 0..10 {
            dadm.round();
        }
        dadm.w().to_vec()
    };
    assert_eq!(run(1), run(1), "same seed must reproduce bit-exactly");
    assert_ne!(run(1), run(2), "different seeds must draw different batches");
}
