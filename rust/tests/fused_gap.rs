//! Fused gap telemetry (DESIGN.md §11): the single-barrier lagged
//! record protocol must be **bit-identical** to the legacy three-barrier
//! eval path (round + primal + dual as separate cluster exchanges) on
//! every backend — Serial, Threads, and TCP loopback including
//! `--local-threads 2` — while issuing exactly one cluster barrier per
//! steady-state round and shipping O(m) instead of O(d·m) eval bytes.
//! Plus the drift bound of the incremental dual conjugate sum against
//! exact resummation.

use dadm::comm::tcp::{serve, synthetic_specs, TcpClusterBuilder, TcpHandle};
use dadm::comm::wire::{WireLoss, WireSolver};
use dadm::comm::{Cluster, CostModel};
use dadm::data::synthetic::SyntheticSpec;
use dadm::data::{Dataset, Partition};
use dadm::loss::SmoothHinge;
use dadm::reg::{ElasticNet, Zero};
use dadm::solver::ProxSdca;
use dadm::{Dadm, DadmOptions, Problem, SolveReport};
use std::net::TcpStream;
use std::thread::JoinHandle;

type TestDadm = Dadm<SmoothHinge, ElasticNet, Zero, ProxSdca>;

const SEED: u64 = 0xFA5ED;

fn spec(n: usize, d: usize) -> SyntheticSpec {
    SyntheticSpec {
        name: "fused-gap".into(),
        n,
        d,
        density: 0.2,
        signal_density: 0.4,
        noise: 0.1,
        seed: 0x5EED5,
    }
}

fn build(
    data: &Dataset,
    part: &Partition,
    cluster: Cluster,
    gap_every: usize,
    local_threads: usize,
    conj_resum_every: usize,
) -> TestDadm {
    Problem::new(data, part)
        .loss(SmoothHinge::default())
        .reg(ElasticNet::new(0.1))
        .lambda(1e-2)
        .build_dadm(
            ProxSdca,
            DadmOptions {
                sp: 0.25,
                cluster,
                cost: CostModel::free(),
                seed: SEED,
                gap_every,
                sparse_comm: true,
                local_threads,
                conj_resum_every,
                ..Default::default()
            },
        )
}

/// Spawn `m` thread-hosted loopback workers (the in-process twin of real
/// `dadm worker` processes; the child-process variant lives in
/// `rust/tests/tcp_cluster.rs`).
fn loopback(m: usize) -> (TcpHandle, Vec<JoinHandle<()>>) {
    let builder = TcpClusterBuilder::bind("127.0.0.1:0").unwrap();
    let addr = builder.local_addr().unwrap();
    let threads: Vec<_> = (0..m)
        .map(|_| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).expect("worker connect");
                serve(stream).expect("worker serve");
            })
        })
        .collect();
    (TcpHandle::new(builder.accept(m).unwrap()), threads)
}

fn join_workers(handle: TcpHandle, threads: Vec<JoinHandle<()>>) {
    handle.with(|c| c.shutdown());
    drop(handle);
    for t in threads {
        t.join().expect("worker thread panicked");
    }
}

/// The trace's deterministic math fields, as bits.
fn math_fields(report: &SolveReport) -> Vec<(usize, u64, u64, u64)> {
    report
        .trace
        .rounds
        .iter()
        .map(|r| (r.round, r.passes.to_bits(), r.primal.to_bits(), r.dual.to_bits()))
        .collect()
}

/// The legacy three-barrier eval path, written against the public API:
/// one fused round, then primal and dual as separate cluster exchanges
/// every `gap_every` rounds. The fused engine trace must reproduce these
/// records bit for bit.
fn three_barrier_records(
    dadm: &mut TestDadm,
    max_rounds: usize,
    gap_every: usize,
) -> (Vec<(usize, u64, u64, u64)>, Vec<f64>) {
    dadm.resync();
    let mut records = Vec::new();
    let mut record = |d: &mut TestDadm, records: &mut Vec<(usize, u64, u64, u64)>| {
        let primal = d.primal();
        let dual = d.dual();
        records.push((d.rounds(), d.passes().to_bits(), primal.to_bits(), dual.to_bits()));
    };
    record(dadm, &mut records);
    for r in 1..=max_rounds {
        dadm.round();
        if r % gap_every == 0 || r == max_rounds {
            record(dadm, &mut records);
        }
    }
    (records, dadm.w().to_vec())
}

#[test]
fn fused_trace_matches_three_barrier_path_in_process() {
    let data = spec(240, 32).generate();
    let part = Partition::balanced(data.n(), 4, 7);
    for cluster in [Cluster::Serial, Cluster::Threads] {
        for gap_every in [1usize, 3] {
            let max_rounds = 10;
            // Fused engine solve (capped: eps = 0 never fires, so the
            // trace covers rounds 0..=max like the legacy loop's).
            let mut fused = build(&data, &part, cluster.clone(), gap_every, 1, 64);
            let report = fused.solve(0.0, max_rounds);
            let mut legacy = build(&data, &part, cluster.clone(), gap_every, 1, 64);
            let (want, want_w) = three_barrier_records(&mut legacy, max_rounds, gap_every);
            assert_eq!(
                math_fields(&report),
                want,
                "trace diverged on {cluster:?} at gap_every {gap_every}"
            );
            assert_eq!(report.w, want_w, "iterates diverged on {cluster:?}");
            assert_eq!(report.rounds, max_rounds);
        }
    }
}

#[test]
fn fused_trace_matches_three_barrier_path_over_tcp() {
    // TCP loopback at T = 1 and T = 2 (multi-threaded workers): the
    // fused engine solve vs the legacy three-barrier loop on a second
    // identical fleet — traces bit-identical, and the fused fleet moves
    // strictly fewer wire bytes.
    let problem = spec(240, 32);
    let data = problem.generate();
    let m = 2usize;
    let part = Partition::balanced(data.n(), m, 7);
    for t in [1usize, 2] {
        let max_rounds = 8;
        let assign = |handle: &TcpHandle| {
            handle
                .with(|c| {
                    c.assign(synthetic_specs(
                        &problem,
                        m,
                        7,
                        SEED,
                        0.25,
                        WireLoss::SmoothHinge(SmoothHinge::default()),
                        WireSolver::ProxSdca,
                        t,
                    ))
                })
                .unwrap();
        };
        let (fused_handle, fused_workers) = loopback(m);
        assign(&fused_handle);
        let mut fused = build(&data, &part, Cluster::Tcp(fused_handle.clone()), 1, t, 64);
        let report = fused.solve(0.0, max_rounds);
        let fused_bytes = fused.wire_bytes();

        let (legacy_handle, legacy_workers) = loopback(m);
        assign(&legacy_handle);
        let mut legacy = build(&data, &part, Cluster::Tcp(legacy_handle.clone()), 1, t, 64);
        let (want, _) = three_barrier_records(&mut legacy, max_rounds, 1);
        let legacy_bytes = legacy.wire_bytes();

        assert_eq!(math_fields(&report), want, "TCP trace diverged at T = {t}");
        assert!(
            fused_bytes < legacy_bytes,
            "fused telemetry must move fewer bytes: {fused_bytes} vs {legacy_bytes}"
        );
        join_workers(fused_handle, fused_workers);
        join_workers(legacy_handle, legacy_workers);
    }
}

#[test]
fn gap_round_eval_wire_is_constant_in_d() {
    // The acceptance pin: at --gap-every 1 the per-round eval wire drops
    // from O(d·m) (shipping the iterate for LossSumAt) to O(m) (16
    // telemetry bytes per machine). Fleet A solves with fused telemetry;
    // fleet B replays the pre-fusion wire pattern — round + LossSumAt(w)
    // + dual — and must move ≳ 8·d bytes per machine per round more.
    let d = 2048usize;
    let problem = spec(120, d);
    let data = problem.generate();
    let m = 2usize;
    let part = Partition::balanced(data.n(), m, 7);
    let rounds = 6usize;

    let (fused_handle, fused_workers) = loopback(m);
    fused_handle
        .with(|c| {
            c.assign(synthetic_specs(
                &problem,
                m,
                7,
                SEED,
                0.25,
                WireLoss::SmoothHinge(SmoothHinge::default()),
                WireSolver::ProxSdca,
                1,
            ))
        })
        .unwrap();
    let mut fused = build(&data, &part, Cluster::Tcp(fused_handle.clone()), 1, 1, 64);
    let _ = fused.solve(0.0, rounds);
    let fused_bytes = fused.wire_bytes();

    let (legacy_handle, legacy_workers) = loopback(m);
    legacy_handle
        .with(|c| {
            c.assign(synthetic_specs(
                &problem,
                m,
                7,
                SEED,
                0.25,
                WireLoss::SmoothHinge(SmoothHinge::default()),
                WireSolver::ProxSdca,
                1,
            ))
        })
        .unwrap();
    let mut legacy = build(&data, &part, Cluster::Tcp(legacy_handle.clone()), 1, 1, 64);
    legacy.resync();
    let _ = legacy.gap();
    for _ in 0..rounds {
        legacy.round();
        // The pre-fusion eval wire: the full iterate ships to every
        // worker for the loss sum.
        let w = legacy.w().to_vec();
        let _ = legacy.loss_sum_at(&w);
        let _ = legacy.dual();
    }
    let legacy_bytes = legacy.wire_bytes();

    // Each legacy gap round ships ≥ 8·d bytes per machine for w alone.
    let w_payload = (rounds * m * 8 * d) as u64;
    assert!(
        legacy_bytes >= fused_bytes + w_payload / 2,
        "legacy eval wire should dominate: legacy {legacy_bytes} vs fused {fused_bytes} \
         (w payload ≈ {w_payload})"
    );
    join_workers(fused_handle, fused_workers);
    join_workers(legacy_handle, legacy_workers);
}

#[test]
fn steady_state_gap_round_is_one_barrier() {
    // Barrier accounting at --gap-every 1: resync + initial record +
    // R fused rounds + closing record — exactly R + 3 cluster barriers,
    // i.e. ONE per steady-state round. The three-barrier loop pays
    // 3 extra barriers per gap round on top of its rounds.
    let data = spec(160, 24).generate();
    let part = Partition::balanced(data.n(), 4, 7);
    let rounds = 12usize;
    for gap_every in [1usize, 3] {
        let mut fused = build(&data, &part, Cluster::Serial, gap_every, 1, 64);
        let report = fused.solve(0.0, rounds);
        assert_eq!(report.rounds, rounds);
        assert_eq!(
            fused.barriers(),
            rounds as u64 + 3,
            "fused solve must issue one barrier per round plus resync, \
             initial and closing records (gap_every {gap_every})"
        );
    }
    // Contrast: the legacy path's explicit evals each pay barriers.
    let mut legacy = build(&data, &part, Cluster::Serial, 1, 1, 64);
    legacy.resync();
    let _ = legacy.gap();
    let base = legacy.barriers(); // resync + fused initial gap
    for _ in 0..rounds {
        legacy.round();
        let _ = legacy.primal(); // sync_workers + loss barrier
        let _ = legacy.dual(); // conj barrier
    }
    let per_round = (legacy.barriers() - base) as usize;
    assert_eq!(
        per_round,
        rounds * 4,
        "three-barrier eval path: round + flush + loss + conj per round"
    );
}

#[test]
fn loss_sum_current_is_bit_identical_to_shipping_w() {
    // EvalOp::LossSumAtCurrent evaluates against the worker replicas;
    // value-setting broadcasts keep those bit-identical to the
    // coordinator's iterate, so the two loss sums must agree exactly.
    let data = spec(200, 24).generate();
    let part = Partition::balanced(data.n(), 4, 7);
    let mut dadm = build(&data, &part, Cluster::Serial, 1, 1, 64);
    dadm.resync();
    for _ in 0..5 {
        dadm.round();
        let shipped = {
            let w = dadm.w().to_vec();
            dadm.sync_workers();
            dadm.loss_sum_at(&w)
        };
        let current = dadm.loss_sum_current();
        assert_eq!(shipped.to_bits(), current.to_bits());
    }
}

#[test]
fn incremental_conj_sum_drift_is_bounded_and_resummable() {
    let data = spec(200, 24).generate();
    let part = Partition::balanced(data.n(), 4, 7);
    let loss = SmoothHinge::default();

    // Never resum: after many rounds of O(1) incremental updates the
    // running sums must still sit within float-drift distance of the
    // exact O(n) recomputation.
    let mut free_run = build(&data, &part, Cluster::Serial, 1, 1, 0);
    free_run.resync();
    let _ = free_run.gap(); // arm the running sums
    for _ in 0..120 {
        free_run.round();
    }
    let _ = free_run.gap();
    for ws in free_run.machine_states() {
        let exact = ws.dual_conj_sum(&loss);
        let running = ws.conj_sum.expect("telemetry armed");
        assert!(
            (running - exact).abs() <= 1e-8 * (1.0 + exact.abs()),
            "incremental conj drifted: running {running} vs exact {exact}"
        );
    }

    // Resum cadence 5: round 120 is a resum round, so right after it the
    // running sums ARE the exact recomputation, bit for bit.
    let mut resummed = build(&data, &part, Cluster::Serial, 1, 1, 5);
    resummed.resync();
    let _ = resummed.gap();
    for _ in 0..120 {
        resummed.round();
    }
    for ws in resummed.machine_states() {
        let exact = ws.dual_conj_sum(&loss);
        let running = ws.conj_sum.expect("telemetry armed");
        assert_eq!(
            running.to_bits(),
            exact.to_bits(),
            "a resum round must land exactly on the recomputed sum"
        );
    }
}

#[test]
fn lagged_stop_trace_still_ends_at_converged_record() {
    // A converging fused solve detects the gap target one round late
    // (the record for round T completes during round T+1) but reports
    // the same trace: its last record is the converged one.
    let data = spec(240, 16).generate();
    let part = Partition::balanced(data.n(), 3, 7);
    let mut dadm = build(&data, &part, Cluster::Serial, 1, 1, 64);
    let report = dadm.solve(1e-5, 400);
    assert!(report.converged, "gap {}", report.normalized_gap());
    let last = report.trace.last().unwrap();
    assert!(last.gap() / data.n() as f64 <= 1e-5);
    assert_eq!(
        report.rounds,
        last.round + 1,
        "lagged stopping overruns by exactly one round"
    );
}
