//! Chaos tests: kill a **real** `dadm worker` child process mid-solve
//! and pin the fault-tolerant TCP backend's promises (DESIGN.md §14):
//!
//! * with resurrection enabled, a replacement process rejoins through
//!   the `Rejoin` replay handshake and the trajectory stays
//!   **bit-identical** to an uninterrupted Serial solve — same w, same
//!   gap, same modeled comm seconds, every round across the kill;
//! * the solve report says it happened ([`SolveReport::retries`]);
//! * with resurrection disabled, death surfaces as a typed
//!   [`CommError::WorkerFault`] within the liveness deadline — never a
//!   hang.
//!
//! Unlike the in-process twins in `comm/tcp.rs`, the workers here are
//! actual child processes of the `dadm` binary and the kill is a real
//! SIGKILL — nothing in the worker gets to run cleanup.

use dadm::comm::sparse::DeltaCodec;
use dadm::comm::tcp::{cache_specs, synthetic_specs, TcpClusterBuilder, TcpHandle};
use dadm::comm::wire::{BroadcastRef, StepFlags, WireLoss, WireSolver};
use dadm::comm::{Cluster, CommError, CostModel, FaultTolerance};
use dadm::coordinator::{Dadm, DadmOptions, Problem};
use dadm::data::synthetic::SyntheticSpec;
use dadm::data::{cache, libsvm, Balance, CsrCache, Dataset, Partition};
use dadm::loss::SmoothHinge;
use dadm::reg::{ElasticNet, Zero};
use dadm::solver::ProxSdca;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

const MACHINES: usize = 4;
const PART_SEED: u64 = 11;
const RNG_SEED: u64 = 0xDAD_A;
const SP: f64 = 0.2;

fn spawn_worker(addr: &str) -> Child {
    Command::new(env!("CARGO_BIN_EXE_dadm"))
        .args(["worker", "--connect", addr])
        .stdin(Stdio::null())
        .spawn()
        .expect("spawning dadm worker process")
}

/// Kills any still-running children on drop so a failing assertion
/// never leaks worker processes into the CI runner.
struct WorkerFleet(Vec<Child>);

impl WorkerFleet {
    fn spawn(addr: &str, m: usize) -> Self {
        WorkerFleet((0..m).map(|_| spawn_worker(addr)).collect())
    }

    /// SIGKILL child `idx` and reap it — the abrupt §14 death. The
    /// victim leaves the fleet, so [`WorkerFleet::join`]'s clean-exit
    /// assertion only covers survivors and replacements.
    fn kill(&mut self, idx: usize) {
        let mut victim = self.0.remove(idx);
        victim.kill().expect("killing worker");
        victim.wait().expect("reaping killed worker");
    }

    /// Spawn a replacement child against the coordinator's retained
    /// listener; the OS backlog parks its connection until the
    /// coordinator's resurrection accepts it.
    fn reinforce(&mut self, addr: &str) {
        self.0.push(spawn_worker(addr));
    }

    /// Wait for every worker to exit and assert clean status.
    fn join(mut self) {
        for child in &mut self.0 {
            let status = child.wait().expect("waiting for worker");
            assert!(status.success(), "worker exited with {status}");
        }
        self.0.clear();
    }
}

impl Drop for WorkerFleet {
    fn drop(&mut self) {
        for child in &mut self.0 {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

fn problem_spec() -> SyntheticSpec {
    SyntheticSpec {
        name: "chaos".into(),
        n: 320,
        d: 48,
        density: 0.25,
        signal_density: 0.4,
        noise: 0.1,
        seed: 0xBEEF,
    }
}

fn build_dadm(
    data: &Dataset,
    part: &Partition,
    cluster: Cluster,
) -> Dadm<SmoothHinge, ElasticNet, Zero, ProxSdca> {
    Problem::new(data, part)
        .loss(SmoothHinge::default())
        .reg(ElasticNet::new(0.1))
        .lambda(1e-2)
        .build_dadm(
            ProxSdca,
            DadmOptions {
                sp: SP,
                cluster,
                cost: CostModel::default(),
                seed: RNG_SEED,
                gap_every: 1,
                sparse_comm: true,
                local_threads: 1,
                conj_resum_every: 64,
                ..Default::default()
            },
        )
}

/// Loopback coordinator + child-process fleet under fault tolerance
/// `ft`, assigned and ready to solve. Also returns the listener address
/// so a replacement can be pointed at it after a kill.
fn connected_fleet(spec: &SyntheticSpec, ft: FaultTolerance) -> (TcpHandle, WorkerFleet, String) {
    let builder = TcpClusterBuilder::bind("127.0.0.1:0")
        .expect("bind")
        .fault_tolerance(ft);
    let addr = builder.local_addr().expect("local addr").to_string();
    let fleet = WorkerFleet::spawn(&addr, MACHINES);
    let mut cluster = builder.accept(MACHINES).expect("accepting workers");
    cluster
        .assign(synthetic_specs(
            spec,
            MACHINES,
            PART_SEED,
            RNG_SEED,
            SP,
            WireLoss::SmoothHinge(SmoothHinge::default()),
            WireSolver::ProxSdca,
            1,
        ))
        .expect("assigning partitions");
    (TcpHandle::new(cluster), fleet, addr)
}

/// The cache-backed twin of [`connected_fleet`]: workers mmap their own
/// contiguous row ranges of the compiled cache (`DataSpec::Cache`)
/// instead of regenerating synthetic shards.
fn connected_fleet_cache(
    cache: &CsrCache,
    path: &str,
    ft: FaultTolerance,
) -> (TcpHandle, WorkerFleet, String) {
    let builder = TcpClusterBuilder::bind("127.0.0.1:0")
        .expect("bind")
        .fault_tolerance(ft);
    let addr = builder.local_addr().expect("local addr").to_string();
    let fleet = WorkerFleet::spawn(&addr, MACHINES);
    let mut cluster = builder.accept(MACHINES).expect("accepting workers");
    cluster
        .assign(cache_specs(
            cache,
            path,
            MACHINES,
            RNG_SEED,
            SP,
            WireLoss::SmoothHinge(SmoothHinge::default()),
            WireSolver::ProxSdca,
            1,
            Balance::Rows,
        ))
        .expect("assigning cache shards");
    (TcpHandle::new(cluster), fleet, addr)
}

fn resurrecting_ft() -> FaultTolerance {
    FaultTolerance {
        worker_timeout: Duration::from_secs(10),
        heartbeat_every: Duration::from_millis(500),
        max_rejoins: 2,
    }
}

#[test]
fn killed_child_process_resurrects_bit_identically() {
    // The tentpole pin, against real OS processes: drive Serial and TCP
    // round by round, SIGKILL one worker child between rounds, hand the
    // coordinator a replacement process, and require every subsequent
    // round's iterate, dual image, and gap to stay bit-identical —
    // resurrection must be algorithmically invisible.
    let spec = problem_spec();
    let data = spec.generate();
    let part = Partition::balanced(data.n(), MACHINES, PART_SEED);

    let (handle, mut fleet, addr) = connected_fleet(&spec, resurrecting_ft());
    let mut serial = build_dadm(&data, &part, Cluster::Serial);
    let mut tcp = build_dadm(&data, &part, Cluster::Tcp(handle.clone()));
    serial.resync();
    tcp.resync();
    for round in 0..8 {
        serial.round();
        tcp.round();
        assert_eq!(serial.w(), tcp.w(), "w diverged at round {round} across the kill");
        assert_eq!(serial.v(), tcp.v(), "v diverged at round {round} across the kill");
        assert_eq!(
            serial.gap().to_bits(),
            tcp.gap().to_bits(),
            "gap diverged at round {round} across the kill"
        );
        if round == 2 {
            // Abrupt death between barriers; the replacement connects
            // into the listener backlog and is admitted by the §14
            // rejoin during round 3's collect.
            fleet.kill(0);
            fleet.reinforce(&addr);
        }
    }
    assert_eq!(
        handle.with(|c| c.rejoins_total()),
        1,
        "exactly one resurrection expected"
    );

    handle.with(|c| c.shutdown());
    drop(tcp);
    drop(handle);
    fleet.join();
}

#[test]
fn full_solve_survives_kill_with_identical_trace_and_retry_telemetry() {
    // End-to-end: a full `solve` whose fleet loses a worker must finish
    // with a trace bit-identical to Serial *and* say so in the report
    // (`retries` — the §14 telemetry hook). The kill lands after
    // assignment, so the very first wire barrier of the solve runs the
    // detection + rejoin path deterministically.
    let spec = problem_spec();
    let data = spec.generate();
    let part = Partition::balanced(data.n(), MACHINES, PART_SEED);

    let mut serial = build_dadm(&data, &part, Cluster::Serial);
    let serial_report = serial.solve(1e-6, 40);

    let (handle, mut fleet, addr) = connected_fleet(&spec, resurrecting_ft());
    fleet.kill(0);
    fleet.reinforce(&addr);
    let mut tcp = build_dadm(&data, &part, Cluster::Tcp(handle.clone()));
    let tcp_report = tcp.solve(1e-6, 40);

    assert_eq!(serial_report.converged, tcp_report.converged);
    assert_eq!(serial_report.rounds, tcp_report.rounds);
    assert_eq!(
        serial_report.trace.rounds.len(),
        tcp_report.trace.rounds.len(),
        "trace lengths differ"
    );
    for (s, t) in serial_report.trace.rounds.iter().zip(&tcp_report.trace.rounds) {
        assert_eq!(s.round, t.round);
        assert_eq!(
            s.passes.to_bits(),
            t.passes.to_bits(),
            "passes diverged at round {}",
            s.round
        );
        assert_eq!(
            s.primal.to_bits(),
            t.primal.to_bits(),
            "primal diverged at round {}: {} vs {}",
            s.round,
            s.primal,
            t.primal
        );
        assert_eq!(
            s.dual.to_bits(),
            t.dual.to_bits(),
            "dual diverged at round {}: {} vs {}",
            s.round,
            s.dual,
            t.dual
        );
        // Modeled comm time is deterministic (message sizes, not wall
        // clock) and is NOT charged for the heal (§14.4), so it must
        // match exactly even across the resurrection round.
        assert_eq!(
            s.comm_secs.to_bits(),
            t.comm_secs.to_bits(),
            "modeled comm diverged at round {}",
            s.round
        );
    }
    assert_eq!(serial_report.w, tcp_report.w, "final iterates differ");

    assert_eq!(serial_report.retries, 0, "Serial backend cannot retry");
    assert!(
        tcp_report.retries >= 1,
        "report should record the resurrection, got retries = {}",
        tcp_report.retries
    );
    assert_eq!(
        handle.with(|c| c.rejoins_total()),
        1,
        "exactly one resurrection expected"
    );

    handle.with(|c| c.shutdown());
    drop(tcp);
    drop(handle);
    fleet.join();
}

#[test]
fn dead_child_without_resurrection_is_typed_fault_within_deadline() {
    // Acceptance criterion: with `max_rejoins = 0`, a killed worker
    // process surfaces as `CommError::WorkerFault` well inside the
    // liveness deadline — a typed error, never a hang, never a panic.
    let ft = FaultTolerance {
        worker_timeout: Duration::from_secs(1),
        heartbeat_every: Duration::from_millis(200),
        max_rejoins: 0,
    };
    let spec = problem_spec();
    let builder = TcpClusterBuilder::bind("127.0.0.1:0")
        .expect("bind")
        .fault_tolerance(ft);
    let addr = builder.local_addr().expect("local addr").to_string();
    let mut fleet = WorkerFleet::spawn(&addr, 2);
    let mut cluster = builder.accept(2).expect("accepting workers");
    cluster
        .assign(synthetic_specs(
            &spec,
            2,
            PART_SEED,
            RNG_SEED,
            SP,
            WireLoss::SmoothHinge(SmoothHinge::default()),
            WireSolver::ProxSdca,
            1,
        ))
        .expect("assigning partitions");
    fleet.kill(0);

    let t0 = Instant::now();
    let err = cluster
        .local_step(1e-2, BroadcastRef::Empty, StepFlags::default(), DeltaCodec::F64)
        .unwrap_err();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "death detection took {:?}",
        t0.elapsed()
    );
    assert!(
        matches!(err, CommError::WorkerFault { .. }),
        "expected WorkerFault, got {err:?}"
    );
    let msg = format!("{err}");
    assert!(msg.contains("declared dead"), "unexpected error: {msg}");
    assert!(msg.contains("resurrection disabled"), "unexpected error: {msg}");

    // Orderly teardown for the survivor.
    drop(cluster);
    fleet.join();
}

#[test]
fn killed_child_resurrects_from_mmap_cache_bit_identically() {
    // The §15.5 pin: cache-backed shards carry their identity (the
    // content hash) in the spec, so a resurrected replacement process
    // re-mmaps the same bytes through the `Rejoin` replay handshake and
    // the trajectory stays bit-identical across the kill — exactly like
    // the synthetic-shard variant above, but with the data served from
    // the on-disk cache instead of regenerated.
    let data = problem_spec().generate();
    let tag = std::process::id();
    let text = std::env::temp_dir().join(format!("dadm_chaos_cache_{tag}.libsvm"));
    let bin = std::env::temp_dir().join(format!("dadm_chaos_cache_{tag}.bin"));
    let mut buf = Vec::new();
    libsvm::write(&data, &mut buf).expect("serialize libsvm");
    std::fs::write(&text, &buf).expect("write text fixture");
    cache::compile(&text, &bin).expect("compile cache");
    let cache = CsrCache::open(&bin).expect("open cache");
    let mapped = cache.dataset().expect("decode cache");
    let part = Partition::contiguous(mapped.n(), MACHINES);

    let (handle, mut fleet, addr) = connected_fleet_cache(
        &cache,
        bin.to_str().expect("utf-8 temp path"),
        resurrecting_ft(),
    );
    let mut serial = build_dadm(&mapped, &part, Cluster::Serial);
    let mut tcp = build_dadm(&mapped, &part, Cluster::Tcp(handle.clone()));
    serial.resync();
    tcp.resync();
    for round in 0..8 {
        serial.round();
        tcp.round();
        assert_eq!(serial.w(), tcp.w(), "w diverged at round {round} across the kill");
        assert_eq!(serial.v(), tcp.v(), "v diverged at round {round} across the kill");
        assert_eq!(
            serial.gap().to_bits(),
            tcp.gap().to_bits(),
            "gap diverged at round {round} across the kill"
        );
        if round == 2 {
            // Abrupt death between barriers; the replacement re-mmaps
            // the cache during the §14 rejoin and must land on the very
            // same bytes (`open_expecting` checks the pinned hash).
            fleet.kill(0);
            fleet.reinforce(&addr);
        }
    }
    assert_eq!(
        handle.with(|c| c.rejoins_total()),
        1,
        "exactly one resurrection expected"
    );

    handle.with(|c| c.shutdown());
    drop(tcp);
    drop(handle);
    fleet.join();
    let _ = std::fs::remove_file(&text);
    let _ = std::fs::remove_file(&bin);
}
