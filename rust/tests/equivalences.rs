//! Integration: the structural equivalences the paper proves.
//!
//! * Proposition 2: the duality gap is non-negative for any state.
//! * Proposition 3/5: the global gap equals the sum of local gaps at the
//!   Prop-5-optimal β.
//! * §6: DADM with h = 0 + balanced partitions ≡ CoCoA+ (here: the
//!   global step reduces to plain averaging, ṽ = v).
//! * Theorem-6 step scale degrades gracefully with batch size.

use dadm::comm::CostModel;
use dadm::coordinator::{Dadm, DadmOptions, Problem};
use dadm::data::synthetic::tiny_classification;
use dadm::data::{Dataset, Partition};
use dadm::loss::{Loss, SmoothHinge};
use dadm::reg::{ElasticNet, ExtraReg, Regularizer, Zero};
use dadm::solver::{LocalSolver, ProxSdca};
use dadm::testing::prop::for_each_case;

fn opts(sp: f64) -> DadmOptions {
    DadmOptions {
        sp,
        cost: CostModel::free(),
        ..Default::default()
    }
}

/// Positional convenience over the [`Problem`] builder — the only
/// construction path — for this file's repetitive setups.
#[allow(clippy::too_many_arguments)]
fn build_dadm<L, R, H, S>(
    data: &Dataset,
    part: &Partition,
    loss: L,
    reg: R,
    h: H,
    lambda: f64,
    solver: S,
    opts: DadmOptions,
) -> Dadm<L, R, H, S>
where
    L: Loss,
    R: Regularizer,
    H: ExtraReg,
    S: LocalSolver,
{
    Problem::new(data, part)
        .loss(loss)
        .reg(reg)
        .extra_reg(h)
        .lambda(lambda)
        .build_dadm(solver, opts)
}

/// Prop 2: P(w) − D(α, β) ≥ 0 along the whole trajectory, for random
/// hyperparameters.
#[test]
fn prop2_gap_nonnegative_random_hyperparams() {
    for_each_case(0xF00D, 12, |g| {
        let n = g.usize_in(40, 120);
        let m = g.usize_in(1, 5);
        let data = tiny_classification(n, 4, g.rng().next_u64());
        let part = Partition::balanced(n, m, 1);
        let lambda = g.f64_log_in(1e-5, 1e-1);
        let tau = if g.bool(0.5) { g.f64_log_in(1e-4, 1.0) } else { 0.0 };
        let mut dadm = build_dadm(
            &data,
            &part,
            SmoothHinge::default(),
            ElasticNet::new(tau),
            Zero,
            lambda,
            ProxSdca,
            opts(0.5),
        );
        dadm.resync();
        for _ in 0..4 {
            dadm.round();
            let gap = dadm.gap();
            assert!(gap >= -1e-8, "negative gap {gap} (λ={lambda}, τ={tau})");
        }
    });
}

/// Prop 3/5: after the global step, Σ_ℓ local gaps == global gap.
///
/// Local gap on machine ℓ (with the Prop-5 β): since ṽ_ℓ = ṽ and
/// w_ℓ = w, it is Σ_{i∈S_ℓ}[φ_i(x_iᵀw) + φ_i*(−α_i) + α_i·x_iᵀw].
#[test]
fn prop5_gap_decomposition() {
    let n = 90;
    let data = tiny_classification(n, 5, 51);
    let part = Partition::balanced(n, 3, 51);
    let lambda = 1e-2;
    let loss = SmoothHinge::default();
    let reg = ElasticNet::new(0.1);
    let mut dadm = build_dadm(&data, &part, loss, reg, Zero, lambda, ProxSdca, opts(0.4));
    dadm.resync();
    for _ in 0..5 {
        dadm.round();
        let global_gap = dadm.gap();
        // Recompute the sum of local gaps from worker state.
        let w = dadm.w().to_vec();
        let mut local_sum = 0.0;
        for ws in dadm.machine_states() {
            for i in 0..ws.n_l() {
                let xi_w = ws.x.row(i).dot(&w);
                local_sum += loss.phi(xi_w, ws.y[i])
                    + loss.conj_neg(ws.alpha[i], ws.y[i])
                    + ws.alpha[i] * xi_w;
            }
        }
        assert!(
            (global_gap - local_sum).abs() < 1e-7 * (1.0 + global_gap.abs()),
            "Prop 5 decomposition violated: global {global_gap} vs Σlocal {local_sum}"
        );
    }
}

/// §6 CoCoA+ equivalence: with h = 0 the global step is plain averaging,
/// so ṽ == v and every machine's ṽ_ℓ equals the global v after sync.
#[test]
fn cocoa_plus_equivalence_h_zero() {
    let n = 80;
    let data = tiny_classification(n, 6, 52);
    let part = Partition::balanced(n, 4, 52);
    let reg = ElasticNet::new(0.2);
    let mut dadm = build_dadm(
        &data,
        &part,
        SmoothHinge::default(),
        reg,
        Zero,
        1e-2,
        ProxSdca,
        opts(0.5),
    );
    dadm.resync();
    for _ in 0..3 {
        dadm.round();
        let v = dadm.v().to_vec();
        // ρ = 0 and ṽ = v ⇒ every worker's synced ṽ_ℓ == v and
        // w_ℓ == ∇g*(v).
        let w_expect = reg.grad_conj(&v);
        for ws in dadm.machine_states() {
            for j in 0..v.len() {
                assert!((ws.v_tilde[j] - v[j]).abs() < 1e-12);
                assert!((ws.w[j] - w_expect[j]).abs() < 1e-12);
            }
        }
    }
}

/// The dual objective never decreases across rounds (ascent property),
/// randomized over solvers and batch sizes.
#[test]
fn dual_ascent_property() {
    for_each_case(0xA5CE, 8, |g| {
        let n = g.usize_in(50, 150);
        let data = tiny_classification(n, 4, g.rng().next_u64());
        let m = g.usize_in(1, 4);
        let part = Partition::balanced(n, m, 2);
        let sp = *g.choose(&[0.1, 0.5, 1.0]);
        let mut dadm = build_dadm(
            &data,
            &part,
            SmoothHinge::default(),
            ElasticNet::new(0.05),
            Zero,
            5e-3,
            ProxSdca,
            opts(sp),
        );
        dadm.resync();
        let mut prev = dadm.dual();
        for _ in 0..6 {
            dadm.round();
            let d = dadm.dual();
            assert!(d >= prev - 1e-9, "dual decreased {prev} -> {d}");
            prev = d;
        }
    });
}
