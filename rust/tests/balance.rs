//! `--balance nnz` integration (DESIGN.md §16): over the checked-in
//! skewed fixture — most stored non-zeros concentrated in a dense head
//! block — an nnz-balanced contiguous partition must (a) actually
//! equalize per-shard work where the row-balanced cut does not, and
//! (b) leave the trajectory **bit-identical** across Serial, Threads,
//! and the TCP loopback backend with hierarchical sub-shards
//! (`local_threads > 1`), including a §14 kill + resurrection of a
//! real `dadm worker` child process. Balance changes *where* the cut
//! points land, never *what* each logical machine computes, so every
//! backend must reproduce the same w, v, and gap bit for bit.

use dadm::comm::tcp::{serve, shard_specs, TcpClusterBuilder, TcpHandle};
use dadm::comm::wire::{WireLoss, WireSolver};
use dadm::comm::{Cluster, CostModel, FaultTolerance};
use dadm::coordinator::{Dadm, DadmOptions, Problem};
use dadm::data::{libsvm, Balance, Dataset, Partition};
use dadm::loss::SmoothHinge;
use dadm::reg::{ElasticNet, Zero};
use dadm::solver::ProxSdca;
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::thread::JoinHandle;
use std::time::Duration;

const MACHINES: usize = 4;
const LOCAL_THREADS: usize = 2;
const RNG_SEED: u64 = 0xDAD_A;
const SP: f64 = 0.5;

fn skewed() -> Dataset {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/testdata/skewed.libsvm");
    libsvm::load(path).expect("parse skewed fixture")
}

fn nnz_partition(data: &Dataset, m: usize) -> Partition {
    Partition::contiguous_nnz(&data.x.nnz_prefix(), m)
}

/// Stored non-zeros owned by each shard of `part`.
fn shard_nnz(data: &Dataset, part: &Partition) -> Vec<u64> {
    (0..part.machines())
        .map(|l| {
            part.shard(l)
                .iter()
                .map(|&i| data.x.row(i).indices.len() as u64)
                .sum()
        })
        .collect()
}

fn build_dadm(
    data: &Dataset,
    part: &Partition,
    cluster: Cluster,
) -> Dadm<SmoothHinge, ElasticNet, Zero, ProxSdca> {
    Problem::new(data, part)
        .loss(SmoothHinge::default())
        .reg(ElasticNet::new(0.1))
        .lambda(1e-2)
        .build_dadm(
            ProxSdca,
            DadmOptions {
                sp: SP,
                cluster,
                cost: CostModel::default(),
                seed: RNG_SEED,
                gap_every: 1,
                sparse_comm: true,
                local_threads: LOCAL_THREADS,
                balance: Balance::Nnz,
                ..Default::default()
            },
        )
}

fn specs(data: &Dataset, part: &Partition) -> Vec<dadm::comm::wire::ProblemSpec> {
    shard_specs(
        data,
        part,
        RNG_SEED,
        SP,
        WireLoss::SmoothHinge(SmoothHinge::default()),
        WireSolver::ProxSdca,
        LOCAL_THREADS,
        Balance::Nnz,
    )
}

#[test]
fn nnz_cuts_repair_the_skew_row_cuts_leave() {
    // The fixture must actually exercise the straggler scenario: under
    // row-balanced contiguous cuts the head shard hoards the nnz; the
    // nnz-balanced cut has to flatten that hoard substantially.
    let data = skewed();
    let rows = shard_nnz(&data, &Partition::contiguous(data.n(), MACHINES));
    let nnz = shard_nnz(&data, &nnz_partition(&data, MACHINES));
    let (rows_max, nnz_max) = (*rows.iter().max().unwrap(), *nnz.iter().max().unwrap());
    assert!(
        rows_max * 2 >= nnz_max * 3,
        "fixture is not skewed enough to test balancing: \
         row-cut max shard {rows_max} nnz vs nnz-cut max shard {nnz_max}"
    );
    // The nnz cut is optimal for contiguous cuts, so it can never be
    // worse than the row cut on any input.
    assert!(nnz_max <= rows_max, "nnz cut worse than row cut");
    let total: u64 = nnz.iter().sum();
    assert_eq!(total, rows.iter().sum::<u64>(), "cuts must cover all nnz");
}

/// Spawn `m` in-process loopback workers (thread-hosted twins of real
/// `dadm worker` processes; the child-process variant is below).
fn loopback(m: usize) -> (TcpHandle, Vec<JoinHandle<()>>) {
    let builder = TcpClusterBuilder::bind("127.0.0.1:0").unwrap();
    let addr = builder.local_addr().unwrap();
    let threads: Vec<_> = (0..m)
        .map(|_| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).expect("worker connect");
                serve(stream).expect("worker serve");
            })
        })
        .collect();
    let cluster = builder.accept(m).unwrap();
    (TcpHandle::new(cluster), threads)
}

#[test]
fn nnz_balanced_traces_are_bit_identical_across_backends() {
    // The §16 parity pin: Serial, Threads, and TCP must walk the same
    // trajectory under nnz-balanced machine cuts *and* nnz-balanced
    // T=2 sub-shards — remote workers derive their sub-cut points from
    // the spec's balance byte over their own rows, so agreement here
    // proves the coordinator and worker chunking formulas match.
    let data = skewed();
    let part = nnz_partition(&data, MACHINES);

    let (handle, threads) = loopback(MACHINES);
    handle.with(|c| c.assign(specs(&data, &part))).unwrap();

    let mut serial = build_dadm(&data, &part, Cluster::Serial);
    let mut shmem = build_dadm(&data, &part, Cluster::Threads);
    let mut tcp = build_dadm(&data, &part, Cluster::Tcp(handle.clone()));
    serial.resync();
    shmem.resync();
    tcp.resync();
    for round in 0..8 {
        serial.round();
        shmem.round();
        tcp.round();
        assert_eq!(serial.w(), shmem.w(), "w diverged on Threads at round {round}");
        assert_eq!(serial.w(), tcp.w(), "w diverged on Tcp at round {round}");
        assert_eq!(serial.v(), shmem.v(), "v diverged on Threads at round {round}");
        assert_eq!(serial.v(), tcp.v(), "v diverged on Tcp at round {round}");
        assert_eq!(
            serial.gap().to_bits(),
            tcp.gap().to_bits(),
            "gap diverged on Tcp at round {round}"
        );
    }

    handle.with(|c| c.shutdown());
    drop(tcp);
    drop(handle);
    for t in threads {
        t.join().expect("worker thread panicked");
    }
}

fn spawn_worker(addr: &str) -> Child {
    Command::new(env!("CARGO_BIN_EXE_dadm"))
        .args(["worker", "--connect", addr])
        .stdin(Stdio::null())
        .spawn()
        .expect("spawning dadm worker process")
}

#[test]
fn nnz_balanced_kill_and_rejoin_stays_bit_identical() {
    // §14 × §16: SIGKILL a real child-process worker mid-solve under
    // nnz cuts and nnz sub-shards; the replacement rebuilds its shard
    // (rows + balance byte) from the replayed spec, so resurrection
    // must stay algorithmically invisible exactly as in the
    // row-balanced chaos tests.
    let data = skewed();
    let part = nnz_partition(&data, MACHINES);

    let builder = TcpClusterBuilder::bind("127.0.0.1:0")
        .expect("bind")
        .fault_tolerance(FaultTolerance {
            worker_timeout: Duration::from_secs(10),
            heartbeat_every: Duration::from_millis(500),
            max_rejoins: 2,
        });
    let addr = builder.local_addr().expect("local addr").to_string();
    let mut fleet: Vec<Child> = (0..MACHINES).map(|_| spawn_worker(&addr)).collect();
    let mut cluster = builder.accept(MACHINES).expect("accepting workers");
    cluster.assign(specs(&data, &part)).expect("assigning shards");
    let handle = TcpHandle::new(cluster);

    let mut serial = build_dadm(&data, &part, Cluster::Serial);
    let mut tcp = build_dadm(&data, &part, Cluster::Tcp(handle.clone()));
    serial.resync();
    tcp.resync();
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        for round in 0..8 {
            serial.round();
            tcp.round();
            assert_eq!(serial.w(), tcp.w(), "w diverged at round {round} across the kill");
            assert_eq!(serial.v(), tcp.v(), "v diverged at round {round} across the kill");
            assert_eq!(
                serial.gap().to_bits(),
                tcp.gap().to_bits(),
                "gap diverged at round {round} across the kill"
            );
            if round == 2 {
                // Abrupt death between barriers; the replacement joins
                // through the §14 rejoin replay during round 3.
                let mut victim = fleet.remove(0);
                victim.kill().expect("killing worker");
                victim.wait().expect("reaping killed worker");
                fleet.push(spawn_worker(&addr));
            }
        }
        assert_eq!(
            handle.with(|c| c.rejoins_total()),
            1,
            "exactly one resurrection expected"
        );
        handle.with(|c| c.shutdown());
    }));
    drop(tcp);
    drop(handle);
    for mut child in fleet {
        if result.is_err() {
            // Failing assertion: don't leak workers into the runner.
            let _ = child.kill();
        }
        let _ = child.wait();
    }
    if let Err(panic) = result {
        std::panic::resume_unwind(panic);
    }
}
