//! Hierarchical intra-machine parallelism (DESIGN.md §10): an `(m, T)`
//! solve is DADM over `m·T` logical machines, so with power-of-two `T`
//! it must reproduce the flat `m·T`-machine solve **bit for bit** —
//! same sub-shard RNG draws, same per-round deltas, same trace math
//! fields, same final iterate — on every in-process backend (the TCP
//! twin lives in `comm/tcp.rs` and `rust/tests/tcp_cluster.rs`).

use dadm::comm::{Cluster, CostModel};
use dadm::coordinator::resolve_local_threads;
use dadm::data::synthetic::tiny_classification;
use dadm::data::{Dataset, Partition};
use dadm::loss::SmoothHinge;
use dadm::reg::{ElasticNet, Zero};
use dadm::solver::{machine_rng, machine_rngs, ProxSdca};
use dadm::testing::prop::for_each_case;
use dadm::{AccDadm, AccDadmOptions, Dadm, DadmOptions, Problem, SolveReport};

type TestDadm = Dadm<SmoothHinge, ElasticNet, Zero, ProxSdca>;

fn build(
    data: &Dataset,
    part: &Partition,
    cluster: Cluster,
    sp: f64,
    local_threads: usize,
) -> TestDadm {
    Problem::new(data, part)
        .loss(SmoothHinge::default())
        .reg(ElasticNet::new(0.1))
        .lambda(1e-3)
        .build_dadm(
            ProxSdca,
            DadmOptions {
                sp,
                cluster,
                cost: CostModel::free(),
                local_threads,
                ..Default::default()
            },
        )
}

/// The deterministic math fields of a trace (modeled compute is
/// wall-clock-measured and modeled comm intentionally differs between a
/// nested solve — m wire participants — and its flat m·T equivalent).
fn math_fields(report: &SolveReport) -> Vec<(usize, u64, u64, u64)> {
    report
        .trace
        .rounds
        .iter()
        .map(|r| {
            (
                r.round,
                r.passes.to_bits(),
                r.primal.to_bits(),
                r.dual.to_bits(),
            )
        })
        .collect()
}

#[test]
fn nested_rng_streams_equal_flat_machine_streams() {
    // Sub-shard k of machine l draws from fork l·T + k — identical to
    // flat logical machine l·T + k (the satellite's RNG-draw property).
    let seed = 0x5EED;
    for (m, t) in [(2usize, 2usize), (3, 4), (1, 8)] {
        for l in 0..m {
            let streams = machine_rngs(seed, l * t, t);
            for (k, mut got) in streams.into_iter().enumerate() {
                let mut flat = machine_rng(seed, l * t + k);
                for _ in 0..64 {
                    assert_eq!(got.next_u64(), flat.next_u64(), "m={m} t={t} l={l} k={k}");
                }
            }
        }
    }
}

#[test]
fn one_round_state_is_bit_identical_to_flat() {
    // After any number of rounds, every logical machine's dual state
    // (α, ṽ, w) in the nested solve equals the corresponding flat
    // machine's, bit for bit — which pins the per-round sub-deltas too
    // (they are deterministic functions of that state and the RNG).
    let n = 240; // divisible by m·T = 8 → split == flat balanced
    let data = tiny_classification(n, 6, 42);
    let part = Partition::balanced(n, 2, 42);
    let flat_part = Partition::balanced(n, 8, 42);

    let mut nested = build(&data, &part, Cluster::Serial, 0.3, 4);
    let mut flat = build(&data, &flat_part, Cluster::Serial, 0.3, 1);
    nested.resync();
    flat.resync();
    for _ in 0..4 {
        nested.round();
        flat.round();
    }
    assert_eq!(nested.w(), flat.w());
    assert_eq!(nested.v(), flat.v());
    let flat_states: Vec<_> = flat
        .machine_states()
        .map(|ws| (ws.alpha.clone(), ws.v_tilde.clone(), ws.w.clone()))
        .collect();
    for (k, ws) in nested.machine_states().enumerate() {
        assert_eq!(ws.alpha, flat_states[k].0, "α diverged on logical machine {k}");
        assert_eq!(ws.v_tilde, flat_states[k].1, "ṽ diverged on logical machine {k}");
        assert_eq!(ws.w, flat_states[k].2, "w diverged on logical machine {k}");
    }
}

#[test]
fn dadm_trace_matches_flat_on_serial_and_threads() {
    // Full-solve bit parity: (m = 2, T = 2) vs flat m = 4, on both
    // in-process backends (the acceptance pin of ISSUE 4).
    let n = 240;
    let data = tiny_classification(n, 8, 91);
    let part = Partition::balanced(n, 2, 91);
    let flat_part = Partition::balanced(n, 4, 91);
    for cluster in [Cluster::Serial, Cluster::Threads] {
        let mut nested = build(&data, &part, cluster.clone(), 0.25, 2);
        let nested_report = nested.solve(1e-6, 40);
        let mut flat = build(&data, &flat_part, cluster.clone(), 0.25, 1);
        let flat_report = flat.solve(1e-6, 40);
        assert_eq!(nested_report.converged, flat_report.converged);
        assert_eq!(
            math_fields(&nested_report),
            math_fields(&flat_report),
            "trace diverged on {cluster:?}"
        );
        assert_eq!(nested_report.w, flat_report.w, "iterates diverged on {cluster:?}");
        assert_eq!(nested.machines(), 2);
        assert_eq!(nested.local_threads(), 2);
        assert_eq!(flat.machines(), 4);
    }
}

#[test]
fn serial_and_threads_agree_under_local_threads() {
    // The threaded backend (pool sub-queue dispatch) must be bit-equal
    // to the serial one at the same (m, T) — including non-power-of-two
    // T, where flat parity is not claimed but backend parity is.
    let n = 210;
    let data = tiny_classification(n, 6, 7);
    let part = Partition::balanced(n, 2, 7);
    for t in [2usize, 3, 4] {
        let mut serial = build(&data, &part, Cluster::Serial, 0.3, t);
        let mut threads = build(&data, &part, Cluster::Threads, 0.3, t);
        serial.resync();
        threads.resync();
        for round in 0..6 {
            serial.round();
            threads.round();
            assert_eq!(serial.w(), threads.w(), "T={t} diverged at round {round}");
        }
        assert_eq!(serial.gap().to_bits(), threads.gap().to_bits(), "T={t}");
        serial.check_v_invariant().unwrap();
        threads.check_v_invariant().unwrap();
    }
}

#[test]
fn prop_one_round_parity_across_shapes() {
    // Random (m, power-of-two T, sp) shapes with m·T | n: one nested
    // round equals one flat round bit for bit on both backends.
    for_each_case(0x10CA1, 12, |g| {
        let m = g.usize_in(1, 4);
        let t = 1usize << g.usize_in(0, 3); // 1, 2, 4
        let per = g.usize_in(2, 7);
        let n = m * t * per * 4;
        let sp = [0.2, 0.5, 1.0][g.usize_in(0, 3)];
        let seed = g.rng().next_u64();
        let data = tiny_classification(n, 5, seed);
        let part = Partition::balanced(n, m, seed);
        let flat_part = Partition::balanced(n, m * t, seed);
        let cluster = if g.bool(0.5) {
            Cluster::Serial
        } else {
            Cluster::Threads
        };
        let mut nested = build(&data, &part, cluster.clone(), sp, t);
        let mut flat = build(&data, &flat_part, cluster, sp, 1);
        nested.resync();
        flat.resync();
        nested.round();
        flat.round();
        assert_eq!(nested.v(), flat.v(), "m={m} t={t} sp={sp}");
        assert_eq!(nested.w(), flat.w(), "m={m} t={t} sp={sp}");
        assert_eq!(nested.gap().to_bits(), flat.gap().to_bits(), "m={m} t={t}");
    });
}

#[test]
fn acc_dadm_trace_matches_flat() {
    // Acc-DADM inherits the hierarchy through its inner DADM; the
    // Remark-12 default κ uses the logical machine count m·T, so the
    // nested and flat stage schedules are identical.
    let n = 240;
    let data = tiny_classification(n, 6, 19);
    let part = Partition::balanced(n, 2, 19);
    let flat_part = Partition::balanced(n, 4, 19);
    let build_acc = |part: &Partition, t: usize| -> AccDadm<_, _, _> {
        Problem::new(&data, part)
            .loss(SmoothHinge::default())
            .lambda(1e-3)
            .l1(1e-5)
            .build_acc_dadm(
                ProxSdca,
                AccDadmOptions {
                    dadm: DadmOptions {
                        sp: 0.5,
                        cost: CostModel::free(),
                        local_threads: t,
                        ..Default::default()
                    },
                    ..Default::default()
                },
            )
    };
    let mut nested = build_acc(&part, 2);
    let nested_report = nested.solve(1e-4, 30);
    let mut flat = build_acc(&flat_part, 1);
    let flat_report = flat.solve(1e-4, 30);
    assert_eq!(nested.kappa.to_bits(), flat.kappa.to_bits(), "κ must agree");
    assert_eq!(nested_report.rounds, flat_report.rounds);
    assert_eq!(math_fields(&nested_report), math_fields(&flat_report));
    assert_eq!(nested_report.w, flat_report.w, "Acc-DADM iterates diverged");
    assert_eq!(nested.stages(), flat.stages());
}

#[test]
fn auto_and_oversized_requests_resolve_safely() {
    let part = Partition::balanced(12, 3, 5); // shards of 4
    // Explicit oversized request clamps to the smallest shard.
    assert_eq!(resolve_local_threads(64, &part), 4);
    // Auto resolves to ≥ 1 and never exceeds the smallest shard.
    let auto = resolve_local_threads(0, &part);
    assert!((1..=4).contains(&auto), "auto resolved to {auto}");
    // A tiny solve with an oversized request still runs (clamped).
    let data = tiny_classification(12, 4, 5);
    let mut dadm = build(&data, &part, Cluster::Serial, 1.0, 64);
    assert_eq!(dadm.local_threads(), 4);
    assert_eq!(dadm.machines(), 3);
    let report = dadm.solve(1e-4, 50);
    assert!(report.primal.is_finite());
    dadm.check_v_invariant().unwrap();
}

#[test]
fn checkpoint_resume_is_bit_exact_under_local_threads() {
    // Snapshots store the logical machines (m·T dual blocks + RNG
    // streams), so a nested solve resumes bit-exactly too.
    let n = 160;
    let data = tiny_classification(n, 5, 77);
    let part = Partition::balanced(n, 2, 77);
    let mut full = build(&data, &part, Cluster::Serial, 0.25, 2);
    full.resync();
    for _ in 0..8 {
        full.round();
    }
    let mut first = build(&data, &part, Cluster::Serial, 0.25, 2);
    first.resync();
    for _ in 0..4 {
        first.round();
    }
    let ck = first.checkpoint();
    let mut resumed = build(&data, &part, Cluster::Serial, 0.25, 2);
    resumed.restore(&ck).unwrap();
    for _ in 0..4 {
        resumed.round();
    }
    assert_eq!(resumed.w(), full.w(), "resumed nested trajectory diverged");
    assert_eq!(resumed.gap().to_bits(), full.gap().to_bits());
}
