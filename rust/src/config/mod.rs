//! Experiment configuration.
//!
//! A small key=value configuration layer (no `serde`/`clap` offline):
//! [`ExperimentConfig`] captures everything a paper experiment needs —
//! dataset, loss, λ/μ grid point, machine count, sampling fraction,
//! method — parsed from CLI `--key value` pairs or a `key = value` file,
//! with validation and defaults matching §10.

use crate::comm::sparse::DeltaCodec;
use crate::data::Balance;
use crate::loss::LossKind;
use crate::solver::SolverKind;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Which execution backend the launcher should build. `Serial` and
/// `Threads` map directly onto [`crate::comm::Cluster`] variants; `Tcp`
/// makes the launcher bind `tcp_listen`, wait for `machines` worker
/// processes (`dadm worker --connect host:port`), and assign them their
/// partitions before solving.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClusterKind {
    /// Deterministic in-process serial execution.
    Serial,
    /// In-process thread-pool parallelism.
    Threads,
    /// Real multi-process TCP transport (DESIGN.md §9).
    Tcp,
}

impl ClusterKind {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "serial" => ClusterKind::Serial,
            "threads" => ClusterKind::Threads,
            "tcp" => ClusterKind::Tcp,
            other => bail!("unknown cluster backend `{other}` (serial|threads|tcp)"),
        })
    }
}

/// How examples are assigned to machines (`partition` key).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionKind {
    /// Seeded-shuffle balanced partition ([`crate::data::Partition::balanced`])
    /// — the paper's §10 protocol and the default for in-memory data.
    Balanced,
    /// Contiguous balanced row ranges ([`crate::data::Partition::contiguous`])
    /// — required (and the default) when training from a compiled cache,
    /// so each worker's shard is a zero-copy range of the mapping.
    Contiguous,
}

impl PartitionKind {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "balanced" => PartitionKind::Balanced,
            "contiguous" => PartitionKind::Contiguous,
            other => bail!("unknown partition scheme `{other}` (balanced|contiguous)"),
        })
    }
}

/// Optimization method to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Plain DADM (≡ CoCoA+ for h = 0, balanced partitions — §6).
    Dadm,
    /// Accelerated DADM (Algorithm 3).
    AccDadm,
    /// OWL-QN batch baseline.
    Owlqn,
}

impl Method {
    /// Parse from string.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "dadm" | "cocoa+" | "cocoa" => Method::Dadm,
            "acc-dadm" | "acc_dadm" | "acc" => Method::AccDadm,
            "owlqn" | "owl-qn" => Method::Owlqn,
            other => bail!("unknown method `{other}`"),
        })
    }

    /// Canonical name.
    pub fn name(self) -> &'static str {
        match self {
            Method::Dadm => "dadm",
            Method::AccDadm => "acc-dadm",
            Method::Owlqn => "owlqn",
        }
    }
}

/// One experiment's full configuration (defaults mirror §10).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Dataset name: one of the synthetic analogues
    /// (`synth-covtype|synth-rcv1|synth-higgs|synth-kdd2010|tiny`) or a
    /// path to a LIBSVM file.
    pub dataset: String,
    /// Scale factor for synthetic generation (fraction of the paper n).
    pub scale: f64,
    /// Train out-of-core from a compiled binary CSR cache at this path
    /// (`dadm compile-cache` output; DESIGN.md §15) instead of parsing
    /// `dataset`. The cache is mmapped and rows are served zero-copy;
    /// under `cluster = tcp` the workers map the file themselves and no
    /// training rows cross the wire. Implies `partition = contiguous`.
    pub cache: Option<String>,
    /// Partition scheme override; `None` = auto (contiguous when `cache`
    /// is set or `balance = nnz`, the seeded balanced shuffle otherwise).
    /// A text-parsed run with `partition = contiguous` is bit-identical
    /// to the cache run of the same file.
    pub partition: Option<PartitionKind>,
    /// Shard chunking formula for contiguous cuts (`balance` key,
    /// DESIGN.md §16): `rows` equalizes row counts (the default and the
    /// historical parity pin), `nnz` equalizes stored non-zeros — on
    /// skewed sparse data the per-round barrier waits on the densest
    /// shard, so nnz balance is what equalizes local-step time. `nnz`
    /// implies contiguous partitioning (a seeded shuffle has no nnz
    /// form).
    pub balance: Balance,
    /// Method.
    pub method: Method,
    /// Loss.
    pub loss: LossKind,
    /// Local solver.
    pub solver: SolverKind,
    /// Regularization λ.
    pub lambda: f64,
    /// L1 weight μ.
    pub mu: f64,
    /// Machines m.
    pub machines: usize,
    /// Intra-machine threads T: each machine runs T concurrent sub-shard
    /// solvers and eval legs (DESIGN.md §10). 1 = single-threaded
    /// machines (the default), 0 = auto from the host core count; the
    /// request is clamped to the smallest shard size.
    pub local_threads: usize,
    /// Sampling fraction sp.
    pub sp: f64,
    /// Target normalized duality gap.
    pub eps: f64,
    /// Maximum passes over the data (the paper caps at 100).
    pub max_passes: f64,
    /// Evaluate the duality gap every `gap_every` rounds (≥ 1). With the
    /// fused telemetry of DESIGN.md §11 a gap round costs no extra
    /// barrier, but the primal sum is still a pass over the data — raise
    /// this at small `sp` if compute is the bottleneck.
    pub gap_every: usize,
    /// Exactly resum the incremental dual telemetry every
    /// `conj_resum_every` rounds (bounds the float drift of the O(1)
    /// running `Σ−φ*(−α)` updates; 0 = never resum). See
    /// `DadmOptions::conj_resum_every`.
    pub conj_resum_every: usize,
    /// Cluster backend.
    pub cluster: ClusterKind,
    /// Coordinator listen address for `cluster = tcp` (use port 0 for an
    /// ephemeral port; the launcher prints the bound address).
    pub tcp_listen: String,
    /// Declare a TCP worker dead after this many seconds without a frame
    /// (DESIGN.md §14 liveness; `cluster = tcp` only).
    pub worker_timeout: f64,
    /// Heartbeat-probe cadence in seconds while a TCP reply is pending —
    /// also the socket read timeout; must be ≤ `worker_timeout`.
    pub heartbeat_every: f64,
    /// How many worker deaths the coordinator may heal by deterministic
    /// resurrection (§14 rejoin protocol); 0 = fail fast with a typed
    /// `CommError::WorkerFault` instead.
    pub max_rejoins: u32,
    /// Write a resumable solver snapshot to this path (DADM only).
    pub checkpoint: Option<String>,
    /// Snapshot cadence in rounds (with `checkpoint`).
    pub checkpoint_every: usize,
    /// Restore solver state from this snapshot before solving
    /// (DADM only; requires the identical dataset/partition/λ).
    pub resume: Option<String>,
    /// Charge communication for the actual sparse Δv/Δṽ messages instead
    /// of dense length-d vectors (see `DadmOptions::sparse_comm`).
    pub sparse_comm: bool,
    /// Wire codec for the Δv/Δṽ payloads: exact `f64` (the default),
    /// `f32`, or scaled `i16` — the lossy codecs keep their quantization
    /// error in per-sender residuals and feed it back into the next
    /// round's delta (DESIGN.md §13; see `DadmOptions::compress`).
    pub compress: DeltaCodec,
    /// Double-buffered rounds: issue round `t+1`'s fused local-step
    /// dispatch while round `t`'s reduce/global step completes, at one
    /// round of bounded broadcast staleness (DADM only; see
    /// `DadmOptions::overlap`).
    pub overlap: bool,
    /// RNG seed.
    pub seed: u64,
    /// Momentum ν = 0 (paper's practical choice) vs theory.
    pub nu_theory: bool,
    /// Comm model latency α (seconds).
    pub comm_alpha: f64,
    /// Comm model inverse bandwidth β (seconds/byte).
    pub comm_beta: f64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            dataset: "synth-covtype".into(),
            scale: 0.01,
            cache: None,
            partition: None,
            balance: Balance::Rows,
            method: Method::AccDadm,
            loss: LossKind::SmoothHinge,
            solver: SolverKind::ProxSdca,
            lambda: 1e-6,
            mu: 1e-5,
            machines: 8,
            local_threads: 1,
            sp: 0.2,
            eps: 1e-3,
            max_passes: 100.0,
            gap_every: 1,
            conj_resum_every: 64,
            cluster: ClusterKind::Serial,
            tcp_listen: "127.0.0.1:7171".into(),
            worker_timeout: 30.0,
            heartbeat_every: 5.0,
            max_rejoins: 0,
            checkpoint: None,
            checkpoint_every: 10,
            resume: None,
            sparse_comm: false,
            compress: DeltaCodec::F64,
            overlap: false,
            seed: 42,
            nu_theory: false,
            comm_alpha: 100e-6,
            comm_beta: 8e-9,
        }
    }
}

impl ExperimentConfig {
    /// Parse from `--key value` CLI arguments.
    pub fn from_args(args: &[String]) -> Result<Self> {
        let mut map = BTreeMap::new();
        let mut it = args.iter();
        while let Some(k) = it.next() {
            let key = k
                .strip_prefix("--")
                .with_context(|| format!("expected `--key`, got `{k}`"))?;
            let v = it
                .next()
                .with_context(|| format!("missing value for `--{key}`"))?;
            map.insert(key.to_string(), v.clone());
        }
        Self::from_map(map)
    }

    /// Parse from a `key = value` config file body (`#` comments allowed).
    pub fn from_file_body(body: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        for (lineno, line) in body.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected `key = value`", lineno + 1))?;
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
        Self::from_map(map)
    }

    fn from_map(mut map: BTreeMap<String, String>) -> Result<Self> {
        let mut cfg = ExperimentConfig::default();
        let mut take = |k: &str| map.remove(k);
        if let Some(v) = take("dataset") {
            cfg.dataset = v;
        }
        if let Some(v) = take("scale") {
            cfg.scale = v.parse().context("scale")?;
        }
        if let Some(v) = take("cache") {
            cfg.cache = Some(v);
        }
        if let Some(v) = take("partition") {
            cfg.partition = Some(PartitionKind::parse(&v)?);
        }
        if let Some(v) = take("balance") {
            cfg.balance = match v.as_str() {
                "rows" => Balance::Rows,
                "nnz" => Balance::Nnz,
                other => bail!("unknown balance mode `{other}` (rows|nnz)"),
            };
        }
        if let Some(v) = take("method") {
            cfg.method = Method::parse(&v)?;
        }
        if let Some(v) = take("loss") {
            cfg.loss = LossKind::parse(&v)?;
        }
        if let Some(v) = take("solver") {
            cfg.solver = SolverKind::parse(&v)?;
        }
        if let Some(v) = take("lambda") {
            cfg.lambda = v.parse().context("lambda")?;
        }
        if let Some(v) = take("mu") {
            cfg.mu = v.parse().context("mu")?;
        }
        if let Some(v) = take("machines") {
            cfg.machines = v.parse().context("machines")?;
        }
        if let Some(v) = take("local-threads") {
            cfg.local_threads = v.parse().context("local-threads")?;
        }
        if let Some(v) = take("sp") {
            cfg.sp = v.parse().context("sp")?;
        }
        if let Some(v) = take("eps") {
            cfg.eps = v.parse().context("eps")?;
        }
        if let Some(v) = take("max-passes") {
            cfg.max_passes = v.parse().context("max-passes")?;
        }
        if let Some(v) = take("gap-every") {
            cfg.gap_every = v.parse().context("gap-every")?;
        }
        if let Some(v) = take("conj-resum-every") {
            cfg.conj_resum_every = v.parse().context("conj-resum-every")?;
        }
        if let Some(v) = take("checkpoint") {
            cfg.checkpoint = Some(v);
        }
        if let Some(v) = take("checkpoint-every") {
            cfg.checkpoint_every = v.parse().context("checkpoint-every")?;
        }
        if let Some(v) = take("resume") {
            cfg.resume = Some(v);
        }
        if let Some(v) = take("cluster") {
            cfg.cluster = ClusterKind::parse(&v)?;
        }
        if let Some(v) = take("tcp-listen") {
            cfg.tcp_listen = v;
        }
        if let Some(v) = take("worker-timeout") {
            cfg.worker_timeout = v.parse().context("worker-timeout")?;
        }
        if let Some(v) = take("heartbeat-every") {
            cfg.heartbeat_every = v.parse().context("heartbeat-every")?;
        }
        if let Some(v) = take("max-rejoins") {
            cfg.max_rejoins = v.parse().context("max-rejoins")?;
        }
        if let Some(v) = take("sparse-comm") {
            cfg.sparse_comm = match v.as_str() {
                "true" | "1" | "on" => true,
                "false" | "0" | "off" => false,
                other => bail!("sparse-comm must be true or false, got `{other}`"),
            };
        }
        if let Some(v) = take("compress") {
            cfg.compress = DeltaCodec::parse(&v)
                .with_context(|| format!("compress must be f64, f32 or i16, got `{v}`"))?;
        }
        if let Some(v) = take("overlap") {
            cfg.overlap = match v.as_str() {
                "true" | "1" | "on" => true,
                "false" | "0" | "off" => false,
                other => bail!("overlap must be true or false, got `{other}`"),
            };
        }
        if let Some(v) = take("seed") {
            cfg.seed = v.parse().context("seed")?;
        }
        if let Some(v) = take("nu") {
            cfg.nu_theory = match v.as_str() {
                "theory" => true,
                "zero" | "0" => false,
                other => bail!("nu must be `theory` or `zero`, got `{other}`"),
            };
        }
        if let Some(v) = take("comm-alpha") {
            cfg.comm_alpha = v.parse().context("comm-alpha")?;
        }
        if let Some(v) = take("comm-beta") {
            cfg.comm_beta = v.parse().context("comm-beta")?;
        }
        if let Some(k) = map.keys().next() {
            bail!("unknown config key `{k}`");
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Validate ranges.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.lambda > 0.0, "lambda must be > 0");
        anyhow::ensure!(self.mu >= 0.0, "mu must be ≥ 0");
        anyhow::ensure!(self.machines >= 1, "machines must be ≥ 1");
        anyhow::ensure!(
            self.sp > 0.0 && self.sp <= 1.0,
            "sp must be in (0, 1], got {}",
            self.sp
        );
        anyhow::ensure!(self.eps > 0.0, "eps must be > 0");
        anyhow::ensure!(self.scale > 0.0, "scale must be > 0");
        anyhow::ensure!(self.gap_every >= 1, "gap-every must be ≥ 1, got {}", self.gap_every);
        anyhow::ensure!(
            self.checkpoint_every >= 1,
            "checkpoint-every must be ≥ 1, got {}",
            self.checkpoint_every
        );
        if self.overlap {
            anyhow::ensure!(
                self.method == Method::Dadm,
                "overlap (double-buffered rounds) is supported for method=dadm only"
            );
        }
        if self.compress != DeltaCodec::F64 {
            anyhow::ensure!(
                self.method != Method::Owlqn,
                "compress applies to the dual methods' Δv exchange (dadm/acc-dadm); \
                 OWL-QN has no delta wire path"
            );
        }
        anyhow::ensure!(
            self.worker_timeout > 0.0,
            "worker-timeout must be > 0 seconds, got {}",
            self.worker_timeout
        );
        anyhow::ensure!(
            self.heartbeat_every > 0.0 && self.heartbeat_every <= self.worker_timeout,
            "heartbeat-every must be in (0, worker-timeout], got {} (worker-timeout {})",
            self.heartbeat_every,
            self.worker_timeout
        );
        if self.cache.is_some() {
            anyhow::ensure!(
                self.partition != Some(PartitionKind::Balanced),
                "cache requires contiguous partitioning: mapped shards are \
                 zero-copy row ranges (drop `partition = balanced` or the cache)"
            );
        }
        if self.balance == Balance::Nnz {
            anyhow::ensure!(
                self.partition != Some(PartitionKind::Balanced),
                "balance = nnz chooses contiguous cut points over the nnz \
                 prefix sums; a seeded shuffle has no nnz form (drop \
                 `partition = balanced` or use `balance = rows`)"
            );
            anyhow::ensure!(
                self.method != Method::Owlqn || self.local_threads == 1,
                "balance = nnz with local-threads > 1 is supported for the \
                 dual methods only: the OWL-QN driver sub-splits shards by \
                 rows, which would disagree with a remote worker's \
                 nnz-balanced sub-shards (use local-threads = 1 or \
                 balance = rows)"
            );
        }
        if self.checkpoint.is_some() || self.resume.is_some() {
            anyhow::ensure!(
                self.method == Method::Dadm,
                "checkpoint/resume are supported for method=dadm only \
                 (Acc-DADM stage state and OWL-QN history are not snapshotted)"
            );
            anyhow::ensure!(
                self.cluster != ClusterKind::Tcp,
                "checkpoint/resume are unsupported on cluster=tcp \
                 (worker dual state lives in remote processes)"
            );
        }
        Ok(())
    }

    /// The §14 liveness/resurrection policy for the TCP backend, as the
    /// comm layer consumes it.
    pub fn fault_tolerance(&self) -> crate::comm::FaultTolerance {
        crate::comm::FaultTolerance {
            worker_timeout: std::time::Duration::from_secs_f64(self.worker_timeout),
            heartbeat_every: std::time::Duration::from_secs_f64(self.heartbeat_every),
            max_rejoins: self.max_rejoins,
        }
    }

    /// Max communication rounds implied by the pass cap: `passes/sp`.
    pub fn max_rounds(&self) -> usize {
        (self.max_passes / self.sp).ceil() as usize
    }

    /// The synthetic generator behind `dataset`, when it names one —
    /// `None` for LIBSVM paths. Used both to materialize the dataset
    /// locally and, under `cluster = tcp`, to ship the *generator* to
    /// the workers so no training data crosses the wire.
    pub fn synthetic_spec(&self) -> Option<crate::data::synthetic::SyntheticSpec> {
        use crate::data::synthetic::SyntheticSpec;
        if self.cache.is_some() {
            // The compiled cache *is* the data source; never regenerate.
            return None;
        }
        Some(match self.dataset.as_str() {
            "synth-covtype" => SyntheticSpec::covtype(self.scale),
            "synth-rcv1" => SyntheticSpec::rcv1(self.scale),
            "synth-higgs" => SyntheticSpec::higgs(self.scale),
            "synth-kdd2010" => SyntheticSpec::kdd2010(self.scale),
            // Matches `tiny_classification(2000, 32, seed)`.
            "tiny" => SyntheticSpec {
                name: "tiny".into(),
                n: 2000,
                d: 32,
                density: 1.0,
                signal_density: 1.0,
                noise: 0.05,
                seed: self.seed,
            },
            _ => return None,
        })
    }

    /// Materialize the dataset: the mmapped cache when `cache` is set,
    /// else the synthetic analogue or LIBSVM path named by `dataset`.
    pub fn load_dataset(&self) -> Result<crate::data::Dataset> {
        if let Some(cache) = &self.cache {
            let c = crate::data::CsrCache::open(std::path::Path::new(cache))?;
            return Ok(c.dataset()?);
        }
        match self.synthetic_spec() {
            Some(spec) => Ok(spec.generate()),
            None => crate::data::libsvm::load(std::path::Path::new(&self.dataset)),
        }
    }

    /// The effective partition scheme: the explicit `partition` key,
    /// else contiguous when training from a cache or under
    /// `balance = nnz` (whose cut points are contiguous by
    /// construction), else the paper's seeded balanced shuffle.
    pub fn partition_kind(&self) -> PartitionKind {
        self.partition
            .unwrap_or(if self.cache.is_some() || self.balance == Balance::Nnz {
                PartitionKind::Contiguous
            } else {
                PartitionKind::Balanced
            })
    }

    /// Build the effective [`crate::data::Partition`] over `data`'s
    /// examples. Under `balance = nnz` the contiguous cut points come
    /// from the data's nnz prefix sums ([`crate::data::Partition::contiguous_nnz`]);
    /// row-balanced cuts need only the example count.
    pub fn build_partition(&self, data: &crate::data::Dataset) -> crate::data::Partition {
        let n = data.n();
        match self.partition_kind() {
            PartitionKind::Balanced => {
                crate::data::Partition::balanced(n, self.machines, self.seed)
            }
            PartitionKind::Contiguous => match self.balance {
                Balance::Rows => crate::data::Partition::contiguous(n, self.machines),
                Balance::Nnz => {
                    crate::data::Partition::contiguous_nnz(&data.x.nnz_prefix(), self.machines)
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_protocol() {
        let c = ExperimentConfig::default();
        assert_eq!(c.mu, 1e-5);
        assert_eq!(c.machines, 8);
        assert_eq!(c.max_passes, 100.0);
        c.validate().unwrap();
    }

    #[test]
    fn parses_cli_args() {
        let args: Vec<String> = [
            "--method", "dadm", "--lambda", "1e-7", "--machines", "20", "--sp", "0.8",
            "--loss", "logistic", "--dataset", "synth-higgs",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let c = ExperimentConfig::from_args(&args).unwrap();
        assert_eq!(c.method, Method::Dadm);
        assert_eq!(c.lambda, 1e-7);
        assert_eq!(c.machines, 20);
        assert_eq!(c.sp, 0.8);
        assert_eq!(c.loss, LossKind::Logistic);
    }

    #[test]
    fn parses_file_body() {
        let body = "# experiment\nmethod = acc-dadm\nlambda = 1e-8\nsp = 0.05\n";
        let c = ExperimentConfig::from_file_body(body).unwrap();
        assert_eq!(c.method, Method::AccDadm);
        assert_eq!(c.lambda, 1e-8);
        assert_eq!(c.sp, 0.05);
    }

    #[test]
    fn parses_sparse_comm_flag() {
        assert!(!ExperimentConfig::default().sparse_comm);
        let c = ExperimentConfig::from_file_body("sparse-comm = true\n").unwrap();
        assert!(c.sparse_comm);
        let c = ExperimentConfig::from_file_body("sparse-comm = off\n").unwrap();
        assert!(!c.sparse_comm);
        assert!(ExperimentConfig::from_file_body("sparse-comm = maybe\n").is_err());
    }

    #[test]
    fn parses_fault_tolerance_keys() {
        let c = ExperimentConfig::default();
        assert_eq!(c.worker_timeout, 30.0);
        assert_eq!(c.heartbeat_every, 5.0);
        assert_eq!(c.max_rejoins, 0);
        let c = ExperimentConfig::from_file_body(
            "worker-timeout = 2.5
heartbeat-every = 0.5
max-rejoins = 3
",
        )
        .unwrap();
        assert_eq!(c.worker_timeout, 2.5);
        assert_eq!(c.heartbeat_every, 0.5);
        assert_eq!(c.max_rejoins, 3);
        let ft = c.fault_tolerance();
        assert_eq!(ft.worker_timeout, std::time::Duration::from_millis(2500));
        assert_eq!(ft.heartbeat_every, std::time::Duration::from_millis(500));
        assert_eq!(ft.max_rejoins, 3);
        // The probe cadence must fit inside the death deadline.
        assert!(ExperimentConfig::from_file_body(
            "worker-timeout = 1
heartbeat-every = 2
"
        )
        .is_err());
        assert!(ExperimentConfig::from_file_body("worker-timeout = 0
").is_err());
        assert!(ExperimentConfig::from_file_body("heartbeat-every = 0
").is_err());
    }

    #[test]
    fn parses_compress_codec() {
        assert_eq!(ExperimentConfig::default().compress, DeltaCodec::F64);
        let c = ExperimentConfig::from_file_body("method = dadm\ncompress = i16\n").unwrap();
        assert_eq!(c.compress, DeltaCodec::I16);
        let c = ExperimentConfig::from_file_body("method = acc\ncompress = f32\n").unwrap();
        assert_eq!(c.compress, DeltaCodec::F32);
        assert!(ExperimentConfig::from_file_body("compress = i8\n").is_err());
        // OWL-QN has no delta wire path to compress.
        assert!(ExperimentConfig::from_file_body("method = owlqn\ncompress = i16\n").is_err());
    }

    #[test]
    fn parses_overlap_flag() {
        assert!(!ExperimentConfig::default().overlap);
        let c = ExperimentConfig::from_file_body("method = dadm\noverlap = true\n").unwrap();
        assert!(c.overlap);
        let c = ExperimentConfig::from_file_body("method = dadm\noverlap = off\n").unwrap();
        assert!(!c.overlap);
        assert!(ExperimentConfig::from_file_body("method = dadm\noverlap = maybe\n").is_err());
        // Double-buffered rounds are a plain-DADM engine mode.
        assert!(ExperimentConfig::from_file_body("method = acc\noverlap = true\n").is_err());
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(ExperimentConfig::from_file_body("bogus = 1\n").is_err());
        assert!(ExperimentConfig::from_file_body("sp = 1.5\n").is_err());
        assert!(ExperimentConfig::from_file_body("lambda = -1\n").is_err());
        let args: Vec<String> = vec!["--sp".into()];
        assert!(ExperimentConfig::from_args(&args).is_err());
    }

    #[test]
    fn parses_gap_every_and_rejects_zero() {
        assert_eq!(ExperimentConfig::default().gap_every, 1);
        let c = ExperimentConfig::from_file_body("gap-every = 7\n").unwrap();
        assert_eq!(c.gap_every, 7);
        assert!(ExperimentConfig::from_file_body("gap-every = 0\n").is_err());
    }

    #[test]
    fn parses_conj_resum_every() {
        assert_eq!(ExperimentConfig::default().conj_resum_every, 64);
        let c = ExperimentConfig::from_file_body("conj-resum-every = 16\n").unwrap();
        assert_eq!(c.conj_resum_every, 16);
        // 0 = never resum (drift unbounded, the user's call).
        let c = ExperimentConfig::from_file_body("conj-resum-every = 0\n").unwrap();
        assert_eq!(c.conj_resum_every, 0);
        assert!(ExperimentConfig::from_file_body("conj-resum-every = -3\n").is_err());
    }

    #[test]
    fn checkpoint_flags_require_dadm() {
        let body = "method = dadm\ncheckpoint = /tmp/x.ck\ncheckpoint-every = 5\n";
        let ok = ExperimentConfig::from_file_body(body).unwrap();
        assert_eq!(ok.checkpoint.as_deref(), Some("/tmp/x.ck"));
        assert_eq!(ok.checkpoint_every, 5);
        let acc = ExperimentConfig::from_file_body("method = acc\ncheckpoint = x.ck\n");
        assert!(acc.is_err());
        let owl = ExperimentConfig::from_file_body("method = owlqn\nresume = x.ck\n");
        assert!(owl.is_err());
        let zero = ExperimentConfig::from_file_body("checkpoint-every = 0\n");
        assert!(zero.is_err());
    }

    #[test]
    fn parses_local_threads() {
        assert_eq!(ExperimentConfig::default().local_threads, 1);
        let c = ExperimentConfig::from_file_body("local-threads = 4\n").unwrap();
        assert_eq!(c.local_threads, 4);
        // 0 = auto (resolved against the partition at launch).
        let c = ExperimentConfig::from_file_body("local-threads = 0\n").unwrap();
        assert_eq!(c.local_threads, 0);
        assert!(ExperimentConfig::from_file_body("local-threads = -1\n").is_err());
    }

    #[test]
    fn parses_cluster_backends() {
        assert_eq!(ExperimentConfig::default().cluster, ClusterKind::Serial);
        let c = ExperimentConfig::from_file_body("cluster = threads\n").unwrap();
        assert_eq!(c.cluster, ClusterKind::Threads);
        let c =
            ExperimentConfig::from_file_body("cluster = tcp\ntcp-listen = 127.0.0.1:0\n").unwrap();
        assert_eq!(c.cluster, ClusterKind::Tcp);
        assert_eq!(c.tcp_listen, "127.0.0.1:0");
        assert!(ExperimentConfig::from_file_body("cluster = bogus\n").is_err());
        // Checkpoint/resume need local worker state.
        let ck = "method = dadm\ncluster = tcp\ncheckpoint = /tmp/x.ck\n";
        assert!(ExperimentConfig::from_file_body(ck).is_err());
    }

    #[test]
    fn parses_cache_and_partition_keys() {
        let c = ExperimentConfig::default();
        assert_eq!(c.cache, None);
        assert_eq!(c.partition, None);
        assert_eq!(c.partition_kind(), PartitionKind::Balanced);

        let c = ExperimentConfig::from_file_body("partition = contiguous\n").unwrap();
        assert_eq!(c.partition, Some(PartitionKind::Contiguous));
        assert_eq!(c.partition_kind(), PartitionKind::Contiguous);

        // A cache implies contiguous shards unless explicitly overridden…
        let c = ExperimentConfig::from_file_body("cache = /tmp/x.dadmcache\n").unwrap();
        assert_eq!(c.cache.as_deref(), Some("/tmp/x.dadmcache"));
        assert_eq!(c.partition_kind(), PartitionKind::Contiguous);
        // …and a shuffled partition cannot be served as mapped row ranges.
        assert!(ExperimentConfig::from_file_body(
            "cache = /tmp/x.dadmcache\npartition = balanced\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_file_body("partition = bogus\n").is_err());
    }

    #[test]
    fn cache_suppresses_synthetic_regeneration() {
        let mut c = ExperimentConfig::default();
        c.dataset = "tiny".into();
        assert!(c.synthetic_spec().is_some());
        c.cache = Some("/tmp/x.dadmcache".into());
        // The compiled cache is the data source even when `dataset`
        // names a generator — a TCP launch must ship DataSpec::Cache,
        // never DataSpec::Synthetic.
        assert!(c.synthetic_spec().is_none());
    }

    #[test]
    fn build_partition_matches_kind() {
        let data = crate::data::synthetic::tiny_classification(10, 4, 1);
        let mut c = ExperimentConfig::default();
        c.machines = 3;
        let p = c.build_partition(&data);
        p.check_invariants(true).unwrap();
        c.partition = Some(PartitionKind::Contiguous);
        let p = c.build_partition(&data);
        assert_eq!(p.shard(0), &[0, 1, 2, 3]);
        assert_eq!(p.shard(2), &[7, 8, 9]);
    }

    #[test]
    fn parses_balance_key_and_implications() {
        assert_eq!(ExperimentConfig::default().balance, Balance::Rows);
        let c = ExperimentConfig::from_file_body("balance = rows\n").unwrap();
        assert_eq!(c.balance, Balance::Rows);
        assert_eq!(c.partition_kind(), PartitionKind::Balanced);

        // nnz balance implies contiguous cut points…
        let c = ExperimentConfig::from_file_body("balance = nnz\n").unwrap();
        assert_eq!(c.balance, Balance::Nnz);
        assert_eq!(c.partition_kind(), PartitionKind::Contiguous);
        // …and a seeded shuffle has no nnz form.
        assert!(
            ExperimentConfig::from_file_body("balance = nnz\npartition = balanced\n").is_err()
        );
        assert!(ExperimentConfig::from_file_body("balance = columns\n").is_err());
    }

    #[test]
    fn nnz_balance_builds_nnz_cuts() {
        let data = crate::data::synthetic::tiny_classification(12, 4, 1);
        let mut c = ExperimentConfig::default();
        c.machines = 3;
        c.balance = Balance::Nnz;
        let p = c.build_partition(&data);
        p.check_invariants(false).unwrap();
        let q = crate::data::Partition::contiguous_nnz(&data.x.nnz_prefix(), 3);
        for l in 0..3 {
            assert_eq!(p.shard(l), q.shard(l), "machine {l}");
        }
    }

    #[test]
    fn synthetic_spec_matches_load_dataset() {
        let mut c = ExperimentConfig::default();
        c.dataset = "tiny".into();
        let spec = c.synthetic_spec().unwrap();
        assert_eq!(spec.n, 2000);
        assert_eq!(spec.d, 32);
        let a = spec.generate();
        let b = c.load_dataset().unwrap();
        assert_eq!(a.n(), b.n());
        assert_eq!(a.y, b.y);
        c.dataset = "/does/not/name/a/generator".into();
        assert!(c.synthetic_spec().is_none());
    }

    #[test]
    fn max_rounds_from_pass_cap() {
        let mut c = ExperimentConfig::default();
        c.sp = 0.05;
        c.max_passes = 100.0;
        assert_eq!(c.max_rounds(), 2000);
    }

    #[test]
    fn loads_synthetic_datasets() {
        let mut c = ExperimentConfig::default();
        c.scale = 2e-4;
        for name in ["synth-covtype", "synth-higgs"] {
            c.dataset = name.into();
            let d = c.load_dataset().unwrap();
            assert!(d.n() > 50);
        }
        c.dataset = "tiny".into();
        assert_eq!(c.load_dataset().unwrap().n(), 2000);
    }
}
