//! `dadm` — leader entrypoint / experiment launcher.
//!
//! See `dadm --help` for usage; all logic lives in [`dadm::cli`] so the
//! launcher is testable in-process.

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    dadm::cli::main_with_args(&args)
}
