//! DADM — Algorithm 2 of the paper.
//!
//! One iteration = a **local step** (every machine approximately
//! maximizes its local dual `D̃_ℓ(α_(ℓ)|β_ℓ)` over a random mini-batch)
//! followed by a **global step** (one allreduce of the weighted `Δv_ℓ`,
//! then the closed-form β-maximization of Propositions 4/5, then a
//! broadcast of `Δṽ`). The duality gap `P(w) − D(α, β)` is computed
//! exactly and drives the stopping condition.
//!
//! Global step in conjugate coordinates (see DESIGN.md §6): with
//! `v ← v + Σ_ℓ (n_ℓ/n)Δv_ℓ`,
//!
//! ```text
//! z  = ∇g*(v)                (elastic-net soft-threshold)
//! w  = prox_{h/(λn)}(z)      (identity when h = 0)
//! ṽ  = v − (z − w)           (so ∇g*(ṽ) = w and β is Prop-5 optimal)
//! ρ  = λn·(z − w)            (= Σ_ℓ β_ℓ = ∇h(w))
//! ```
//!
//! With `h = 0` and balanced partitions this procedure is exactly CoCoA+
//! (§6), which is how the CoCoA+ baseline is run in the benches.

use crate::comm::sparse::{should_densify, tree_allreduce_delta, Delta, SparseDelta};
use crate::comm::{Cluster, CostModel};
use crate::data::{Dataset, Partition};
use crate::loss::Loss;
use crate::metrics::{RoundRecord, Trace};
use crate::reg::{ExtraReg, Regularizer};
use crate::solver::{LocalSolver, WorkerState};
use crate::utils::Rng;
use std::time::Instant;

/// DADM driver options.
#[derive(Clone, Debug)]
pub struct DadmOptions {
    /// Mini-batch sampling fraction `sp = M_ℓ/n_ℓ` (§10).
    pub sp: f64,
    /// Execution backend for local steps.
    pub cluster: Cluster,
    /// Communication cost model.
    pub cost: CostModel,
    /// Seed for partition-independent mini-batch draws.
    pub seed: u64,
    /// Evaluate the duality gap every `gap_every` rounds (1 = every
    /// round). Gap evaluation is instrumentation: excluded from modeled
    /// compute/comm time.
    pub gap_every: usize,
    /// Charge communication for the *actual* sparse Δv/Δṽ messages the
    /// pipeline exchanges (index+value pairs, 12 B per stored entry,
    /// capped at the dense size) instead of dense length-d vectors — the
    /// paper's "it may be beneficial to pass Δṽ instead, especially when
    /// Δṽ is sparse but ṽ is dense" (§6). The data path always sends
    /// sparse messages when the support is small (DESIGN.md §7);
    /// algorithmically both settings are identical, the flag only selects
    /// which message size the α-β cost model charges.
    pub sparse_comm: bool,
}

impl Default for DadmOptions {
    fn default() -> Self {
        DadmOptions {
            sp: 0.2,
            cluster: Cluster::Serial,
            cost: CostModel::default(),
            seed: 0xDAD_A,
            gap_every: 1,
            sparse_comm: false,
        }
    }
}

/// Result of a [`Dadm::solve`] run.
#[derive(Clone, Debug)]
pub struct SolveReport {
    /// Final primal iterate.
    pub w: Vec<f64>,
    /// Final primal objective.
    pub primal: f64,
    /// Final dual objective.
    pub dual: f64,
    /// Communication rounds used.
    pub rounds: usize,
    /// Passes over the data.
    pub passes: f64,
    /// Whether the gap target was reached.
    pub converged: bool,
    /// Full per-round trace.
    pub trace: Trace,
}

impl SolveReport {
    /// Final normalized duality gap `(P − D)/n`.
    pub fn normalized_gap(&self) -> f64 {
        (self.primal - self.dual) / self.trace.n as f64
    }
}

/// One simulated machine: shard state + its private mini-batch RNG.
#[derive(Clone, Debug)]
pub struct Machine {
    /// Shard + dual state.
    pub state: WorkerState,
    /// Private RNG stream (mirrors the per-process seed of §10).
    pub rng: Rng,
    /// Mini-batch size `M_ℓ`.
    pub batch: usize,
}

/// The DADM coordinator (Algorithm 2), generic over loss `L`, strongly
/// convex regularizer `R` (= `g`), extra regularizer `H` (= `h`), and the
/// local solver `S`.
#[derive(Debug)]
pub struct Dadm<L, R, H, S> {
    /// Loss `φ`.
    pub loss: L,
    /// Regularizer `g` (swapped per stage by Acc-DADM).
    pub reg: R,
    /// Extra regularizer `h`.
    pub h: H,
    /// Effective regularization weight λ (λ̃ during Acc-DADM stages).
    pub lambda: f64,
    /// Local solver.
    pub solver: S,
    machines: Vec<Machine>,
    weights: Vec<f64>, // n_ℓ/n
    v: Vec<f64>,       // global v = Σ X_i α_i / (λn)
    v_tilde: Vec<f64>, // global ṽ (Eq. 15)
    w: Vec<f64>,       // global primal iterate ∇g*(ṽ)
    rho: Vec<f64>,     // Σ_ℓ β_ℓ = ∇h(w)
    n: usize,
    d: usize,
    opts: DadmOptions,
    // cumulative accounting
    rounds: usize,
    passes: f64,
    compute_secs: f64,
    comm_secs: f64,
    wall_start: Instant,
}

impl<L, R, H, S> Dadm<L, R, H, S>
where
    L: Loss,
    R: Regularizer,
    H: ExtraReg,
    S: LocalSolver,
{
    /// Build a DADM instance: shard the data per `part`, zero-initialize
    /// all dual state.
    pub fn new(
        data: &Dataset,
        part: &Partition,
        loss: L,
        reg: R,
        h: H,
        lambda: f64,
        solver: S,
        opts: DadmOptions,
    ) -> Self {
        assert!(lambda > 0.0, "λ must be positive");
        assert!(
            opts.sp > 0.0 && opts.sp <= 1.0,
            "sampling fraction must be in (0, 1]"
        );
        let m = part.machines();
        let mut seed_rng = Rng::new(opts.seed);
        let machines: Vec<Machine> = (0..m)
            .map(|l| {
                let state = WorkerState::from_partition(data, part, l);
                let batch = ((opts.sp * state.n_l() as f64).ceil() as usize)
                    .clamp(1, state.n_l());
                Machine {
                    state,
                    rng: seed_rng.fork(l as u64),
                    batch,
                }
            })
            .collect();
        let n = data.n();
        let d = data.dim();
        let weights = machines
            .iter()
            .map(|mch| mch.state.n_l() as f64 / n as f64)
            .collect();
        Dadm {
            loss,
            reg,
            h,
            lambda,
            solver,
            machines,
            weights,
            v: vec![0.0; d],
            v_tilde: vec![0.0; d],
            w: vec![0.0; d],
            rho: vec![0.0; d],
            n,
            d,
            opts,
            rounds: 0,
            passes: 0.0,
            compute_secs: 0.0,
            comm_secs: 0.0,
            wall_start: Instant::now(),
        }
    }

    /// Number of machines `m`.
    pub fn machines(&self) -> usize {
        self.machines.len()
    }

    /// Problem size `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Current primal iterate `w`.
    pub fn w(&self) -> &[f64] {
        &self.w
    }

    /// Current global `v` (dual combination / λn).
    pub fn v(&self) -> &[f64] {
        &self.v
    }

    /// Immutable view of the machines (tests / invariant checks).
    pub fn machine_states(&self) -> impl Iterator<Item = &WorkerState> {
        self.machines.iter().map(|m| &m.state)
    }

    /// Communication rounds so far.
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Passes over the data so far.
    pub fn passes(&self) -> f64 {
        self.passes
    }

    /// Cumulative (compute, comm) modeled seconds.
    pub fn modeled_secs(&self) -> (f64, f64) {
        (self.compute_secs, self.comm_secs)
    }

    /// The Proposition-4/5 global synchronization, recomputing
    /// `(z, w, ṽ, ρ)` from the current `v`. Called after every aggregate
    /// and by [`Dadm::resync`].
    fn global_sync(&mut self) {
        let z = self.reg.grad_conj(&self.v);
        let w = self.h.prox(&z, 1.0 / (self.lambda * self.n as f64));
        for j in 0..self.d {
            self.rho[j] = self.lambda * self.n as f64 * (z[j] - w[j]);
            self.v_tilde[j] = self.v[j] - (z[j] - w[j]);
        }
        self.w = w;
    }

    /// Broadcast the current global `ṽ` to every machine (sets, not
    /// increments — used at init and Acc-DADM stage boundaries).
    pub fn resync(&mut self) {
        self.global_sync();
        let (v_tilde, reg) = (&self.v_tilde, &self.reg);
        for m in &mut self.machines {
            m.state.set_v_tilde(v_tilde, reg);
        }
    }

    /// One DADM iteration (Algorithm 2): local step on every machine,
    /// aggregate, global step, broadcast. Returns the modeled
    /// (compute, comm) seconds of this round.
    pub fn round(&mut self) -> (f64, f64) {
        let loss = &self.loss;
        let reg = &self.reg;
        let solver = &self.solver;
        let lambda = self.lambda;

        // --- Local step (parallel across machines) ---
        let run = self.opts.cluster.run(&mut self.machines, |_, m| {
            let n_l = m.state.n_l();
            let batch_idx = m.rng.sample_indices(n_l, m.batch);
            solver.local_step(
                &mut m.state,
                &batch_idx,
                loss,
                reg,
                lambda * n_l as f64,
                &mut m.rng,
            )
        });

        // --- Global step ---
        // v ← v + Σ (n_ℓ/n)·Δv_ℓ  (one sparse-aware tree allreduce). The
        // per-worker Δv_ℓ arrive as the exact messages that would go on
        // the wire (sparse index/value pairs in the mini-batch regime,
        // dense vectors otherwise); the reduce also reports the largest
        // message carried on any tree edge — merged supports grow toward
        // the root — which is what the cost model charges.
        let (delta_v, reduce_elems) = tree_allreduce_delta(run.results, &self.weights);
        delta_v.add_into(&mut self.v);
        let v_tilde_old = self.v_tilde.clone();
        self.global_sync();
        // Δṽ broadcast; workers update incrementally (Algorithm 2). The
        // support of Δṽ can exceed Δv's (h's prox couples coordinates),
        // so it is extracted from the synced ṽ rather than assumed; the
        // message densifies once the sparse encoding stops paying off.
        let mut bcast_idx: Vec<u32> = Vec::new();
        let mut bcast_val: Vec<f64> = Vec::new();
        for j in 0..self.d {
            let dv = self.v_tilde[j] - v_tilde_old[j];
            if dv != 0.0 {
                bcast_idx.push(j as u32);
                bcast_val.push(dv);
            }
        }
        let bcast = SparseDelta {
            dim: self.d,
            idx: bcast_idx,
            val: bcast_val,
        };
        let delta_v_tilde = if should_densify(bcast.nnz(), self.d) {
            Delta::Dense(bcast.to_dense())
        } else {
            Delta::Sparse(bcast)
        };
        let bcast_elems = delta_v_tilde.message_elems();
        let reg = &self.reg;
        match &delta_v_tilde {
            Delta::Dense(dv) => {
                for m in &mut self.machines {
                    m.state.apply_global(dv, reg);
                }
            }
            Delta::Sparse(s) => {
                for m in &mut self.machines {
                    m.state.apply_global_sparse(s, reg);
                }
            }
        }

        // --- Accounting ---
        let m = self.machines.len();
        let comm = if self.opts.sparse_comm {
            // Charge the actual message sizes: the reduce leg by the
            // largest message anywhere in its tree (leaf or merged), the
            // broadcast leg by the Δṽ message just sent.
            self.opts
                .cost
                .allreduce_time(m, reduce_elems.max(bcast_elems))
        } else {
            self.opts.cost.allreduce_time(m, self.d)
        };
        self.compute_secs += run.parallel_secs;
        self.comm_secs += comm;
        self.rounds += 1;
        self.passes += self.opts.sp;
        (run.parallel_secs, comm)
    }

    /// Distributed loss sum `Σ_i φ_i(x_iᵀ w)` at an arbitrary `w`
    /// (one parallel pass; also used by Acc-DADM's original-problem gap).
    pub fn loss_sum_at(&mut self, w: &[f64]) -> f64 {
        let loss = &self.loss;
        let run = self
            .opts
            .cluster
            .run(&mut self.machines, |_, m| m.state.primal_loss_sum(loss, w));
        run.results.iter().sum()
    }

    /// Distributed conjugate sum `Σ_i −φ_i*(−α_i)` at the current duals.
    pub fn conj_sum(&mut self) -> f64 {
        let loss = &self.loss;
        let run = self
            .opts
            .cluster
            .run(&mut self.machines, |_, m| m.state.dual_conj_sum(loss));
        run.results.iter().sum()
    }

    /// Exact primal objective `P(w) = Σφ_i(x_iᵀw) + λn·g(w) + h(w)` at the
    /// current iterate.
    pub fn primal(&mut self) -> f64 {
        let w = self.w.clone();
        let loss_sum = self.loss_sum_at(&w);
        loss_sum + self.lambda * self.n as f64 * self.reg.value(&self.w) + self.h.value(&self.w)
    }

    /// Exact dual objective
    /// `D(α, β) = Σ−φ*(−α_i) − λn·g*(ṽ) − h*(ρ)` at the Prop-5-optimal β.
    pub fn dual(&mut self) -> f64 {
        let conj_sum = self.conj_sum();
        conj_sum - self.lambda * self.n as f64 * self.reg.conj(&self.v_tilde)
            - self.h.conj(&self.rho)
    }

    /// Current duality gap `P − D` (one full pass; instrumentation).
    pub fn gap(&mut self) -> f64 {
        self.primal() - self.dual()
    }

    /// Run until the **normalized** duality gap `(P−D)/n ≤ eps` or
    /// `max_rounds` is exhausted.
    pub fn solve(&mut self, eps: f64, max_rounds: usize) -> SolveReport {
        self.wall_start = Instant::now();
        let mut trace = Trace::new(self.n);
        self.resync();
        let record = |s: &mut Self, trace: &mut Trace| {
            let primal = s.primal();
            let dual = s.dual();
            trace.push(RoundRecord {
                round: s.rounds,
                passes: s.passes,
                primal,
                dual,
                compute_secs: s.compute_secs,
                comm_secs: s.comm_secs,
                wall_secs: s.wall_start.elapsed().as_secs_f64(),
            });
            primal - dual
        };
        let mut gap = record(self, &mut trace);
        let mut converged = gap / self.n as f64 <= eps;
        let mut rounds_done = 0usize;
        while !converged && rounds_done < max_rounds {
            self.round();
            rounds_done += 1;
            if rounds_done % self.opts.gap_every == 0 || rounds_done == max_rounds {
                gap = record(self, &mut trace);
                converged = gap / self.n as f64 <= eps;
            }
        }
        SolveReport {
            w: self.w.clone(),
            primal: trace.last().map(|r| r.primal).unwrap_or(f64::NAN),
            dual: trace.last().map(|r| r.dual).unwrap_or(f64::NAN),
            rounds: self.rounds,
            passes: self.passes,
            converged,
            trace,
        }
    }

    /// Replace the regularizer (Acc-DADM stage transition) keeping all
    /// dual state, then re-synchronize `ṽ`, `w` in the new geometry.
    pub fn set_reg(&mut self, reg: R) {
        self.reg = reg;
        self.resync();
    }

    /// Decompose into (machines, v) for state hand-off (Acc-DADM reuses
    /// the same instance, so this is only for tests / inspection).
    pub fn dual_state(&self) -> (&[f64], Vec<&[f64]>) {
        (
            &self.v,
            self.machines.iter().map(|m| m.state.alpha.as_slice()).collect(),
        )
    }

    /// Snapshot the dual state (see [`super::Checkpoint`]): `(λ, v, α)`
    /// fully determine the solve; everything else is one global sync.
    pub fn checkpoint(&self) -> super::Checkpoint {
        super::Checkpoint {
            lambda: self.lambda,
            v: self.v.clone(),
            alpha: self
                .machines
                .iter()
                .map(|m| m.state.alpha.clone())
                .collect(),
        }
    }

    /// Restore a snapshot taken on an identically-configured instance
    /// (same dataset, partition, λ) and re-synchronize.
    pub fn restore(&mut self, ck: &super::Checkpoint) -> anyhow::Result<()> {
        anyhow::ensure!(
            (ck.lambda - self.lambda).abs() <= 1e-15 * self.lambda.abs(),
            "checkpoint λ = {} does not match instance λ = {}",
            ck.lambda,
            self.lambda
        );
        anyhow::ensure!(ck.v.len() == self.d, "dimension mismatch");
        anyhow::ensure!(
            ck.alpha.len() == self.machines.len(),
            "machine count mismatch"
        );
        for (m, a) in self.machines.iter_mut().zip(&ck.alpha) {
            anyhow::ensure!(
                a.len() == m.state.n_l(),
                "shard size mismatch (same partition seed required)"
            );
            m.state.alpha.copy_from_slice(a);
        }
        self.v.copy_from_slice(&ck.v);
        self.resync();
        anyhow::Context::context(self.check_v_invariant(), "restored state is inconsistent")?;
        Ok(())
    }

    /// Validate the cross-machine bookkeeping invariant
    /// `v == Σ_ℓ (n_ℓ/n) · X_ℓᵀα_ℓ/(λ n_ℓ)` (tests only; full recompute).
    pub fn check_v_invariant(&self) -> anyhow::Result<()> {
        let mut want = vec![0.0; self.d];
        for m in &self.machines {
            let raw = m.state.raw_dual_combination();
            for (wj, rj) in want.iter_mut().zip(&raw) {
                *wj += rj / (self.lambda * self.n as f64);
            }
        }
        for (j, (got, want)) in self.v.iter().zip(&want).enumerate() {
            anyhow::ensure!(
                (got - want).abs() < 1e-8 * (1.0 + want.abs()),
                "v[{j}] drifted: {got} vs recomputed {want}"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{tiny_classification, tiny_regression};
    use crate::loss::{Logistic, SmoothHinge, Squared};
    use crate::reg::{ElasticNet, Zero};
    use crate::solver::{ProxSdca, TheoremStep};

    fn opts() -> DadmOptions {
        DadmOptions {
            cost: CostModel::free(),
            ..Default::default()
        }
    }

    #[test]
    fn gap_is_nonnegative_and_decreases() {
        let data = tiny_classification(200, 8, 1);
        let part = Partition::balanced(200, 4, 1);
        let mut dadm = Dadm::new(
            &data,
            &part,
            SmoothHinge::default(),
            ElasticNet::new(0.0),
            Zero,
            1e-2,
            ProxSdca,
            opts(),
        );
        dadm.resync();
        let gap0 = dadm.gap();
        assert!(gap0 >= -1e-9, "initial gap negative: {gap0}");
        // The dual objective is monotone non-decreasing (each local step
        // improves the local dual, Prop-5 β-maximization improves D); the
        // primal — and hence the gap — may wiggle between rounds but must
        // trend down.
        let mut prev_dual = dadm.dual();
        for _ in 0..15 {
            dadm.round();
            let gap = dadm.gap();
            assert!(gap >= -1e-9, "gap negative: {gap}");
            let dual = dadm.dual();
            assert!(
                dual >= prev_dual - 1e-8,
                "dual decreased: {prev_dual} -> {dual}"
            );
            prev_dual = dual;
        }
        let gap_end = dadm.gap();
        assert!(gap_end < 0.5 * gap0, "no overall progress: {gap0} -> {gap_end}");
        dadm.check_v_invariant().unwrap();
    }

    #[test]
    fn converges_to_target() {
        let data = tiny_classification(150, 6, 2);
        let part = Partition::balanced(150, 3, 2);
        let mut dadm = Dadm::new(
            &data,
            &part,
            SmoothHinge::default(),
            ElasticNet::new(0.1),
            Zero,
            1e-2,
            ProxSdca,
            DadmOptions { sp: 1.0, ..opts() },
        );
        let report = dadm.solve(1e-6, 300);
        assert!(report.converged, "gap = {}", report.normalized_gap());
        assert!(report.normalized_gap() <= 1e-6);
        // Trace rounds increase and the dual ascends monotonically.
        assert!(report.trace.rounds.len() >= 2);
        for pair in report.trace.rounds.windows(2) {
            assert!(pair[1].round > pair[0].round);
            assert!(pair[1].dual >= pair[0].dual - 1e-8);
        }
    }

    #[test]
    fn single_machine_equals_multi_machine_start() {
        // After the first global step from a zero start with sp = 1, the
        // m-machine primal iterate must be reproducible from the dual
        // combination regardless of m (the β decoupling at work).
        let data = tiny_classification(120, 5, 3);
        for m in [1usize, 2, 4] {
            let part = Partition::balanced(120, m, 3);
            let mut dadm = Dadm::new(
                &data,
                &part,
                SmoothHinge::default(),
                ElasticNet::new(0.0),
                Zero,
                1e-2,
                TheoremStep::default(),
                DadmOptions { sp: 1.0, ..opts() },
            );
            dadm.resync();
            dadm.round();
            dadm.check_v_invariant().unwrap();
            // w == ∇g*(ṽ) == ṽ for τ = 0 and h = 0, and ṽ == v.
            assert_eq!(dadm.w(), &dadm.v_tilde[..]);
        }
    }

    #[test]
    fn logistic_converges() {
        let data = tiny_classification(100, 4, 4);
        let part = Partition::balanced(100, 4, 4);
        let mut dadm = Dadm::new(
            &data,
            &part,
            Logistic,
            ElasticNet::new(0.05),
            Zero,
            5e-3,
            ProxSdca,
            DadmOptions { sp: 0.5, ..opts() },
        );
        let report = dadm.solve(1e-5, 500);
        assert!(report.converged, "gap = {}", report.normalized_gap());
    }

    #[test]
    fn ridge_regression_matches_closed_form() {
        // Squared loss, τ = 0, h = 0: P(w) = Σ(x_iᵀw − y_i)² + (λn/2)‖w‖²
        // has closed form w* = (XᵀX·2 + λn I)⁻¹ · 2Xᵀy … solve via DADM and
        // verify the primal optimality conditions ∇P(w*) ≈ 0 instead of
        // inverting: ∇P(w) = 2Xᵀ(Xw − y) + λn·w.
        let data = tiny_regression(80, 4, 0.05, 5);
        let part = Partition::balanced(80, 2, 5);
        let lambda = 0.05;
        let mut dadm = Dadm::new(
            &data,
            &part,
            Squared,
            ElasticNet::l2(),
            Zero,
            lambda,
            ProxSdca,
            DadmOptions { sp: 1.0, ..opts() },
        );
        let report = dadm.solve(1e-10, 2000);
        assert!(report.converged);
        let w = &report.w;
        let preds = data.x.matvec(w);
        let resid: Vec<f64> = preds.iter().zip(&data.y).map(|(p, y)| p - y).collect();
        let grad_loss = data.x.matvec_t(&resid);
        let n = data.n() as f64;
        for j in 0..data.dim() {
            let g = 2.0 * grad_loss[j] + lambda * n * w[j];
            assert!(g.abs() < 1e-3, "∇P[{j}] = {g}");
        }
    }

    #[test]
    fn serial_and_threads_agree() {
        let data = tiny_classification(100, 5, 6);
        let part = Partition::balanced(100, 4, 6);
        let build = |cluster: Cluster| {
            Dadm::new(
                &data,
                &part,
                SmoothHinge::default(),
                ElasticNet::new(0.1),
                Zero,
                1e-2,
                ProxSdca,
                DadmOptions {
                    cluster,
                    ..opts()
                },
            )
        };
        let mut a = build(Cluster::Serial);
        let mut b = build(Cluster::Threads);
        a.resync();
        b.resync();
        for _ in 0..5 {
            a.round();
            b.round();
        }
        for (x, y) in a.w().iter().zip(b.w()) {
            assert!((x - y).abs() < 1e-12, "cluster backends diverge");
        }
        assert!((a.gap() - b.gap()).abs() < 1e-9);
    }

    #[test]
    fn comm_accounting_scales_with_machines() {
        let data = tiny_classification(120, 16, 7);
        let run = |m: usize| {
            let part = Partition::balanced(120, m, 7);
            let mut dadm = Dadm::new(
                &data,
                &part,
                SmoothHinge::default(),
                ElasticNet::new(0.0),
                Zero,
                1e-2,
                ProxSdca,
                DadmOptions::default(), // default (non-free) cost model
            );
            dadm.resync();
            for _ in 0..3 {
                dadm.round();
            }
            dadm.modeled_secs().1
        };
        assert_eq!(run(1), 0.0); // single machine: no comm
        assert!(run(8) > run(2));
    }

    #[test]
    fn checkpoint_resume_continues_identically() {
        let data = tiny_classification(120, 6, 71);
        let part = Partition::balanced(120, 3, 71);
        let build = || {
            Dadm::new(
                &data,
                &part,
                SmoothHinge::default(),
                ElasticNet::new(0.1),
                Zero,
                1e-3,
                ProxSdca,
                opts(),
            )
        };
        // Reference: 10 uninterrupted rounds.
        let mut full = build();
        full.resync();
        for _ in 0..10 {
            full.round();
        }
        // Checkpoint after 5, restore into a fresh instance, run 5 more.
        let mut first = build();
        first.resync();
        for _ in 0..5 {
            first.round();
        }
        let mut buf = Vec::new();
        first.checkpoint().save(&mut buf).unwrap();
        let ck = crate::coordinator::Checkpoint::load(std::io::Cursor::new(buf)).unwrap();
        let mut resumed = build();
        resumed.restore(&ck).unwrap();
        // Mini-batch RNG streams restart, so the trajectories differ, but
        // the restored state must be exactly the checkpointed one…
        for (a, b) in resumed.w().iter().zip(first.w()) {
            assert!((a - b).abs() < 1e-15);
        }
        assert!((resumed.gap() - first.gap()).abs() < 1e-9);
        // …and further rounds must keep converging from there.
        let before = resumed.gap();
        for _ in 0..5 {
            resumed.round();
        }
        assert!(resumed.gap() < before);
        // And the uninterrupted run's gap is in the same ballpark (same
        // algorithm, different mini-batch draws after round 5).
        assert!(full.gap() > 0.0);
    }

    #[test]
    fn sparse_comm_cheaper_same_math() {
        // Sparse data + tiny mini-batches ⇒ Δv has small support, so the
        // §6 sparse-message option must charge less comm time while
        // producing bit-identical iterates.
        use crate::data::synthetic::SyntheticSpec;
        let data = SyntheticSpec {
            name: "sparse-comm".into(),
            n: 300,
            d: 512,
            density: 0.01,
            signal_density: 0.1,
            noise: 0.1,
            seed: 99,
        }
        .generate();
        let part = Partition::balanced(300, 4, 9);
        let run = |sparse_comm: bool| {
            let mut dadm = Dadm::new(
                &data,
                &part,
                SmoothHinge::default(),
                ElasticNet::new(0.1),
                Zero,
                1e-2,
                ProxSdca,
                DadmOptions {
                    sp: 0.05,
                    sparse_comm,
                    ..DadmOptions::default() // default (non-free) cost model
                },
            );
            dadm.resync();
            for _ in 0..5 {
                dadm.round();
            }
            (dadm.w().to_vec(), dadm.modeled_secs().1)
        };
        let (w_dense, t_dense) = run(false);
        let (w_sparse, t_sparse) = run(true);
        assert_eq!(w_dense, w_sparse, "cost model must not change the math");
        assert!(
            t_sparse < t_dense,
            "sparse messages not cheaper: {t_sparse} vs {t_dense}"
        );
    }

    #[test]
    fn gap_every_skips_instrumentation() {
        let data = tiny_classification(100, 4, 8);
        let part = Partition::balanced(100, 2, 8);
        let mut dadm = Dadm::new(
            &data,
            &part,
            SmoothHinge::default(),
            ElasticNet::new(0.0),
            Zero,
            1e-2,
            ProxSdca,
            DadmOptions {
                gap_every: 5,
                ..opts()
            },
        );
        let report = dadm.solve(0.0, 12); // never converges; 12 rounds
        // Records: initial + rounds 5, 10, 12 (final).
        let recorded: Vec<usize> = report.trace.rounds.iter().map(|r| r.round).collect();
        assert_eq!(recorded, vec![0, 5, 10, 12]);
    }
}
