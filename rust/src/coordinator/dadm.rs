//! DADM — Algorithm 2 of the paper.
//!
//! One iteration = a **local step** (every machine approximately
//! maximizes its local dual `D̃_ℓ(α_(ℓ)|β_ℓ)` over a random mini-batch)
//! followed by a **global step** (one allreduce of the weighted `Δv_ℓ`,
//! then the closed-form β-maximization of Propositions 4/5, then a
//! broadcast of `Δṽ`). The duality gap `P(w) − D(α, β)` is computed
//! exactly and drives the stopping condition.
//!
//! Global step in conjugate coordinates (see DESIGN.md §6): with
//! `v ← v + Σ_ℓ (n_ℓ/n)Δv_ℓ`,
//!
//! ```text
//! z  = ∇g*(v)                (elastic-net soft-threshold)
//! w  = prox_{h/(λn)}(z)      (identity when h = 0)
//! ṽ  = v − (z − w)           (so ∇g*(ṽ) = w and β is Prop-5 optimal)
//! ρ  = λn·(z − w)            (= Σ_ℓ β_ℓ = ∇h(w))
//! ```
//!
//! With `h = 0` and balanced partitions this procedure is exactly CoCoA+
//! (§6), which is how the CoCoA+ baseline is run in the benches.
//!
//! Two hot-path properties of the round (DESIGN.md §4/§7):
//!
//! * **Fused broadcast apply.** The `Δṽ` broadcast is *not* applied to
//!   the machines on the coordinator thread (that loop was O(m·d) serial
//!   per round); it is parked in a reusable [`PendingBroadcast`] and each
//!   pool worker applies it to its own machine at the start of the *next*
//!   round's parallel section, fused with the local-step dispatch — one
//!   pool barrier per round instead of two, and the apply runs
//!   machine-parallel. [`Dadm::sync_workers`] flushes the pending message
//!   when worker state must be observed between rounds.
//! * **Allocation-free global step.** `∇g*`, the `h`-prox, the old-`ṽ`
//!   copy and the broadcast extraction all write into persistent scratch
//!   buffers; after warm-up a round performs no heap allocation on the
//!   coordinator side.
//! * **Fused gap telemetry.** (DESIGN.md §11.) The duality-gap sums ride
//!   the same barrier: the leg evaluates `Σφ_i(x_iᵀw)` right after the
//!   broadcast apply (i.e. at the entering synced iterate) and reads the
//!   machines' running `Σ−φ*(−α)` after the step, so a `--gap-every 1`
//!   solve issues exactly one cluster barrier per steady-state round and
//!   its records — lagged by one round — are bit-identical to the
//!   three-barrier eval path's ([`Dadm::round_fused`], [`Dadm::gap_sums`],
//!   [`Dadm::barriers`]).
//!
//! The solve loop itself lives in [`crate::runtime::engine`]: `Dadm`
//! implements [`RoundAlgorithm`] and [`Dadm::solve`] is a thin wrapper
//! over the shared [`Driver`].

use super::problem::Problem;
use crate::comm::allreduce::tree_sum;
use crate::comm::sparse::{
    codec_image, compress_delta, i16_step, max_abs, should_densify, should_densify_with,
    sparse_message_elems, sparse_message_elems_with, tree_allreduce_delta, Delta, DeltaCodec,
    SparseDelta, DENSE_ENTRY_BYTES,
};
use crate::comm::wire::{BroadcastRef, EvalOp, StepFlags};
use crate::comm::{run_subgroup, Cluster, CostModel};
use crate::data::{Balance, Dataset, Partition};
use crate::loss::Loss;
use crate::metrics::StepStats;
use crate::reg::{ExtraReg, Regularizer};
use crate::runtime::engine::{Driver, RoundAlgorithm, RoundOutcome, RoundRequest};
use crate::solver::{batch_size, machine_rngs, run_fused_step, LocalSolver, WorkerState};
use crate::utils::Rng;

pub use crate::runtime::engine::SolveReport;

/// DADM driver options.
#[derive(Clone, Debug)]
pub struct DadmOptions {
    /// Mini-batch sampling fraction `sp = M_ℓ/n_ℓ` (§10).
    pub sp: f64,
    /// Execution backend for local steps.
    pub cluster: Cluster,
    /// Communication cost model.
    pub cost: CostModel,
    /// Seed for partition-independent mini-batch draws.
    pub seed: u64,
    /// Evaluate the duality gap every `gap_every` rounds (1 = every
    /// round; must be ≥ 1). Gap evaluation is instrumentation: excluded
    /// from modeled compute/comm time.
    pub gap_every: usize,
    /// Charge communication for the *actual* sparse Δv/Δṽ messages the
    /// pipeline exchanges (index+value pairs, 12 B per stored entry,
    /// capped at the dense size) instead of dense length-d vectors — the
    /// paper's "it may be beneficial to pass Δṽ instead, especially when
    /// Δṽ is sparse but ṽ is dense" (§6). The data path always sends
    /// sparse messages when the support is small (DESIGN.md §7);
    /// algorithmically both settings are identical, the flag only selects
    /// which message size the α-β cost model charges.
    pub sparse_comm: bool,
    /// Intra-machine parallelism `T` (DESIGN.md §10): every machine's
    /// shard is sub-partitioned once at setup into `T` sub-shards, each
    /// with its own ProxSDCA sub-solver, dual block, RNG stream
    /// (logical index `ℓ·T + k`, same fork discipline as a flat solve)
    /// and scratch; the `T` sub-deltas merge machine-locally at zero
    /// modeled wire cost before the cross-machine reduce. `1` (the
    /// default) is exactly the previous single-solver behavior; `0`
    /// resolves to the host's available parallelism. The request is
    /// clamped to the smallest shard size. Because this is DADM applied
    /// one level down, an `(m, T)` solve with power-of-two `T` is
    /// bit-identical to a flat `m·T`-machine solve over the split
    /// partition (pinned in `rust/tests/local_threads.rs`).
    pub local_threads: usize,
    /// Exact-resummation cadence for the incremental dual telemetry
    /// (DESIGN.md §11): every `conj_resum_every`-th round each machine
    /// recomputes its running `Σ−φ*(−α_i)` with one exact O(n_ℓ) pass,
    /// bounding the float drift of the O(1) per-coordinate updates.
    /// `0` disables resummation. Driven by the coordinator's round
    /// counter, so every backend — and a checkpoint-resumed run — resums
    /// at the same rounds (bit parity).
    pub conj_resum_every: usize,
    /// Per-value codec for the cross-machine delta messages
    /// (DESIGN.md §13): each machine quantizes its Δv reply at the wire
    /// boundary and the coordinator quantizes the Δṽ broadcast, both
    /// with error feedback — the quantization error is carried in a
    /// residual and re-sent in later rounds instead of being dropped, so
    /// convergence is preserved. [`DeltaCodec::F64`] (the default) is
    /// exact and bit-identical to the uncompressed pipeline.
    pub compress: DeltaCodec,
    /// Double-buffered rounds (DESIGN.md §13): the engine issues round
    /// `t+1`'s fused local-step dispatch before completing round `t`'s
    /// reduce/global step, hiding the coordinator leg behind worker
    /// compute at the price of one round of staleness on the broadcast
    /// iterate. Opt-in; checkpoint snapshots are disabled while
    /// overlapping (the pipeline holds un-reduced rounds).
    pub overlap: bool,
    /// Cut-point objective for the hierarchical sub-split when
    /// `local_threads > 1` (DESIGN.md §16): [`Balance::Rows`] (the
    /// default) equalizes example counts via [`Partition::split`];
    /// [`Balance::Nnz`] equalizes stored non-zeros via
    /// [`Partition::split_nnz`], so no sub-shard drags a round out
    /// because it drew the dense rows. Must match the machine-level
    /// partition's balance mode — remote TCP workers derive their
    /// sub-shards from the same formula over the `balance` byte shipped
    /// in the wire spec, so coordinator and worker cut points agree by
    /// construction (bit parity).
    pub balance: Balance,
}

impl Default for DadmOptions {
    fn default() -> Self {
        DadmOptions {
            sp: 0.2,
            cluster: Cluster::Serial,
            cost: CostModel::default(),
            seed: 0xDAD_A,
            gap_every: 1,
            sparse_comm: false,
            local_threads: 1,
            conj_resum_every: 64,
            compress: DeltaCodec::F64,
            overlap: false,
            balance: Balance::Rows,
        }
    }
}

impl DadmOptions {
    /// The effective intra-machine thread count for `part` — see
    /// [`resolve_local_threads`].
    pub fn resolved_local_threads(&self, part: &Partition) -> usize {
        resolve_local_threads(self.local_threads, part)
    }
}

/// The effective intra-machine thread count for a requested
/// `local_threads` over `part`: `0` resolves to the host's available
/// parallelism, and any request is clamped to the smallest shard size
/// (every sub-shard needs ≥ 1 example). The single resolution rule
/// shared by [`Dadm`], `AccDadm` (whose Remark-12 κ depends on the
/// *logical* machine count `m·T`), the OWL-QN driver and the launcher's
/// TCP worker specs — so they can never disagree on `T`.
pub fn resolve_local_threads(requested: usize, part: &Partition) -> usize {
    let requested = if requested == 0 {
        std::thread::available_parallelism().map_or(1, |n| n.get())
    } else {
        requested
    };
    requested.min(part.min_shard()).max(1)
}

/// One *logical* machine: shard state + its private mini-batch RNG.
/// Under hierarchical parallelism (`local_threads = T`, DESIGN.md §10)
/// a physical machine hosts `T` consecutive of these — logical machine
/// `k = ℓ·T + t` is physical machine `ℓ`'s sub-solver `t` — and the
/// coordinator dispatches them in groups of `T`. With `T = 1` the two
/// notions coincide and this is exactly the paper's per-machine state.
#[derive(Clone, Debug)]
pub struct Machine {
    /// Shard + dual state.
    pub state: WorkerState,
    /// Private RNG stream (mirrors the per-process seed of §10).
    pub rng: Rng,
    /// Mini-batch size `M_ℓ`.
    pub batch: usize,
}

/// The broadcast of the previous round's global step, parked until the
/// next parallel section applies it (fused with the local-step
/// dispatch). In exact mode the message carries the coordinates of `ṽ`
/// that changed — as their new **values**, not increments, so worker
/// replicas stay bit-identical to the coordinator (see
/// [`WorkerState::set_v_tilde_sparse_parts`]); its support and wire size
/// are exactly those of the paper's `Δṽ`, and the buffers are reused
/// round after round (no per-round allocation after warm-up). Under a
/// compressed codec the message is instead an **increment** (`Add`): the
/// quantized Δṽ images of DESIGN.md §13, applied with plain f64 adds so
/// every replica — and the coordinator's `v_image` shadow — performs the
/// identical operations.
#[derive(Clone, Debug)]
struct PendingBroadcast {
    kind: BroadcastKind,
    idx: Vec<u32>,
    val: Vec<f64>,
    dense: Vec<f64>,
    /// The compressed-broadcast increment message (`Add` kind only).
    add: Delta,
    /// Codec of `add` (stamped on the wire frame).
    codec: DeltaCodec,
}

impl Default for PendingBroadcast {
    fn default() -> Self {
        PendingBroadcast {
            kind: BroadcastKind::Empty,
            idx: Vec::new(),
            val: Vec::new(),
            dense: Vec::new(),
            add: Delta::Sparse(SparseDelta::default()),
            codec: DeltaCodec::F64,
        }
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
enum BroadcastKind {
    /// Nothing pending (freshly synced or already applied).
    #[default]
    Empty,
    /// Sparse index/value message (`idx`/`val`).
    Sparse,
    /// Dense message (`dense` = the full new `ṽ`).
    Dense,
    /// Quantized increment message (`add`) — compressed codecs only.
    Add,
}

impl PendingBroadcast {
    fn apply_to<R: Regularizer>(&self, state: &mut WorkerState, reg: &R) {
        match self.kind {
            BroadcastKind::Empty => {}
            BroadcastKind::Sparse => state.set_v_tilde_sparse_parts(&self.idx, &self.val, reg),
            BroadcastKind::Dense => state.set_v_tilde(&self.dense, reg),
            BroadcastKind::Add => match &self.add {
                Delta::Sparse(s) => state.add_v_tilde_sparse_parts(&s.idx, &s.val, reg),
                Delta::Dense(v) => state.apply_global(v, reg),
            },
        }
    }

    /// The wire form of the parked message (zero-copy: borrows the
    /// reusable buffers for the TCP backend's encoder).
    fn as_wire(&self) -> BroadcastRef<'_> {
        match self.kind {
            BroadcastKind::Empty => BroadcastRef::Empty,
            BroadcastKind::Sparse => BroadcastRef::SparseSet {
                idx: &self.idx,
                val: &self.val,
            },
            BroadcastKind::Dense => BroadcastRef::DenseSet(&self.dense),
            BroadcastKind::Add => BroadcastRef::Add {
                delta: &self.add,
                codec: self.codec,
            },
        }
    }

    fn clear(&mut self) {
        self.kind = BroadcastKind::Empty;
    }
}

/// Persistent scratch for the Proposition-4/5 global step — keeps the
/// per-round coordinator work allocation-free (`z = ∇g*(v)`, the prox
/// output, and the previous `ṽ` for broadcast extraction all live here).
#[derive(Clone, Debug)]
struct GlobalScratch {
    z: Vec<f64>,
    v_tilde_old: Vec<f64>,
}

/// The per-machine results of one round's fused parallel section.
#[derive(Debug)]
struct RoundReplies {
    deltas: Vec<Delta>,
    losses: Vec<f64>,
    conjs: Vec<f64>,
    parallel_secs: f64,
    /// Per-physical-machine local-step seconds, in machine order —
    /// the straggler telemetry's raw legs (DESIGN.md §16). Their max is
    /// `parallel_secs`.
    leg_secs: Vec<f64>,
}

/// One issued-but-not-completed round in the two-slot pipeline
/// (DESIGN.md §13). In-process backends compute eagerly at issue time —
/// the worker math is identical either way, because a TCP worker also
/// runs round `t+1`'s step before any later coordinator state exists —
/// so only the coordinator's reduce/global step is actually deferred;
/// under TCP the replies genuinely stay on the sockets until collected.
#[derive(Debug)]
struct InflightRound {
    flags: StepFlags,
    /// Eagerly computed worker results; `None` while the replies are
    /// still outstanding on the TCP connections.
    ready: Option<RoundReplies>,
}

/// The DADM coordinator (Algorithm 2), generic over loss `L`, strongly
/// convex regularizer `R` (= `g`), extra regularizer `H` (= `h`), and the
/// local solver `S`.
#[derive(Debug)]
pub struct Dadm<L, R, H, S> {
    /// Loss `φ`.
    pub loss: L,
    /// Regularizer `g` (swapped per stage by Acc-DADM).
    pub reg: R,
    /// Extra regularizer `h`.
    pub h: H,
    /// Effective regularization weight λ (λ̃ during Acc-DADM stages).
    pub lambda: f64,
    /// Local solver.
    pub solver: S,
    /// Logical machines (physical machine ℓ = `machines[ℓT..(ℓ+1)T]`).
    machines: Vec<Machine>,
    /// Resolved intra-machine thread count `T` (≥ 1).
    local_threads: usize,
    weights: Vec<f64>, // n_k/n per *logical* machine
    /// All-ones weights for the cross-machine reduce when `T > 1` (the
    /// machine-local merge already applied the `n_k/n` leaf scaling).
    unit_weights: Vec<f64>,
    v: Vec<f64>,       // global v = Σ X_i α_i / (λn)
    v_tilde: Vec<f64>, // global ṽ (Eq. 15)
    w: Vec<f64>,       // global primal iterate ∇g*(ṽ)
    rho: Vec<f64>,     // Σ_ℓ β_ℓ = ∇h(w)
    pending: PendingBroadcast,
    scratch: GlobalScratch,
    /// Compressed-broadcast shadow of the workers' replica `ṽ`
    /// (DESIGN.md §13): the cumulative quantized increments, updated
    /// with exactly the adds every replica applies, so shadow and
    /// replicas are bitwise identical. The outstanding broadcast error
    /// feedback is implicitly `ṽ − v_image`. Empty in exact-f64 mode.
    v_image: Vec<f64>,
    /// The two-slot round pipeline: issued rounds whose reduce/global
    /// step has not completed yet. Empty except inside an `--overlap`
    /// schedule (sequential rounds push and pop within one call).
    inflight: std::collections::VecDeque<InflightRound>,
    /// Rounds issued so far — runs ahead of `rounds` while the pipeline
    /// holds work; drives the resummation cadence so an overlapped
    /// schedule resums at the same logical rounds as a sequential one.
    issued: usize,
    /// Global `Σ−φ*(−α)` at the *current* duals, when a round leg or an
    /// eval just combined the machines' running sums (DESIGN.md §11).
    /// `None` = no fresh combination (the per-machine sums may still be
    /// maintained; a conj read re-combines them in one cheap exchange).
    conj_cache: Option<f64>,
    n: usize,
    d: usize,
    opts: DadmOptions,
    // cumulative accounting
    rounds: usize,
    passes: f64,
    compute_secs: f64,
    comm_secs: f64,
    /// Cluster synchronization points issued so far: every parallel
    /// section / TCP round trip counts one. The quantity the
    /// single-barrier-per-round acceptance tests pin (DESIGN.md §11).
    barriers: u64,
    /// Per-machine local-step spread of the last completed round —
    /// straggler telemetry only (wall-clock, excluded from trace parity;
    /// DESIGN.md §16). Zeros before the first round completes.
    last_step_stats: StepStats,
}

impl<L, R, H, S> Dadm<L, R, H, S>
where
    L: Loss,
    R: Regularizer,
    H: ExtraReg,
    S: LocalSolver,
{
    /// Build a DADM instance from a completed [`Problem`] description
    /// (the [`Problem::build_dadm`] entry point): shard the data per its
    /// partition, zero-initialize all dual state.
    pub(crate) fn from_problem(p: Problem<'_, L, R, H>, solver: S, opts: DadmOptions) -> Self {
        let lambda = p.lambda_value();
        let Problem {
            data,
            part,
            loss,
            reg,
            h,
            ..
        } = p;
        assert!(lambda > 0.0, "λ must be positive");
        assert!(
            opts.sp > 0.0 && opts.sp <= 1.0,
            "sampling fraction must be in (0, 1]"
        );
        assert!(opts.gap_every >= 1, "gap_every must be ≥ 1");
        let m = part.machines();
        if let Some(handle) = opts.cluster.remote() {
            assert_eq!(
                handle.workers(),
                m,
                "TCP cluster has {} workers but the partition has {m} machines",
                handle.workers()
            );
        }
        // Hierarchical parallelism (DESIGN.md §10): sub-partition every
        // machine's shard once at setup into T sub-shards; the solve then
        // runs over m·T *logical* machines dispatched in groups of T.
        let t = opts.resolved_local_threads(part);
        let lpart_owned;
        let lpart: &Partition = if t == 1 {
            part
        } else {
            lpart_owned = match opts.balance {
                Balance::Rows => part.split(t),
                // Same `split_nnz` formula a remote worker applies to its
                // shard's indptr slice, so sub-cut points agree across
                // backends (DESIGN.md §16).
                Balance::Nnz => {
                    let prefix = data.x.nnz_prefix();
                    let row_nnz: Vec<u64> =
                        prefix.windows(2).map(|w| w[1] - w[0]).collect();
                    part.split_nnz(t, &row_nnz)
                }
            };
            &lpart_owned
        };
        let m_logical = lpart.machines();
        // `machine_rngs`/`batch_size` are the same helpers remote TCP
        // workers use — shared so in-process and remote machine state is
        // identical by construction (stream k = the k-th fork in logical
        // index order, exactly a flat m·T solve's discipline). Under the
        // TCP backend the machines live in their own processes, so no
        // local shard copies are built at all: worker state exists only
        // behind the sockets.
        let machines: Vec<Machine> = if !opts.cluster.has_local_workers() {
            Vec::new()
        } else {
            machine_rngs(opts.seed, 0, m_logical)
                .into_iter()
                .enumerate()
                .map(|(k, rng)| {
                    let state = WorkerState::from_partition(data, lpart, k);
                    let batch = batch_size(opts.sp, state.n_l());
                    Machine { state, rng, batch }
                })
                .collect()
        };
        let n = data.n();
        let d = data.dim();
        let weights = (0..m_logical)
            .map(|k| lpart.shard_size(k) as f64 / n as f64)
            .collect();
        Dadm {
            loss,
            reg,
            h,
            lambda,
            solver,
            machines,
            local_threads: t,
            weights,
            unit_weights: vec![1.0; m],
            v: vec![0.0; d],
            v_tilde: vec![0.0; d],
            w: vec![0.0; d],
            rho: vec![0.0; d],
            pending: PendingBroadcast::default(),
            scratch: GlobalScratch {
                z: vec![0.0; d],
                v_tilde_old: vec![0.0; d],
            },
            v_image: if opts.compress != DeltaCodec::F64 {
                vec![0.0; d]
            } else {
                Vec::new()
            },
            inflight: std::collections::VecDeque::new(),
            issued: 0,
            conj_cache: None,
            n,
            d,
            opts,
            rounds: 0,
            passes: 0.0,
            compute_secs: 0.0,
            comm_secs: 0.0,
            barriers: 0,
            last_step_stats: StepStats::default(),
        }
    }

    /// Number of *physical* machines `m` (remote workers under the TCP
    /// backend; comm-cost participants).
    pub fn machines(&self) -> usize {
        self.weights.len() / self.local_threads
    }

    /// Resolved intra-machine thread count `T` (sub-solvers per machine).
    pub fn local_threads(&self) -> usize {
        self.local_threads
    }

    /// The remote transport handle when running on the multi-process
    /// backend (`None` in-process) — the one dispatch point this
    /// coordinator branches on.
    fn remote(&self) -> Option<&crate::comm::TcpHandle> {
        self.opts.cluster.remote()
    }

    /// Drain the resurrections-performed-since-last-read counter from
    /// the remote transport (`0` in-process) — the engine's
    /// `RoundOutcome::retried` telemetry feed (DESIGN.md §14).
    fn take_rejoins(&self) -> usize {
        self.remote().map_or(0, |h| h.with(|c| c.take_rejoins()))
    }

    /// Cumulative **actual** wire bytes moved by the TCP transport
    /// (header + payload, both directions); `0` on in-process backends.
    /// This is the measured quantity the `sparse_comm` α-β cost model's
    /// message sizes can be validated against.
    pub fn wire_bytes(&self) -> u64 {
        self.remote().map_or(0, |h| h.stats().total_bytes())
    }

    /// Cumulative **actual** bytes of `DeltaReply` frames received from
    /// TCP workers (header + payload; `0` on in-process backends) — the
    /// reduce leg's traffic in isolation, which the compression
    /// acceptance gate compares across codecs (DESIGN.md §13).
    pub fn delta_reply_bytes(&self) -> u64 {
        self.remote().map_or(0, |h| h.stats().delta_reply_bytes)
    }

    /// Cluster synchronization points (parallel sections / TCP round
    /// trips) issued so far — on every backend. With the fused gap
    /// telemetry of DESIGN.md §11 a `--gap-every 1` solve issues exactly
    /// **one** barrier per steady-state round; the legacy three-barrier
    /// eval path (`round` + `primal` + `dual`) issues three.
    pub fn barriers(&self) -> u64 {
        self.barriers
    }

    /// Problem size `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Current primal iterate `w`.
    pub fn w(&self) -> &[f64] {
        &self.w
    }

    /// Current global `v` (dual combination / λn).
    pub fn v(&self) -> &[f64] {
        &self.v
    }

    /// Immutable view of the *logical* machines (tests / invariant
    /// checks) — `m·T` states in logical order under hierarchical
    /// parallelism. Takes `&mut self` because any pending broadcast is
    /// flushed first, so the observed worker state is the synchronized
    /// one. In-process backends only: under TCP the worker state lives
    /// in remote processes and cannot be borrowed.
    pub fn machine_states(&mut self) -> impl Iterator<Item = &WorkerState> {
        assert!(
            self.opts.cluster.has_local_workers(),
            "machine_states: worker state lives in remote TCP processes"
        );
        self.sync_workers();
        self.machines.iter().map(|m| &m.state)
    }

    /// Communication rounds so far.
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Passes over the data so far.
    pub fn passes(&self) -> f64 {
        self.passes
    }

    /// Cumulative (compute, comm) modeled seconds.
    pub fn modeled_secs(&self) -> (f64, f64) {
        (self.compute_secs, self.comm_secs)
    }

    /// The Proposition-4/5 global synchronization, recomputing
    /// `(z, w, ṽ, ρ)` from the current `v` — entirely into persistent
    /// buffers (no allocation). Called after every aggregate and by
    /// [`Dadm::resync`].
    fn global_sync(&mut self) {
        let lambda_n = self.lambda * self.n as f64;
        self.reg.grad_conj_into(&self.v, &mut self.scratch.z);
        self.h.prox_into(&self.scratch.z, 1.0 / lambda_n, &mut self.w);
        let z = &self.scratch.z;
        for j in 0..self.d {
            let diff = z[j] - self.w[j];
            self.rho[j] = lambda_n * diff;
            self.v_tilde[j] = self.v[j] - diff;
        }
    }

    /// Broadcast the current global `ṽ` to every machine in parallel
    /// (sets, not increments — used at init and Acc-DADM stage
    /// boundaries; supersedes any pending incremental broadcast). On the
    /// TCP backend this also pushes the current regularizer, so workers
    /// are always synchronized with stage transitions before any apply.
    pub fn resync(&mut self) {
        self.global_sync();
        self.pending.clear();
        if self.opts.compress != DeltaCodec::F64 {
            // A value-setting resync puts every replica at exactly ṽ, so
            // the image shadow is ṽ and no broadcast error is
            // outstanding (DESIGN.md §13).
            self.v_image.clear();
            self.v_image.extend_from_slice(&self.v_tilde);
        }
        self.barriers += 1;
        if let Some(h) = self.opts.cluster.remote() {
            let spec = self.reg.wire_spec().expect(
                "the TCP backend requires a wire-serializable regularizer \
                 (Regularizer::wire_spec returned None)",
            );
            h.with(|c| {
                c.set_reg(&spec)?;
                c.broadcast(BroadcastRef::DenseSet(&self.v_tilde))
            })
            .expect("tcp resync failed");
            return;
        }
        let cluster = self.opts.cluster.clone();
        let par = cluster.parallel_local();
        let (v_tilde, reg) = (&self.v_tilde, &self.reg);
        let mut groups: Vec<&mut [Machine]> =
            self.machines.chunks_mut(self.local_threads).collect();
        cluster.run(&mut groups, |_, group| {
            run_subgroup(par, group, |_, m| m.state.set_v_tilde(v_tilde, reg));
        });
    }

    /// Apply any still-pending broadcast `Δṽ` to the machines (one
    /// parallel section, no accounting — the apply is normally fused
    /// into the next round and charged there). Needed only when worker
    /// state must be observed between rounds.
    pub fn sync_workers(&mut self) {
        if self.pending.kind == BroadcastKind::Empty {
            return;
        }
        self.barriers += 1;
        if let Some(h) = self.opts.cluster.remote() {
            h.with(|c| c.broadcast(self.pending.as_wire()))
                .expect("tcp worker sync failed");
            self.pending.clear();
            return;
        }
        let cluster = self.opts.cluster.clone();
        let par = cluster.parallel_local();
        let (pending, reg) = (&self.pending, &self.reg);
        let mut groups: Vec<&mut [Machine]> =
            self.machines.chunks_mut(self.local_threads).collect();
        cluster.run(&mut groups, |_, group| {
            run_subgroup(par, group, |_, m| pending.apply_to(&mut m.state, reg));
        });
        self.pending.clear();
    }

    /// One DADM iteration (Algorithm 2): apply the previous round's
    /// broadcast and run the local step on every machine (one fused
    /// parallel section; with `local_threads = T` each machine runs its
    /// `T` sub-solvers concurrently and merges their sub-deltas
    /// machine-locally at zero wire cost), aggregate across machines,
    /// global step, park the new broadcast. Returns the modeled
    /// (compute, comm) seconds of this round. Telemetry-free — see
    /// [`Dadm::round_fused`] for the fused-gap variant the engine drives.
    pub fn round(&mut self) -> (f64, f64) {
        self.round_fused(false, false).0
    }

    /// One DADM iteration with **fused gap telemetry** (DESIGN.md §11):
    /// on top of [`Dadm::round`]'s fused broadcast-apply + local step,
    /// the same single barrier can
    ///
    /// * with `eval_entering` — have every machine evaluate its local
    ///   `Σφ_i(x_iᵀw)` immediately after the broadcast apply, i.e. at
    ///   exactly the *entering* synchronized iterate `w_{t−1}`, and
    ///   piggyback the sum in its reply (16 extra bytes per machine on
    ///   the TCP wire instead of a separate `8·d`-byte eval exchange);
    ///   combined with the conjugate sum piggybacked by the *previous*
    ///   round, the coordinator then returns the previous round's exact
    ///   `(P, D)` — the one-round-lagged record the engine consumes;
    /// * with `want_conj` — piggyback each machine's post-step running
    ///   `Σ−φ*(−α)` (an O(1) read), caching the tree-combined global
    ///   value for the *next* round's lagged record or any direct
    ///   [`Dadm::conj_sum`] read.
    ///
    /// `eval_entering` requires the previous round (or a preceding
    /// objectives evaluation) to have requested the conjugate sum — the
    /// entering α is gone once this round's local step runs.
    pub fn round_fused(
        &mut self,
        eval_entering: bool,
        want_conj: bool,
    ) -> ((f64, f64), Option<(f64, f64)>) {
        self.round_issue(eval_entering, want_conj);
        self.round_complete()
    }

    /// Issue one round's fused parallel section — pending-broadcast
    /// apply + local step + piggybacked telemetry — without consuming
    /// the results ([`Dadm::round_complete`] does). At most two rounds
    /// may be in flight (the two-slot buffer of DESIGN.md §13). Issuing
    /// round `t+1` before completing round `t` overlaps the worker
    /// compute with the coordinator's reduce/global step; the price is
    /// that round `t+1` steps against the broadcast parked by round
    /// `t−1` — bounded staleness of one round on the broadcast iterate.
    pub fn round_issue(&mut self, eval_entering: bool, want_conj: bool) {
        assert!(
            self.inflight.len() < 2,
            "round_issue: at most two rounds may be in flight"
        );
        // Exact-resummation cadence for the running dual sums, driven by
        // the issue counter (== the round counter whenever the pipeline
        // is drained) so all backends and schedules — sequential or
        // overlapped — resum at the same logical rounds (DESIGN.md §11).
        let resum = self.opts.conj_resum_every > 0
            && (self.issued + 1) % self.opts.conj_resum_every == 0;
        self.issued += 1;
        let flags = StepFlags {
            eval_loss: eval_entering,
            want_conj,
            resum_conj: resum,
        };
        let ready = if let Some(h) = self.opts.cluster.remote() {
            // Send only: the replies stay on the sockets until
            // `round_complete` collects them, so a second round's frames
            // can go out while these are being worked on.
            h.with(|c| {
                c.local_step_issue(self.lambda, self.pending.as_wire(), flags, self.opts.compress)
            })
            .expect("tcp local step issue failed");
            None
        } else {
            Some(self.run_local_step(flags))
        };
        self.pending.clear();
        self.inflight.push_back(InflightRound { flags, ready });
    }

    /// The in-process fused parallel section (one pool barrier): apply
    /// the pending broadcast, run every logical machine's local step,
    /// merge the `T` sub-deltas machine-locally, and quantize each
    /// machine delta at the (virtual) wire boundary. The body mirrors
    /// the TCP worker's `LocalStep` handler operation for operation, so
    /// the backends stay bit-identical (DESIGN.md §9/§11/§13).
    fn run_local_step(&mut self, flags: StepFlags) -> RoundReplies {
        let loss = &self.loss;
        let reg = &self.reg;
        let solver = &self.solver;
        let lambda = self.lambda;
        let t = self.local_threads;
        let compress = self.opts.compress;
        let cluster = self.opts.cluster.clone();
        let par = cluster.parallel_local();
        let pending = &self.pending;
        let weights = &self.weights;
        let mut groups: Vec<&mut [Machine]> = self.machines.chunks_mut(t).collect();
        let run = cluster.run(&mut groups, |l, group| {
            // The T sub-shard legs of machine l, concurrent under
            // Cluster::Threads (the pool's sub-queue tier). The leg
            // body is `run_fused_step`, shared with the TCP worker's
            // LocalStep handler — the telemetry points can never
            // drift apart between backends (DESIGN.md §9/§11).
            let sub = run_subgroup(par, group, |_, m| {
                pending.apply_to(&mut m.state, reg);
                run_fused_step(
                    solver,
                    &mut m.state,
                    &mut m.rng,
                    m.batch,
                    loss,
                    reg,
                    lambda,
                    flags.eval_loss,
                    flags.want_conj,
                    flags.resum_conj,
                )
            });
            // Machine-local merge: the same tree reduce as the
            // cross-machine leg, applied to the T sub-deltas with
            // their global n_k/n leaf weights — wire-free, so its
            // message sizes are *not* charged. A flat tree over m·T
            // leaves factors into exactly this local tree followed by
            // the cross-machine tree for power-of-two T (bit parity,
            // DESIGN.md §10); the telemetry scalars pre-reduce with
            // the same pairwise tree as the eval legs. The machine's
            // modeled time is the max over its concurrent sub-legs.
            let mut deltas = Vec::with_capacity(sub.results.len());
            let mut losses = Vec::with_capacity(sub.results.len());
            let mut conjs = Vec::with_capacity(sub.results.len());
            for (delta, loss_sum, conj) in sub.results {
                deltas.push(delta);
                losses.extend(loss_sum);
                conjs.extend(conj);
            }
            let mut delta = if t == 1 {
                deltas.into_iter().next().expect("one sub-solver")
            } else {
                tree_allreduce_delta(deltas, &weights[l * t..l * t + group.len()]).0
            };
            // Quantize once per machine, at the wire boundary (after
            // the wire-free sub-merge), with the error feedback on the
            // lead sub-solver — exactly where the TCP worker keeps it
            // (DESIGN.md §13). F64 is the identity.
            compress_delta(&mut delta, compress, &mut group[0].state.residual);
            let loss_sum = flags.eval_loss.then(|| tree_sum(&losses));
            let conj = flags.want_conj.then(|| tree_sum(&conjs));
            ((delta, loss_sum, conj), sub.parallel_secs)
        });
        let mut deltas = Vec::with_capacity(run.results.len());
        let mut losses = Vec::new();
        let mut conjs = Vec::new();
        let mut parallel_secs = 0.0f64;
        let mut leg_secs = Vec::with_capacity(run.results.len());
        for ((delta, loss_sum, conj), secs) in run.results {
            deltas.push(delta);
            losses.extend(loss_sum);
            conjs.extend(conj);
            parallel_secs = parallel_secs.max(secs);
            leg_secs.push(secs);
        }
        RoundReplies {
            deltas,
            losses,
            conjs,
            parallel_secs,
            leg_secs,
        }
    }

    /// Complete the **oldest** in-flight round: collect its worker
    /// replies (TCP — in machine order, FIFO per connection) or take the
    /// eagerly computed in-process ones, finish the lagged telemetry
    /// record, reduce the machine deltas, run the global step and park
    /// the next Δṽ broadcast. Returns the modeled (compute, comm)
    /// seconds plus the previous round's `(P, D)` when its entering
    /// evaluation was requested. Under an overlapped schedule the
    /// entering **primal** is approximate — the loss sums were evaluated
    /// at the one-round-stale replicas — while the dual side stays exact
    /// (α and the running conjugate sums are local state, DESIGN.md §13).
    pub fn round_complete(&mut self) -> ((f64, f64), Option<(f64, f64)>) {
        let entry = self
            .inflight
            .pop_front()
            .expect("round_complete: no round in flight");
        let flags = entry.flags;
        let eval_entering = flags.eval_loss;
        let want_conj = flags.want_conj;
        assert!(
            !eval_entering || self.conj_cache.is_some(),
            "round_fused: entering objectives need the previous round's \
             conjugate sum (request want_conj there, or evaluate objectives first)"
        );
        let RoundReplies {
            deltas: results,
            losses: machine_losses,
            conjs: machine_conjs,
            parallel_secs,
            leg_secs,
        } = match entry.ready {
            Some(r) => r,
            None => {
                let codec = self.opts.compress;
                let h = self.remote().expect("TCP replies without a TCP cluster");
                let (replies, leg_secs) = h
                    .with(|c| c.local_step_collect(flags, codec))
                    .expect("tcp local step failed");
                let mut deltas = Vec::with_capacity(replies.len());
                let mut losses = Vec::new();
                let mut conjs = Vec::new();
                for r in replies {
                    deltas.push(r.delta);
                    losses.extend(r.loss_sum);
                    conjs.extend(r.conj_sum);
                }
                RoundReplies {
                    deltas,
                    losses,
                    conjs,
                    parallel_secs: leg_secs.iter().cloned().fold(0.0, f64::max),
                    leg_secs,
                }
            }
        };
        // A barrier is a point with no worker work outstanding: every
        // sequential round drains the pipeline here (one barrier per
        // round, exactly as before), while an overlapped schedule keeps
        // a round in flight and only drains at the end — the collapse
        // [`Dadm::barriers`] pins (DESIGN.md §13).
        if self.inflight.is_empty() {
            self.barriers += 1;
        }

        // --- Complete the previous round's record while (w, ṽ, ρ) still
        // hold the entering state: the piggybacked loss sums are at
        // w_{t−1}, the cached conjugate sum is at α_{t−1} — together the
        // exact (P, D) the legacy three-barrier eval path would have
        // produced after round t−1, bit for bit (DESIGN.md §11). ---
        let entering = eval_entering.then(|| {
            let lambda_n = self.lambda * self.n as f64;
            let loss_sum = tree_sum(&machine_losses);
            let primal = loss_sum + lambda_n * self.reg.value(&self.w) + self.h.value(&self.w);
            let dual = self.conj_cache.expect("checked above")
                - lambda_n * self.reg.conj(&self.v_tilde)
                - self.h.conj(&self.rho);
            (primal, dual)
        });
        // The post-step conjugate sum (if read) supersedes the entering
        // one; otherwise the cache is stale — α moved without a read.
        self.conj_cache = want_conj.then(|| tree_sum(&machine_conjs));

        // --- Global step ---
        // v ← v + Σ (n_ℓ/n)·Δv_ℓ  (one sparse-aware tree allreduce). The
        // per-worker Δv_ℓ arrive as the exact messages that would go on
        // the wire (sparse index/value pairs in the mini-batch regime,
        // dense vectors otherwise); the reduce also reports the largest
        // message carried on any tree edge — merged supports grow toward
        // the root — which is what the cost model charges. With T > 1
        // the machine deltas are already leaf-weighted by the local
        // merge, so the cross-machine reduce runs with unit weights.
        let (delta_v, reduce_elems) = if self.local_threads == 1 {
            tree_allreduce_delta(results, &self.weights)
        } else {
            tree_allreduce_delta(results, &self.unit_weights)
        };
        delta_v.add_into(&mut self.v);
        self.scratch.v_tilde_old.copy_from_slice(&self.v_tilde);
        self.global_sync();
        // Δṽ broadcast, extracted into the reusable pending buffers. The
        // support of Δṽ can exceed Δv's (h's prox couples coordinates),
        // so it is extracted from the synced ṽ rather than assumed; the
        // message densifies once the sparse encoding stops paying off.
        // Workers apply it at the start of the next round's parallel
        // section (fused — see the module docs).
        let bcast_elems = if self.opts.compress == DeltaCodec::F64 {
            let PendingBroadcast {
                kind,
                idx,
                val,
                dense,
                ..
            } = &mut self.pending;
            idx.clear();
            val.clear();
            for (j, (&vt, &vo)) in self
                .v_tilde
                .iter()
                .zip(&self.scratch.v_tilde_old)
                .enumerate()
            {
                if vt - vo != 0.0 {
                    idx.push(j as u32);
                    val.push(vt);
                }
            }
            if should_densify(idx.len(), self.d) {
                dense.resize(self.d, 0.0);
                dense.copy_from_slice(&self.v_tilde);
                *kind = BroadcastKind::Dense;
                self.d
            } else {
                *kind = BroadcastKind::Sparse;
                sparse_message_elems(idx.len(), self.d)
            }
        } else {
            self.park_compressed_broadcast()
        };

        // --- Accounting ---
        // Comm participants are the *physical* machines: the T sub-deltas
        // merged inside a machine never touch the wire — that is the
        // whole point of the hierarchy.
        let m = self.machines();
        let comm = if self.opts.sparse_comm {
            // Charge the actual message sizes: the reduce leg by the
            // largest message anywhere in its tree (leaf or merged), the
            // broadcast leg by the Δṽ message just parked.
            self.opts
                .cost
                .allreduce_time(m, reduce_elems.max(bcast_elems))
        } else {
            self.opts.cost.allreduce_time(m, self.d)
        };
        self.compute_secs += parallel_secs;
        self.last_step_stats = StepStats::from_legs(&leg_secs);
        self.comm_secs += comm;
        self.rounds += 1;
        self.passes += self.opts.sp;
        ((parallel_secs, comm), entering)
    }

    /// Extract the compressed Δṽ broadcast (DESIGN.md §13). The worker
    /// replicas hold `v_image` — the cumulative quantized increments
    /// applied so far — so the exact outstanding increment at each
    /// coordinate, this round's Δṽ *plus* all previously unsent
    /// quantization error, is `ṽ − v_image`. Quantizing *that* is the
    /// error-feedback loop: error is never dropped, only deferred, and
    /// because it is re-measured against `v_image` every round it can
    /// never silently accumulate. The images are applied to `v_image`
    /// with the same per-coordinate f64 adds every replica performs,
    /// keeping shadow and replicas bitwise identical. Returns the parked
    /// message's size in dense-equivalent f64 elements (per-codec bytes,
    /// for the sparse-comm cost model).
    fn park_compressed_broadcast(&mut self) -> usize {
        let codec = self.opts.compress;
        let d = self.d;
        let mut idx: Vec<u32> = Vec::new();
        let mut val: Vec<f64> = Vec::new();
        for (j, (&vt, &img)) in self.v_tilde.iter().zip(&self.v_image).enumerate() {
            let inc = vt - img;
            if inc != 0.0 {
                idx.push(j as u32);
                val.push(inc);
            }
        }
        // Canonical step over the raw increments. The max-magnitude
        // increment keeps a level in (16383, 32767], so the wire encoder
        // re-derives the identical step from the image values alone
        // (see [`i16_step`]).
        let step = match codec {
            DeltaCodec::I16 => i16_step(max_abs(&val)),
            _ => 1.0,
        };
        // Quantize; drop zero images (increments below half a step stay
        // owed in `ṽ − v_image` and re-appear in a later round).
        let mut kept = 0;
        for k in 0..val.len() {
            let image = codec_image(codec, val[k], step);
            if image != 0.0 {
                idx[kept] = idx[k];
                val[kept] = image;
                kept += 1;
            }
        }
        idx.truncate(kept);
        val.truncate(kept);
        self.pending.codec = codec;
        self.pending.kind = BroadcastKind::Add;
        if should_densify_with(codec, idx.len(), d) {
            let mut dense = vec![0.0; d];
            for (&j, &image) in idx.iter().zip(&val) {
                dense[j as usize] = image;
            }
            // Replicas add the full dense image, zeros included; the
            // shadow applies the identical operations.
            for (vi, &image) in self.v_image.iter_mut().zip(&dense) {
                *vi += image;
            }
            self.pending.add = Delta::Dense(dense);
            (d * codec.dense_entry_bytes()).div_ceil(DENSE_ENTRY_BYTES)
        } else {
            for (&j, &image) in idx.iter().zip(&val) {
                self.v_image[j as usize] += image;
            }
            let elems = sparse_message_elems_with(codec, idx.len(), d);
            self.pending.add = Delta::Sparse(SparseDelta { dim: d, idx, val });
            elems
        }
    }

    /// Distributed loss sum `Σ_i φ_i(x_iᵀ w)` at an **arbitrary** `w`
    /// (one parallel pass, sub-shard-parallel inside each machine; used
    /// by Acc-DADM's original-problem gap, whose reconstructed iterates
    /// the workers do not hold — this is the one eval that still ships
    /// `8·d` bytes per machine on the TCP backend). Per-machine partials
    /// combine by pairwise [`tree_sum`] — locally over the `T` sub-shard
    /// sums, then over the `m` machine sums — the combination that makes
    /// a nested evaluation bit-identical to a flat `m·T` one (DESIGN.md
    /// §10) and that the TCP coordinator replicates. Current-iterate
    /// evals use [`Dadm::loss_sum_current`] instead (zero payload).
    pub fn loss_sum_at(&mut self, w: &[f64]) -> f64 {
        self.barriers += 1;
        if let Some(h) = self.opts.cluster.remote() {
            return h
                .with(|c| c.eval_sum(&EvalOp::LossSumAt(w.to_vec()), BroadcastRef::Empty))
                .expect("tcp loss-sum eval failed");
        }
        let loss = &self.loss;
        let cluster = self.opts.cluster.clone();
        let par = cluster.parallel_local();
        let mut groups: Vec<&mut [Machine]> =
            self.machines.chunks_mut(self.local_threads).collect();
        let run = cluster.run(&mut groups, |_, group| {
            tree_sum(&run_subgroup(par, group, |_, m| m.state.primal_loss_sum(loss, w)).results)
        });
        tree_sum(&run.results)
    }

    /// Distributed loss sum at the **current** synchronized iterate,
    /// evaluated against each worker's own replica `w_ℓ`
    /// ([`EvalOp::LossSumAtCurrent`]) — bit-identical to
    /// `loss_sum_at(self.w())` because the replicas are value-set
    /// (DESIGN.md §7), but no `8·d·m` iterate payload moves. Flushes any
    /// pending broadcast first so the replicas *are* current.
    pub fn loss_sum_current(&mut self) -> f64 {
        self.sync_workers();
        self.barriers += 1;
        if let Some(h) = self.opts.cluster.remote() {
            return h
                .with(|c| c.eval_sum(&EvalOp::LossSumAtCurrent, BroadcastRef::Empty))
                .expect("tcp loss-sum eval failed");
        }
        let loss = &self.loss;
        let cluster = self.opts.cluster.clone();
        let par = cluster.parallel_local();
        let mut groups: Vec<&mut [Machine]> =
            self.machines.chunks_mut(self.local_threads).collect();
        let run = cluster.run(&mut groups, |_, group| {
            let sub = run_subgroup(par, group, |_, m| m.state.primal_loss_sum(loss, &m.state.w));
            tree_sum(&sub.results)
        });
        tree_sum(&run.results)
    }

    /// Distributed conjugate sum `Σ_i −φ_i*(−α_i)` at the current duals:
    /// the tree combination of the machines' **running** sums
    /// (DESIGN.md §11) — an O(m·T) read of already-held scalars rather
    /// than the O(n) pass it used to be. Served from the cache when a
    /// round leg or gap eval just combined them; the first-ever read
    /// initializes each machine's running sum exactly.
    pub fn conj_sum(&mut self) -> f64 {
        if let Some(c) = self.conj_cache {
            return c;
        }
        self.barriers += 1;
        let c = if let Some(h) = self.opts.cluster.remote() {
            h.with(|c| c.eval_sum(&EvalOp::ConjSum, BroadcastRef::Empty))
                .expect("tcp conjugate-sum eval failed")
        } else {
            let loss = &self.loss;
            let cluster = self.opts.cluster.clone();
            let par = cluster.parallel_local();
            let mut groups: Vec<&mut [Machine]> =
                self.machines.chunks_mut(self.local_threads).collect();
            let run = cluster.run(&mut groups, |_, group| {
                tree_sum(&run_subgroup(par, group, |_, m| m.state.conj_running(loss)).results)
            });
            tree_sum(&run.results)
        };
        self.conj_cache = Some(c);
        c
    }

    /// The eval-only fused frame (DESIGN.md §11): apply any pending
    /// broadcast and evaluate **both** duality-gap sums —
    /// `(Σφ_i(x_iᵀw), Σ−φ*(−α_i))` at the current synchronized state —
    /// in a single barrier. This is what [`Dadm::gap`] and the engine's
    /// initial/final records ride.
    pub fn gap_sums(&mut self) -> (f64, f64) {
        self.barriers += 1;
        let (loss_sum, conj) = if let Some(h) = self.opts.cluster.remote() {
            let sums = h
                .with(|c| c.eval_gap_sums(self.pending.as_wire()))
                .expect("tcp gap eval failed");
            self.pending.clear();
            sums
        } else {
            let loss = &self.loss;
            let reg = &self.reg;
            let pending = &self.pending;
            let cluster = self.opts.cluster.clone();
            let par = cluster.parallel_local();
            let mut groups: Vec<&mut [Machine]> =
                self.machines.chunks_mut(self.local_threads).collect();
            let run = cluster.run(&mut groups, |_, group| {
                let sub = run_subgroup(par, group, |_, m| {
                    pending.apply_to(&mut m.state, reg);
                    let loss_sum = m.state.primal_loss_sum(loss, &m.state.w);
                    (loss_sum, m.state.conj_running(loss))
                });
                let (losses, conjs): (Vec<f64>, Vec<f64>) = sub.results.into_iter().unzip();
                (tree_sum(&losses), tree_sum(&conjs))
            });
            let (losses, conjs): (Vec<f64>, Vec<f64>) = run.results.into_iter().unzip();
            self.pending.clear();
            (tree_sum(&losses), tree_sum(&conjs))
        };
        self.conj_cache = Some(conj);
        (loss_sum, conj)
    }

    /// Exact `(P, D)` at the current state from one fused gap-sums
    /// barrier — the engine's objectives hook.
    pub fn current_objectives(&mut self) -> (f64, f64) {
        let (loss_sum, conj) = self.gap_sums();
        let lambda_n = self.lambda * self.n as f64;
        let primal = loss_sum + lambda_n * self.reg.value(&self.w) + self.h.value(&self.w);
        let dual = conj - lambda_n * self.reg.conj(&self.v_tilde) - self.h.conj(&self.rho);
        (primal, dual)
    }

    /// Exact primal objective `P(w) = Σφ_i(x_iᵀw) + λn·g(w) + h(w)` at
    /// the current iterate, evaluated against the worker replicas
    /// ([`Dadm::loss_sum_current`] — no iterate ships on the TCP
    /// backend).
    pub fn primal(&mut self) -> f64 {
        let loss_sum = self.loss_sum_current();
        loss_sum + self.lambda * self.n as f64 * self.reg.value(&self.w) + self.h.value(&self.w)
    }

    /// Exact dual objective
    /// `D(α, β) = Σ−φ*(−α_i) − λn·g*(ṽ) − h*(ρ)` at the Prop-5-optimal β.
    pub fn dual(&mut self) -> f64 {
        let conj_sum = self.conj_sum();
        conj_sum - self.lambda * self.n as f64 * self.reg.conj(&self.v_tilde)
            - self.h.conj(&self.rho)
    }

    /// Current duality gap `P − D` (instrumentation; one fused barrier
    /// via [`Dadm::gap_sums`]).
    pub fn gap(&mut self) -> f64 {
        let (primal, dual) = self.current_objectives();
        primal - dual
    }

    /// Run until the **normalized** duality gap `(P−D)/n ≤ eps` or
    /// `max_rounds` is exhausted — a thin wrapper over the shared
    /// [`Driver`] with this instance's `gap_every` cadence.
    pub fn solve(&mut self, eps: f64, max_rounds: usize) -> SolveReport {
        let gap_every = self.opts.gap_every;
        Driver::new(eps, max_rounds)
            .with_gap_every(gap_every)
            .solve(self)
    }

    /// Replace the regularizer (Acc-DADM stage transition) keeping all
    /// dual state, then re-synchronize `ṽ`, `w` in the new geometry.
    pub fn set_reg(&mut self, reg: R) {
        self.reg = reg;
        self.resync();
    }

    /// Decompose into (machines, v) for state hand-off (Acc-DADM reuses
    /// the same instance, so this is only for tests / inspection).
    /// In-process backends only.
    pub fn dual_state(&self) -> (&[f64], Vec<&[f64]>) {
        assert!(
            self.opts.cluster.has_local_workers(),
            "dual_state: worker duals live in remote TCP processes"
        );
        (
            &self.v,
            self.machines.iter().map(|m| m.state.alpha.as_slice()).collect(),
        )
    }

    /// Snapshot the dual state (see [`super::Checkpoint`]): `(λ, v, α)`
    /// plus the round/pass counters and the per-machine RNG states, so a
    /// restored instance continues the exact solve trajectory.
    /// In-process backends only (the TCP backend's worker duals are
    /// remote; its engine [`RoundAlgorithm::snapshot`] returns `None`).
    pub fn checkpoint(&self) -> super::Checkpoint {
        assert!(
            self.opts.cluster.supports_checkpoint(),
            "checkpoint: worker duals live in remote TCP processes"
        );
        assert!(
            self.inflight.is_empty(),
            "checkpoint: rounds still in flight (drain the overlap pipeline first)"
        );
        let compressed = self.opts.compress != DeltaCodec::F64;
        super::Checkpoint {
            lambda: self.lambda,
            rounds: self.rounds,
            passes: self.passes,
            v: self.v.clone(),
            alpha: self
                .machines
                .iter()
                .map(|m| m.state.alpha.clone())
                .collect(),
            rng: Some(self.machines.iter().map(|m| m.rng.state()).collect()),
            // The running dual sums are solver state too (DESIGN.md §11):
            // without them a resumed run would restart from an exact
            // resummation and drift off the uninterrupted trajectory by
            // ulps. `None` when telemetry was never read (all-or-none:
            // the sums arm together in one eval leg).
            conj: self.machines.iter().map(|m| m.state.conj_sum).collect(),
            // Compressed-mode solver state (checkpoint v4, DESIGN.md
            // §13): the per-machine error-feedback residuals and the
            // broadcast image shadow. Without them a resumed run would
            // quantize different deltas — and value-set replicas to ṽ
            // instead of the image they actually held — drifting off the
            // uninterrupted trajectory.
            residual: compressed
                .then(|| self.machines.iter().map(|m| m.state.residual.clone()).collect()),
            v_image: compressed.then(|| self.v_image.clone()),
        }
    }

    /// Restore a snapshot taken on an identically-configured instance
    /// (same dataset, partition, λ) and re-synchronize. Snapshots
    /// carrying RNG state (the v2 format) resume the exact mini-batch
    /// stream; v1 snapshots restart the streams from the seed.
    pub fn restore(&mut self, ck: &super::Checkpoint) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.opts.cluster.supports_checkpoint(),
            "restore is not supported on the TCP backend (worker duals are remote)"
        );
        anyhow::ensure!(
            (ck.lambda - self.lambda).abs() <= 1e-15 * self.lambda.abs(),
            "checkpoint λ = {} does not match instance λ = {}",
            ck.lambda,
            self.lambda
        );
        anyhow::ensure!(ck.v.len() == self.d, "dimension mismatch");
        anyhow::ensure!(
            ck.alpha.len() == self.machines.len(),
            "machine count mismatch"
        );
        if let Some(conj) = &ck.conj {
            anyhow::ensure!(conj.len() == self.machines.len(), "conj record count mismatch");
        }
        for (k, (m, a)) in self.machines.iter_mut().zip(&ck.alpha).enumerate() {
            anyhow::ensure!(
                a.len() == m.state.n_l(),
                "shard size mismatch (same partition seed required)"
            );
            m.state.alpha.copy_from_slice(a);
            // Restore the running dual sums alongside α (v3 snapshots) or
            // mark them stale — the next telemetry read rebuilds exactly.
            m.state.conj_sum = ck.conj.as_ref().map(|c| c[k]);
        }
        self.conj_cache = None;
        if let Some(states) = &ck.rng {
            anyhow::ensure!(
                states.len() == self.machines.len(),
                "rng stream count mismatch"
            );
            for (m, s) in self.machines.iter_mut().zip(states) {
                // dadm-lint: allow(rng-construction) — checkpoint restore resumes the captured fork stream verbatim
                m.rng = Rng::from_state(*s);
            }
        }
        // Compressed-mode residuals (v4 records): restore them verbatim,
        // or clear them for pre-v4 snapshots (a fresh error-feedback
        // state — exact-f64 runs never have any).
        if let Some(res) = &ck.residual {
            anyhow::ensure!(
                res.len() == self.machines.len(),
                "residual record count mismatch"
            );
            for (m, r) in self.machines.iter_mut().zip(res) {
                m.state.residual.clear();
                m.state.residual.extend_from_slice(r);
            }
        } else {
            for m in &mut self.machines {
                m.state.residual.clear();
            }
        }
        self.rounds = ck.rounds;
        self.passes = ck.passes;
        self.issued = ck.rounds;
        self.inflight.clear();
        self.v.copy_from_slice(&ck.v);
        self.resync();
        // Compressed-broadcast image shadow (v4): the replicas must hold
        // the quantized image they held at save time, not the exact ṽ
        // the resync just value-set — re-set them to the saved image so
        // the resumed broadcast increments are bit-identical to the
        // uninterrupted run's (DESIGN.md §13).
        if let Some(img) = &ck.v_image {
            anyhow::ensure!(
                self.opts.compress != DeltaCodec::F64,
                "checkpoint carries a broadcast image but compression is off"
            );
            anyhow::ensure!(img.len() == self.d, "v_image dimension mismatch");
            self.v_image.copy_from_slice(img);
            self.barriers += 1;
            let cluster = self.opts.cluster.clone();
            let par = cluster.parallel_local();
            let (v_image, reg) = (&self.v_image, &self.reg);
            let mut groups: Vec<&mut [Machine]> =
                self.machines.chunks_mut(self.local_threads).collect();
            cluster.run(&mut groups, |_, group| {
                run_subgroup(par, group, |_, m| m.state.set_v_tilde(v_image, reg));
            });
        }
        anyhow::Context::context(self.check_v_invariant(), "restored state is inconsistent")?;
        Ok(())
    }

    /// Validate the cross-machine bookkeeping invariant
    /// `v == Σ_ℓ (n_ℓ/n) · X_ℓᵀα_ℓ/(λ n_ℓ)` (tests only; full recompute;
    /// in-process backends only).
    pub fn check_v_invariant(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.opts.cluster.has_local_workers(),
            "check_v_invariant needs local machine state (TCP backend)"
        );
        let mut want = vec![0.0; self.d];
        for m in &self.machines {
            let raw = m.state.raw_dual_combination();
            for (wj, rj) in want.iter_mut().zip(&raw) {
                *wj += rj / (self.lambda * self.n as f64);
            }
        }
        // Under a compressed codec `v` holds the sum of *transmitted
        // images*, which lags the exact dual combination by exactly the
        // per-machine error-feedback residuals (DESIGN.md §13) — in raw
        // per-machine units with T = 1 (the n_ℓ/n leaf scaling happens
        // in the cross-machine tree), already leaf-weighted with T > 1
        // (the machine-local merge applied it before quantization).
        if self.opts.compress != DeltaCodec::F64 {
            let t = self.local_threads;
            for (l, group) in self.machines.chunks(t).enumerate() {
                let scale = if t == 1 { self.weights[l] } else { 1.0 };
                for (wj, rj) in want.iter_mut().zip(&group[0].state.residual) {
                    *wj -= scale * rj;
                }
            }
        }
        for (j, (got, want)) in self.v.iter().zip(&want).enumerate() {
            anyhow::ensure!(
                (got - want).abs() < 1e-8 * (1.0 + want.abs()),
                "v[{j}] drifted: {got} vs recomputed {want}"
            );
        }
        Ok(())
    }
}

impl<L, R, H, S> RoundAlgorithm for Dadm<L, R, H, S>
where
    L: Loss,
    R: Regularizer,
    H: ExtraReg,
    S: LocalSolver,
{
    fn n(&self) -> usize {
        self.n
    }

    fn prepare(&mut self) {
        self.resync();
    }

    fn round(&mut self, req: RoundRequest) -> RoundOutcome {
        // One Algorithm-2 iteration with the driver's fused-telemetry
        // requests riding the same barrier (DESIGN.md §11).
        let (_secs, entering) = self.round_fused(req.eval_entering_primal, req.want_exit_conj);
        RoundOutcome {
            entering_objectives: entering,
            retried: self.take_rejoins(),
            ..RoundOutcome::default()
        }
    }

    /// Double-buffered rounds when the instance opted in (DESIGN.md §13).
    fn overlap_capable(&self) -> bool {
        self.opts.overlap
    }

    fn round_issue(&mut self, req: &RoundRequest) {
        Dadm::round_issue(self, req.eval_entering_primal, req.want_exit_conj);
    }

    fn round_complete(&mut self, _req: RoundRequest) -> RoundOutcome {
        // The telemetry requests were fixed at issue time; the driver
        // passes the same request back for interface symmetry.
        let (_secs, entering) = Dadm::round_complete(self);
        RoundOutcome {
            entering_objectives: entering,
            retried: self.take_rejoins(),
            ..RoundOutcome::default()
        }
    }

    fn objectives(&mut self) -> (f64, f64) {
        self.current_objectives()
    }

    /// DADM supports the one-round-lagged fused gap protocol on every
    /// backend.
    fn fused_gap(&self) -> bool {
        true
    }

    fn rounds(&self) -> usize {
        self.rounds
    }

    fn passes(&self) -> f64 {
        self.passes
    }

    fn modeled_secs(&self) -> (f64, f64) {
        (self.compute_secs, self.comm_secs)
    }

    fn step_stats(&self) -> StepStats {
        self.last_step_stats
    }

    fn final_w(&mut self) -> Vec<f64> {
        self.w.clone()
    }

    fn snapshot(&self) -> Option<super::Checkpoint> {
        if !self.opts.cluster.supports_checkpoint() {
            // Worker duals are remote; §14 resurrection is the TCP
            // backend's fault-tolerance story instead.
            return None;
        }
        if self.opts.overlap {
            // The pipeline may hold un-reduced rounds between driver
            // steps; overlapped solves don't snapshot (DESIGN.md §13).
            return None;
        }
        Some(self.checkpoint())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{tiny_classification, tiny_regression};
    use crate::loss::{Logistic, SmoothHinge, Squared};
    use crate::reg::{ElasticNet, Zero};
    use crate::solver::{ProxSdca, TheoremStep};

    fn opts() -> DadmOptions {
        DadmOptions {
            cost: CostModel::free(),
            ..Default::default()
        }
    }

    /// Positional convenience over the [`Problem`] builder — the only
    /// construction path — for this module's repetitive setups.
    #[allow(clippy::too_many_arguments)]
    fn build_dadm<L, R, H, S>(
        data: &Dataset,
        part: &Partition,
        loss: L,
        reg: R,
        h: H,
        lambda: f64,
        solver: S,
        opts: DadmOptions,
    ) -> Dadm<L, R, H, S>
    where
        L: Loss,
        R: Regularizer,
        H: ExtraReg,
        S: LocalSolver,
    {
        Problem::new(data, part)
            .loss(loss)
            .reg(reg)
            .extra_reg(h)
            .lambda(lambda)
            .build_dadm(solver, opts)
    }

    #[test]
    fn gap_is_nonnegative_and_decreases() {
        let data = tiny_classification(200, 8, 1);
        let part = Partition::balanced(200, 4, 1);
        let mut dadm = build_dadm(
            &data,
            &part,
            SmoothHinge::default(),
            ElasticNet::new(0.0),
            Zero,
            1e-2,
            ProxSdca,
            opts(),
        );
        dadm.resync();
        let gap0 = dadm.gap();
        assert!(gap0 >= -1e-9, "initial gap negative: {gap0}");
        // The dual objective is monotone non-decreasing (each local step
        // improves the local dual, Prop-5 β-maximization improves D); the
        // primal — and hence the gap — may wiggle between rounds but must
        // trend down.
        let mut prev_dual = dadm.dual();
        for _ in 0..15 {
            dadm.round();
            let gap = dadm.gap();
            assert!(gap >= -1e-9, "gap negative: {gap}");
            let dual = dadm.dual();
            assert!(
                dual >= prev_dual - 1e-8,
                "dual decreased: {prev_dual} -> {dual}"
            );
            prev_dual = dual;
        }
        let gap_end = dadm.gap();
        assert!(gap_end < 0.5 * gap0, "no overall progress: {gap0} -> {gap_end}");
        dadm.check_v_invariant().unwrap();
    }

    #[test]
    fn converges_to_target() {
        let data = tiny_classification(150, 6, 2);
        let part = Partition::balanced(150, 3, 2);
        let mut dadm = build_dadm(
            &data,
            &part,
            SmoothHinge::default(),
            ElasticNet::new(0.1),
            Zero,
            1e-2,
            ProxSdca,
            DadmOptions { sp: 1.0, ..opts() },
        );
        let report = dadm.solve(1e-6, 300);
        assert!(report.converged, "gap = {}", report.normalized_gap());
        assert!(report.normalized_gap() <= 1e-6);
        // Trace rounds increase and the dual ascends monotonically.
        assert!(report.trace.rounds.len() >= 2);
        for pair in report.trace.rounds.windows(2) {
            assert!(pair[1].round > pair[0].round);
            assert!(pair[1].dual >= pair[0].dual - 1e-8);
        }
    }

    #[test]
    fn single_machine_equals_multi_machine_start() {
        // After the first global step from a zero start with sp = 1, the
        // m-machine primal iterate must be reproducible from the dual
        // combination regardless of m (the β decoupling at work).
        let data = tiny_classification(120, 5, 3);
        for m in [1usize, 2, 4] {
            let part = Partition::balanced(120, m, 3);
            let mut dadm = build_dadm(
                &data,
                &part,
                SmoothHinge::default(),
                ElasticNet::new(0.0),
                Zero,
                1e-2,
                TheoremStep::default(),
                DadmOptions { sp: 1.0, ..opts() },
            );
            dadm.resync();
            dadm.round();
            dadm.check_v_invariant().unwrap();
            // w == ∇g*(ṽ) == ṽ for τ = 0 and h = 0, and ṽ == v.
            assert_eq!(dadm.w(), &dadm.v_tilde[..]);
        }
    }

    #[test]
    fn deferred_broadcast_applies_before_observation() {
        // After round() the broadcast is parked; machine_states() must
        // flush it so the observed worker ṽ_ℓ equals the global ṽ.
        let data = tiny_classification(80, 6, 19);
        let part = Partition::balanced(80, 4, 19);
        let mut dadm = build_dadm(
            &data,
            &part,
            SmoothHinge::default(),
            ElasticNet::new(0.1),
            Zero,
            1e-2,
            ProxSdca,
            opts(),
        );
        dadm.resync();
        for _ in 0..3 {
            dadm.round();
        }
        let v_tilde = dadm.v_tilde.clone();
        for ws in dadm.machine_states() {
            for (a, b) in ws.v_tilde.iter().zip(&v_tilde) {
                assert!((a - b).abs() < 1e-15, "worker ṽ not synced: {a} vs {b}");
            }
        }
        // A second sync is a no-op (the pending message was consumed).
        dadm.sync_workers();
        for ws in dadm.machine_states() {
            for (a, b) in ws.v_tilde.iter().zip(&v_tilde) {
                assert!((a - b).abs() < 1e-15, "double apply corrupted ṽ");
            }
        }
    }

    #[test]
    fn logistic_converges() {
        let data = tiny_classification(100, 4, 4);
        let part = Partition::balanced(100, 4, 4);
        let mut dadm = build_dadm(
            &data,
            &part,
            Logistic,
            ElasticNet::new(0.05),
            Zero,
            5e-3,
            ProxSdca,
            DadmOptions { sp: 0.5, ..opts() },
        );
        let report = dadm.solve(1e-5, 500);
        assert!(report.converged, "gap = {}", report.normalized_gap());
    }

    #[test]
    fn ridge_regression_matches_closed_form() {
        // Squared loss, τ = 0, h = 0: P(w) = Σ(x_iᵀw − y_i)² + (λn/2)‖w‖²
        // has closed form w* = (XᵀX·2 + λn I)⁻¹ · 2Xᵀy … solve via DADM and
        // verify the primal optimality conditions ∇P(w*) ≈ 0 instead of
        // inverting: ∇P(w) = 2Xᵀ(Xw − y) + λn·w.
        let data = tiny_regression(80, 4, 0.05, 5);
        let part = Partition::balanced(80, 2, 5);
        let lambda = 0.05;
        let mut dadm = build_dadm(
            &data,
            &part,
            Squared,
            ElasticNet::l2(),
            Zero,
            lambda,
            ProxSdca,
            DadmOptions { sp: 1.0, ..opts() },
        );
        let report = dadm.solve(1e-10, 2000);
        assert!(report.converged);
        let w = &report.w;
        let preds = data.x.matvec(w);
        let resid: Vec<f64> = preds.iter().zip(&data.y).map(|(p, y)| p - y).collect();
        let grad_loss = data.x.matvec_t(&resid);
        let n = data.n() as f64;
        for j in 0..data.dim() {
            let g = 2.0 * grad_loss[j] + lambda * n * w[j];
            assert!(g.abs() < 1e-3, "∇P[{j}] = {g}");
        }
    }

    #[test]
    fn serial_and_threads_agree() {
        let data = tiny_classification(100, 5, 6);
        let part = Partition::balanced(100, 4, 6);
        let build = |cluster: Cluster| {
            build_dadm(
                &data,
                &part,
                SmoothHinge::default(),
                ElasticNet::new(0.1),
                Zero,
                1e-2,
                ProxSdca,
                DadmOptions {
                    cluster,
                    ..opts()
                },
            )
        };
        let mut a = build(Cluster::Serial);
        let mut b = build(Cluster::Threads);
        a.resync();
        b.resync();
        for _ in 0..5 {
            a.round();
            b.round();
        }
        for (x, y) in a.w().iter().zip(b.w()) {
            assert!((x - y).abs() < 1e-12, "cluster backends diverge");
        }
        assert!((a.gap() - b.gap()).abs() < 1e-9);
    }

    #[test]
    fn comm_accounting_scales_with_machines() {
        let data = tiny_classification(120, 16, 7);
        let run = |m: usize| {
            let part = Partition::balanced(120, m, 7);
            let mut dadm = build_dadm(
                &data,
                &part,
                SmoothHinge::default(),
                ElasticNet::new(0.0),
                Zero,
                1e-2,
                ProxSdca,
                DadmOptions::default(), // default (non-free) cost model
            );
            dadm.resync();
            for _ in 0..3 {
                dadm.round();
            }
            dadm.modeled_secs().1
        };
        assert_eq!(run(1), 0.0); // single machine: no comm
        assert!(run(8) > run(2));
    }

    #[test]
    fn checkpoint_resume_continues_identically() {
        let data = tiny_classification(120, 6, 71);
        let part = Partition::balanced(120, 3, 71);
        let build = || {
            build_dadm(
                &data,
                &part,
                SmoothHinge::default(),
                ElasticNet::new(0.1),
                Zero,
                1e-3,
                ProxSdca,
                opts(),
            )
        };
        // Reference: 10 uninterrupted rounds. The mid-run gap read
        // mirrors the resumed run's round-5 read below: gap telemetry is
        // solver state now (the first read arms the machines' running
        // Σ−φ*(−α) sums, DESIGN.md §11), so a bit-exact comparison must
        // replay the same instrumentation schedule.
        let mut full = build();
        full.resync();
        for _ in 0..5 {
            full.round();
        }
        let _ = full.gap();
        for _ in 0..5 {
            full.round();
        }
        // Checkpoint after 5, restore into a fresh instance, run 5 more.
        let mut first = build();
        first.resync();
        for _ in 0..5 {
            first.round();
        }
        let mut buf = Vec::new();
        first.checkpoint().save(&mut buf).unwrap();
        let ck = crate::coordinator::Checkpoint::load(std::io::Cursor::new(buf)).unwrap();
        let mut resumed = build();
        resumed.restore(&ck).unwrap();
        // The restored state must be exactly the checkpointed one…
        for (a, b) in resumed.w().iter().zip(first.w()) {
            assert!((a - b).abs() < 1e-15);
        }
        assert!((resumed.gap() - first.gap()).abs() < 1e-9);
        assert_eq!(resumed.rounds(), 5);
        // …and — the RNG streams being part of the v2 snapshot — the
        // resumed trajectory must match the uninterrupted one bit for
        // bit, round for round.
        for _ in 0..5 {
            resumed.round();
        }
        assert_eq!(resumed.rounds(), 10);
        assert_eq!(resumed.w(), full.w(), "resumed trajectory diverged");
        assert_eq!(resumed.gap(), full.gap());
    }

    #[test]
    fn sparse_comm_cheaper_same_math() {
        // Sparse data + tiny mini-batches ⇒ Δv has small support, so the
        // §6 sparse-message option must charge less comm time while
        // producing bit-identical iterates.
        use crate::data::synthetic::SyntheticSpec;
        let data = SyntheticSpec {
            name: "sparse-comm".into(),
            n: 300,
            d: 512,
            density: 0.01,
            signal_density: 0.1,
            noise: 0.1,
            seed: 99,
        }
        .generate();
        let part = Partition::balanced(300, 4, 9);
        let run = |sparse_comm: bool| {
            let mut dadm = build_dadm(
                &data,
                &part,
                SmoothHinge::default(),
                ElasticNet::new(0.1),
                Zero,
                1e-2,
                ProxSdca,
                DadmOptions {
                    sp: 0.05,
                    sparse_comm,
                    ..DadmOptions::default() // default (non-free) cost model
                },
            );
            dadm.resync();
            for _ in 0..5 {
                dadm.round();
            }
            (dadm.w().to_vec(), dadm.modeled_secs().1)
        };
        let (w_dense, t_dense) = run(false);
        let (w_sparse, t_sparse) = run(true);
        assert_eq!(w_dense, w_sparse, "cost model must not change the math");
        assert!(
            t_sparse < t_dense,
            "sparse messages not cheaper: {t_sparse} vs {t_dense}"
        );
    }

    #[test]
    fn gap_every_skips_instrumentation() {
        let data = tiny_classification(100, 4, 8);
        let part = Partition::balanced(100, 2, 8);
        let mut dadm = build_dadm(
            &data,
            &part,
            SmoothHinge::default(),
            ElasticNet::new(0.0),
            Zero,
            1e-2,
            ProxSdca,
            DadmOptions {
                gap_every: 5,
                ..opts()
            },
        );
        let report = dadm.solve(0.0, 12); // never converges; 12 rounds
        // Records: initial + rounds 5, 10, 12 (final).
        let recorded: Vec<usize> = report.trace.rounds.iter().map(|r| r.round).collect();
        assert_eq!(recorded, vec![0, 5, 10, 12]);
    }

    #[test]
    fn compressed_rounds_converge_and_track_exact() {
        // Error-feedback quantization (DESIGN.md §13) must preserve
        // convergence: the dual stays monotone (α updates are exact and
        // local — only the broadcast iterate each step works from is
        // slightly stale), and the final gap stays within a small factor
        // of the exact run's.
        let data = tiny_classification(200, 8, 21);
        let part = Partition::balanced(200, 4, 21);
        let run = |compress: DeltaCodec| {
            let mut dadm = build_dadm(
                &data,
                &part,
                SmoothHinge::default(),
                ElasticNet::new(0.1),
                Zero,
                1e-2,
                ProxSdca,
                DadmOptions { compress, ..opts() },
            );
            dadm.resync();
            let mut prev_dual = dadm.dual();
            for _ in 0..20 {
                dadm.round();
                let dual = dadm.dual();
                assert!(
                    dual >= prev_dual - 1e-8,
                    "{compress:?}: dual decreased: {prev_dual} -> {dual}"
                );
                prev_dual = dual;
            }
            dadm.check_v_invariant().unwrap();
            dadm.gap()
        };
        let gap_exact = run(DeltaCodec::F64);
        for codec in [DeltaCodec::F32, DeltaCodec::I16] {
            let gap = run(codec);
            assert!(
                gap <= 10.0 * gap_exact.max(1e-12),
                "{codec:?} gap {gap} not within 10x of exact {gap_exact}"
            );
        }
    }

    #[test]
    fn issue_complete_split_matches_fused() {
        // Structural staleness-0 parity: a manually driven
        // issue-then-complete schedule is the fused round, bit for bit
        // — on the exact and the compressed path.
        let data = tiny_classification(120, 6, 22);
        let part = Partition::balanced(120, 3, 22);
        for compress in [DeltaCodec::F64, DeltaCodec::I16] {
            let build = || {
                build_dadm(
                    &data,
                    &part,
                    SmoothHinge::default(),
                    ElasticNet::new(0.1),
                    Zero,
                    1e-2,
                    ProxSdca,
                    DadmOptions { compress, ..opts() },
                )
            };
            let mut fused = build();
            let mut split = build();
            fused.resync();
            split.resync();
            for _ in 0..5 {
                fused.round_fused(false, false);
                split.round_issue(false, false);
                split.round_complete();
            }
            assert_eq!(fused.w(), split.w(), "{compress:?}: split diverged");
            assert_eq!(fused.barriers(), split.barriers());
            assert_eq!(fused.gap(), split.gap());
        }
    }

    #[test]
    fn overlapped_schedule_converges_and_collapses_barriers() {
        // A depth-2 pipelined schedule: round t+1 is issued before round
        // t completes, so its local step runs against the broadcast of
        // round t−1 (staleness 1). Convergence degrades gracefully, and
        // the pipeline only drains once — the barrier collapse the
        // overlap acceptance gate pins (DESIGN.md §13).
        let data = tiny_classification(200, 8, 23);
        let part = Partition::balanced(200, 4, 23);
        let mut dadm = build_dadm(
            &data,
            &part,
            SmoothHinge::default(),
            ElasticNet::new(0.1),
            Zero,
            1e-2,
            ProxSdca,
            DadmOptions {
                overlap: true,
                ..opts()
            },
        );
        dadm.resync();
        let gap0 = dadm.gap();
        let before = dadm.barriers();
        let rounds = 12;
        dadm.round_issue(false, false);
        for _ in 1..rounds {
            dadm.round_issue(false, false);
            dadm.round_complete();
        }
        dadm.round_complete();
        // 12 overlapped rounds issue 12 parallel sections but drain the
        // pipeline exactly once (the last complete).
        assert_eq!(dadm.barriers(), before + 1, "overlap schedule not pinned");
        assert_eq!(dadm.rounds(), rounds);
        let gap_end = dadm.gap();
        assert!(
            gap_end < 0.5 * gap0,
            "no progress under staleness: {gap0} -> {gap_end}"
        );
        dadm.check_v_invariant().unwrap();
    }

    #[test]
    fn compressed_checkpoint_resume_continues_identically() {
        // Checkpoint v4 carries the live error-feedback residuals and
        // the broadcast image shadow, so a compressed run resumes on the
        // exact bit trajectory (the replicas are re-set to the image
        // they held, not to the exact ṽ).
        let data = tiny_classification(120, 6, 73);
        let part = Partition::balanced(120, 3, 73);
        let build = || {
            build_dadm(
                &data,
                &part,
                SmoothHinge::default(),
                ElasticNet::new(0.1),
                Zero,
                1e-3,
                ProxSdca,
                DadmOptions {
                    compress: DeltaCodec::I16,
                    ..opts()
                },
            )
        };
        let mut full = build();
        full.resync();
        for _ in 0..5 {
            full.round();
        }
        let _ = full.gap();
        for _ in 0..5 {
            full.round();
        }
        let mut first = build();
        first.resync();
        for _ in 0..5 {
            first.round();
        }
        let ck = first.checkpoint();
        assert!(ck.residual.is_some(), "v4 residual records missing");
        assert!(ck.v_image.is_some(), "v4 image record missing");
        let mut buf = Vec::new();
        ck.save(&mut buf).unwrap();
        let ck = crate::coordinator::Checkpoint::load(std::io::Cursor::new(buf)).unwrap();
        let mut resumed = build();
        resumed.restore(&ck).unwrap();
        let _ = resumed.gap();
        for _ in 0..5 {
            resumed.round();
        }
        assert_eq!(resumed.rounds(), 10);
        assert_eq!(resumed.w(), full.w(), "compressed resume diverged");
        assert_eq!(resumed.gap(), full.gap());
    }

    #[test]
    #[should_panic]
    fn rejects_zero_gap_every() {
        let data = tiny_classification(40, 3, 9);
        let part = Partition::balanced(40, 2, 9);
        let _ = build_dadm(
            &data,
            &part,
            SmoothHinge::default(),
            ElasticNet::new(0.0),
            Zero,
            1e-2,
            ProxSdca,
            DadmOptions {
                gap_every: 0,
                ..opts()
            },
        );
    }
}
