//! Acc-DADM — Algorithm 3 of the paper.
//!
//! An inner–outer (Catalyst-style) acceleration of DADM: each outer stage
//! `t` solves the proximal-point objective
//!
//! ```text
//! P_t(w) = Σφ_i(X_iᵀw) + λn·g(w) + h(w) + (κn/2)‖w − y^{t−1}‖²
//! ```
//!
//! with the warm-started inner DADM to the geometric gap target
//! `ε_t = η·ξ_{t−1}/(2 + 2η⁻²)`, then updates the prox center with
//! momentum `y^t = w^t + ν(w^t − w^{t−1})` and the schedule
//! `ξ_t = (1 − η/2)·ξ_{t−1}`, where `η = √(λ/(λ+2κ))` and
//! `ν = (1−η)/(1+η)` (the paper also recommends the empirically smoother
//! `ν = 0` — both are exposed, Figure 1 compares them).
//!
//! Default `κ = mR/(γn) − λ` per Remark 12 — the choice that yields the
//! `√(condition)` total-work bound and the square-root speedup over
//! single-machine AccProxSDCA.
//!
//! The inner problem maps onto a *standard* DADM instance with
//! `λ̃ = λ + κ` and the shifted elastic net of §9.8
//! ([`crate::reg::ShiftedElasticNet`]), so the whole inner machinery —
//! local solvers, the sparse Δv/Δṽ message pipeline (DESIGN.md §7),
//! global step, cluster, accounting — is reused unchanged; stage
//! transitions re-broadcast `ṽ` densely through [`Dadm::set_reg`] since
//! the regularizer shift moves every coordinate.
//!
//! There is no bespoke inner-stage loop: `AccDadm` implements the
//! engine's [`RoundAlgorithm`] — one engine round = one inner DADM round
//! — with the stage machinery (target schedule, prox-center momentum,
//! stage regularizer swap) living in the [`RoundAlgorithm::on_record`]
//! hook, driven at the per-stage cadence the algorithm itself requests
//! through [`RoundOutcome::record_due`].

use super::dadm::{Dadm, DadmOptions, SolveReport};
use super::problem::Problem;
use crate::data::{Dataset, Partition};
use crate::loss::Loss;
use crate::reg::{ElasticNet, ExtraReg, Regularizer, ShiftedElasticNet};
use crate::runtime::engine::{
    Driver, GapCadence, RecordCtx, RoundAlgorithm, RoundOutcome, RoundRequest,
};
use crate::solver::LocalSolver;

/// Momentum choice for the prox-center update (Figure 1's comparison).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NuChoice {
    /// `ν = (1−η)/(1+η)` — the theory value.
    Theory,
    /// `ν = 0` — the paper's empirically smoother choice (§10).
    Zero,
    /// Fixed user value.
    Fixed(f64),
}

/// Acc-DADM options.
#[derive(Clone, Debug)]
pub struct AccDadmOptions {
    /// Prox weight κ. `None` → the Remark-12 default `mR/(γn) − λ`
    /// (clamped at ≥ 0; κ = 0 degenerates to plain DADM geometry).
    pub kappa: Option<f64>,
    /// Momentum choice.
    pub nu: NuChoice,
    /// Cap on inner rounds per stage (safety net on top of the ε_t
    /// schedule).
    pub inner_max_rounds: usize,
    /// Multiplier on the Algorithm-3 inner target ε_t (1.0 = exact
    /// schedule; > 1 is looser/faster in practice).
    pub stage_target_factor: f64,
    /// Inner DADM options (sp, cluster, cost model, seed, gap cadence).
    pub dadm: DadmOptions,
}

impl Default for AccDadmOptions {
    fn default() -> Self {
        AccDadmOptions {
            kappa: None,
            nu: NuChoice::Zero,
            inner_max_rounds: 200,
            stage_target_factor: 1.0,
            dadm: DadmOptions::default(),
        }
    }
}

/// The Acc-DADM coordinator (Algorithm 3).
#[derive(Debug)]
pub struct AccDadm<L, H, S> {
    inner: Dadm<L, ShiftedElasticNet, H, S>,
    /// Original-problem regularization weight λ.
    pub lambda: f64,
    /// Original-problem L1 weight μ (so `g(w) = ½‖w‖² + (μ/λ)‖w‖₁`).
    pub mu: f64,
    /// Prox weight κ.
    pub kappa: f64,
    /// `η = √(λ/(λ+2κ))`.
    pub eta: f64,
    /// Momentum ν.
    pub nu: f64,
    opts: AccDadmOptions,
    w_prev: Vec<f64>,
    y: Vec<f64>,
    n: usize,
    stages_done: usize,
    // --- engine stage machinery (was the bespoke inner loop's locals) ---
    xi: f64,
    inner_eps: f64,
    inner_rounds_in_stage: usize,
    stage_cap: usize,
    start_stage: bool,
}

impl<L, H, S> AccDadm<L, H, S>
where
    L: Loss,
    H: ExtraReg,
    S: LocalSolver,
{
    /// Build from a completed [`Problem`] description (the
    /// [`Problem::build_acc_dadm`] entry point). The inner DADM's stage
    /// regularizer is derived here (§9.8), which is why the problem must
    /// arrive with its `g` slot unset.
    ///
    /// `radius` is the data radius `R = max‖x_i‖²` used by the default κ.
    pub(crate) fn from_problem(p: Problem<'_, L, (), H>, solver: S, opts: AccDadmOptions) -> Self {
        let lambda = p.lambda_value();
        let Problem {
            data,
            part,
            loss,
            h,
            mu,
            ..
        } = p;
        let n = data.n();
        // Remark 12's m is the number of *independent dual blocks* — under
        // hierarchical parallelism (DESIGN.md §10) that is the logical
        // count m·T, the same value a flat m·T-machine solve would use
        // (the (m, T)-vs-flat bit-parity tests depend on the κ agreeing).
        let m = part.machines() * opts.dadm.resolved_local_threads(part);
        let radius = data.max_row_norm_sq();
        let gamma = loss.gamma();
        let kappa = opts
            .kappa
            .unwrap_or_else(|| {
                // Remark 12: κ = mR/(γn) − λ (γ > 0 for smooth losses; for
                // Lipschitz losses the caller smooths first — Corollary 13).
                assert!(
                    gamma > 0.0,
                    "Acc-DADM on a non-smooth loss: apply Nesterov smoothing \
                     (SmoothHinge::nesterov) per §8.2 first"
                );
                m as f64 * radius / (gamma * n as f64) - lambda
            })
            .max(0.0);
        let lambda_tilde = lambda + kappa;
        let eta = (lambda / (lambda + 2.0 * kappa)).sqrt();
        let nu = match opts.nu {
            NuChoice::Theory => (1.0 - eta) / (1.0 + eta),
            NuChoice::Zero => 0.0,
            NuChoice::Fixed(v) => v,
        };
        let d = data.dim();
        let stage_reg = ShiftedElasticNet::acc_stage(mu, lambda_tilde, kappa, &vec![0.0; d]);
        let inner = Problem::new(data, part)
            .loss(loss)
            .reg(stage_reg)
            .extra_reg(h)
            .lambda(lambda_tilde)
            .build_dadm(solver, opts.dadm.clone());
        AccDadm {
            inner,
            lambda,
            mu,
            kappa,
            eta,
            nu,
            opts,
            w_prev: vec![0.0; d],
            y: vec![0.0; d],
            n,
            stages_done: 0,
            xi: 0.0,
            inner_eps: f64::INFINITY,
            inner_rounds_in_stage: 0,
            stage_cap: usize::MAX,
            start_stage: false,
        }
    }

    /// Number of machines.
    pub fn machines(&self) -> usize {
        self.inner.machines()
    }

    /// Outer stages completed.
    pub fn stages(&self) -> usize {
        self.stages_done
    }

    /// Original-problem primal/dual at the current inner state.
    ///
    /// The inner dual state `(α, v_inner)` is feasible for the original
    /// dual too: `v_orig = v_inner·(λ̃/λ)`, then one Proposition-4/5
    /// synchronization in the *original* geometry yields a valid
    /// `(w_o, ṽ_o, ρ_o)` and hence a valid original duality gap.
    pub fn original_objectives(&mut self) -> (f64, f64) {
        let lambda_tilde = self.lambda + self.kappa;
        let scale = lambda_tilde / self.lambda;
        let v_orig: Vec<f64> = self.inner.v().iter().map(|v| v * scale).collect();
        let reg_o = ElasticNet::new(self.mu / self.lambda);
        let z = reg_o.grad_conj(&v_orig);
        let w_o = self.inner.h.prox(&z, 1.0 / (self.lambda * self.n as f64));
        let (mut rho, mut v_tilde_o) = (vec![0.0; z.len()], vec![0.0; z.len()]);
        for j in 0..z.len() {
            rho[j] = self.lambda * self.n as f64 * (z[j] - w_o[j]);
            v_tilde_o[j] = v_orig[j] - (z[j] - w_o[j]);
        }
        // Two valid primal bounds: the dual reconstruction w_o (exact at
        // optimality, but amplified by λ̃/λ early when κ ≫ λ) and the
        // inner prox iterate w_in (feasible, near the prox path). Report
        // the better one — both upper-bound P*, so the gap stays valid.
        let p_at = |s: &mut Self, w: &[f64]| {
            let loss_sum = s.inner.loss_sum_at(w);
            loss_sum + s.lambda * s.n as f64 * reg_o.value(w) + s.inner.h.value(w)
        };
        let w_in = self.inner.w().to_vec();
        let primal = p_at(self, &w_o).min(p_at(self, &w_in));
        let dual = self.inner.conj_sum()
            - self.lambda * self.n as f64 * reg_o.conj(&v_tilde_o)
            - self.inner.h.conj(&rho);
        (primal, dual)
    }

    /// The original-problem primal iterate implied by the current state
    /// (the better of the dual reconstruction and the inner prox iterate,
    /// matching [`AccDadm::original_objectives`]).
    pub fn w_original(&mut self) -> Vec<f64> {
        let lambda_tilde = self.lambda + self.kappa;
        let scale = lambda_tilde / self.lambda;
        let v_orig: Vec<f64> = self.inner.v().iter().map(|v| v * scale).collect();
        let reg_o = ElasticNet::new(self.mu / self.lambda);
        let z = reg_o.grad_conj(&v_orig);
        let w_o = self.inner.h.prox(&z, 1.0 / (self.lambda * self.n as f64));
        let w_in = self.inner.w().to_vec();
        let p_at = |s: &mut Self, w: &[f64]| {
            let loss_sum = s.inner.loss_sum_at(w);
            loss_sum + s.lambda * s.n as f64 * reg_o.value(w) + s.inner.h.value(w)
        };
        if p_at(self, &w_o) <= p_at(self, &w_in) {
            w_o
        } else {
            w_in
        }
    }

    /// Run Algorithm 3 until the **original** normalized duality gap
    /// `(P−D)/n ≤ eps` or `max_rounds` total communication rounds — a
    /// thin wrapper over the shared [`Driver`] with the algorithm-driven
    /// (per-stage) record cadence.
    pub fn solve(&mut self, eps: f64, max_rounds: usize) -> SolveReport {
        Driver::new(eps, max_rounds)
            .with_cadence(GapCadence::AlgorithmDriven)
            .solve(self)
    }
}

impl<L, H, S> RoundAlgorithm for AccDadm<L, H, S>
where
    L: Loss,
    H: ExtraReg,
    S: LocalSolver,
{
    fn n(&self) -> usize {
        self.n
    }

    fn prepare(&mut self) {
        self.inner.resync();
        // Practical per-stage round cap: ≈ two passes over the data on
        // top of the user cap, so a bounded total budget still cycles the
        // prox center — a stage that never completes leaves the iterate
        // biased toward a stale y.
        self.stage_cap = self
            .opts
            .inner_max_rounds
            .min(((2.0 / self.opts.dadm.sp).ceil() as usize).max(3));
        self.inner_rounds_in_stage = 0;
        self.start_stage = false; // armed by the initial on_record
    }

    fn round(&mut self, _req: RoundRequest) -> RoundOutcome {
        // Acc-DADM records on its algorithm-driven (per-stage) cadence,
        // where stage transitions must see the gap eagerly — the lagged
        // fused-gap protocol stays off (`fused_gap` = false). Its gap
        // evals still ride the single-barrier fused frames through the
        // inner DADM (`Dadm::gap_sums` / the running conjugate sums).
        if self.start_stage {
            // Stage target ε_t = η·ξ_{t−1}/(2 + 2η⁻²), scaled; build the
            // stage regularizer around the current prox center y.
            let inner_target = self.opts.stage_target_factor * self.eta * self.xi
                / (2.0 + 2.0 * self.eta.powi(-2));
            self.inner_eps = inner_target / self.n as f64;
            let lambda_tilde = self.lambda + self.kappa;
            let reg = ShiftedElasticNet::acc_stage(self.mu, lambda_tilde, self.kappa, &self.y);
            self.inner.set_reg(reg);
            self.inner_rounds_in_stage = 0;
            self.start_stage = false;
        }
        self.inner.round();
        self.inner_rounds_in_stage += 1;
        RoundOutcome {
            record_due: self.inner_rounds_in_stage % self.opts.dadm.gap_every == 0
                || self.inner_rounds_in_stage >= self.stage_cap,
            ..RoundOutcome::default()
        }
    }

    fn objectives(&mut self) -> (f64, f64) {
        self.original_objectives()
    }

    fn rounds(&self) -> usize {
        self.inner.rounds()
    }

    fn passes(&self) -> f64 {
        self.inner.passes()
    }

    fn modeled_secs(&self) -> (f64, f64) {
        self.inner.modeled_secs()
    }

    fn step_stats(&self) -> crate::metrics::StepStats {
        self.inner.step_stats()
    }

    fn final_w(&mut self) -> Vec<f64> {
        self.w_original()
    }

    fn on_record(&mut self, ctx: &RecordCtx) {
        if ctx.initial {
            // ξ₀ = (1 + η⁻²)(P(0) − D(0,0)) on the original problem.
            self.xi = (1.0 + self.eta.powi(-2)) * ctx.gap;
            self.start_stage = true;
            return;
        }
        if ctx.converged || ctx.at_round_cap {
            // Deliberate divergence from the deleted legacy loop at the
            // round cap: the legacy code additionally ran the momentum
            // update and double-incremented `stages_done` on its way
            // out, but `y`/`w_prev` of an exhausted run feed nothing —
            // `w_original()` reads only inner state — so the truncated
            // stage is counted once and left as-is.
            self.stages_done += 1;
            return;
        }
        let inner_gap = self.inner.gap();
        if inner_gap / self.n as f64 <= self.inner_eps
            || self.inner_rounds_in_stage >= self.stage_cap
        {
            // Stage complete: momentum update of the prox center (Eq. 20)
            // and the geometric ξ schedule; the next round opens the next
            // stage around the new y.
            let w_new = self.inner.w().to_vec();
            for (yj, (&wn, &wp)) in self.y.iter_mut().zip(w_new.iter().zip(&self.w_prev)) {
                *yj = wn + self.nu * (wn - wp);
            }
            self.w_prev = w_new;
            self.stages_done += 1;
            self.xi *= 1.0 - self.eta / 2.0;
            self.start_stage = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{Cluster, CostModel};
    use crate::data::synthetic::tiny_classification;
    use crate::loss::SmoothHinge;
    use crate::metrics::{RoundRecord, Trace};
    use crate::reg::Zero;
    use crate::solver::ProxSdca;
    use std::time::Instant;

    fn acc_opts(sp: f64) -> AccDadmOptions {
        AccDadmOptions {
            dadm: DadmOptions {
                sp,
                cost: CostModel::free(),
                cluster: Cluster::Serial,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    /// Positional convenience over the [`Problem`] builder — the only
    /// construction path — for this module's repetitive setups.
    #[allow(clippy::too_many_arguments)]
    fn build_acc<L, H, S>(
        data: &Dataset,
        part: &Partition,
        loss: L,
        h: H,
        lambda: f64,
        mu: f64,
        solver: S,
        opts: AccDadmOptions,
    ) -> AccDadm<L, H, S>
    where
        L: Loss,
        H: ExtraReg,
        S: LocalSolver,
    {
        Problem::new(data, part)
            .loss(loss)
            .extra_reg(h)
            .lambda(lambda)
            .l1(mu)
            .build_acc_dadm(solver, opts)
    }

    /// Verbatim replica of the pre-engine bespoke Acc-DADM solve loop
    /// (the deleted `AccDadm::solve` body), kept as the parity reference:
    /// the engine-driven solve must reproduce its trace bit for bit.
    fn legacy_solve<L, H, S>(
        acc: &mut AccDadm<L, H, S>,
        eps: f64,
        max_rounds: usize,
    ) -> SolveReport
    where
        L: Loss,
        H: ExtraReg,
        S: LocalSolver,
    {
        let wall_start = Instant::now();
        let mut trace = Trace::new(acc.n);
        acc.inner.resync();

        let (p0, d0) = acc.original_objectives();
        let gap0 = p0 - d0;
        let mut xi = (1.0 + acc.eta.powi(-2)) * gap0;
        let record = |s: &mut AccDadm<L, H, S>, trace: &mut Trace| -> f64 {
            let (p, d) = s.original_objectives();
            let (compute_secs, comm_secs) = s.inner.modeled_secs();
            trace.push(RoundRecord {
                round: s.inner.rounds(),
                passes: s.inner.passes(),
                primal: p,
                dual: d,
                compute_secs,
                comm_secs,
                wall_secs: wall_start.elapsed().as_secs_f64(),
                steps: s.inner.step_stats(),
            });
            p - d
        };
        let mut gap = record(acc, &mut trace);
        let mut converged = gap / acc.n as f64 <= eps;

        let stage_cap = acc
            .opts
            .inner_max_rounds
            .min(((2.0 / acc.opts.dadm.sp).ceil() as usize).max(3));

        'outer: while !converged && acc.inner.rounds() < max_rounds {
            let inner_target =
                acc.opts.stage_target_factor * acc.eta * xi / (2.0 + 2.0 * acc.eta.powi(-2));
            let lambda_tilde = acc.lambda + acc.kappa;
            let reg = ShiftedElasticNet::acc_stage(acc.mu, lambda_tilde, acc.kappa, &acc.y);
            acc.inner.set_reg(reg);
            let inner_eps = inner_target / acc.n as f64;
            let mut inner_rounds = 0usize;
            loop {
                acc.inner.round();
                inner_rounds += 1;
                let check =
                    inner_rounds % acc.opts.dadm.gap_every == 0 || inner_rounds >= stage_cap;
                if check {
                    gap = record(acc, &mut trace);
                    converged = gap / acc.n as f64 <= eps;
                    if converged || acc.inner.rounds() >= max_rounds {
                        acc.stages_done += 1;
                        if converged {
                            break 'outer;
                        } else {
                            break;
                        }
                    }
                    let inner_gap = acc.inner.gap();
                    if inner_gap / acc.n as f64 <= inner_eps || inner_rounds >= stage_cap {
                        break;
                    }
                }
            }
            let w_new = acc.inner.w().to_vec();
            for j in 0..w_new.len() {
                acc.y[j] = w_new[j] + acc.nu * (w_new[j] - acc.w_prev[j]);
            }
            acc.w_prev = w_new;
            acc.stages_done += 1;
            xi *= 1.0 - acc.eta / 2.0;
            if acc.inner.rounds() >= max_rounds {
                break;
            }
        }

        let w = acc.w_original();
        SolveReport {
            w,
            primal: trace.last().map(|r| r.primal).unwrap_or(f64::NAN),
            dual: trace.last().map(|r| r.dual).unwrap_or(f64::NAN),
            rounds: acc.inner.rounds(),
            passes: acc.inner.passes(),
            converged,
            retries: 0,
            stragglers: trace.straggler_summary(),
            trace,
        }
    }

    #[test]
    fn engine_matches_legacy_loop_bit_for_bit() {
        // Driver-vs-old-loop parity at gap_every = 1 (where the legacy
        // cap semantics and the engine's strict cap coincide), across a
        // converging run and a capped run, with both momentum choices.
        let data = tiny_classification(300, 8, 26);
        let part = Partition::balanced(300, 3, 26);
        for (nu, eps, max_rounds) in [
            (NuChoice::Zero, 1e-4, 400usize),
            (NuChoice::Theory, 1e-12, 25), // hits the round cap
        ] {
            let build = || {
                build_acc(
                    &data,
                    &part,
                    SmoothHinge::default(),
                    Zero,
                    1e-4,
                    1e-5,
                    ProxSdca,
                    AccDadmOptions {
                        nu,
                        ..acc_opts(0.5)
                    },
                )
            };
            let mut engine = build();
            let got = engine.solve(eps, max_rounds);
            let mut legacy = build();
            let want = legacy_solve(&mut legacy, eps, max_rounds);
            assert_eq!(got.converged, want.converged);
            assert_eq!(got.rounds, want.rounds);
            assert_eq!(got.passes, want.passes);
            assert_eq!(got.w, want.w, "final iterates diverge");
            assert_eq!(got.trace.rounds.len(), want.trace.rounds.len());
            for (a, b) in got.trace.rounds.iter().zip(&want.trace.rounds) {
                assert_eq!(a.round, b.round);
                assert_eq!(a.passes, b.passes);
                assert_eq!(a.primal, b.primal, "primal diverges at round {}", a.round);
                assert_eq!(a.dual, b.dual, "dual diverges at round {}", a.round);
            }
        }
    }

    #[test]
    fn converges_on_well_conditioned_problem() {
        let data = tiny_classification(150, 6, 21);
        let part = Partition::balanced(150, 3, 21);
        let mut acc = build_acc(
            &data,
            &part,
            SmoothHinge::default(),
            Zero,
            1e-2,
            1e-4,
            ProxSdca,
            acc_opts(1.0),
        );
        let report = acc.solve(1e-5, 500);
        assert!(report.converged, "gap = {}", report.normalized_gap());
    }

    #[test]
    fn kappa_default_matches_remark_12() {
        let data = tiny_classification(100, 5, 22);
        let part = Partition::balanced(100, 4, 22);
        let acc = build_acc(
            &data,
            &part,
            SmoothHinge::default(),
            Zero,
            1e-3,
            0.0,
            ProxSdca,
            acc_opts(0.5),
        );
        let r = data.max_row_norm_sq();
        let want = (4.0 * r / (1.0 * 100.0) - 1e-3).max(0.0);
        assert!((acc.kappa - want).abs() < 1e-12);
        assert!((acc.eta - (1e-3 / (1e-3 + 2.0 * acc.kappa)).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn nu_choices() {
        let data = tiny_classification(80, 4, 23);
        let part = Partition::balanced(80, 2, 23);
        let mk = |nu| {
            build_acc(
                &data,
                &part,
                SmoothHinge::default(),
                Zero,
                1e-3,
                0.0,
                ProxSdca,
                AccDadmOptions {
                    nu,
                    ..acc_opts(1.0)
                },
            )
        };
        assert_eq!(mk(NuChoice::Zero).nu, 0.0);
        let t = mk(NuChoice::Theory);
        assert!((t.nu - (1.0 - t.eta) / (1.0 + t.eta)).abs() < 1e-12);
        assert_eq!(mk(NuChoice::Fixed(0.5)).nu, 0.5);
    }

    #[test]
    fn beats_plain_dadm_when_badly_conditioned() {
        // Small λ ⇒ large condition number: Acc-DADM should reach the gap
        // target in fewer communication rounds than plain DADM (the
        // paper's headline claim, Figures 2–5).
        let data = tiny_classification(400, 10, 24);
        let part = Partition::balanced(400, 4, 24);
        let lambda = 2e-5; // condition number R/(γλ) = 5·10⁴ ≫ n/m
        let eps = 1e-3;
        let max_rounds = 150;

        let mut plain = Problem::new(&data, &part)
            .loss(SmoothHinge::default())
            .reg(ElasticNet::new(0.0))
            .lambda(lambda)
            .build_dadm(
                ProxSdca,
                DadmOptions {
                    sp: 1.0,
                    cost: CostModel::free(),
                    ..Default::default()
                },
            );
        let plain_report = plain.solve(eps, max_rounds);

        let mut acc = build_acc(
            &data,
            &part,
            SmoothHinge::default(),
            Zero,
            lambda,
            0.0,
            ProxSdca,
            acc_opts(1.0),
        );
        let acc_report = acc.solve(eps, max_rounds);

        assert!(
            acc_report.converged,
            "Acc-DADM did not converge: gap {}",
            acc_report.normalized_gap()
        );
        let plain_gap = plain_report.normalized_gap();
        let acc_rounds = acc_report.rounds;
        assert!(
            !plain_report.converged || acc_rounds < plain_report.rounds,
            "no acceleration: acc {} rounds vs plain {} (plain gap {plain_gap:.2e})",
            acc_rounds,
            plain_report.rounds,
        );
    }

    #[test]
    fn original_gap_is_nonnegative() {
        let data = tiny_classification(100, 5, 25);
        let part = Partition::balanced(100, 2, 25);
        let mut acc = build_acc(
            &data,
            &part,
            SmoothHinge::default(),
            Zero,
            1e-4,
            1e-5,
            ProxSdca,
            acc_opts(0.5),
        );
        let report = acc.solve(1e-4, 60);
        for r in &report.trace.rounds {
            assert!(r.gap() >= -1e-6, "negative original gap: {}", r.gap());
        }
    }
}
