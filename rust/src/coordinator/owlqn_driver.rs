//! Distributed OWL-QN driver — the batch baseline of Figures 6–7.
//!
//! Minimizes the normalized experiments objective
//!
//! ```text
//! F(w) = (1/n)Σφ_i(x_iᵀw) + (λ/2)‖w‖² + μ‖w‖₁
//! ```
//!
//! with the smooth part's value/gradient computed by the workers and
//! combined through the same allreduce + cost model DADM uses: every
//! oracle evaluation is one pass over the data plus one communication
//! round (gradient allreduce of `d + 1` floats), which is exactly the
//! accounting the paper's OWL-QN comparison assumes (sp = 1.0 ⇒ one
//! communication per pass).
//!
//! [`DistributedOwlqn`] implements the engine's
//! [`RoundAlgorithm`]: one engine round = one OWL-QN outer iteration of
//! the stepwise [`OwlqnState`] (≥ 1 oracle evaluations). Being a
//! primal-only method it overrides the gap stopping rule and terminates
//! through [`RoundOutcome::finished`] (tolerance / failed line search /
//! pass cap); its trace records carry the normalized objective as the
//! primal and `0.0` as the dual. `Problem::solve_owlqn` is the batch
//! wrapper the benches use.

use super::dadm::resolve_local_threads;
use super::problem::Problem;
use crate::comm::allreduce::tree_allreduce;
use crate::comm::{run_subgroup, Cluster, CostModel};
use crate::data::{Dataset, Partition};
use crate::loss::Loss;
use crate::reg::Zero;
use crate::runtime::engine::{Driver, RoundAlgorithm, RoundOutcome, RoundRequest};
use crate::solver::{Owlqn, OwlqnOptions, OwlqnState, WorkerState};

/// Report of a distributed OWL-QN run.
#[derive(Clone, Debug)]
pub struct OwlqnDriverReport {
    /// Final iterate.
    pub w: Vec<f64>,
    /// Final normalized objective `F(w)`.
    pub objective: f64,
    /// Normalized objective after every oracle evaluation (= pass).
    pub objective_per_pass: Vec<f64>,
    /// Passes over the data (= communications).
    pub passes: usize,
    /// Modeled compute seconds (max across machines per evaluation).
    pub compute_secs: f64,
    /// Modeled communication seconds.
    pub comm_secs: f64,
    /// Real wall-clock seconds.
    pub wall_secs: f64,
}

/// Distributed OWL-QN as a [`RoundAlgorithm`].
#[derive(Debug)]
pub struct DistributedOwlqn<L> {
    /// Logical shard states (`m·T` under hierarchical parallelism,
    /// dispatched in groups of `local_threads` — DESIGN.md §10).
    workers: Vec<WorkerState>,
    /// Resolved intra-machine thread count `T`.
    local_threads: usize,
    loss: L,
    lambda: f64,
    owlqn: Owlqn,
    state: Option<OwlqnState>,
    n: usize,
    d: usize,
    max_passes: usize,
    cluster: Cluster,
    cost: CostModel,
    compute_secs: f64,
    comm_secs: f64,
}

/// Grouped borrow of the algorithm state one oracle evaluation needs —
/// what used to be `oracle_eval`'s 11 positional arguments.
///
/// One distributed smooth-part oracle evaluation:
/// `f(w) = (1/n)Σφ + (λ/2)‖w‖²` with its gradient, one fused pass over
/// every shard plus one `(d+1)`-float allreduce, charged to the modeled
/// compute/comm accumulators. Each machine runs its `T` sub-shard passes
/// concurrently and pre-reduces the `T` raw-sum vectors machine-locally
/// (unit-weight tree — wire-free), so the cross-machine reduce sees one
/// `(d+1)`-vector per physical machine; for power-of-two `T` the
/// factored reduction is bit-identical to a flat `m·T` one (DESIGN.md
/// §10). On the TCP backend the per-shard pass and the local pre-reduce
/// run in the worker processes (`Eval::GradOracle` frames) and return
/// the identical machine vectors, so the reduced oracle is bit-identical
/// across backends.
struct OracleCtx<'c, L> {
    workers: &'c mut [WorkerState],
    local_threads: usize,
    loss: &'c L,
    lambda: f64,
    n: f64,
    d: usize,
    cluster: &'c Cluster,
    cost: &'c CostModel,
    compute_secs: &'c mut f64,
    comm_secs: &'c mut f64,
}

fn oracle_eval<L: Loss>(ctx: &mut OracleCtx<'_, L>, w: &[f64]) -> (f64, Vec<f64>) {
    let (local_threads, loss, lambda, n, d) =
        (ctx.local_threads, ctx.loss, ctx.lambda, ctx.n, ctx.d);
    let (cluster, cost) = (ctx.cluster, ctx.cost);
    let workers = &mut *ctx.workers;
    let (compute_secs, comm_secs) = (&mut *ctx.compute_secs, &mut *ctx.comm_secs);
    let (results, parallel_secs) = if let Some(h) = cluster.remote() {
        h.with(|c| c.eval_gradients(w))
            .expect("tcp gradient oracle failed")
    } else {
        // Per-worker (Σφ_i, Σ x_i·φ'_i) — one fused pass over each
        // sub-shard, via the same `grad_oracle_sums` the TCP worker runs.
        let par = cluster.parallel_local();
        let mut groups: Vec<&mut [WorkerState]> = workers.chunks_mut(local_threads).collect();
        let run = cluster.run(&mut groups, |_, group| {
            let mut sub = run_subgroup(par, group, |_, ws| ws.grad_oracle_sums(loss, w));
            // Single sub-shard: the unit-weight pre-reduce is a bitwise
            // identity (1.0 · v), so skip its O(d) copy on the default
            // T = 1 path.
            let machine = if sub.results.len() == 1 {
                sub.results.pop().expect("one sub-shard")
            } else {
                tree_allreduce(&sub.results, &vec![1.0; sub.results.len()])
            };
            (machine, sub.parallel_secs)
        });
        let mut vectors = Vec::with_capacity(run.results.len());
        let mut machine_secs = 0.0f64;
        for (v, secs) in run.results {
            vectors.push(v);
            machine_secs = machine_secs.max(secs);
        }
        (vectors, machine_secs)
    };
    let m = results.len(); // physical machines = comm participants
    *compute_secs += parallel_secs;
    *comm_secs += cost.allreduce_time(m, d + 1);
    // Weighted by 1 (raw sums; balanced weighting is implicit), then
    // normalized by n.
    let ones = vec![1.0; m];
    let reduced = tree_allreduce(&results, &ones);
    let fval = reduced[d] / n + 0.5 * lambda * crate::utils::math::l2_norm_sq(w);
    let grad: Vec<f64> = (0..d).map(|j| reduced[j] / n + lambda * w[j]).collect();
    (fval, grad)
}

impl<L: Loss> DistributedOwlqn<L> {
    /// Build from a completed [`Problem`] description (the
    /// [`Problem::build_owlqn`] entry point) on `part.machines()`
    /// workers, each evaluating its shard with `local_threads` sub-shard
    /// legs (`1` = the previous serial per-machine pass, `0` = auto from
    /// the core count).
    pub(crate) fn from_problem(
        p: Problem<'_, L, (), Zero>,
        max_passes: usize,
        cluster: Cluster,
        cost: CostModel,
        local_threads: usize,
    ) -> Self {
        let lambda = p.lambda_value();
        let Problem {
            data,
            part,
            loss,
            mu,
            ..
        } = p;
        let t = resolve_local_threads(local_threads, part);
        let lpart_owned;
        let lpart: &Partition = if t == 1 {
            part
        } else {
            lpart_owned = part.split(t);
            &lpart_owned
        };
        // Under the TCP backend the shards live in the worker processes;
        // no local copies are built.
        let workers: Vec<WorkerState> = if !cluster.has_local_workers() {
            Vec::new()
        } else {
            (0..lpart.machines())
                .map(|k| WorkerState::from_partition(data, lpart, k))
                .collect()
        };
        let owlqn = Owlqn::new(OwlqnOptions {
            mu,
            memory: 10, // §10: "we set the memory parameter as 10"
            max_iters: max_passes,
            tol: 1e-12,
            max_line_search: 30,
        });
        DistributedOwlqn {
            workers,
            local_threads: t,
            loss,
            lambda,
            owlqn,
            state: None,
            n: data.n(),
            d: data.dim(),
            max_passes,
            cluster,
            cost,
            compute_secs: 0.0,
            comm_secs: 0.0,
        }
    }

    fn state(&self) -> &OwlqnState {
        self.state
            .as_ref()
            .expect("Driver::solve prepares before use")
    }

    /// Consume into the figure report (`report_wall` = wall-clock seconds
    /// from the engine trace).
    fn into_report(self, report_wall: f64) -> OwlqnDriverReport {
        let max_passes = self.max_passes;
        let objective = self.owlqn.objective(self.state());
        let st = self.state.expect("solved state");
        OwlqnDriverReport {
            w: st.w,
            objective,
            objective_per_pass: st.eval_trace.into_iter().take(max_passes).collect(),
            passes: st.evals.min(max_passes),
            compute_secs: self.compute_secs,
            comm_secs: self.comm_secs,
            wall_secs: report_wall,
        }
    }
}

impl<L: Loss> RoundAlgorithm for DistributedOwlqn<L> {
    fn n(&self) -> usize {
        self.n
    }

    fn prepare(&mut self) {
        let DistributedOwlqn {
            workers,
            local_threads,
            loss,
            lambda,
            owlqn,
            state,
            n,
            d,
            cluster,
            cost,
            compute_secs,
            comm_secs,
            ..
        } = self;
        let mut ctx = OracleCtx {
            workers,
            local_threads: *local_threads,
            loss,
            lambda: *lambda,
            n: *n as f64,
            d: *d,
            cluster,
            cost,
            compute_secs,
            comm_secs,
        };
        let mut oracle = |w: &[f64]| oracle_eval(&mut ctx, w);
        *state = Some(owlqn.begin(vec![0.0; *d], &mut oracle));
    }

    fn round(&mut self, _req: RoundRequest) -> RoundOutcome {
        // Primal-only: no duality-gap telemetry to fuse (`fused_gap` =
        // false), the driver records eagerly after every iteration.
        let DistributedOwlqn {
            workers,
            local_threads,
            loss,
            lambda,
            owlqn,
            state,
            n,
            d,
            max_passes,
            cluster,
            cost,
            compute_secs,
            comm_secs,
        } = self;
        let st = state.as_mut().expect("Driver::solve prepares before use");
        let mut ctx = OracleCtx {
            workers,
            local_threads: *local_threads,
            loss,
            lambda: *lambda,
            n: *n as f64,
            d: *d,
            cluster,
            cost,
            compute_secs,
            comm_secs,
        };
        let mut oracle = |w: &[f64]| oracle_eval(&mut ctx, w);
        owlqn.step(st, &mut oracle);
        RoundOutcome {
            record_due: true,
            // The budget caps *iterations* (the engine round counter),
            // exactly like the batch `minimize` with max_iters =
            // max_passes — evals may overrun mid-line-search and are
            // truncated in the report, matching the legacy accounting.
            finished: st.done || st.iters >= *max_passes,
            ..RoundOutcome::default()
        }
    }

    fn objectives(&mut self) -> (f64, f64) {
        (self.owlqn.objective(self.state()), 0.0)
    }

    fn rounds(&self) -> usize {
        // Comm rounds = oracle evaluations (one allreduce each), capped
        // at the pass budget like the paper's accounting.
        self.state
            .as_ref()
            .map_or(0, |st| st.evals.min(self.max_passes))
    }

    fn passes(&self) -> f64 {
        self.rounds() as f64
    }

    fn modeled_secs(&self) -> (f64, f64) {
        (self.compute_secs, self.comm_secs)
    }

    fn final_w(&mut self) -> Vec<f64> {
        self.state().w.clone()
    }

    /// Primal-only method: never stops on the duality gap.
    fn gap_converged(&self, _normalized_gap: f64, _eps: f64) -> bool {
        false
    }
}

/// Run distributed OWL-QN on a completed [`Problem`] description (batch
/// wrapper over the engine: `Driver` + [`DistributedOwlqn`]) — the
/// [`Problem::solve_owlqn`] entry point.
pub(crate) fn solve_owlqn_problem<L: Loss>(
    p: Problem<'_, L, (), Zero>,
    max_passes: usize,
    cluster: Cluster,
    cost: CostModel,
    local_threads: usize,
) -> OwlqnDriverReport {
    let mut algo = DistributedOwlqn::from_problem(p, max_passes, cluster, cost, local_threads);
    let report = Driver::new(0.0, max_passes).solve(&mut algo);
    let wall = report.trace.last().map(|r| r.wall_secs).unwrap_or(0.0);
    algo.into_report(wall)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::tiny_classification;
    use crate::loss::Logistic;

    /// Positional convenience over the [`Problem`] builder — the only
    /// construction path — for this module's repetitive setups.
    #[allow(clippy::too_many_arguments)]
    fn run_owlqn<L: Loss>(
        data: &Dataset,
        part: &Partition,
        loss: L,
        lambda: f64,
        mu: f64,
        max_passes: usize,
        cluster: Cluster,
        cost: CostModel,
        local_threads: usize,
    ) -> OwlqnDriverReport {
        Problem::new(data, part)
            .loss(loss)
            .lambda(lambda)
            .l1(mu)
            .solve_owlqn(max_passes, cluster, cost, local_threads)
    }

    #[test]
    fn decreases_objective_and_counts_passes() {
        let data = tiny_classification(200, 6, 31);
        let part = Partition::balanced(200, 4, 31);
        let report = run_owlqn(
            &data,
            &part,
            Logistic,
            1e-3,
            1e-4,
            60,
            Cluster::Serial,
            CostModel::free(),
            1,
        );
        assert!(report.passes >= 2);
        let first = report.objective_per_pass[0];
        let last = *report.objective_per_pass.last().unwrap();
        assert!(last < first, "no progress: {first} -> {last}");
        assert!((last - report.objective).abs() < 1e-9 || last <= report.objective);
    }

    #[test]
    fn machine_count_does_not_change_the_math() {
        let data = tiny_classification(120, 5, 32);
        let run = |m: usize| {
            let part = Partition::balanced(120, m, 32);
            run_owlqn(
                &data,
                &part,
                Logistic,
                1e-3,
                1e-4,
                30,
                Cluster::Serial,
                CostModel::free(),
                1,
            )
        };
        let a = run(1);
        let b = run(4);
        assert!((a.objective - b.objective).abs() < 1e-6);
        for (x, y) in a.w.iter().zip(&b.w) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn engine_round_equals_batch_minimize() {
        // Driver-vs-old-loop parity: one machine, serial cluster — the
        // distributed oracle reduces to the plain in-process oracle, so
        // the engine-driven run must match `Owlqn::minimize` on the same
        // objective bit for bit.
        let data = tiny_classification(150, 5, 35);
        let part = Partition::balanced(150, 1, 35);
        let (lambda, mu, max_passes) = (1e-3, 1e-4, 40usize);
        let report = run_owlqn(
            &data,
            &part,
            Logistic,
            lambda,
            mu,
            max_passes,
            Cluster::Serial,
            CostModel::free(),
            1,
        );
        let n = data.n() as f64;
        let d = data.dim();
        let oracle = |w: &[f64]| {
            // Same shard traversal order as the single worker (the
            // balanced partition shuffles), so sums match bit for bit.
            let mut grad = vec![0.0; d];
            let mut fsum = 0.0;
            for &i in part.shard(0) {
                let row = data.x.row(i);
                let u = row.dot(w);
                fsum += Logistic.phi(u, data.y[i]);
                let gi = Logistic.grad(u, data.y[i]);
                if gi != 0.0 {
                    row.axpy_into(gi, &mut grad[..]);
                }
            }
            let fval = fsum / n + 0.5 * lambda * crate::utils::math::l2_norm_sq(w);
            let g: Vec<f64> = (0..d).map(|j| grad[j] / n + lambda * w[j]).collect();
            (fval, g)
        };
        let owlqn = Owlqn::new(OwlqnOptions {
            mu,
            memory: 10,
            max_iters: max_passes,
            tol: 1e-12,
            max_line_search: 30,
        });
        let reference = owlqn.minimize(vec![0.0; d], oracle);
        assert_eq!(report.w, reference.w, "engine and batch loops diverge");
        assert_eq!(report.objective, reference.objective);
        let want: Vec<f64> = reference
            .eval_trace
            .iter()
            .copied()
            .take(max_passes)
            .collect();
        assert_eq!(report.objective_per_pass, want);
        assert_eq!(report.passes, reference.evals.min(max_passes));
    }

    #[test]
    fn local_threads_match_flat_logical_machines() {
        // (m, T) with power-of-two T must reproduce the flat m·T run bit
        // for bit: same logical shards (split == balanced when m·T | n),
        // same tree-factored oracle reduction (DESIGN.md §10).
        let data = tiny_classification(240, 5, 36);
        let run = |m: usize, t: usize| {
            let part = Partition::balanced(240, m, 36);
            run_owlqn(
                &data,
                &part,
                Logistic,
                1e-3,
                1e-4,
                25,
                Cluster::Serial,
                CostModel::free(),
                t,
            )
        };
        let nested = run(2, 2);
        let flat = run(4, 1);
        assert_eq!(nested.w, flat.w, "nested OWL-QN diverged from flat");
        assert_eq!(nested.objective.to_bits(), flat.objective.to_bits());
        assert_eq!(nested.passes, flat.passes);
        assert_eq!(nested.objective_per_pass, flat.objective_per_pass);
    }

    #[test]
    fn comm_cost_counted_per_evaluation() {
        let data = tiny_classification(100, 4, 33);
        let part = Partition::balanced(100, 4, 33);
        let report = run_owlqn(
            &data,
            &part,
            Logistic,
            1e-3,
            0.0,
            20,
            Cluster::Serial,
            CostModel::default(),
            1,
        );
        assert!(report.comm_secs > 0.0);
    }

    #[test]
    fn matches_reference_on_separable_problem() {
        // Sanity: strongly-regularized LR reaches a small gradient norm.
        let data = tiny_classification(150, 4, 34);
        let part = Partition::balanced(150, 2, 34);
        let report = run_owlqn(
            &data,
            &part,
            Logistic,
            0.1,
            0.0,
            100,
            Cluster::Serial,
            CostModel::free(),
            1,
        );
        // ∇F(w*) ≈ 0: check via finite difference of the objective.
        let f = |w: &[f64]| {
            let mut s = 0.0;
            for i in 0..data.n() {
                s += Logistic.phi(data.x.row(i).dot(w), data.y[i]);
            }
            s / data.n() as f64 + 0.05 * crate::utils::math::l2_norm_sq(w)
        };
        let base = f(&report.w);
        for j in 0..4 {
            let mut wp = report.w.clone();
            wp[j] += 1e-4;
            assert!(f(&wp) >= base - 1e-6, "not a minimum along coord {j}");
        }
    }
}
