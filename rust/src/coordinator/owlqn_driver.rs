//! Distributed OWL-QN driver — the batch baseline of Figures 6–7.
//!
//! Minimizes the normalized experiments objective
//!
//! ```text
//! F(w) = (1/n)Σφ_i(x_iᵀw) + (λ/2)‖w‖² + μ‖w‖₁
//! ```
//!
//! with the smooth part's value/gradient computed by the workers and
//! combined through the same allreduce + cost model DADM uses: every
//! oracle evaluation is one pass over the data plus one communication
//! round (gradient allreduce of `d + 1` floats), which is exactly the
//! accounting the paper's OWL-QN comparison assumes (sp = 1.0 ⇒ one
//! communication per pass).

use crate::comm::allreduce::tree_allreduce;
use crate::comm::{Cluster, CostModel};
use crate::data::{Dataset, Partition};
use crate::loss::Loss;
use crate::solver::{Owlqn, OwlqnOptions, WorkerState};
use std::time::Instant;

/// Report of a distributed OWL-QN run.
#[derive(Clone, Debug)]
pub struct OwlqnDriverReport {
    /// Final iterate.
    pub w: Vec<f64>,
    /// Final normalized objective `F(w)`.
    pub objective: f64,
    /// Normalized objective after every oracle evaluation (= pass).
    pub objective_per_pass: Vec<f64>,
    /// Passes over the data (= communications).
    pub passes: usize,
    /// Modeled compute seconds (max across machines per evaluation).
    pub compute_secs: f64,
    /// Modeled communication seconds.
    pub comm_secs: f64,
    /// Real wall-clock seconds.
    pub wall_secs: f64,
}

/// Run distributed OWL-QN on the experiments objective.
#[allow(clippy::too_many_arguments)]
pub fn run_owlqn_distributed<L: Loss + Clone>(
    data: &Dataset,
    part: &Partition,
    loss: L,
    lambda: f64,
    mu: f64,
    max_passes: usize,
    cluster: Cluster,
    cost: CostModel,
) -> OwlqnDriverReport {
    let n = data.n() as f64;
    let d = data.dim();
    let m = part.machines();
    let mut workers: Vec<WorkerState> = (0..m)
        .map(|l| WorkerState::from_partition(data, part, l))
        .collect();
    let weights: Vec<f64> = workers.iter().map(|w| w.n_l() as f64 / n).collect();

    let compute_secs = std::cell::Cell::new(0.0f64);
    let comm_secs = std::cell::Cell::new(0.0f64);
    let wall_start = Instant::now();

    // Smooth-part oracle: f(w) = (1/n)Σφ + (λ/2)‖w‖².
    let oracle = |w: &[f64]| -> (f64, Vec<f64>) {
        let loss = &loss;
        let run = cluster.run(&mut workers, |_, ws: &mut WorkerState| {
            // Per-worker (Σφ_i, Σ x_i·φ'_i) — one fused pass over the shard.
            let mut grad = vec![0.0; d + 1];
            for i in 0..ws.n_l() {
                let row = ws.x.row(i);
                let u = row.dot(w);
                grad[d] += loss.phi(u, ws.y[i]);
                let gi = loss.grad(u, ws.y[i]);
                if gi != 0.0 {
                    row.axpy_into(gi, &mut grad[..d]);
                }
            }
            grad
        });
        compute_secs.set(compute_secs.get() + run.parallel_secs);
        comm_secs.set(comm_secs.get() + cost.allreduce_time(m, d + 1));
        // Weighted by 1 (raw sums), then normalized by n.
        let ones = vec![1.0; m];
        let reduced = tree_allreduce(&run.results, &ones);
        let fval = reduced[d] / n + 0.5 * lambda * crate::utils::math::l2_norm_sq(w);
        let grad: Vec<f64> = (0..d).map(|j| reduced[j] / n + lambda * w[j]).collect();
        (fval, grad)
    };

    let owlqn = Owlqn::new(OwlqnOptions {
        mu,
        memory: 10, // §10: "we set the memory parameter as 10"
        max_iters: max_passes,
        tol: 1e-12,
        max_line_search: 30,
    });
    // OwlqnResult.evals counts oracle calls; cap total passes by giving the
    // optimizer max_iters = max_passes (it does ≥ 1 eval per iter).
    let result = owlqn.minimize(vec![0.0; d], oracle);
    let _ = weights; // balanced weighting is implicit in the raw sums

    OwlqnDriverReport {
        w: result.w,
        objective: result.objective,
        objective_per_pass: result.eval_trace.into_iter().take(max_passes).collect(),
        passes: result.evals.min(max_passes),
        compute_secs: compute_secs.get(),
        comm_secs: comm_secs.get(),
        wall_secs: wall_start.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::tiny_classification;
    use crate::loss::Logistic;

    #[test]
    fn decreases_objective_and_counts_passes() {
        let data = tiny_classification(200, 6, 31);
        let part = Partition::balanced(200, 4, 31);
        let report = run_owlqn_distributed(
            &data,
            &part,
            Logistic,
            1e-3,
            1e-4,
            60,
            Cluster::Serial,
            CostModel::free(),
        );
        assert!(report.passes >= 2);
        let first = report.objective_per_pass[0];
        let last = *report.objective_per_pass.last().unwrap();
        assert!(last < first, "no progress: {first} -> {last}");
        assert!((last - report.objective).abs() < 1e-9 || last <= report.objective);
    }

    #[test]
    fn machine_count_does_not_change_the_math() {
        let data = tiny_classification(120, 5, 32);
        let run = |m: usize| {
            let part = Partition::balanced(120, m, 32);
            run_owlqn_distributed(
                &data,
                &part,
                Logistic,
                1e-3,
                1e-4,
                30,
                Cluster::Serial,
                CostModel::free(),
            )
        };
        let a = run(1);
        let b = run(4);
        assert!((a.objective - b.objective).abs() < 1e-6);
        for (x, y) in a.w.iter().zip(&b.w) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn comm_cost_counted_per_evaluation() {
        let data = tiny_classification(100, 4, 33);
        let part = Partition::balanced(100, 4, 33);
        let report = run_owlqn_distributed(
            &data,
            &part,
            Logistic,
            1e-3,
            0.0,
            20,
            Cluster::Serial,
            CostModel::default(),
        );
        assert!(report.comm_secs > 0.0);
    }

    #[test]
    fn matches_reference_on_separable_problem() {
        // Sanity: strongly-regularized LR reaches a small gradient norm.
        let data = tiny_classification(150, 4, 34);
        let part = Partition::balanced(150, 2, 34);
        let report = run_owlqn_distributed(
            &data,
            &part,
            Logistic,
            0.1,
            0.0,
            100,
            Cluster::Serial,
            CostModel::free(),
        );
        // ∇F(w*) ≈ 0: check via finite difference of the objective.
        let f = |w: &[f64]| {
            let mut s = 0.0;
            for i in 0..data.n() {
                s += Logistic.phi(data.x.row(i).dot(w), data.y[i]);
            }
            s / data.n() as f64 + 0.05 * crate::utils::math::l2_norm_sq(w)
        };
        let base = f(&report.w);
        for j in 0..4 {
            let mut wp = report.w.clone();
            wp[j] += 1e-4;
            assert!(f(&wp) >= base - 1e-6, "not a minimum along coord {j}");
        }
    }
}
