//! The paper's system contribution: Distributed Alternating Dual
//! Maximization and its accelerated variant.
//!
//! * [`dadm`] — Algorithm 2: the alternating local/global loop over the
//!   simulated cluster, with the closed-form β-maximization global step
//!   of Propositions 4/5 and exact duality-gap tracking. With `h = 0` and
//!   balanced partitions this *is* CoCoA+ (§6), so the CoCoA+ baseline in
//!   every bench is DADM without acceleration.
//! * [`acc_dadm`] — Algorithm 3: the Catalyst-style inner–outer
//!   acceleration with stage regularizer `g_t` (see
//!   [`crate::reg::ShiftedElasticNet`]), momentum `ν` (theory value or
//!   the paper's empirically-smoother `ν = 0`), and the geometric
//!   stage-target schedule `ξ_t`.
//! * [`owlqn_driver`] — the distributed OWL-QN baseline of Figures 6–7,
//!   sharing the cluster/cost accounting.

pub mod acc_dadm;
pub mod checkpoint;
pub mod dadm;
pub mod owlqn_driver;

pub use acc_dadm::{AccDadm, AccDadmOptions, NuChoice};
pub use checkpoint::Checkpoint;
pub use dadm::{Dadm, DadmOptions, SolveReport};
pub use owlqn_driver::{run_owlqn_distributed, OwlqnDriverReport};
