//! The paper's system contribution: Distributed Alternating Dual
//! Maximization and its accelerated variant.
//!
//! All three methods run through the shared round engine
//! ([`crate::runtime::engine`]): each coordinator implements
//! [`crate::runtime::engine::RoundAlgorithm`] — one round of work plus
//! objective hooks — and the engine's `Driver` owns the solve loop
//! (stopping policy, gap cadence, trace emission, accounting, periodic
//! checkpoints). There are no per-method solve loops.
//!
//! * [`dadm`] — Algorithm 2: the alternating local/global round over the
//!   simulated cluster, with the closed-form β-maximization global step
//!   of Propositions 4/5 and exact duality-gap tracking. The round is a
//!   single fused pool section (broadcast apply + local step) and an
//!   allocation-free global step. With `h = 0` and balanced partitions
//!   this *is* CoCoA+ (§6), so the CoCoA+ baseline in every bench is
//!   DADM without acceleration.
//! * [`acc_dadm`] — Algorithm 3: the Catalyst-style inner–outer
//!   acceleration with stage regularizer `g_t` (see
//!   [`crate::reg::ShiftedElasticNet`]), momentum `ν` (theory value or
//!   the paper's empirically-smoother `ν = 0`), and the geometric
//!   stage-target schedule `ξ_t` — expressed as engine record hooks, not
//!   a bespoke nested loop.
//! * [`owlqn_driver`] — the distributed OWL-QN baseline of Figures 6–7,
//!   stepping the stepwise [`crate::solver::OwlqnState`] one iteration
//!   per engine round and sharing the cluster/cost accounting.
//! * [`problem`] — the [`Problem`] builder, the one front door that
//!   names the objective ingredients `(φ, g, h, λ, μ)` and constructs
//!   any of the three coordinators (the old positional `new`
//!   constructors are gone; every construction goes through it).
//! * [`checkpoint`] — resumable solver snapshots (v2: dual state plus
//!   round counters and RNG streams for bit-exact resumption), written
//!   by the engine's snapshot hook (CLI `--checkpoint`/`--resume`).

pub mod acc_dadm;
pub mod checkpoint;
pub mod dadm;
pub mod owlqn_driver;
pub mod problem;

pub use acc_dadm::{AccDadm, AccDadmOptions, NuChoice};
pub use checkpoint::Checkpoint;
pub use dadm::{resolve_local_threads, Dadm, DadmOptions, SolveReport};
pub use owlqn_driver::{DistributedOwlqn, OwlqnDriverReport};
pub use problem::Problem;
