//! Solver-state checkpointing.
//!
//! Long distributed solves (the paper's kdd2010 runs take hours on a real
//! cluster) need resumable state. The dual state of Algorithm 2 is fully
//! characterized by `(α, v)` — everything else (`ṽ`, `w`, `β`) is
//! recomputed by one Proposition-4/5 global sync — so a checkpoint is
//! small: one f64 per example plus one per feature, stored in a
//! versioned, self-describing text format (no serde offline). The v2
//! format adds the cumulative round/pass counters and the per-machine
//! mini-batch RNG states, so a resumed solve continues the *exact*
//! sampling stream and reproduces the uninterrupted trajectory bit for
//! bit (pinned by `rust/tests/engine.rs`). The v3 format adds the
//! per-machine running dual sums `Σ−φ*(−α_i)` (DESIGN.md §11) — they
//! are incrementally maintained solver state, so a resumed run that
//! merely recomputed them exactly would drift off the uninterrupted
//! gap trace by ulps. The v4 format adds the quantized-delta error
//! feedback of DESIGN.md §13: the per-machine wire residuals and the
//! coordinator's broadcast image `W` (the bitwise shadow of the worker
//! replicas' `ṽ`) — both live solver state under `--compress`, so a
//! bit-parity resume must carry them. v1–v3 files still load; v1
//! restarts the RNG streams, v1/v2 mark the running sums stale
//! (rebuilt exactly on the next telemetry read), and v1–v3 imply no
//! compression state (residuals restart at zero).
//!
//! Format:
//! ```text
//! dadm-checkpoint v4
//! lambda <float>
//! rounds <int>
//! passes <float>
//! machines <m>
//! v <d> <float>*d
//! alpha <l> <n_l> <float>*n_l        (one line per machine)
//! rng <l> <u64>*4                    (one line per machine; v2+)
//! conj <l> <float>                   (one line per machine; v3+, only
//!                                     when telemetry was armed)
//! residual <l> <d> <float>*d         (one line per machine; v4, only
//!                                     under a non-exact codec)
//! vimage <d> <float>*d               (v4, only under a non-exact codec)
//! ```
//!
//! Checkpoints are written by the engine's snapshot hook
//! ([`crate::runtime::engine::CheckpointPolicy`], CLI `--checkpoint` /
//! `--checkpoint-every`) and restored through [`super::Dadm::restore`]
//! (CLI `--resume`).

use anyhow::{bail, Context, Result};
use std::io::{BufRead, Write};

/// A dual-state snapshot: global `v` plus per-machine `α_(ℓ)`, with the
/// cumulative counters and RNG streams needed for exact resumption.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Effective λ the state was produced under (λ̃ during Acc-DADM).
    pub lambda: f64,
    /// Communication rounds completed when the snapshot was taken.
    pub rounds: usize,
    /// Passes over the data when the snapshot was taken.
    pub passes: f64,
    /// Global `v = Σ X_i α_i / (λn)`.
    pub v: Vec<f64>,
    /// Per-machine local duals, in machine order.
    pub alpha: Vec<Vec<f64>>,
    /// Per-machine mini-batch RNG states (`None` in v1 files: streams
    /// restart on restore).
    pub rng: Option<Vec<[u64; 4]>>,
    /// Per-machine running dual sums `Σ−φ*(−α_i)` (`None` in v1/v2
    /// files, or when gap telemetry was never armed: the sums are
    /// rebuilt exactly on the next read).
    pub conj: Option<Vec<f64>>,
    /// Per-machine quantization residuals of the error-feedback wire
    /// codec (DESIGN.md §13). `None` in v1–v3 files and whenever the
    /// run used the exact `f64` codec.
    pub residual: Option<Vec<Vec<f64>>>,
    /// The coordinator's broadcast image `W` — the bitwise shadow of
    /// the worker replicas' `ṽ` under a lossy codec. `None` exactly
    /// when `residual` is.
    pub v_image: Option<Vec<f64>>,
}

impl Checkpoint {
    /// Serialize to a writer (always the v4 format).
    pub fn save<W: Write>(&self, mut w: W) -> Result<()> {
        writeln!(w, "dadm-checkpoint v4")?;
        writeln!(w, "lambda {:e}", self.lambda)?;
        writeln!(w, "rounds {}", self.rounds)?;
        writeln!(w, "passes {:e}", self.passes)?;
        writeln!(w, "machines {}", self.alpha.len())?;
        write!(w, "v {}", self.v.len())?;
        for x in &self.v {
            write!(w, " {x:e}")?;
        }
        writeln!(w)?;
        for (l, a) in self.alpha.iter().enumerate() {
            write!(w, "alpha {l} {}", a.len())?;
            for x in a {
                write!(w, " {x:e}")?;
            }
            writeln!(w)?;
        }
        if let Some(states) = &self.rng {
            for (l, s) in states.iter().enumerate() {
                writeln!(w, "rng {l} {} {} {} {}", s[0], s[1], s[2], s[3])?;
            }
        }
        if let Some(conj) = &self.conj {
            for (l, c) in conj.iter().enumerate() {
                writeln!(w, "conj {l} {c:e}")?;
            }
        }
        if let Some(residual) = &self.residual {
            for (l, r) in residual.iter().enumerate() {
                write!(w, "residual {l} {}", r.len())?;
                for x in r {
                    write!(w, " {x:e}")?;
                }
                writeln!(w)?;
            }
        }
        if let Some(img) = &self.v_image {
            write!(w, "vimage {}", img.len())?;
            for x in img {
                write!(w, " {x:e}")?;
            }
            writeln!(w)?;
        }
        Ok(())
    }

    /// Parse from a reader (v1 through v4).
    pub fn load<R: BufRead>(r: R) -> Result<Self> {
        let mut lines = r.lines();
        let header = lines.next().context("empty checkpoint")??;
        match header.trim() {
            "dadm-checkpoint v1" | "dadm-checkpoint v2" | "dadm-checkpoint v3"
            | "dadm-checkpoint v4" => {}
            other => bail!("unknown checkpoint header `{other}`"),
        }
        let mut lambda = None;
        let mut rounds = 0usize;
        let mut passes = 0.0f64;
        let mut machines = None;
        let mut v: Option<Vec<f64>> = None;
        let mut alpha: Vec<(usize, Vec<f64>)> = vec![];
        let mut rng: Vec<(usize, [u64; 4])> = vec![];
        let mut conj: Vec<(usize, f64)> = vec![];
        let mut residual: Vec<(usize, Vec<f64>)> = vec![];
        let mut v_image: Option<Vec<f64>> = None;
        for line in lines {
            let line = line?;
            let mut toks = line.split_ascii_whitespace();
            match toks.next() {
                Some("lambda") => {
                    lambda = Some(toks.next().context("lambda value")?.parse()?);
                }
                Some("rounds") => {
                    rounds = toks.next().context("rounds value")?.parse()?;
                }
                Some("passes") => {
                    passes = toks.next().context("passes value")?.parse()?;
                }
                Some("machines") => {
                    machines = Some(toks.next().context("machine count")?.parse::<usize>()?);
                }
                Some("v") => {
                    let d: usize = toks.next().context("v length")?.parse()?;
                    let vals: Vec<f64> = toks
                        .map(|t| t.parse::<f64>().context("v entry"))
                        .collect::<Result<_>>()?;
                    anyhow::ensure!(vals.len() == d, "v length mismatch");
                    v = Some(vals);
                }
                Some("alpha") => {
                    let l: usize = toks.next().context("machine id")?.parse()?;
                    let n: usize = toks.next().context("alpha length")?.parse()?;
                    let vals: Vec<f64> = toks
                        .map(|t| t.parse::<f64>().context("alpha entry"))
                        .collect::<Result<_>>()?;
                    anyhow::ensure!(vals.len() == n, "alpha[{l}] length mismatch");
                    alpha.push((l, vals));
                }
                Some("rng") => {
                    let l: usize = toks.next().context("machine id")?.parse()?;
                    let words: Vec<u64> = toks
                        .map(|t| t.parse::<u64>().context("rng word"))
                        .collect::<Result<_>>()?;
                    anyhow::ensure!(words.len() == 4, "rng[{l}] needs 4 words");
                    // The all-zero state is xoshiro256**'s fixed point:
                    // reject at load time instead of panicking (debug)
                    // or freezing the stream (release) at restore.
                    anyhow::ensure!(
                        words.iter().any(|w| *w != 0),
                        "rng[{l}] state is all-zero (corrupt checkpoint)"
                    );
                    rng.push((l, [words[0], words[1], words[2], words[3]]));
                }
                Some("conj") => {
                    let l: usize = toks.next().context("machine id")?.parse()?;
                    let c: f64 = toks.next().context("conj value")?.parse()?;
                    conj.push((l, c));
                }
                Some("residual") => {
                    let l: usize = toks.next().context("machine id")?.parse()?;
                    let d: usize = toks.next().context("residual length")?.parse()?;
                    let vals: Vec<f64> = toks
                        .map(|t| t.parse::<f64>().context("residual entry"))
                        .collect::<Result<_>>()?;
                    anyhow::ensure!(vals.len() == d, "residual[{l}] length mismatch");
                    residual.push((l, vals));
                }
                Some("vimage") => {
                    let d: usize = toks.next().context("vimage length")?.parse()?;
                    let vals: Vec<f64> = toks
                        .map(|t| t.parse::<f64>().context("vimage entry"))
                        .collect::<Result<_>>()?;
                    anyhow::ensure!(vals.len() == d, "vimage length mismatch");
                    v_image = Some(vals);
                }
                Some(other) => bail!("unknown checkpoint record `{other}`"),
                None => continue,
            }
        }
        let machines = machines.context("missing machines record")?;
        anyhow::ensure!(
            alpha.len() == machines,
            "expected {machines} alpha records, found {}",
            alpha.len()
        );
        alpha.sort_by_key(|(l, _)| *l);
        for (want, (got, _)) in alpha.iter().enumerate() {
            anyhow::ensure!(*got == want, "missing alpha record for machine {want}");
        }
        let rng = if rng.is_empty() {
            None
        } else {
            anyhow::ensure!(
                rng.len() == machines,
                "expected {machines} rng records, found {}",
                rng.len()
            );
            rng.sort_by_key(|(l, _)| *l);
            for (want, (got, _)) in rng.iter().enumerate() {
                anyhow::ensure!(*got == want, "missing rng record for machine {want}");
            }
            Some(rng.into_iter().map(|(_, s)| s).collect())
        };
        let conj = if conj.is_empty() {
            None
        } else {
            anyhow::ensure!(
                conj.len() == machines,
                "expected {machines} conj records, found {}",
                conj.len()
            );
            conj.sort_by_key(|(l, _)| *l);
            for (want, (got, _)) in conj.iter().enumerate() {
                anyhow::ensure!(*got == want, "missing conj record for machine {want}");
            }
            Some(conj.into_iter().map(|(_, c)| c).collect())
        };
        let residual = if residual.is_empty() {
            None
        } else {
            anyhow::ensure!(
                residual.len() == machines,
                "expected {machines} residual records, found {}",
                residual.len()
            );
            residual.sort_by_key(|(l, _)| *l);
            for (want, (got, _)) in residual.iter().enumerate() {
                anyhow::ensure!(*got == want, "missing residual record for machine {want}");
            }
            Some(residual.into_iter().map(|(_, r)| r).collect::<Vec<_>>())
        };
        anyhow::ensure!(
            residual.is_some() == v_image.is_some(),
            "residual and vimage records must appear together"
        );
        Ok(Checkpoint {
            lambda: lambda.context("missing lambda record")?,
            rounds,
            passes,
            v: v.context("missing v record")?,
            alpha: alpha.into_iter().map(|(_, a)| a).collect(),
            rng,
            conj,
            residual,
            v_image,
        })
    }

    /// Save to a file path.
    pub fn save_file(&self, path: &std::path::Path) -> Result<()> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        self.save(std::io::BufWriter::new(f))
    }

    /// Load from a file path.
    pub fn load_file(path: &std::path::Path) -> Result<Self> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        Self::load(std::io::BufReader::new(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            lambda: 1e-6,
            rounds: 17,
            passes: 3.4000000000000004, // deliberately non-representable
            v: vec![0.25, -1.5e-8, 0.0],
            alpha: vec![vec![1.0, -0.5], vec![0.0, 0.125, 3.0]],
            rng: Some(vec![[1, 2, 3, 4], [u64::MAX, 7, 0, 9]]),
            conj: Some(vec![-1.2500000000000002, 0.75]),
            residual: None,
            v_image: None,
        }
    }

    fn sample_compressed() -> Checkpoint {
        Checkpoint {
            residual: Some(vec![vec![1e-9, -2.5e-17, 0.0], vec![]]),
            v_image: Some(vec![0.25, -1.5e-8, 0.0]),
            ..sample()
        }
    }

    #[test]
    fn roundtrip_exact() {
        let ck = sample();
        let mut buf = Vec::new();
        ck.save(&mut buf).unwrap();
        let back = Checkpoint::load(std::io::Cursor::new(buf)).unwrap();
        assert_eq!(ck, back); // bit-exact through `{:e}` printing
    }

    #[test]
    fn roundtrip_exact_with_compression_state() {
        let ck = sample_compressed();
        let mut buf = Vec::new();
        ck.save(&mut buf).unwrap();
        let text = String::from_utf8(buf.clone()).unwrap();
        assert!(text.starts_with("dadm-checkpoint v4\n"));
        assert!(text.contains("\nresidual 0 3 "));
        assert!(text.contains("\nresidual 1 0\n"), "empty residuals still recorded");
        assert!(text.contains("\nvimage 3 "));
        let back = Checkpoint::load(std::io::Cursor::new(buf)).unwrap();
        assert_eq!(ck, back);
    }

    #[test]
    fn loads_v3_shaped_body_without_compression_state() {
        // A v3-era body (no residual/vimage records) under either header
        // loads with the compression state absent — lossy-codec state
        // restarts at zero on restore.
        for header in ["dadm-checkpoint v3", "dadm-checkpoint v4"] {
            let text = format!(
                "{header}\nlambda 1e-6\nrounds 3\npasses 0.6\nmachines 1\n\
                 v 1 0.5\nalpha 0 1 1.0\nrng 0 1 2 3 4\nconj 0 0.25\n"
            );
            let ck = Checkpoint::load(std::io::Cursor::new(text)).unwrap();
            assert!(ck.residual.is_none());
            assert!(ck.v_image.is_none());
            assert!(ck.conj.is_some());
        }
    }

    #[test]
    fn rejects_partial_residual_records() {
        let text = "dadm-checkpoint v4\nlambda 1e-6\nmachines 2\nv 1 0.5\n\
                    alpha 0 1 1.0\nalpha 1 1 2.0\nresidual 0 1 0.25\nvimage 1 0.5\n";
        let err = Checkpoint::load(std::io::Cursor::new(text)).unwrap_err();
        assert!(format!("{err:#}").contains("residual records"));
    }

    #[test]
    fn rejects_residual_without_vimage() {
        let text = "dadm-checkpoint v4\nlambda 1e-6\nmachines 1\nv 1 0.5\n\
                    alpha 0 1 1.0\nresidual 0 1 0.25\n";
        let err = Checkpoint::load(std::io::Cursor::new(text)).unwrap_err();
        assert!(format!("{err:#}").contains("must appear together"));
    }

    #[test]
    fn loads_v1_without_counters_or_rng() {
        let text = "dadm-checkpoint v1\nlambda 1e-6\nmachines 1\nv 1 0.5\nalpha 0 2 1.0 2.0\n";
        let ck = Checkpoint::load(std::io::Cursor::new(text)).unwrap();
        assert_eq!(ck.rounds, 0);
        assert_eq!(ck.passes, 0.0);
        assert!(ck.rng.is_none());
        assert!(ck.conj.is_none());
        assert_eq!(ck.v, vec![0.5]);
    }

    #[test]
    fn loads_v2_without_conj_records() {
        let text = "dadm-checkpoint v2\nlambda 1e-6\nrounds 3\npasses 0.6\nmachines 1\n\
                    v 1 0.5\nalpha 0 1 1.0\nrng 0 1 2 3 4\n";
        let ck = Checkpoint::load(std::io::Cursor::new(text)).unwrap();
        assert!(ck.conj.is_none(), "v2 files carry no running dual sums");
        assert!(ck.rng.is_some());
    }

    #[test]
    fn rejects_partial_conj_records() {
        let text = "dadm-checkpoint v3\nlambda 1e-6\nmachines 2\nv 1 0.5\n\
                    alpha 0 1 1.0\nalpha 1 1 2.0\nconj 0 0.25\n";
        let err = Checkpoint::load(std::io::Cursor::new(text)).unwrap_err();
        assert!(format!("{err:#}").contains("conj records"));
    }

    #[test]
    fn rejects_bad_header_and_truncation() {
        assert!(Checkpoint::load(std::io::Cursor::new("nope\n")).is_err());
        let mut buf = Vec::new();
        sample().save(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let truncated: String = text.lines().take(3).collect::<Vec<_>>().join("\n");
        assert!(Checkpoint::load(std::io::Cursor::new(truncated)).is_err());
    }

    #[test]
    fn rejects_missing_machine_record() {
        let text = "dadm-checkpoint v1\nlambda 1e-6\nmachines 2\nv 1 0.5\nalpha 0 1 1.0\n";
        let err = Checkpoint::load(std::io::Cursor::new(text)).unwrap_err();
        assert!(format!("{err:#}").contains("alpha records"));
    }

    #[test]
    fn rejects_all_zero_rng_state() {
        let text = "dadm-checkpoint v2\nlambda 1e-6\nmachines 1\nv 1 0.5\n\
                    alpha 0 1 1.0\nrng 0 0 0 0 0\n";
        let err = Checkpoint::load(std::io::Cursor::new(text)).unwrap_err();
        assert!(format!("{err:#}").contains("all-zero"));
    }

    #[test]
    fn rejects_partial_rng_records() {
        let text = "dadm-checkpoint v2\nlambda 1e-6\nmachines 2\nv 1 0.5\n\
                    alpha 0 1 1.0\nalpha 1 1 2.0\nrng 0 1 2 3 4\n";
        let err = Checkpoint::load(std::io::Cursor::new(text)).unwrap_err();
        assert!(format!("{err:#}").contains("rng records"));
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("dadm-ck-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.ck");
        sample().save_file(&path).unwrap();
        assert_eq!(Checkpoint::load_file(&path).unwrap(), sample());
        std::fs::remove_file(&path).ok();
    }
}
