//! Problem description builder — the one front door to the three
//! coordinators.
//!
//! The regularized loss minimization problem of the paper,
//!
//! ```text
//! P(w) = Σ_i φ_i(X_iᵀw) + λn·g(w) + h(w)
//! ```
//!
//! used to be spelled out positionally at every construction site:
//! `Dadm::new` took 8 arguments, `AccDadm::new` 8,
//! `DistributedOwlqn::new` and `run_owlqn_distributed` 9 — every one
//! hiding behind `#[allow(clippy::too_many_arguments)]` and easy to
//! transpose (λ and μ are both `f64`...). [`Problem`] replaces them: a
//! type-state builder that names each ingredient once and hands the
//! completed description to the solver constructors in a single grouped
//! argument.
//!
//! ```ignore
//! let dadm = Problem::new(&data, &part)
//!     .loss(SmoothHinge::nesterov())
//!     .reg(ElasticNet::new(mu / lambda))
//!     .lambda(lambda)
//!     .build_dadm(ProxSdca, opts);
//! ```
//!
//! Type-state does the argument checking at compile time: `build_dadm`
//! only exists once `.loss(..)` and `.reg(..)` have been called (the
//! placeholder `()` types implement neither trait), `build_acc_dadm` /
//! `build_owlqn` only while **no** explicit `g` regularizer has been set
//! (those methods derive their own — the Acc-DADM stage regularizer and
//! the OWL-QN L1 term — so a caller-supplied one would be silently
//! dropped, and the builder makes that a type error instead). The only
//! runtime check left is λ: it has no safe default, so building without
//! `.lambda(..)` panics with a message naming the missing call.
//!
//! The old positional constructors were kept as `#[deprecated]` shims
//! for one release and have since been removed — the builder is the only
//! construction path, and the `builder_is_deterministic_*` tests below
//! pin that two identical builder chains produce bitwise-identical
//! solves (the property the old shim-vs-builder parity tests
//! established).

use super::acc_dadm::{AccDadm, AccDadmOptions};
use super::dadm::{Dadm, DadmOptions};
use super::owlqn_driver::{solve_owlqn_problem, DistributedOwlqn, OwlqnDriverReport};
use crate::comm::{Cluster, CostModel};
use crate::data::{Dataset, Partition};
use crate::loss::Loss;
use crate::reg::{ExtraReg, Regularizer, Zero};
use crate::solver::LocalSolver;

/// A regularized loss minimization problem under construction: the data
/// and its machine partition plus the objective ingredients
/// `(φ, g, h, λ, μ)` as they are named. See the module docs for the
/// type-state rules; the `build_*` / `solve_*` methods hand the
/// completed description to the coordinator constructors.
#[derive(Clone, Debug)]
pub struct Problem<'a, L = (), R = (), H = Zero> {
    pub(crate) data: &'a Dataset,
    pub(crate) part: &'a Partition,
    pub(crate) loss: L,
    pub(crate) reg: R,
    pub(crate) h: H,
    pub(crate) lambda: Option<f64>,
    pub(crate) mu: f64,
}

impl<'a> Problem<'a, (), (), Zero> {
    /// Start describing a problem over `data` sharded by `part`. No
    /// loss, no regularizer, `h = 0`, `μ = 0`, λ unset.
    pub fn new(data: &'a Dataset, part: &'a Partition) -> Self {
        Problem {
            data,
            part,
            loss: (),
            reg: (),
            h: Zero,
            lambda: None,
            mu: 0.0,
        }
    }
}

impl<'a, L, R, H> Problem<'a, L, R, H> {
    /// Set the loss `φ` (required before any `build_*`).
    pub fn loss<L2: Loss>(self, loss: L2) -> Problem<'a, L2, R, H> {
        Problem {
            data: self.data,
            part: self.part,
            loss,
            reg: self.reg,
            h: self.h,
            lambda: self.lambda,
            mu: self.mu,
        }
    }

    /// Set the strongly-convex regularizer `g` (required for
    /// [`Problem::build_dadm`]; **not** accepted by the Acc-DADM /
    /// OWL-QN builds, which derive their own — see the module docs).
    pub fn reg<R2: Regularizer>(self, reg: R2) -> Problem<'a, L, R2, H> {
        Problem {
            data: self.data,
            part: self.part,
            loss: self.loss,
            reg,
            h: self.h,
            lambda: self.lambda,
            mu: self.mu,
        }
    }

    /// Set the extra (possibly non-strongly-convex) regularizer `h`
    /// (default [`Zero`]).
    pub fn extra_reg<H2: ExtraReg>(self, h: H2) -> Problem<'a, L, R, H2> {
        Problem {
            data: self.data,
            part: self.part,
            loss: self.loss,
            reg: self.reg,
            h,
            lambda: self.lambda,
            mu: self.mu,
        }
    }

    /// Set the strong-convexity weight λ (required — building without
    /// it panics).
    pub fn lambda(mut self, lambda: f64) -> Self {
        self.lambda = Some(lambda);
        self
    }

    /// Set the L1 weight μ (default `0.0`). Consumed by the Acc-DADM
    /// and OWL-QN builds; the plain DADM build encodes L1 inside its
    /// explicit `g` (e.g. `ElasticNet::new(μ/λ)`) instead.
    pub fn l1(mut self, mu: f64) -> Self {
        self.mu = mu;
        self
    }

    /// λ, or a clear panic if the builder chain never set it.
    pub(crate) fn lambda_value(&self) -> f64 {
        match self.lambda {
            Some(l) => l,
            None => panic!("Problem: call .lambda(λ) before building a solver"),
        }
    }
}

impl<'a, L: Loss, R: Regularizer, H: ExtraReg> Problem<'a, L, R, H> {
    /// Build the DADM coordinator (Algorithm 2) for this problem.
    pub fn build_dadm<S: LocalSolver>(self, solver: S, opts: DadmOptions) -> Dadm<L, R, H, S> {
        Dadm::from_problem(self, solver, opts)
    }
}

impl<'a, L: Loss, H: ExtraReg> Problem<'a, L, (), H> {
    /// Build the Acc-DADM coordinator (Algorithm 3) for
    /// `P(w) = Σφ + (λn/2)‖w‖² + μn‖w‖₁ + h(w)` — the g regularizer is
    /// the stage-derived shifted elastic net, so this build only exists
    /// while `.reg(..)` has not been called.
    pub fn build_acc_dadm<S: LocalSolver>(
        self,
        solver: S,
        opts: AccDadmOptions,
    ) -> AccDadm<L, H, S> {
        AccDadm::from_problem(self, solver, opts)
    }
}

impl<'a, L: Loss> Problem<'a, L, (), Zero> {
    /// Build the distributed OWL-QN baseline for the normalized
    /// objective `F(w) = (1/n)Σφ + (λ/2)‖w‖² + μ‖w‖₁` (primal-only;
    /// `g`/`h` are fixed by the method, so this build only exists on the
    /// default `()`/[`Zero`] placeholders).
    pub fn build_owlqn(
        self,
        max_passes: usize,
        cluster: Cluster,
        cost: CostModel,
        local_threads: usize,
    ) -> DistributedOwlqn<L> {
        DistributedOwlqn::from_problem(self, max_passes, cluster, cost, local_threads)
    }

    /// Build **and solve** with distributed OWL-QN: the batch wrapper
    /// the benches use (engine `Driver` + [`DistributedOwlqn`]).
    pub fn solve_owlqn(
        self,
        max_passes: usize,
        cluster: Cluster,
        cost: CostModel,
        local_threads: usize,
    ) -> OwlqnDriverReport {
        solve_owlqn_problem(self, max_passes, cluster, cost, local_threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::acc_dadm::NuChoice;
    use crate::data::synthetic::tiny_classification;
    use crate::loss::{Logistic, SmoothHinge};
    use crate::reg::ElasticNet;
    use crate::solver::ProxSdca;

    fn opts() -> DadmOptions {
        DadmOptions {
            sp: 0.5,
            seed: 7,
            ..Default::default()
        }
    }

    #[test]
    fn builder_is_deterministic_dadm_bitwise() {
        let data = tiny_classification(160, 6, 11);
        let part = Partition::balanced(160, 4, 11);
        let (lambda, mu) = (1e-3, 1e-4);
        let build = || {
            Problem::new(&data, &part)
                .loss(SmoothHinge::nesterov(0.1))
                .reg(ElasticNet::new(mu / lambda))
                .lambda(lambda)
                .build_dadm(ProxSdca, opts())
        };
        let a = build().solve(0.0, 12);
        let b = build().solve(0.0, 12);
        assert_eq!(a.primal.to_bits(), b.primal.to_bits());
        assert_eq!(a.dual.to_bits(), b.dual.to_bits());
        assert_eq!(a.w.len(), b.w.len());
        for (x, y) in a.w.iter().zip(&b.w) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn builder_is_deterministic_acc_dadm_bitwise() {
        let data = tiny_classification(160, 6, 12);
        let part = Partition::balanced(160, 4, 12);
        let (lambda, mu) = (1e-3, 1e-4);
        let build = || {
            Problem::new(&data, &part)
                .loss(Logistic)
                .lambda(lambda)
                .l1(mu)
                .build_acc_dadm(
                    ProxSdca,
                    AccDadmOptions {
                        nu: NuChoice::Zero,
                        dadm: opts(),
                        ..Default::default()
                    },
                )
        };
        let a = build().solve(1e-9, 15);
        let b = build().solve(1e-9, 15);
        assert_eq!(a.primal.to_bits(), b.primal.to_bits());
        for (x, y) in a.w.iter().zip(&b.w) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn builder_is_deterministic_owlqn_bitwise() {
        let data = tiny_classification(120, 5, 13);
        let part = Partition::balanced(120, 4, 13);
        let run = || {
            Problem::new(&data, &part)
                .loss(Logistic)
                .lambda(1e-3)
                .l1(1e-4)
                .solve_owlqn(20, Cluster::Serial, CostModel::free(), 1)
        };
        let a = run();
        let b = run();
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
        assert_eq!(a.passes, b.passes);
        for (x, y) in a.w.iter().zip(&b.w) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "call .lambda")]
    fn missing_lambda_panics_clearly() {
        let data = tiny_classification(40, 3, 14);
        let part = Partition::balanced(40, 2, 14);
        let _ = Problem::new(&data, &part)
            .loss(Logistic)
            .reg(ElasticNet::new(0.1))
            .build_dadm(ProxSdca, DadmOptions::default());
    }
}
