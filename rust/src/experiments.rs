//! Experiment harness shared by the figure benches.
//!
//! Encodes the §10 protocol once: the λ grid (matched to the paper's by
//! the product `λn`, which is what the condition number `R/(γλn_ℓ·m)`
//! actually depends on — our synthetic analogues have smaller n, so the
//! paper's `λ ∈ {1e-6, 1e-7, 1e-8}` maps to `λn ∈ {0.7, 0.07, 0.007}`),
//! the sp grid `{0.05, 0.20, 0.80}`, the 100-pass cap, and the
//! CoCoA+-vs-Acc-DADM cell runner used by Figures 1–13.

use crate::comm::CostModel;
use crate::config::Method;
use crate::coordinator::{AccDadmOptions, DadmOptions, NuChoice, Problem, SolveReport};
use crate::data::{Dataset, Partition};
use crate::loss::Loss;
use crate::reg::ElasticNet;
use crate::runtime::engine::{Driver, GapCadence, RoundAlgorithm};
use crate::solver::ProxSdca;
use std::sync::OnceLock;

/// The paper's λ grid translated to this n through λn-matching.
pub fn lambda_grid(n: usize) -> [f64; 3] {
    [0.7 / n as f64, 0.07 / n as f64, 0.007 / n as f64]
}

/// The paper's λ label for grid index `i` (for printing).
pub fn lambda_label(i: usize) -> &'static str {
    ["1e-6", "1e-7", "1e-8"][i]
}

/// The §10 sampling-percentage grid.
pub const SP_GRID: [f64; 3] = [0.05, 0.20, 0.80];

/// The §10 L1 weight.
pub const MU: f64 = 1e-5;

/// Default `DADM_BENCH_SCALE` (full micro-bench sizes).
pub const DEFAULT_BENCH_SCALE: f64 = 5e-4;

/// The scale the symbolic `DADM_BENCH_SCALE=smoke` setting maps to —
/// a 10× shrink that keeps every bench cell in CI-smoke territory while
/// still exercising the real code paths (the `bench-smoke` job runs
/// `perf_hotpath` at this scale and archives the JSON it emits).
pub const SMOKE_BENCH_SCALE: f64 = 5e-5;

/// The `DADM_BENCH_SCALE` factor, parsed once per process (a `OnceLock`
/// pins the value, so repeated bench cells can never observe different
/// scales if the environment mutates mid-run). Accepts a float or the
/// symbolic value `smoke` ([`SMOKE_BENCH_SCALE`]).
pub fn bench_scale() -> f64 {
    static BENCH_SCALE: OnceLock<f64> = OnceLock::new();
    *BENCH_SCALE.get_or_init(|| match std::env::var("DADM_BENCH_SCALE") {
        Ok(s) if s.trim().eq_ignore_ascii_case("smoke") => SMOKE_BENCH_SCALE,
        Ok(s) => s.trim().parse().unwrap_or(DEFAULT_BENCH_SCALE),
        Err(_) => DEFAULT_BENCH_SCALE,
    })
}

/// [`bench_scale`] relative to the default — the multiplier micro-bench
/// problem sizes apply (`smoke` ⇒ 0.1).
pub fn bench_scale_factor() -> f64 {
    bench_scale() / DEFAULT_BENCH_SCALE
}

/// Scale a micro-bench problem size by [`bench_scale_factor`], keeping a
/// floor so smoke runs still exercise the vectorized paths.
pub fn scaled_bench_n(base: usize) -> usize {
    ((base as f64 * bench_scale_factor()).round() as usize).max(512)
}

/// Benchmark datasets at [`bench_scale`] (covtype/rcv1 analogues big
/// enough to show the condition-number effect, HIGGS/kdd small).
pub fn bench_datasets() -> Vec<Dataset> {
    crate::data::synthetic::paper_suite(bench_scale())
        .iter()
        .map(|s| s.generate())
        .collect()
}

/// One experiment cell's summary.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// Communications to reach the 1e-3 normalized gap (None = capped).
    pub comms_to_target: Option<usize>,
    /// Modeled seconds (compute + comm) to reach the target.
    pub time_to_target: Option<f64>,
    /// Total modeled communication seconds at the end of the run.
    pub comm_secs: f64,
    /// Final normalized gap.
    pub final_gap: f64,
    /// Full report.
    pub report: SolveReport,
}

/// Paper's target accuracy for the scalability figures.
pub const EPS: f64 = 1e-3;

/// Run one (dataset, method, λ, sp, m) cell under the 100-pass cap.
/// (`L: 'static` because the method dispatch boxes the coordinator as a
/// `dyn RoundAlgorithm`; every loss in the crate is a plain value type.)
#[allow(clippy::too_many_arguments)]
pub fn run_cell<L: Loss + Clone + 'static>(
    data: &Dataset,
    loss: L,
    method: Method,
    lambda: f64,
    sp: f64,
    machines: usize,
    nu: NuChoice,
    max_passes: f64,
) -> CellResult {
    let part = Partition::balanced(data.n(), machines, 7);
    let max_rounds = (max_passes / sp).ceil() as usize;
    let gap_every = ((0.5 / sp).round() as usize).max(1); // ~2 gap checks/pass
    let opts = DadmOptions {
        sp,
        cost: CostModel::default(),
        gap_every,
        ..Default::default()
    };
    // Dispatch = engine construction; the solve loop is the shared Driver.
    let (mut algo, cadence): (Box<dyn RoundAlgorithm>, GapCadence) = match method {
        Method::Dadm => (
            Box::new(
                Problem::new(data, &part)
                    .loss(loss)
                    .reg(ElasticNet::new(MU / lambda))
                    .lambda(lambda)
                    .build_dadm(ProxSdca, opts),
            ),
            GapCadence::EveryRounds(gap_every),
        ),
        Method::AccDadm => (
            Box::new(
                Problem::new(data, &part)
                    .loss(loss)
                    .lambda(lambda)
                    .l1(MU)
                    .build_acc_dadm(
                        ProxSdca,
                        AccDadmOptions {
                            nu,
                            dadm: opts,
                            ..Default::default()
                        },
                    ),
            ),
            GapCadence::AlgorithmDriven,
        ),
        Method::Owlqn => unreachable!("use Problem::solve_owlqn for OWL-QN"),
    };
    let report = Driver::new(EPS, max_rounds)
        .with_cadence(cadence)
        .solve(algo.as_mut());
    summarize(report)
}

/// Summarize a solve report into the figure quantities.
pub fn summarize(report: SolveReport) -> CellResult {
    CellResult {
        comms_to_target: report.trace.rounds_to_gap(EPS),
        time_to_target: report.trace.time_to_gap(EPS),
        comm_secs: report.trace.last().map(|r| r.comm_secs).unwrap_or(0.0),
        final_gap: report.normalized_gap(),
        report,
    }
}

/// Format an optional count with the paper's "Max Comm." convention:
/// capped runs print the cap marker.
pub fn fmt_or_max(v: Option<usize>, max: usize) -> String {
    match v {
        Some(x) => x.to_string(),
        None => format!(">{max}"),
    }
}

/// Format optional seconds.
pub fn fmt_secs_opt(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x:.3}"),
        None => "capped".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::SmoothHinge;

    #[test]
    fn lambda_grid_matches_paper_lambda_n() {
        let g = lambda_grid(677_399);
        assert!((g[0] * 677_399.0 - 0.7).abs() < 1e-9);
        // Paper's λ = 1e-6 at rcv1's n gives λn = 0.677 ≈ 0.7 ✓
        assert!((g[0] - 1.03e-6).abs() < 5e-8);
    }

    #[test]
    fn run_cell_produces_consistent_summary() {
        let data = crate::data::synthetic::tiny_classification(300, 8, 77);
        let cell = run_cell(
            &data,
            SmoothHinge::default(),
            Method::Dadm,
            1e-3,
            1.0,
            2,
            NuChoice::Zero,
            60.0,
        );
        assert!(cell.final_gap.is_finite());
        if let Some(c) = cell.comms_to_target {
            assert!(c <= cell.report.rounds);
            assert!(cell.time_to_target.is_some());
        }
    }

    #[test]
    fn bench_scale_is_pinned_and_positive() {
        // The OnceLock pins whatever the process environment said first;
        // assert stability and sanity rather than a specific value so
        // this passes under any DADM_BENCH_SCALE (including `smoke`).
        let a = bench_scale();
        assert_eq!(a, bench_scale());
        assert!(a > 0.0 && a.is_finite());
        assert!(bench_scale_factor() > 0.0);
        assert!(scaled_bench_n(10) >= 512, "floor keeps smoke cells real");
        assert!(scaled_bench_n(100_000_000) >= 512);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_or_max(Some(12), 500), "12");
        assert_eq!(fmt_or_max(None, 500), ">500");
        assert_eq!(fmt_secs_opt(None), "capped");
    }
}
