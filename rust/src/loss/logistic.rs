//! Logistic loss `φ(u) = log(1 + exp(−y·u))` — ¼-smooth (γ = 4).
//!
//! Conjugate (a := y·α): `φ*(−α) = a·ln(a) + (1−a)·ln(1−a)` for
//! `a ∈ [0, 1]`, else ∞. The coordinate subproblem
//!
//! ```text
//! max_{a∈(0,1)}  −a·ln a − (1−a)·ln(1−a) − y(a − ā)·u − q(a − ā)²/2
//! ```
//!
//! has no closed form; we maximize it with a safeguarded Newton iteration
//! (monotone bisection fallback), which is also what the paper's local
//! ProxSDCA procedure does in practice for LR.

use super::Loss;
use crate::utils::math::{clip, log1p_exp, xlogx};

/// Logistic loss.
#[derive(Clone, Copy, Debug, Default)]
pub struct Logistic;

/// Solve `f'(a) = −ln(a/(1−a)) − y·u − q(a − ā) = 0` on (0, 1) by Newton
/// with bisection safeguard. `f'` is strictly decreasing (f is strictly
/// concave), so the root is unique; f'(0⁺) = +∞, f'(1⁻) = −∞ guarantee it
/// exists in the open interval.
fn solve_coordinate(a_bar: f64, yu: f64, q: f64) -> f64 {
    let fprime = |a: f64| -(a / (1.0 - a)).ln() - yu - q * (a - a_bar);
    // Bracket.
    let (mut lo, mut hi) = (1e-15, 1.0 - 1e-15);
    // Newton from a reasonable start: the sigmoid of −yu (the unregularized
    // stationary point), nudged toward ā.
    let mut a = clip(0.5 * (1.0 / (1.0 + yu.exp()) + a_bar), 1e-12, 1.0 - 1e-12);
    for _ in 0..100 {
        let f = fprime(a);
        // Converged? Check *before* moving, otherwise a fully-converged
        // Newton point (f ≈ 0, newton == a == bracket edge) would bounce
        // to the bisection midpoint and the loop could end mid-bounce.
        if f.abs() < 1e-12 {
            break;
        }
        if f > 0.0 {
            lo = a;
        } else {
            hi = a;
        }
        if hi - lo < 1e-16 {
            break;
        }
        // f''(a) = −1/(a(1−a)) − q
        let fpp = -1.0 / (a * (1.0 - a)) - q;
        let newton = a - f / fpp;
        a = if newton > lo && newton < hi {
            newton
        } else {
            0.5 * (lo + hi)
        };
    }
    a
}

impl Loss for Logistic {
    fn phi(&self, u: f64, y: f64) -> f64 {
        log1p_exp(-y * u)
    }

    fn grad(&self, u: f64, y: f64) -> f64 {
        // −y·σ(−y·u) computed stably.
        let z = y * u;
        let s = if z >= 0.0 {
            let e = (-z).exp();
            e / (1.0 + e)
        } else {
            1.0 / (1.0 + z.exp())
        };
        -y * s
    }

    fn conj_neg(&self, alpha: f64, y: f64) -> f64 {
        let a = y * alpha;
        if !(0.0..=1.0).contains(&a) {
            f64::INFINITY
        } else {
            xlogx(a) + xlogx(1.0 - a)
        }
    }

    fn coordinate_delta(&self, alpha: f64, u: f64, q: f64, y: f64) -> f64 {
        let a_bar = y * alpha;
        let a_new = solve_coordinate(clip(a_bar, 0.0, 1.0), y * u, q);
        y * (a_new - a_bar)
    }

    fn gamma(&self) -> f64 {
        4.0
    }

    fn lipschitz(&self) -> f64 {
        1.0
    }

    fn project_dual(&self, alpha: f64, y: f64) -> f64 {
        y * clip(y * alpha, 0.0, 1.0)
    }

    fn name(&self) -> &'static str {
        "logistic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::test_support::*;
    use crate::testing::prop::for_each_case;

    #[test]
    fn values_and_symmetry() {
        let l = Logistic;
        assert!((l.phi(0.0, 1.0) - std::f64::consts::LN_2).abs() < 1e-12);
        assert!((l.phi(1.0, 1.0) - l.phi(-1.0, -1.0)).abs() < 1e-12);
        assert!(l.phi(50.0, 1.0) < 1e-20);
        assert!((l.phi(-50.0, 1.0) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let l = Logistic;
        for_each_case(0x71, 100, |g| {
            let y = g.label();
            let u = g.f64_in(-5.0, 5.0);
            let h = 1e-6;
            let fd = (l.phi(u + h, y) - l.phi(u - h, y)) / (2.0 * h);
            assert!((l.grad(u, y) - fd).abs() < 1e-6);
        });
    }

    #[test]
    fn conjugate_entropy_values() {
        let l = Logistic;
        assert_eq!(l.conj_neg(0.0, 1.0), 0.0);
        assert_eq!(l.conj_neg(1.0, 1.0), 0.0);
        let mid = l.conj_neg(0.5, 1.0);
        assert!((mid + std::f64::consts::LN_2).abs() < 1e-12); // −ln 2
        assert!(l.conj_neg(-0.2, 1.0).is_infinite());
    }

    #[test]
    fn fenchel_young() {
        check_fenchel_young(&Logistic, 0x72);
    }

    #[test]
    fn quarter_smoothness() {
        check_smoothness(&Logistic, 0x73);
    }

    #[test]
    fn coordinate_update_is_optimal() {
        check_coordinate_optimal(&Logistic, 0x74, 1e-5);
    }

    #[test]
    fn newton_handles_extreme_q() {
        let l = Logistic;
        for &q in &[1e-8, 1e8] {
            let d = l.coordinate_delta(0.3, -2.0, q, 1.0);
            assert!(d.is_finite());
            assert!(l.conj_neg(0.3 + d, 1.0).is_finite());
        }
    }

    #[test]
    fn newton_matches_golden_section() {
        // Independent check of the 1-D solver against golden-section search.
        let l = Logistic;
        for_each_case(0x75, 30, |g| {
            let y = g.label();
            let u = g.f64_in(-3.0, 3.0);
            let q = g.f64_log_in(1e-2, 1e2);
            let alpha = l.project_dual(g.f64_in(-1.0, 1.0), y);
            let delta = l.coordinate_delta(alpha, u, q, y);
            let obj = |d: f64| coord_obj(&l, alpha, d, u, q, y);
            // golden-section on δ over the feasible interval
            let a_bar = y * alpha;
            let (mut lo, mut hi) = if y > 0.0 {
                (-a_bar, 1.0 - a_bar)
            } else {
                (a_bar - 1.0, a_bar)
            };
            let phi = (5f64.sqrt() - 1.0) / 2.0;
            for _ in 0..200 {
                let x1 = hi - phi * (hi - lo);
                let x2 = lo + phi * (hi - lo);
                if obj(x1) < obj(x2) {
                    lo = x1;
                } else {
                    hi = x2;
                }
            }
            let golden = 0.5 * (lo + hi);
            assert!(
                obj(delta) >= obj(golden) - 1e-9,
                "newton {delta} worse than golden {golden}"
            );
        });
    }
}
