//! Loss functions `φ_i`, their convex conjugates `φ_i*`, and the
//! per-coordinate dual maximizers used by the local solvers.
//!
//! The paper (§10) evaluates three classification losses — the smooth
//! hinge (1-smooth), logistic (¼-smooth), and the non-smooth hinge
//! (1-Lipschitz, handled via Nesterov smoothing per §8.2) — and the
//! general framework also covers squared loss. Each implementation
//! provides:
//!
//! * the primal value `φ(u)` and a subgradient,
//! * the conjugate `φ*(−α)` restricted to its effective domain,
//! * `closed_form_delta`: the exact maximizer of the 1-D dual subproblem
//!
//!   ```text
//!   max_δ  −φ*(−(α + δ)) − δ·u − δ²·q/2        (q = ‖x_i‖²/(λ n_ℓ))
//!   ```
//!
//!   which is the ProxSDCA coordinate step (Shalev-Shwartz & Zhang 2014,
//!   "option I"); for logistic there is no closed form and a safeguarded
//!   Newton iteration is used (`solver::scalar`),
//! * the Theorem-6/7 special update direction `u_i = −∇φ_i(x_iᵀw)`.
//!
//! All losses here are scalar (`q = 1` in the paper's `X_i ∈ R^{d×q}`).

mod hinge;
mod logistic;
mod smooth_hinge;
mod squared;

pub use hinge::Hinge;
pub use logistic::Logistic;
pub use smooth_hinge::SmoothHinge;
pub use squared::Squared;

/// A scalar convex loss with label, plus its dual-side interface.
///
/// `y` is the example's label; classification losses use `y ∈ {−1, +1}`,
/// squared loss uses real `y`.
pub trait Loss: Send + Sync + std::fmt::Debug {
    /// Primal loss `φ(u)` at margin/prediction `u = x_iᵀ w`.
    fn phi(&self, u: f64, y: f64) -> f64;

    /// A subgradient `∇φ(u)` (the derivative where smooth).
    fn grad(&self, u: f64, y: f64) -> f64;

    /// Conjugate `φ*(−α)`. Returns `f64::INFINITY` outside the effective
    /// domain (e.g. hinge requires `yα ∈ [0, 1]`).
    fn conj_neg(&self, alpha: f64, y: f64) -> f64;

    /// Exact (or high-precision) maximizer `δ*` of the coordinate dual
    /// subproblem `max_δ −φ*(−(α+δ)) − δu − δ²q/2`.
    fn coordinate_delta(&self, alpha: f64, u: f64, q: f64, y: f64) -> f64;

    /// The Theorem-6/7 direction `u_i = −∇φ(u)` (a feasible dual point).
    fn theorem_direction(&self, u: f64, y: f64) -> f64 {
        -self.grad(u, y)
    }

    /// Smoothness constant: `φ` is `(1/γ)`-smooth; `γ = 0` means
    /// non-smooth (Lipschitz only).
    fn gamma(&self) -> f64;

    /// Lipschitz constant `L` (∞-safe upper bound for smooth losses too).
    fn lipschitz(&self) -> f64;

    /// Clamp a dual variable into the conjugate's effective domain
    /// (identity for losses with full domain).
    fn project_dual(&self, alpha: f64, y: f64) -> f64;

    /// Loss name (bench output key).
    fn name(&self) -> &'static str;
}

/// Enum dispatch over the loss zoo — lets configs choose a loss without
/// trait objects in the hot loop (the solvers are generic over `L: Loss`,
/// benches use this enum at the boundary).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossKind {
    /// Smooth hinge, γ = 1 (paper Eq. 32).
    SmoothHinge,
    /// Logistic, γ = 4 (¼-smooth).
    Logistic,
    /// Non-smooth hinge (used with Nesterov smoothing, §8.2).
    Hinge,
    /// Squared loss `(u − y)²`.
    Squared,
}

impl LossKind {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "smooth_hinge" | "svm" => LossKind::SmoothHinge,
            "logistic" | "lr" => LossKind::Logistic,
            "hinge" => LossKind::Hinge,
            "squared" | "lsq" => LossKind::Squared,
            other => anyhow::bail!("unknown loss `{other}`"),
        })
    }

    /// Canonical name.
    pub fn name(self) -> &'static str {
        match self {
            LossKind::SmoothHinge => "smooth_hinge",
            LossKind::Logistic => "logistic",
            LossKind::Hinge => "hinge",
            LossKind::Squared => "squared",
        }
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    //! Shared conformance checks every loss must pass; each loss module
    //! instantiates these against its own implementation.
    use super::Loss;
    use crate::testing::prop::{for_each_case, Gen};

    /// Grid-search the coordinate subproblem objective.
    pub fn grid_best<L: Loss>(loss: &L, alpha: f64, u: f64, q: f64, y: f64) -> f64 {
        let obj = |delta: f64| {
            let a = alpha + delta;
            let c = loss.conj_neg(a, y);
            if !c.is_finite() {
                return f64::NEG_INFINITY;
            }
            -c - delta * u - 0.5 * q * delta * delta
        };
        let mut best = f64::NEG_INFINITY;
        let mut arg = 0.0;
        let span = 1.0_f64.max(loss.lipschitz().min(10.0)) * 3.0;
        let steps = 40_000;
        for k in 0..=steps {
            let delta = -span + 2.0 * span * (k as f64) / (steps as f64);
            let v = obj(delta);
            if v > best {
                best = v;
                arg = delta;
            }
        }
        arg
    }

    /// The coordinate objective value at a given δ.
    pub fn coord_obj<L: Loss>(loss: &L, alpha: f64, delta: f64, u: f64, q: f64, y: f64) -> f64 {
        let c = loss.conj_neg(alpha + delta, y);
        if !c.is_finite() {
            return f64::NEG_INFINITY;
        }
        -c - delta * u - 0.5 * q * delta * delta
    }

    /// Fenchel–Young: `φ(u) + φ*(−α) ≥ −α·u`, equality at `α = −∇φ(u)`.
    pub fn check_fenchel_young<L: Loss>(loss: &L, seed: u64) {
        for_each_case(seed, 200, |g: &mut Gen| {
            let y = g.label();
            let u = g.f64_in(-4.0, 4.0);
            let alpha = loss.project_dual(g.f64_in(-3.0, 3.0), y);
            let lhs = loss.phi(u, y) + loss.conj_neg(alpha, y);
            let rhs = -alpha * u;
            assert!(
                lhs >= rhs - 1e-8,
                "Fenchel-Young violated: φ({u})+φ*(−{alpha}) = {lhs} < {rhs}"
            );
            // Equality at the gradient pairing.
            let a_star = -loss.grad(u, y);
            let lhs_eq = loss.phi(u, y) + loss.conj_neg(a_star, y);
            let rhs_eq = -a_star * u;
            assert!(
                (lhs_eq - rhs_eq).abs() < 1e-6,
                "FY equality fails at maximizer: {lhs_eq} vs {rhs_eq} (u={u}, y={y})"
            );
        });
    }

    /// The coordinate update must (a) stay in the dual domain and
    /// (b) be at least as good as a fine grid search.
    pub fn check_coordinate_optimal<L: Loss>(loss: &L, seed: u64, tol: f64) {
        for_each_case(seed, 60, |g: &mut Gen| {
            let y = g.label();
            let u = g.f64_in(-3.0, 3.0);
            let q = g.f64_log_in(1e-3, 1e2);
            let alpha = loss.project_dual(g.f64_in(-1.5, 1.5), y);
            let delta = loss.coordinate_delta(alpha, u, q, y);
            let v_closed = coord_obj(loss, alpha, delta, u, q, y);
            assert!(
                v_closed.is_finite(),
                "update left dual domain: α={alpha} δ={delta} y={y}"
            );
            let arg_grid = grid_best(loss, alpha, u, q, y);
            let v_grid = coord_obj(loss, alpha, arg_grid, u, q, y);
            assert!(
                v_closed >= v_grid - tol,
                "coordinate update suboptimal: {v_closed} < grid {v_grid} \
                 (α={alpha}, u={u}, q={q}, y={y}, δ={delta}, δ_grid={arg_grid})"
            );
        });
    }

    /// Smoothness: `φ(b) ≤ φ(a) + φ'(a)(b−a) + (b−a)²/(2γ)`.
    pub fn check_smoothness<L: Loss>(loss: &L, seed: u64) {
        let gamma = loss.gamma();
        assert!(gamma > 0.0, "smoothness check requires γ > 0");
        for_each_case(seed, 200, |g: &mut Gen| {
            let y = g.label();
            let a = g.f64_in(-4.0, 4.0);
            let b = g.f64_in(-4.0, 4.0);
            let bound = loss.phi(a, y) + loss.grad(a, y) * (b - a)
                + (b - a) * (b - a) / (2.0 * gamma);
            assert!(
                loss.phi(b, y) <= bound + 1e-9,
                "smoothness violated: φ({b}) = {} > {bound}",
                loss.phi(b, y)
            );
        });
    }
}
