//! Smooth(ed) hinge loss — paper Eq. (32), γ-smooth.
//!
//! ```text
//! φ(u) = 0                 if y·u ≥ 1
//!        1 − y·u − γ/2     if y·u ≤ 1 − γ
//!        (1 − y·u)²/(2γ)   otherwise
//! ```
//!
//! With γ = 1 this is the paper's SVM loss (§10); with γ = ε/L² it is the
//! Nesterov smoothing of the plain hinge used for Figures 12–13 (§8.2) —
//! smoothing the hinge by adding `(γ/2)‖α‖²` to its conjugate yields
//! exactly this family, so [`SmoothHinge::nesterov`] is the §8.2
//! construction.
//!
//! Conjugate (a := y·α): `φ*(−α) = −a + (γ/2)a²` for `a ∈ [0, 1]`, else ∞.
//! The coordinate maximizer is the classic SDCA closed form
//! `a* = clip(a + (1 − y·u − γ·a)/(γ + q), 0, 1)`.

use super::Loss;
use crate::utils::math::clip;

/// Smooth hinge with smoothing parameter `γ > 0`.
#[derive(Clone, Copy, Debug)]
pub struct SmoothHinge {
    gamma: f64,
}

impl Default for SmoothHinge {
    fn default() -> Self {
        SmoothHinge::new(1.0)
    }
}

impl SmoothHinge {
    /// Smooth hinge with explicit γ.
    pub fn new(gamma: f64) -> Self {
        assert!(gamma > 0.0, "γ must be positive (use `Hinge` for γ = 0)");
        SmoothHinge { gamma }
    }

    /// §8.2 Nesterov smoothing of the plain hinge for target accuracy `ε`:
    /// `γ = ε/L²` with `L = 1`.
    pub fn nesterov(epsilon: f64) -> Self {
        SmoothHinge::new(epsilon) // L = 1 for the hinge
    }
}

impl Loss for SmoothHinge {
    fn phi(&self, u: f64, y: f64) -> f64 {
        let z = y * u;
        let g = self.gamma;
        if z >= 1.0 {
            0.0
        } else if z <= 1.0 - g {
            1.0 - z - g / 2.0
        } else {
            (1.0 - z) * (1.0 - z) / (2.0 * g)
        }
    }

    fn grad(&self, u: f64, y: f64) -> f64 {
        let z = y * u;
        let g = self.gamma;
        if z >= 1.0 {
            0.0
        } else if z <= 1.0 - g {
            -y
        } else {
            -y * (1.0 - z) / g
        }
    }

    fn conj_neg(&self, alpha: f64, y: f64) -> f64 {
        let a = y * alpha;
        if !(0.0..=1.0).contains(&a) {
            f64::INFINITY
        } else {
            -a + self.gamma * a * a / 2.0
        }
    }

    fn coordinate_delta(&self, alpha: f64, u: f64, q: f64, y: f64) -> f64 {
        let a = y * alpha;
        let a_new = clip(a + (1.0 - y * u - self.gamma * a) / (self.gamma + q), 0.0, 1.0);
        y * (a_new - a)
    }

    fn gamma(&self) -> f64 {
        self.gamma
    }

    fn lipschitz(&self) -> f64 {
        1.0
    }

    fn project_dual(&self, alpha: f64, y: f64) -> f64 {
        y * clip(y * alpha, 0.0, 1.0)
    }

    fn name(&self) -> &'static str {
        "smooth_hinge"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::test_support::*;

    #[test]
    fn values_match_piecewise_definition() {
        let l = SmoothHinge::new(1.0);
        assert_eq!(l.phi(2.0, 1.0), 0.0); // z = 2 ≥ 1
        assert_eq!(l.phi(-1.0, 1.0), 1.5); // z = −1 ≤ 0: 1 − (−1) − ½
        assert_eq!(l.phi(0.5, 1.0), 0.125); // z = 0.5: (0.5)²/2
        // label symmetry
        assert_eq!(l.phi(-0.5, -1.0), l.phi(0.5, 1.0));
    }

    #[test]
    fn gradient_is_continuous_at_kinks() {
        let l = SmoothHinge::new(1.0);
        for y in [1.0, -1.0] {
            for z0 in [0.0, 1.0] {
                let u = y * z0;
                let eps = 1e-7;
                let g_left = l.grad(u - eps * y, y);
                let g_right = l.grad(u + eps * y, y);
                assert!((g_left - g_right).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn conjugate_domain() {
        let l = SmoothHinge::new(1.0);
        assert!(l.conj_neg(0.5, 1.0).is_finite());
        assert!(l.conj_neg(-0.1, 1.0).is_infinite());
        assert!(l.conj_neg(1.1, 1.0).is_infinite());
        // y = −1 flips the feasible interval
        assert!(l.conj_neg(-0.5, -1.0).is_finite());
        assert!(l.conj_neg(0.5, -1.0).is_infinite());
    }

    #[test]
    fn fenchel_young() {
        check_fenchel_young(&SmoothHinge::new(1.0), 0x51);
        check_fenchel_young(&SmoothHinge::new(0.25), 0x52);
    }

    #[test]
    fn smoothness_bound() {
        check_smoothness(&SmoothHinge::new(1.0), 0x53);
        check_smoothness(&SmoothHinge::new(0.1), 0x54);
    }

    #[test]
    fn coordinate_update_is_optimal() {
        check_coordinate_optimal(&SmoothHinge::new(1.0), 0x55, 1e-6);
        check_coordinate_optimal(&SmoothHinge::new(0.3), 0x56, 1e-6);
    }

    #[test]
    fn theorem_direction_is_feasible() {
        let l = SmoothHinge::new(1.0);
        for &(u, y) in &[(0.5, 1.0), (-2.0, 1.0), (3.0, -1.0), (0.0, -1.0)] {
            let dir = l.theorem_direction(u, y);
            assert!(l.conj_neg(dir, y).is_finite(), "u_i outside dual domain");
        }
    }

    #[test]
    fn nesterov_construction_shrinks_gap_bound() {
        // 0 ≤ φ̃(u) − φ_hinge(u) ≤ γL²/2 (paper §8.2)
        let eps = 0.01;
        let smooth = SmoothHinge::nesterov(eps);
        let hinge = crate::loss::Hinge;
        for &u in &[-2.0, -0.5, 0.0, 0.3, 0.99, 1.0, 2.0] {
            for &y in &[1.0, -1.0] {
                let diff = hinge.phi(u, y) - smooth.phi(u, y);
                assert!(
                    (0.0..=eps / 2.0 + 1e-12).contains(&diff),
                    "smoothing gap {diff} outside [0, γ/2] at u={u}"
                );
            }
        }
    }
}
