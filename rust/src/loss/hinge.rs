//! Plain (non-smooth) hinge loss `φ(u) = max(0, 1 − y·u)` — 1-Lipschitz.
//!
//! Conjugate (a := y·α): `φ*(−α) = −a` for `a ∈ [0, 1]`, else ∞
//! (Lemma 16: `φ*` is +∞ outside the L-ball). The coordinate maximizer is
//! the classic SVM-SDCA box update `a* = clip(a + (1 − y·u)/q, 0, 1)`.
//!
//! DADM uses this loss directly under Theorem 7 (Lipschitz rate); the
//! accelerated path (Figures 12–13) instead runs on
//! [`super::SmoothHinge::nesterov`] per §8.2.

use super::Loss;
use crate::utils::math::clip;

/// Non-smooth hinge loss.
#[derive(Clone, Copy, Debug, Default)]
pub struct Hinge;

impl Loss for Hinge {
    fn phi(&self, u: f64, y: f64) -> f64 {
        (1.0 - y * u).max(0.0)
    }

    fn grad(&self, u: f64, y: f64) -> f64 {
        // Subgradient: −y on the active branch, 0 otherwise; at the kink we
        // return −y (any element of [−y, 0] is valid for y = +1).
        if y * u < 1.0 {
            -y
        } else {
            0.0
        }
    }

    fn conj_neg(&self, alpha: f64, y: f64) -> f64 {
        let a = y * alpha;
        if !(0.0..=1.0).contains(&a) {
            f64::INFINITY
        } else {
            -a
        }
    }

    fn coordinate_delta(&self, alpha: f64, u: f64, q: f64, y: f64) -> f64 {
        let a = y * alpha;
        // q = 0 (empty feature row): the subproblem is linear in δ, so the
        // box constraint is active — avoid the 0/0 NaN at y·u = 1.
        let a_new = if q == 0.0 {
            let slope = 1.0 - y * u;
            if slope > 0.0 {
                1.0
            } else if slope < 0.0 {
                0.0
            } else {
                a
            }
        } else {
            clip(a + (1.0 - y * u) / q, 0.0, 1.0)
        };
        y * (a_new - a)
    }

    fn gamma(&self) -> f64 {
        0.0
    }

    fn lipschitz(&self) -> f64 {
        1.0
    }

    fn project_dual(&self, alpha: f64, y: f64) -> f64 {
        y * clip(y * alpha, 0.0, 1.0)
    }

    fn name(&self) -> &'static str {
        "hinge"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::test_support::*;

    #[test]
    fn values() {
        let l = Hinge;
        assert_eq!(l.phi(2.0, 1.0), 0.0);
        assert_eq!(l.phi(0.0, 1.0), 1.0);
        assert_eq!(l.phi(-1.0, 1.0), 2.0);
        assert_eq!(l.phi(1.0, -1.0), 2.0);
    }

    #[test]
    fn lipschitz_bound_holds() {
        let l = Hinge;
        for &(a, b, y) in &[(0.0, 1.0, 1.0), (-3.0, 2.5, -1.0), (0.9, 1.1, 1.0)] {
            assert!((l.phi(a, y) - l.phi(b, y)).abs() <= (a - b).abs() + 1e-12);
        }
    }

    #[test]
    fn conjugate_is_linear_on_box() {
        let l = Hinge;
        assert_eq!(l.conj_neg(0.0, 1.0), 0.0);
        assert_eq!(l.conj_neg(1.0, 1.0), -1.0);
        assert_eq!(l.conj_neg(0.5, 1.0), -0.5);
        assert!(l.conj_neg(1.5, 1.0).is_infinite());
    }

    #[test]
    fn fenchel_young() {
        check_fenchel_young(&Hinge, 0x61);
    }

    #[test]
    fn coordinate_update_is_optimal() {
        check_coordinate_optimal(&Hinge, 0x62, 1e-4);
    }

    #[test]
    fn theorem_direction_feasible() {
        let l = Hinge;
        for &(u, y) in &[(0.5, 1.0), (2.0, 1.0), (-1.0, -1.0)] {
            assert!(l.conj_neg(l.theorem_direction(u, y), y).is_finite());
        }
    }
}
