//! Squared loss `φ(u) = (u − y)²` — 2-smooth (γ = ½), unbounded dual.
//!
//! Conjugate: `φ*(s) = s·y + s²/4`, so `φ*(−α) = −α·y + α²/4` with full
//! domain. Coordinate maximizer is the ridge-regression closed form
//! `δ* = (y − u − α/2)/(½ + q)`.
//!
//! This is the loss of the paper's motivating L2-L1 regularized least
//! squares example (§4) and gives us a closed-form global optimum to
//! cross-check the whole DADM stack against (ridge when μ = 0).

use super::Loss;

/// Squared loss for regression.
#[derive(Clone, Copy, Debug, Default)]
pub struct Squared;

impl Loss for Squared {
    fn phi(&self, u: f64, y: f64) -> f64 {
        (u - y) * (u - y)
    }

    fn grad(&self, u: f64, y: f64) -> f64 {
        2.0 * (u - y)
    }

    fn conj_neg(&self, alpha: f64, y: f64) -> f64 {
        -alpha * y + alpha * alpha / 4.0
    }

    fn coordinate_delta(&self, alpha: f64, u: f64, q: f64, y: f64) -> f64 {
        (y - u - alpha / 2.0) / (0.5 + q)
    }

    fn gamma(&self) -> f64 {
        0.5
    }

    fn lipschitz(&self) -> f64 {
        f64::INFINITY
    }

    fn project_dual(&self, alpha: f64, _y: f64) -> f64 {
        alpha
    }

    fn name(&self) -> &'static str {
        "squared"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::test_support::*;
    use crate::loss::Loss;
    use crate::testing::prop::for_each_case;

    #[test]
    fn values_and_grad() {
        let l = Squared;
        assert_eq!(l.phi(3.0, 1.0), 4.0);
        assert_eq!(l.grad(3.0, 1.0), 4.0);
        assert_eq!(l.phi(1.0, 1.0), 0.0);
    }

    #[test]
    fn conjugate_identity() {
        // φ*(s) = sup_u [s·u − (u−y)²] = s·y + s²/4, checked numerically.
        let l = Squared;
        for_each_case(0x81, 100, |g| {
            let y = g.f64_in(-2.0, 2.0);
            let s = g.f64_in(-3.0, 3.0);
            let mut best = f64::NEG_INFINITY;
            let mut u = -30.0;
            while u <= 30.0 {
                best = best.max(s * u - (u - y) * (u - y));
                u += 1e-3;
            }
            assert!((l.conj_neg(-s, y) - best).abs() < 1e-5);
        });
    }

    #[test]
    fn fenchel_young() {
        check_fenchel_young(&Squared, 0x82);
    }

    #[test]
    fn half_smoothness() {
        check_smoothness(&Squared, 0x83);
    }

    #[test]
    fn coordinate_update_is_optimal() {
        check_coordinate_optimal(&Squared, 0x84, 1e-6);
    }

    #[test]
    fn coordinate_update_closed_form_is_stationary() {
        // f'(δ*) = 0 analytically: y − u − (α+δ*)/2 − qδ* = 0.
        let l = Squared;
        for_each_case(0x85, 100, |g| {
            let (y, u) = (g.f64_in(-2.0, 2.0), g.f64_in(-2.0, 2.0));
            let q = g.f64_log_in(1e-3, 1e3);
            let alpha = g.f64_in(-2.0, 2.0);
            let d = l.coordinate_delta(alpha, u, q, y);
            let stationarity = y - u - (alpha + d) / 2.0 - q * d;
            assert!(stationarity.abs() < 1e-9);
        });
    }
}
