//! CLI launcher plumbing for the `dadm` binary.
//!
//! Builds a boxed [`RoundAlgorithm`] from a parsed [`ExperimentConfig`]
//! and runs it through the one shared engine [`Driver`] — the per-method
//! solve-loop dispatch collapsed into engine construction — then
//! prints/persists the trace: the equivalent of the paper's experiment
//! driver scripts. Kept out of `main.rs` so integration tests can run the
//! launcher in-process.

use crate::comm::tcp::{cache_specs, shard_specs, synthetic_specs, TcpClusterBuilder, TcpHandle};
use crate::comm::wire::{WireLoss, WireSolver};
use crate::comm::{Cluster, CostModel};
use crate::config::{ClusterKind, ExperimentConfig, Method};
use crate::coordinator::{AccDadmOptions, Checkpoint, DadmOptions, NuChoice, Problem, SolveReport};
use crate::data::{Balance, Dataset, Partition};
use crate::loss::{LossKind, SmoothHinge};
use crate::reg::ElasticNet;
use crate::runtime::engine::{Driver, GapCadence, RoundAlgorithm};
use crate::solver::ProxSdca;
use anyhow::{bail, Context, Result};

/// Outcome of a launcher run (uniform across methods).
#[derive(Clone, Debug)]
pub struct RunOutcome {
    /// Method name.
    pub method: &'static str,
    /// Final normalized metric (duality gap for the dual methods,
    /// objective value for OWL-QN).
    pub final_metric: f64,
    /// Communications used.
    pub comms: usize,
    /// Passes over the data.
    pub passes: f64,
    /// Modeled compute + comm seconds.
    pub modeled_secs: f64,
    /// CSV trace body (round records) for dual methods.
    pub trace_csv: Option<String>,
    /// Whole-solve straggler roll-up (DESIGN.md §16.3); zero rounds
    /// measured for algorithms without per-machine step timing.
    pub stragglers: crate::metrics::StragglerSummary,
}

/// The wire loss spec matching [`run_experiment`]'s loss dispatch
/// (including the §8.2 hinge smoothing under Acc-DADM) — what TCP
/// workers are assigned so their local steps replicate the
/// coordinator's bit for bit.
fn wire_loss_for(cfg: &ExperimentConfig) -> WireLoss {
    match cfg.loss {
        LossKind::SmoothHinge => WireLoss::SmoothHinge(SmoothHinge::default()),
        LossKind::Logistic => WireLoss::Logistic,
        LossKind::Hinge => {
            if cfg.method == Method::AccDadm {
                WireLoss::SmoothHinge(SmoothHinge::nesterov(cfg.eps))
            } else {
                WireLoss::Hinge
            }
        }
        LossKind::Squared => WireLoss::Squared,
    }
}

/// Materialize the execution backend. For `cluster = tcp` this binds the
/// listener, waits for `machines` worker processes, and ships each its
/// assignment: the synthetic *generator* when the dataset names one (no
/// training data crosses the wire), otherwise exactly its shard's rows.
fn build_cluster(cfg: &ExperimentConfig, data: &Dataset, part: &Partition) -> Result<Cluster> {
    Ok(match cfg.cluster {
        ClusterKind::Serial => Cluster::Serial,
        ClusterKind::Threads => Cluster::Threads,
        ClusterKind::Tcp => {
            let builder =
                TcpClusterBuilder::bind(&cfg.tcp_listen)?.fault_tolerance(cfg.fault_tolerance());
            let addr = builder.local_addr()?;
            println!(
                "coordinator listening on {addr}; waiting for {} workers \
                 (start each with `dadm worker --connect {addr}`)",
                cfg.machines
            );
            let mut cluster = builder.accept(cfg.machines)?;
            // The launcher's local solver is ProxSDCA (paper §10); the
            // workers must match it. Workers receive the *resolved*
            // intra-machine thread count (0 = auto already mapped to the
            // core count and clamped), the same value the coordinator's
            // DadmOptions resolution produces.
            let (loss, solver) = (wire_loss_for(cfg), WireSolver::ProxSdca);
            let local_threads = crate::coordinator::resolve_local_threads(cfg.local_threads, part);
            let specs = if let Some(cache_path) = &cfg.cache {
                // Out-of-core assignment (wire v6): ship the cache path,
                // each worker's contiguous row range, and the content
                // hash; workers mmap the file locally, so no training
                // rows cross the wire and a resurrected worker provably
                // re-maps the same bytes.
                let cache = crate::data::CsrCache::open(std::path::Path::new(cache_path))
                    .with_context(|| format!("open cache {cache_path}"))?;
                cache_specs(
                    &cache,
                    cache_path,
                    cfg.machines,
                    cfg.seed,
                    cfg.sp,
                    loss,
                    solver,
                    local_threads,
                    cfg.balance,
                )
            } else {
                match cfg.synthetic_spec() {
                    // Generator seeds only travel under row balance: the
                    // worker regenerates the seeded balanced partition,
                    // which has no nnz form. Under `--balance nnz` the
                    // coordinator's explicit nnz-cut shards ship instead
                    // (DESIGN.md §16).
                    Some(spec) if cfg.balance == Balance::Rows => synthetic_specs(
                        &spec,
                        cfg.machines,
                        cfg.seed,
                        cfg.seed,
                        cfg.sp,
                        loss,
                        solver,
                        local_threads,
                    ),
                    _ => shard_specs(
                        data,
                        part,
                        cfg.seed,
                        cfg.sp,
                        loss,
                        solver,
                        local_threads,
                        cfg.balance,
                    ),
                }
            };
            cluster.assign(specs)?;
            Cluster::Tcp(TcpHandle::new(cluster))
        }
    })
}

/// Run one experiment according to `cfg`.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<RunOutcome> {
    let data = cfg.load_dataset()?;
    let part = cfg.build_partition(&data);
    let cost = CostModel {
        alpha: cfg.comm_alpha,
        beta: cfg.comm_beta,
    };
    let cluster = build_cluster(cfg, &data, &part)?;
    let dadm_opts = DadmOptions {
        sp: cfg.sp,
        cluster: cluster.clone(),
        cost,
        seed: cfg.seed,
        gap_every: cfg.gap_every,
        sparse_comm: cfg.sparse_comm,
        local_threads: cfg.local_threads,
        conj_resum_every: cfg.conj_resum_every,
        compress: cfg.compress,
        overlap: cfg.overlap,
        balance: cfg.balance,
    };

    // Loss selection happens exactly once, in `wire_loss_for` (the §8.2
    // smoothed hinge substitution included), and the coordinator runs on
    // the resulting `WireLoss` — the *same* value TCP workers are
    // assigned, so the two sides cannot dispatch to different losses.
    // The method match builds an engine algorithm — the solve loop
    // itself is the one shared `Driver`.
    macro_rules! with_loss {
        ($loss:expr) => {{
            let loss = $loss;
            let (algo, cadence, max_rounds): (Box<dyn RoundAlgorithm>, GapCadence, usize) =
                match cfg.method {
                    Method::Dadm => {
                        let mut dadm = Problem::new(&data, &part)
                            .loss(loss)
                            .reg(ElasticNet::new(cfg.mu / cfg.lambda))
                            .lambda(cfg.lambda)
                            .build_dadm(ProxSdca, dadm_opts.clone());
                        if let Some(path) = &cfg.resume {
                            let ck = Checkpoint::load_file(std::path::Path::new(path))
                                .with_context(|| format!("resume from {path}"))?;
                            dadm.restore(&ck)
                                .with_context(|| format!("restore {path}"))?;
                        }
                        // The pass cap is a *total* budget: restored
                        // rounds count against it, so a resumed run stops
                        // where the uninterrupted run would have.
                        let budget = cfg.max_rounds().saturating_sub(dadm.rounds());
                        (
                            Box::new(dadm),
                            GapCadence::EveryRounds(cfg.gap_every),
                            budget,
                        )
                    }
                    Method::AccDadm => {
                        let acc = Problem::new(&data, &part)
                            .loss(loss)
                            .lambda(cfg.lambda)
                            .l1(cfg.mu)
                            .build_acc_dadm(
                                ProxSdca,
                                AccDadmOptions {
                                    nu: if cfg.nu_theory {
                                        NuChoice::Theory
                                    } else {
                                        NuChoice::Zero
                                    },
                                    dadm: dadm_opts.clone(),
                                    ..Default::default()
                                },
                            );
                        (
                            Box::new(acc),
                            GapCadence::AlgorithmDriven,
                            cfg.max_rounds(),
                        )
                    }
                    Method::Owlqn => {
                        let owlqn = Problem::new(&data, &part)
                            .loss(loss)
                            .lambda(cfg.lambda)
                            .l1(cfg.mu)
                            .build_owlqn(
                                cfg.max_passes as usize,
                                cluster.clone(),
                                cost,
                                cfg.local_threads,
                            );
                        (
                            Box::new(owlqn),
                            GapCadence::EveryRounds(1),
                            cfg.max_passes as usize,
                        )
                    }
                };
            solve_boxed(cfg, algo, cadence, max_rounds)
        }};
    }

    Ok(with_loss!(wire_loss_for(cfg)))
}

/// Run a boxed algorithm through the shared driver and map the report
/// onto the launcher outcome.
fn solve_boxed(
    cfg: &ExperimentConfig,
    mut algo: Box<dyn RoundAlgorithm>,
    cadence: GapCadence,
    max_rounds: usize,
) -> RunOutcome {
    let mut driver = Driver::new(cfg.eps, max_rounds).with_cadence(cadence);
    if let Some(path) = &cfg.checkpoint {
        driver = driver.with_checkpoint(path.into(), cfg.checkpoint_every);
    }
    let report = driver.solve(algo.as_mut());
    match cfg.method {
        // OWL-QN is primal-only: the recorded primal *is* the normalized
        // objective, and one comm round = one oracle evaluation.
        Method::Owlqn => RunOutcome {
            method: "owlqn",
            final_metric: report.primal,
            comms: report.rounds,
            passes: report.passes,
            modeled_secs: report
                .trace
                .last()
                .map(|r| r.modeled_secs())
                .unwrap_or(0.0),
            trace_csv: None,
            stragglers: report.stragglers,
        },
        m => outcome_from_report(m.name(), report),
    }
}

fn outcome_from_report(method: &'static str, report: SolveReport) -> RunOutcome {
    let mut csv = Vec::new();
    report
        .trace
        .write_csv(&mut csv)
        .expect("in-memory CSV write cannot fail");
    let modeled = report
        .trace
        .last()
        .map(|r| r.modeled_secs())
        .unwrap_or(0.0);
    RunOutcome {
        method,
        final_metric: report.normalized_gap(),
        comms: report.rounds,
        passes: report.passes,
        modeled_secs: modeled,
        trace_csv: Some(String::from_utf8(csv).expect("csv is utf8")),
        stragglers: report.stragglers,
    }
}

/// `dadm worker` subcommand: host one machine's state for a TCP
/// coordinator until it sends `Shutdown` or disconnects.
fn worker_main(args: &[String]) -> Result<()> {
    let mut connect: Option<String> = None;
    let mut it = args.iter();
    while let Some(k) = it.next() {
        match k.as_str() {
            "--connect" => {
                connect = Some(
                    it.next()
                        .context("missing value for `--connect`")?
                        .clone(),
                );
            }
            "--help" => {
                println!(
                    "dadm worker — one TCP cluster machine\n\n\
                     USAGE: dadm worker --connect HOST:PORT\n\n\
                     Connects to a coordinator started with `--cluster tcp`,\n\
                     receives its partition assignment (a synthetic-data seed\n\
                     or an explicit shard — training data never moves for\n\
                     synthetic runs), then serves fused local-step rounds\n\
                     until the coordinator shuts the fleet down."
                );
                return Ok(());
            }
            other => bail!("unknown worker flag `{other}` (try `dadm worker --help`)"),
        }
    }
    let addr = connect.context("worker requires `--connect host:port`")?;
    Ok(crate::comm::tcp::run_worker(&addr)?)
}

/// `dadm compile-cache` subcommand: compile a LIBSVM text file into the
/// binary CSR cache of DESIGN.md §15 (streaming two-pass; the input is
/// never materialized in memory).
fn compile_cache_main(args: &[String]) -> Result<()> {
    if args.first().map(String::as_str) == Some("--help") || args.is_empty() {
        println!(
            "dadm compile-cache — compile LIBSVM text into a binary CSR cache\n\n\
             USAGE: dadm compile-cache INPUT.libsvm OUTPUT.dadmcache\n\n\
             Parses INPUT once (streaming, two passes, O(1) memory in n)\n\
             and writes a versioned, 8-byte-aligned little-endian CSR\n\
             image: header (magic, version, FNV-1a-64 content hash, n, d,\n\
             nnz, section offsets) + labels + row offsets + column\n\
             indices + values. Training with `--cache OUTPUT` then mmaps\n\
             the file and serves rows zero-copy — open is O(1) instead of\n\
             re-parsing the text — and produces bit-identical iterates to\n\
             a text-parsed run with `partition = contiguous`."
        );
        return Ok(());
    }
    anyhow::ensure!(
        args.len() == 2,
        "expected `dadm compile-cache INPUT OUTPUT` (try `dadm compile-cache --help`)"
    );
    let (input, output) = (&args[0], &args[1]);
    let report =
        crate::data::cache::compile(std::path::Path::new(input), std::path::Path::new(output))
            .with_context(|| format!("compile {input} -> {output}"))?;
    println!(
        "compiled {input} -> {output}: n={} d={} nnz={} bytes={} hash={:016x}",
        report.n, report.d, report.nnz, report.bytes, report.content_hash
    );
    Ok(())
}

/// Entry point used by `main.rs`.
pub fn main_with_args(args: &[String]) -> Result<()> {
    if args.first().map(String::as_str) == Some("worker") {
        return worker_main(&args[1..]);
    }
    if args.first().map(String::as_str) == Some("compile-cache") {
        return compile_cache_main(&args[1..]);
    }
    if args.first().map(String::as_str) == Some("--help") || args.is_empty() {
        println!(
            "dadm — Distributed Alternating Dual Maximization (Zheng et al., 2016)\n\n\
             USAGE: dadm --key value ...        (coordinator / launcher)\n       \
             dadm worker --connect HOST:PORT  (TCP cluster worker)\n       \
             dadm compile-cache INPUT OUTPUT  (LIBSVM -> binary CSR cache)\n\n\
             Keys: dataset scale method loss solver lambda mu machines sp eps\n\
                   max-passes gap-every conj-resum-every cluster tcp-listen\n\
                   local-threads seed nu comm-alpha comm-beta sparse-comm\n\
                   compress overlap checkpoint checkpoint-every resume\n\
                   worker-timeout heartbeat-every max-rejoins cache partition\n\
                   balance\n\n\
             --cache PATH (default unset)\n  \
             Train out-of-core from a compiled binary CSR cache (the\n  \
             output of `dadm compile-cache`; DESIGN.md §15) instead of\n  \
             parsing --dataset. The cache is mmapped — open is O(1) and\n  \
             the OS pages rows in on demand — and rows are served\n  \
             zero-copy out of the mapping. Under --cluster tcp each\n  \
             worker maps PATH itself (shared filesystem or a local\n  \
             copy; a content hash in the assignment catches divergent\n  \
             copies) so no training rows cross the wire, and a\n  \
             resurrected worker re-maps instead of re-parsing. Implies\n  \
             --partition contiguous; iterates are bit-identical to a\n  \
             text-parsed run of the same file with that partition.\n\n\
             --partition balanced|contiguous (default: auto)\n  \
             How examples are assigned to machines: `balanced` is the\n  \
             paper's seeded-shuffle protocol (the default for in-memory\n  \
             data); `contiguous` assigns contiguous balanced row ranges\n  \
             (the default — and the only legal choice — with --cache,\n  \
             where each shard is a zero-copy range of the mapping).\n\n\
             --balance rows|nnz (default rows)\n  \
             Chunking formula for contiguous shard cuts. `rows`\n  \
             equalizes row counts (the historical parity pin); `nnz`\n  \
             chooses the contiguous cut points that minimize the\n  \
             maximum shard nnz — on skewed sparse data the per-round\n  \
             barrier waits on the densest shard, so nnz balance is what\n  \
             equalizes local-step time. Implies --partition contiguous\n  \
             (a seeded shuffle has no nnz form); over --cluster tcp the\n  \
             explicit nnz-cut row ranges ship in the assignment, so all\n  \
             backends produce bit-identical traces. The per-round\n  \
             spread lands in the trace's step_min/mean/max_secs and\n  \
             imbalance columns.\n\n\
             --cluster serial|threads|tcp (default serial)\n  \
             Execution backend for the per-machine local steps. `serial`\n  \
             and `threads` simulate the cluster in-process; `tcp` is a\n  \
             real coordinator/worker deployment: the launcher binds\n  \
             --tcp-listen (default 127.0.0.1:7171, port 0 = ephemeral),\n  \
             waits for `machines` worker processes started with\n  \
             `dadm worker --connect HOST:PORT`, and ships each worker its\n  \
             assignment. Synthetic datasets travel as generator seeds —\n  \
             training data never crosses the wire — and actual wire bytes\n  \
             are recorded alongside the modeled comm cost. Iterates are\n  \
             bit-identical across all three backends.\n\n\
             --local-threads T (default 1)\n  \
             Intra-machine parallelism: every machine (in-process worker\n  \
             or remote `dadm worker` process) sub-partitions its shard\n  \
             into T sub-shards and runs T concurrent ProxSDCA sub-solvers\n  \
             plus T-way parallel gap/oracle passes, merging sub-results\n  \
             machine-locally at zero wire cost — DADM applied one level\n  \
             down, so an (m, T) solve with power-of-two T is bit-identical\n  \
             to a flat m*T solve over the split partition. T=0 picks the\n  \
             host core count; requests are clamped to the smallest shard.\n\n\
             --gap-every K (default 1)\n  \
             Evaluate the duality gap every K rounds instead of every\n  \
             round. Gap telemetry is fused into the round itself: a gap\n  \
             round costs no extra cluster barrier, and over TCP it adds\n  \
             16 bytes per machine instead of re-shipping the 8*d-byte\n  \
             iterate — the reported trace trails the solve by one round\n  \
             and is bit-identical to a separate-barrier evaluation.\n  \
             The primal sum is still one pass over the data, so raising\n  \
             K still saves compute at small sp.\n\n\
             --conj-resum-every K (default 64, 0 = never)\n  \
             The dual side of the gap is a running per-machine sum of\n  \
             -phi*(-alpha), updated in O(1) per touched coordinate\n  \
             instead of recomputed with an O(n) pass. Every K rounds\n  \
             each machine resums it exactly, bounding the accumulated\n  \
             float drift; the cadence follows the round counter, so all\n  \
             backends (and checkpoint-resumed runs) resum at the same\n  \
             rounds and traces stay bit-identical across backends.\n\n\
             --checkpoint PATH / --checkpoint-every K (default 10)\n  \
             Write a resumable solver snapshot to PATH every K rounds\n  \
             (dadm only; in-process backends only). --resume PATH restores\n  \
             such a snapshot before solving — with the identical\n  \
             dataset/partition/seed/lambda the resumed run reproduces the\n  \
             uninterrupted trajectory bit for bit (snapshots carry the\n  \
             mini-batch RNG streams), and the restored rounds count\n  \
             against max-passes so the total budget matches an\n  \
             uninterrupted run.\n\n\
             --sparse-comm true|false (default false)\n  \
             The data path always exchanges Δv/Δṽ as sparse index+value\n  \
             messages when their support is small (falling back to dense\n  \
             vectors past the wire break-even). With sparse-comm=true the\n  \
             alpha-beta cost model charges those actual message sizes\n  \
             (12 B per stored entry, capped at the dense 8·d bytes);\n  \
             with false it charges dense length-d vectors. The iterates\n  \
             are bit-identical either way — only modeled comm time moves.\n\n\
             --compress f64|f32|i16 (default f64)\n  \
             Wire codec for the Δv/Δṽ payloads (dual methods). f64 is\n  \
             exact and bit-identical to not compressing. f32 and scaled\n  \
             i16 quantize each sender's delta at the wire boundary and\n  \
             keep the quantization error in a per-sender residual that\n  \
             is fed back into the next round's delta (error feedback),\n  \
             so the solve still converges to the same solution; i16\n  \
             cuts dense payloads to 2 bytes per element (vs 8).\n\n\
             --worker-timeout S / --heartbeat-every S / --max-rejoins N\n  \
             (defaults 30 / 5 / 0; cluster=tcp only — DESIGN.md §14.)\n  \
             Liveness and fault tolerance for remote workers: while a\n  \
             reply is pending the coordinator probes each worker every\n  \
             heartbeat-every seconds and declares it dead after\n  \
             worker-timeout seconds of silence — a typed WorkerFault\n  \
             error instead of an indefinite hang. With max-rejoins > 0\n  \
             up to N deaths are healed in place: the coordinator\n  \
             re-listens, re-ships the dead worker's assignment plus a\n  \
             replay of every frame it had already consumed, verifies the\n  \
             rebuilt replica bit-for-bit, and resumes — the trace is\n  \
             bit-identical to an uninterrupted run.\n\n\
             --overlap true|false (default false, dadm only)\n  \
             Double-buffered rounds: issue round t+1's fused local-step\n  \
             dispatch while round t's reduce and global step complete,\n  \
             overlapping communication with the coordinator's work at\n  \
             one round of bounded broadcast staleness. The trace keeps\n  \
             the exact dual telemetry; entering-primal records are\n  \
             approximate under overlap.\n\n\
             Example:\n  dadm --dataset synth-rcv1 --scale 0.01 --method acc-dadm \\\n       \
             --loss logistic --lambda 1e-7 --machines 8 --sp 0.2 --sparse-comm true"
        );
        return Ok(());
    }
    let cfg = ExperimentConfig::from_args(args)?;
    let outcome = run_experiment(&cfg)?;
    println!(
        "method={} final_metric={:.6e} comms={} passes={:.1} modeled_secs={:.4}",
        outcome.method, outcome.final_metric, outcome.comms, outcome.passes, outcome.modeled_secs
    );
    if outcome.stragglers.rounds_measured > 0 {
        let s = &outcome.stragglers;
        println!(
            "stragglers: imbalance mean={:.2} max={:.2} idle_secs={:.4} over {} rounds",
            s.mean_imbalance, s.max_imbalance, s.idle_secs, s.rounds_measured
        );
    }
    if let Some(csv) = &outcome.trace_csv {
        let path = format!("target/{}_trace.csv", outcome.method);
        std::fs::create_dir_all("target").ok();
        std::fs::write(&path, csv)?;
        println!("trace written to {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(method: &str) -> ExperimentConfig {
        let args: Vec<String> = [
            "--dataset", "tiny", "--method", method, "--lambda", "1e-3", "--mu", "1e-5",
            "--machines", "4", "--sp", "1.0", "--eps", "1e-3", "--max-passes", "40",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        ExperimentConfig::from_args(&args).unwrap()
    }

    #[test]
    fn launcher_runs_all_methods() {
        for method in ["dadm", "acc-dadm", "owlqn"] {
            let outcome = run_experiment(&quick_cfg(method)).unwrap();
            assert!(outcome.final_metric.is_finite(), "{method}");
            assert!(outcome.comms > 0, "{method}");
        }
    }

    #[test]
    fn launcher_runs_all_methods_with_local_threads() {
        for method in ["dadm", "acc-dadm", "owlqn"] {
            let mut cfg = quick_cfg(method);
            cfg.local_threads = 2;
            let outcome = run_experiment(&cfg).unwrap();
            assert!(outcome.final_metric.is_finite(), "{method}");
            assert!(outcome.comms > 0, "{method}");
        }
    }

    #[test]
    fn launcher_runs_compressed_and_overlapped_dadm() {
        let exact = run_experiment(&quick_cfg("dadm")).unwrap();
        for codec in ["f32", "i16"] {
            let mut cfg = quick_cfg("dadm");
            cfg.compress = crate::comm::sparse::DeltaCodec::parse(codec).unwrap();
            let outcome = run_experiment(&cfg).unwrap();
            assert!(outcome.final_metric.is_finite(), "{codec}");
            // Error feedback keeps the lossy run in the exact run's
            // neighborhood at equal budget.
            assert!(
                outcome.final_metric <= exact.final_metric.max(cfg.eps) * 10.0,
                "{codec}: {} vs {}",
                outcome.final_metric,
                exact.final_metric
            );
        }
        let mut cfg = quick_cfg("dadm");
        cfg.overlap = true;
        let outcome = run_experiment(&cfg).unwrap();
        assert!(outcome.final_metric.is_finite());
        assert!(outcome.comms > 0);
    }

    #[test]
    fn dual_methods_emit_trace_csv() {
        let outcome = run_experiment(&quick_cfg("dadm")).unwrap();
        let csv = outcome.trace_csv.unwrap();
        assert!(csv.starts_with("round,"));
        assert!(csv.lines().count() >= 2);
    }

    #[test]
    fn launcher_checkpoints_and_resumes() {
        let dir = std::env::temp_dir().join("dadm-cli-ck");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cli.ck");
        let path_str = path.to_str().unwrap().to_string();

        // Short capped run that writes a snapshot…
        let mut cfg = quick_cfg("dadm");
        cfg.eps = 1e-12; // unreachable in 4 passes → runs to the cap
        cfg.max_passes = 4.0;
        cfg.checkpoint = Some(path_str.clone());
        cfg.checkpoint_every = 2;
        let first = run_experiment(&cfg).unwrap();
        assert_eq!(first.comms, 4);
        let ck = Checkpoint::load_file(&path).unwrap();
        assert_eq!(ck.rounds, 4);

        // …and a resumed run that continues from it under a raised
        // *total* budget (the 4 restored rounds count against it).
        let mut resumed_cfg = quick_cfg("dadm");
        resumed_cfg.eps = 1e-12;
        resumed_cfg.max_passes = 8.0;
        resumed_cfg.resume = Some(path_str.clone());
        let resumed = run_experiment(&resumed_cfg).unwrap();
        assert_eq!(resumed.comms, 8, "total budget: 4 restored + 4 new");
        assert!(resumed.final_metric.is_finite());
        // Four further epochs from the restored state keep converging
        // (generous factor: the primal may wiggle round to round).
        assert!(resumed.final_metric <= first.final_metric * 1.5);

        // Same total budget as the first run ⇒ nothing left to do.
        let mut spent_cfg = quick_cfg("dadm");
        spent_cfg.eps = 1e-12;
        spent_cfg.max_passes = 4.0;
        spent_cfg.resume = Some(path_str);
        let spent = run_experiment(&spent_cfg).unwrap();
        assert_eq!(spent.comms, 4, "budget already spent by the snapshot");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn help_does_not_error() {
        main_with_args(&["--help".to_string()]).unwrap();
    }

    #[test]
    fn compile_cache_subcommand_validates_and_compiles() {
        // --help and arity errors happen before any I/O.
        main_with_args(&["compile-cache".into(), "--help".into()]).unwrap();
        assert!(main_with_args(&["compile-cache".into(), "only-one".into()]).is_err());

        let dir = std::env::temp_dir().join(format!("dadm-cli-cache-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let text = dir.join("in.libsvm");
        let cache = dir.join("in.dadmcache");
        let data = crate::data::synthetic::tiny_classification(60, 12, 7);
        let mut buf = Vec::new();
        crate::data::libsvm::write(&data, &mut buf).unwrap();
        std::fs::write(&text, &buf).unwrap();
        main_with_args(&[
            "compile-cache".into(),
            text.to_str().unwrap().into(),
            cache.to_str().unwrap().into(),
        ])
        .unwrap();
        let opened = crate::data::CsrCache::open(&cache).unwrap();
        assert_eq!(opened.rows(), 60);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The trace CSV's first eight columns (`round..comm_secs`) — the
    /// parity-pinned modeled math, which must reproduce bit for bit.
    /// Everything after is wall-clock-derived (`wall_secs` plus the
    /// straggler telemetry `step_*`/`imbalance` columns, DESIGN.md §16);
    /// `scripts/cache_smoke.sh` applies the same projection with `cut`.
    fn math_columns(csv: &str) -> String {
        csv.lines()
            .map(|l| l.split(',').take(8).collect::<Vec<_>>().join(","))
            .collect::<Vec<_>>()
            .join("\n")
    }

    #[test]
    fn cache_solve_is_bit_identical_to_text_solve() {
        // The acceptance pin at the launcher level: a solve started from
        // the compiled cache reproduces the text-parsed solve (with the
        // same contiguous partition) bit for bit — trace CSV included.
        let dir = std::env::temp_dir().join(format!("dadm-cli-parity-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let text = dir.join("p.libsvm");
        let cache = dir.join("p.dadmcache");
        let data = crate::data::synthetic::tiny_classification(200, 16, 3);
        let mut buf = Vec::new();
        crate::data::libsvm::write(&data, &mut buf).unwrap();
        std::fs::write(&text, &buf).unwrap();
        crate::data::cache::compile(&text, &cache).unwrap();

        let mut text_cfg = quick_cfg("dadm");
        text_cfg.dataset = text.to_str().unwrap().to_string();
        text_cfg.partition = Some(crate::config::PartitionKind::Contiguous);
        text_cfg.max_passes = 6.0;
        let mut cache_cfg = quick_cfg("dadm");
        cache_cfg.cache = Some(cache.to_str().unwrap().to_string());
        cache_cfg.max_passes = 6.0;

        let from_text = run_experiment(&text_cfg).unwrap();
        let from_cache = run_experiment(&cache_cfg).unwrap();
        assert_eq!(
            math_columns(from_text.trace_csv.as_deref().unwrap()),
            math_columns(from_cache.trace_csv.as_deref().unwrap())
        );
        assert_eq!(
            from_text.final_metric.to_bits(),
            from_cache.final_metric.to_bits()
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn worker_subcommand_validates_flags() {
        // Missing --connect and unknown flags are errors before any
        // network activity; --help succeeds.
        assert!(main_with_args(&["worker".to_string()]).is_err());
        assert!(main_with_args(&["worker".to_string(), "--bogus".to_string()]).is_err());
        main_with_args(&["worker".to_string(), "--help".to_string()]).unwrap();
    }
}
