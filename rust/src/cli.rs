//! CLI launcher plumbing for the `dadm` binary.
//!
//! Dispatches a parsed [`ExperimentConfig`] to the right coordinator and
//! prints/persists the trace — the equivalent of the paper's experiment
//! driver scripts. Kept out of `main.rs` so integration tests can run the
//! launcher in-process.

use crate::comm::CostModel;
use crate::config::{ExperimentConfig, Method};
use crate::coordinator::{
    run_owlqn_distributed, AccDadm, AccDadmOptions, Dadm, DadmOptions, NuChoice, SolveReport,
};
use crate::data::Partition;
use crate::loss::{Hinge, Logistic, LossKind, SmoothHinge, Squared};
use crate::reg::{ElasticNet, Zero};
use crate::solver::ProxSdca;
use anyhow::Result;

/// Outcome of a launcher run (uniform across methods).
#[derive(Clone, Debug)]
pub struct RunOutcome {
    /// Method name.
    pub method: &'static str,
    /// Final normalized metric (duality gap for the dual methods,
    /// objective value for OWL-QN).
    pub final_metric: f64,
    /// Communications used.
    pub comms: usize,
    /// Passes over the data.
    pub passes: f64,
    /// Modeled compute + comm seconds.
    pub modeled_secs: f64,
    /// CSV trace body (round records) for dual methods.
    pub trace_csv: Option<String>,
}

/// Run one experiment according to `cfg`.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<RunOutcome> {
    let data = cfg.load_dataset()?;
    let part = Partition::balanced(data.n(), cfg.machines, cfg.seed);
    let cost = CostModel {
        alpha: cfg.comm_alpha,
        beta: cfg.comm_beta,
    };
    let dadm_opts = DadmOptions {
        sp: cfg.sp,
        cluster: cfg.cluster,
        cost,
        seed: cfg.seed,
        gap_every: 1,
        sparse_comm: cfg.sparse_comm,
    };

    // Dispatch over loss at this boundary only: the coordinators are
    // generic, and the smoothed hinge (§8.2) substitutes for the plain
    // hinge inside the accelerated method.
    macro_rules! with_loss {
        ($loss:expr) => {{
            let loss = $loss;
            match cfg.method {
                Method::Dadm => {
                    let mut dadm = Dadm::new(
                        &data,
                        &part,
                        loss,
                        ElasticNet::new(cfg.mu / cfg.lambda),
                        Zero,
                        cfg.lambda,
                        ProxSdca,
                        dadm_opts.clone(),
                    );
                    let report = dadm.solve(cfg.eps, cfg.max_rounds());
                    outcome_from_report("dadm", report)
                }
                Method::AccDadm => {
                    let mut acc = AccDadm::new(
                        &data,
                        &part,
                        loss,
                        Zero,
                        cfg.lambda,
                        cfg.mu,
                        ProxSdca,
                        AccDadmOptions {
                            nu: if cfg.nu_theory {
                                NuChoice::Theory
                            } else {
                                NuChoice::Zero
                            },
                            dadm: dadm_opts.clone(),
                            ..Default::default()
                        },
                    );
                    let report = acc.solve(cfg.eps, cfg.max_rounds());
                    outcome_from_report("acc-dadm", report)
                }
                Method::Owlqn => {
                    let report = run_owlqn_distributed(
                        &data,
                        &part,
                        loss,
                        cfg.lambda,
                        cfg.mu,
                        cfg.max_passes as usize,
                        cfg.cluster,
                        cost,
                    );
                    RunOutcome {
                        method: "owlqn",
                        final_metric: report.objective,
                        comms: report.passes,
                        passes: report.passes as f64,
                        modeled_secs: report.compute_secs + report.comm_secs,
                        trace_csv: None,
                    }
                }
            }
        }};
    }

    Ok(match cfg.loss {
        LossKind::SmoothHinge => with_loss!(SmoothHinge::default()),
        LossKind::Logistic => with_loss!(Logistic),
        LossKind::Hinge => {
            if cfg.method == Method::AccDadm {
                // §8.2 / Corollary 13: smooth with γ = ε/L² then accelerate.
                with_loss!(SmoothHinge::nesterov(cfg.eps))
            } else {
                with_loss!(Hinge)
            }
        }
        LossKind::Squared => with_loss!(Squared),
    })
}

fn outcome_from_report(method: &'static str, report: SolveReport) -> RunOutcome {
    let mut csv = Vec::new();
    report
        .trace
        .write_csv(&mut csv)
        .expect("in-memory CSV write cannot fail");
    let modeled = report
        .trace
        .last()
        .map(|r| r.modeled_secs())
        .unwrap_or(0.0);
    RunOutcome {
        method,
        final_metric: report.normalized_gap(),
        comms: report.rounds,
        passes: report.passes,
        modeled_secs: modeled,
        trace_csv: Some(String::from_utf8(csv).expect("csv is utf8")),
    }
}

/// Entry point used by `main.rs`.
pub fn main_with_args(args: &[String]) -> Result<()> {
    if args.first().map(String::as_str) == Some("--help") || args.is_empty() {
        println!(
            "dadm — Distributed Alternating Dual Maximization (Zheng et al., 2016)\n\n\
             USAGE: dadm --key value ...\n\n\
             Keys: dataset scale method loss solver lambda mu machines sp eps\n\
                   max-passes cluster seed nu comm-alpha comm-beta sparse-comm\n\n\
             --sparse-comm true|false (default false)\n  \
             The data path always exchanges Δv/Δṽ as sparse index+value\n  \
             messages when their support is small (falling back to dense\n  \
             vectors past the wire break-even). With sparse-comm=true the\n  \
             alpha-beta cost model charges those actual message sizes\n  \
             (12 B per stored entry, capped at the dense 8·d bytes);\n  \
             with false it charges dense length-d vectors. The iterates\n  \
             are bit-identical either way — only modeled comm time moves.\n\n\
             Example:\n  dadm --dataset synth-rcv1 --scale 0.01 --method acc-dadm \\\n       \
             --loss logistic --lambda 1e-7 --machines 8 --sp 0.2 --sparse-comm true"
        );
        return Ok(());
    }
    let cfg = ExperimentConfig::from_args(args)?;
    let outcome = run_experiment(&cfg)?;
    println!(
        "method={} final_metric={:.6e} comms={} passes={:.1} modeled_secs={:.4}",
        outcome.method, outcome.final_metric, outcome.comms, outcome.passes, outcome.modeled_secs
    );
    if let Some(csv) = &outcome.trace_csv {
        let path = format!("target/{}_trace.csv", outcome.method);
        std::fs::create_dir_all("target").ok();
        std::fs::write(&path, csv)?;
        println!("trace written to {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(method: &str) -> ExperimentConfig {
        let args: Vec<String> = [
            "--dataset", "tiny", "--method", method, "--lambda", "1e-3", "--mu", "1e-5",
            "--machines", "4", "--sp", "1.0", "--eps", "1e-3", "--max-passes", "40",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        ExperimentConfig::from_args(&args).unwrap()
    }

    #[test]
    fn launcher_runs_all_methods() {
        for method in ["dadm", "acc-dadm", "owlqn"] {
            let outcome = run_experiment(&quick_cfg(method)).unwrap();
            assert!(outcome.final_metric.is_finite(), "{method}");
            assert!(outcome.comms > 0, "{method}");
        }
    }

    #[test]
    fn dual_methods_emit_trace_csv() {
        let outcome = run_experiment(&quick_cfg("dadm")).unwrap();
        let csv = outcome.trace_csv.unwrap();
        assert!(csv.starts_with("round,"));
        assert!(csv.lines().count() >= 2);
    }

    #[test]
    fn help_does_not_error() {
        main_with_args(&["--help".to_string()]).unwrap();
    }
}
