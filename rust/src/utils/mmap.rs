//! Minimal read-only memory mapping — no new dependencies.
//!
//! The offline build environment ships no `memmap2`-style crate, so the
//! cache layer (DESIGN.md §15) carries its own audited binding: two
//! `extern "C"` declarations (`mmap`/`munmap`) behind a safe [`Mmap`]
//! wrapper. The surface is deliberately tiny — read-only, whole-file,
//! private mappings — because every extra knob would widen the unsafe
//! audit.
//!
//! # Safety argument (dadm-lint `unsafe_allowlist.txt` entry)
//!
//! * The mapping is `PROT_READ` + `MAP_PRIVATE`: the kernel rejects any
//!   write through it (SIGSEGV on a bug, never silent corruption), and
//!   writes to the underlying file by *other* processes are not
//!   guaranteed visible — the cache layer therefore treats a mapped
//!   file as immutable and verifies its header before trusting offsets.
//! * `as_slice` hands out `&[u8]` borrows tied to the `Mmap`'s
//!   lifetime; `munmap` runs only in `Drop`, so no live borrow can
//!   outlast the mapping. Callers that need longer-lived views (the
//!   mapped `SparseMatrix` storage) hold the `Mmap` in an `Arc` and
//!   re-derive slices from raw parts per call — the `Arc` keeps the
//!   pages mapped for as long as any view exists.
//! * Truncating the file *after* mapping makes the pages beyond the new
//!   EOF fault with SIGBUS on access. That is an operator error (the
//!   cache is append-never, rewrite-by-replace); the failure mode is a
//!   crash, not UB or wrong answers. See DESIGN.md §15.4.
//! * `Send`/`Sync` are sound because the mapping is immutable shared
//!   memory: concurrent reads race with nothing, and the unmap is
//!   serialized by Rust's ownership of the single `Mmap` value.

use std::fs::File;
use std::io;

#[cfg(unix)]
pub use unix_impl::Mmap;

#[cfg(not(unix))]
pub use fallback_impl::Mmap;

/// Map a file read-only. Rejects empty files (zero-length `mmap` is
/// EINVAL on Linux; an empty cache is malformed anyway).
pub fn map_readonly(file: &File) -> io::Result<Mmap> {
    Mmap::map_readonly(file)
}

#[cfg(unix)]
mod unix_impl {
    use std::ffi::c_void;
    use std::fs::File;
    use std::io;
    use std::os::raw::c_int;
    use std::os::unix::io::AsRawFd;

    // Stable POSIX constants, identical on Linux and macOS — the two
    // unix targets this repo builds on.
    const PROT_READ: c_int = 1;
    const MAP_PRIVATE: c_int = 2;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    /// A read-only, private, whole-file memory mapping.
    pub struct Mmap {
        ptr: *const u8,
        len: usize,
    }

    // SAFETY: the mapping is immutable (`PROT_READ`) shared memory, so
    // aliased reads from any thread are data-race free; `munmap` runs
    // exactly once, in `Drop`, under exclusive ownership.
    unsafe impl Send for Mmap {}
    unsafe impl Sync for Mmap {}

    impl Mmap {
        /// Map `file` read-only in its entirety.
        pub fn map_readonly(file: &File) -> io::Result<Mmap> {
            let len = file.metadata()?.len();
            if len == 0 {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    "cannot mmap an empty file",
                ));
            }
            let len = usize::try_from(len).map_err(|_| {
                io::Error::new(
                    io::ErrorKind::InvalidInput,
                    "file too large to map on this platform",
                )
            })?;
            // SAFETY: null hint, validated non-zero length, PROT_READ |
            // MAP_PRIVATE over an owned fd that outlives this call. The
            // kernel picks the address; we never alias it writable.
            let ptr = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    len,
                    PROT_READ,
                    MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr as usize == usize::MAX {
                return Err(io::Error::last_os_error());
            }
            Ok(Mmap {
                ptr: ptr as *const u8,
                len,
            })
        }

        /// The mapped bytes. The borrow cannot outlive the mapping.
        pub fn as_slice(&self) -> &[u8] {
            // SAFETY: `ptr` is a live PROT_READ mapping of exactly
            // `len` bytes (established in `map_readonly`), unmapped
            // only in `Drop`, which cannot run while `self` is
            // borrowed.
            unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
        }

        /// Mapped length in bytes.
        pub fn len(&self) -> usize {
            self.len
        }

        /// Never true: zero-length mappings are rejected at creation.
        pub fn is_empty(&self) -> bool {
            self.len == 0
        }
    }

    impl Drop for Mmap {
        fn drop(&mut self) {
            // SAFETY: `ptr`/`len` came from a successful `mmap` and are
            // unmapped exactly once. Failure here is unrecoverable but
            // harmless (the mapping leaks); ignore the return code.
            unsafe {
                let _ = munmap(self.ptr as *mut c_void, self.len);
            }
        }
    }

    impl std::fmt::Debug for Mmap {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            // Never dump mapped data — it can be gigabytes.
            f.debug_struct("Mmap").field("len", &self.len).finish()
        }
    }
}

#[cfg(not(unix))]
mod fallback_impl {
    use std::fs::File;
    use std::io::{self, Read, Seek, SeekFrom};

    /// Portable fallback: read the whole file into owned memory. Not
    /// out-of-core, but behaviorally identical — non-unix targets are
    /// not a deployment platform for this repo. Backing storage is a
    /// `Vec<u64>` so the base pointer is 8-byte aligned like a real
    /// page-aligned mapping (the cache layer reinterprets 8-aligned
    /// sections as `u64`/`f64`).
    #[derive(Debug)]
    pub struct Mmap {
        data: Vec<u64>,
        len: usize,
    }

    impl Mmap {
        pub fn map_readonly(file: &File) -> io::Result<Mmap> {
            let mut bytes = Vec::new();
            let mut f = file.try_clone()?;
            // Real mmap always maps from offset 0 regardless of the
            // file cursor; match that, or a caller that read the header
            // first would get a silently shifted "mapping".
            f.seek(SeekFrom::Start(0))?;
            f.read_to_end(&mut bytes)?;
            if bytes.is_empty() {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    "cannot mmap an empty file",
                ));
            }
            let len = bytes.len();
            let mut data = vec![0u64; len.div_ceil(8)];
            // SAFETY: the destination holds at least `len` bytes and
            // u64 has no invalid bit patterns.
            unsafe {
                std::ptr::copy_nonoverlapping(bytes.as_ptr(), data.as_mut_ptr() as *mut u8, len);
            }
            Ok(Mmap { data, len })
        }

        pub fn as_slice(&self) -> &[u8] {
            // SAFETY: `data` owns at least `len` initialized bytes.
            unsafe { std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.len) }
        }

        pub fn len(&self) -> usize {
            self.len
        }

        pub fn is_empty(&self) -> bool {
            self.len == 0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn maps_file_contents_exactly() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("dadm_mmap_test_{}.bin", std::process::id()));
        let payload: Vec<u8> = (0..4096u32).map(|i| (i % 251) as u8).collect();
        {
            let mut f = File::create(&path).unwrap();
            f.write_all(&payload).unwrap();
        }
        let f = File::open(&path).unwrap();
        let map = map_readonly(&f).unwrap();
        assert_eq!(map.len(), payload.len());
        assert!(!map.is_empty());
        assert_eq!(map.as_slice(), &payload[..]);
        drop(map);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_file_is_rejected() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("dadm_mmap_empty_{}.bin", std::process::id()));
        File::create(&path).unwrap();
        let f = File::open(&path).unwrap();
        let err = map_readonly(&f).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mapping_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Mmap>();
    }
}
