//! Dense numeric kernels shared by the solvers.
//!
//! These are the innermost loops of the Layer-3 hot path; they are written
//! so LLVM auto-vectorizes them (slice iterators, no bounds checks in the
//! hot loop) and benchmarked in `benches/perf_hotpath.rs`.

/// Dense dot product `xᵀ y`.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

/// Squared Euclidean norm `‖x‖₂²`.
#[inline]
pub fn l2_norm_sq(x: &[f64]) -> f64 {
    x.iter().map(|a| a * a).sum()
}

/// `‖x‖₁`.
#[inline]
pub fn l1_norm(x: &[f64]) -> f64 {
    x.iter().map(|a| a.abs()).sum()
}

/// `y ← y + c·x` (axpy).
#[inline]
pub fn axpy(c: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += c * xi;
    }
}

/// Elementwise soft-threshold: `sign(v)·max(|v| − τ, 0)`.
///
/// This is `∇g*` for the paper's experimental regularizer
/// `g(w) = ½‖w‖² + (μ/λ)‖w‖₁` with `τ = μ/λ` (§10), and equally the prox
/// map of `τ‖·‖₁`.
#[inline]
pub fn soft_threshold_scalar(v: f64, tau: f64) -> f64 {
    if v > tau {
        v - tau
    } else if v < -tau {
        v + tau
    } else {
        0.0
    }
}

/// Vectorized [`soft_threshold_scalar`], writing into `out`.
#[inline]
pub fn soft_threshold_into(v: &[f64], tau: f64, out: &mut [f64]) {
    debug_assert_eq!(v.len(), out.len());
    for (o, &vi) in out.iter_mut().zip(v) {
        *o = soft_threshold_scalar(vi, tau);
    }
}

/// Allocating convenience wrapper around [`soft_threshold_into`].
pub fn soft_threshold(v: &[f64], tau: f64) -> Vec<f64> {
    let mut out = vec![0.0; v.len()];
    soft_threshold_into(v, tau, &mut out);
    out
}

/// Clamp `x` into `[lo, hi]`.
#[inline]
pub fn clip(x: f64, lo: f64, hi: f64) -> f64 {
    x.max(lo).min(hi)
}

/// Relative/absolute tolerance comparison for tests.
pub fn approx_eq(a: f64, b: f64, tol: f64) -> bool {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    (a - b).abs() <= tol * scale
}

/// `log(1 + exp(x))` computed without overflow.
#[inline]
pub fn log1p_exp(x: f64) -> f64 {
    if x > 35.0 {
        x
    } else if x < -35.0 {
        x.exp() // ≈ 0, but keeps strict positivity
    } else {
        x.exp().ln_1p()
    }
}

/// Binary entropy term `a·ln(a)` with the `0·ln 0 = 0` convention.
#[inline]
pub fn xlogx(a: f64) -> f64 {
    if a <= 0.0 {
        0.0
    } else {
        a * a.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn norms() {
        assert_eq!(l2_norm_sq(&[3.0, 4.0]), 25.0);
        assert_eq!(l1_norm(&[-3.0, 4.0]), 7.0);
    }

    #[test]
    fn axpy_accumulates() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, -1.0], &mut y);
        assert_eq!(y, vec![7.0, -1.0]);
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold_scalar(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold_scalar(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold_scalar(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold_scalar(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(&[2.0, -2.0, 0.1], 1.0), vec![1.0, -1.0, 0.0]);
    }

    #[test]
    fn soft_threshold_is_prox_of_l1() {
        // prox_{τ‖·‖₁}(v) = argmin_w ½(w−v)² + τ|w| — verify by grid search.
        let tau = 0.7;
        for &v in &[-2.0, -0.5, 0.0, 0.3, 1.5] {
            let st = soft_threshold_scalar(v, tau);
            let obj = |w: f64| 0.5 * (w - v) * (w - v) + tau * w.abs();
            let mut best = f64::INFINITY;
            let mut arg = 0.0;
            let mut w = -3.0;
            while w <= 3.0 {
                if obj(w) < best {
                    best = obj(w);
                    arg = w;
                }
                w += 1e-4;
            }
            assert!((st - arg).abs() < 1e-3, "v={v}: {st} vs {arg}");
        }
    }

    #[test]
    fn log1p_exp_stable() {
        assert!((log1p_exp(0.0) - std::f64::consts::LN_2).abs() < 1e-12);
        assert!((log1p_exp(100.0) - 100.0).abs() < 1e-9);
        assert!(log1p_exp(-100.0) >= 0.0);
        assert!(log1p_exp(-100.0) < 1e-40);
    }

    #[test]
    fn clip_bounds() {
        assert_eq!(clip(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clip(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clip(0.5, 0.0, 1.0), 0.5);
    }
}
