//! Deterministic pseudo-random number generation.
//!
//! xoshiro256** seeded via splitmix64 — fast, high quality, and most
//! importantly *reproducible*: every experiment in the paper reproduction
//! is keyed by an explicit `u64` seed so that the baseline and the
//! accelerated method see identical data partitions and identical
//! mini-batch draws, mirroring the paper's "same balanced data partitions
//! and random seeds" protocol (§10).

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// The raw generator state (checkpointing: a restored state resumes
    /// the exact mini-batch stream).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild from a state captured by [`Rng::state`]. The all-zero
    /// state is the one fixed point of xoshiro256** and never occurs in
    /// a state captured from a seeded generator.
    pub fn from_state(s: [u64; 4]) -> Self {
        debug_assert!(s.iter().any(|&x| x != 0), "degenerate all-zero state");
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)`. `n` must be positive.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is overkill here;
        // modulo bias for n << 2^64 is negligible for our purposes, but we
        // still use the widening-multiply method for uniformity.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm for
    /// small k, shuffle for large k). Order is unspecified.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        // Floyd's method with a small open-addressing set.
        let mut chosen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        for &(n, k) in &[(10, 3), (100, 90), (50, 50), (7, 1)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn state_roundtrip_resumes_stream() {
        let mut a = Rng::new(77);
        for _ in 0..13 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(1234);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
