//! Small shared utilities: deterministic RNG and numeric helpers.
//!
//! The offline build environment ships no `rand` crate, so we carry a
//! small, well-tested PRNG of our own (xoshiro256** seeded via
//! splitmix64), plus the handful of float helpers the solvers share.

pub mod math;
pub mod mmap;
pub mod rng;

pub use math::{approx_eq, dot, l1_norm, l2_norm_sq, soft_threshold};
pub use rng::Rng;
