//! # DADM — Distributed Alternating Dual Maximization
//!
//! A production-quality reproduction of *"A General Distributed Dual
//! Coordinate Optimization Framework for Regularized Loss Minimization"*
//! (Zheng, Wang, Xia, Xu, Zhang; 2016).
//!
//! The crate implements the paper's full system as the Layer-3 (Rust)
//! coordinator of a three-layer Rust + JAX + Pallas stack:
//!
//! * [`data`] — sparse/dense design matrices, LIBSVM parsing, synthetic
//!   dataset generators mimicking the paper's four benchmark datasets,
//!   balanced partitioning across simulated machines.
//! * [`loss`] — the loss-function zoo (smooth hinge, logistic, hinge,
//!   squared) with convex conjugates and closed-form / Newton coordinate
//!   maximizers.
//! * [`reg`] — strongly convex regularizers `g` and the extra convex term
//!   `h` (elastic net, group lasso), with `∇g*` maps and prox operators.
//! * [`solver`] — local dual solvers: ProxSDCA, the Theorem-6 mini-batch
//!   update, and the OWL-QN / L-BFGS primal baselines.
//! * [`coordinator`] — the paper's contribution: the DADM round
//!   (Algorithm 2), the accelerated outer stages of Acc-DADM
//!   (Algorithm 3), the distributed OWL-QN baseline, and the CoCoA+
//!   equivalence mode — all driven by the shared round engine.
//! * [`comm`] — the simulated multi-machine substrate: worker threads,
//!   an allreduce tree, and an alpha-beta communication cost model.
//! * [`runtime`] — the unified round engine (`runtime::engine`: one
//!   `Driver` solve loop + `RoundAlgorithm` per method, with gap
//!   cadence, trace emission and periodic checkpoints) and the PJRT
//!   client wrapper loading the AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`) for the batched hot path.
//! * [`metrics`] — duality-gap traces, timers, CSV emission for benches.
//! * [`config`] / [`cli`] — experiment configuration and the launcher.
//! * [`testing`] — an in-tree property-based testing harness (stand-in
//!   for `proptest`, which is unavailable offline).

pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod loss;
pub mod metrics;
pub mod reg;
pub mod runtime;
pub mod solver;
pub mod testing;
pub mod utils;

pub use coordinator::{AccDadm, AccDadmOptions, Dadm, DadmOptions, Problem, SolveReport};
pub use data::{Dataset, Partition, SparseMatrix};
pub use loss::Loss;
pub use reg::{ElasticNet, Regularizer};
pub use runtime::{Driver, GapCadence, RoundAlgorithm};
