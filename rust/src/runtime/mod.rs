//! Runtime substrate: the unified round engine plus the PJRT client for
//! AOT-compiled artifacts.
//!
//! * [`engine`] — the shared solve loop. A [`engine::Driver`] owns the
//!   stopping policy, gap cadence, trace emission, modeled accounting and
//!   periodic checkpoints for every [`engine::RoundAlgorithm`] (DADM,
//!   Acc-DADM, distributed OWL-QN); the coordinators supply only the
//!   per-round work. See DESIGN.md §4.
//! * [`artifact`]/[`local_step`] — the PJRT runtime. The build-time
//!   Python layers (`python/compile/`) lower the batched Theorem-6 local
//!   step to HLO **text** (`artifacts/local_step_*.hlo.txt`; text, not
//!   serialized proto — xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit
//!   instruction ids). The `xla` crate's PJRT CPU client compiles those
//!   artifacts once and executes them from the Rust hot path, so Python
//!   is never on the solve path.

mod artifact;
pub mod engine;
mod local_step;

pub use artifact::{artifact_path, ArtifactSpec, XlaRuntime};
pub use engine::{Driver, GapCadence, RoundAlgorithm, RoundOutcome, RoundRequest, SolveReport};
pub use local_step::XlaLocalStep;
