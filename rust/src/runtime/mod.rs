//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The build-time Python layers (`python/compile/`) lower the batched
//! Theorem-6 local step to HLO **text** (`artifacts/local_step_*.hlo.txt`;
//! text, not serialized proto — xla_extension 0.5.1 rejects jax ≥ 0.5's
//! 64-bit instruction ids). This module wraps the `xla` crate's PJRT CPU
//! client to compile those artifacts once and execute them from the Rust
//! hot path, so Python is never on the solve path.

mod artifact;
mod local_step;

pub use artifact::{artifact_path, ArtifactSpec, XlaRuntime};
pub use local_step::XlaLocalStep;
