//! The XLA-backed local solver: executes the AOT-compiled batched
//! Theorem-6 update through PJRT.
//!
//! Division of labor (see DESIGN.md §2): the *regularizer* side
//! (`w = ∇g*(ṽ)`, exact in f64, including the Acc-DADM shift) stays in
//! Rust; the artifact computes the batched loss-side hot spot
//!
//! ```text
//! u      = X_b · w                     (scores)
//! u_dir  = −∇φ(u, y)                   (Theorem-6 direction)
//! Δα     = s·(u_dir − α_b)
//! Δv_raw = X_bᵀ · Δα                   (unscaled dual combination)
//! ```
//!
//! in f32 with `s` passed as a scalar input, exactly matching
//! [`crate::solver::TheoremStep`] (cross-checked in `rust/tests/`).
//! Batches are padded/chunked to the artifact's static `M`; zero rows
//! (x = 0, y = 0, α = 0) provably produce `Δα = 0` for every loss.

use crate::comm::sparse::{should_densify, Delta, SparseDelta};
use crate::loss::Loss;
use crate::reg::Regularizer;
use crate::solver::{LocalSolver, WorkerState};
use crate::utils::Rng;
use anyhow::Result;
use std::sync::Mutex;

use super::artifact::{ArtifactSpec, XlaRuntime};

/// PJRT-backed Theorem-6 local step.
///
/// Holds the runtime behind a `Mutex`: PJRT execution is serialized
/// across worker threads (the CPU client is already internally threaded,
/// so this costs little; use `Cluster::Serial` for fully deterministic
/// runs).
#[derive(Debug)]
pub struct XlaLocalStep {
    runtime: Mutex<XlaRuntime>,
    /// Artifact batch rows `M`.
    pub batch_rows: usize,
    /// Artifact feature dim `d`.
    pub dim: usize,
    /// Data radius `R` used for the step scale.
    pub radius: f64,
}

impl XlaLocalStep {
    /// Create for a given artifact shape, verifying the artifact exists.
    pub fn new(loss_name: &str, batch_rows: usize, dim: usize, radius: f64) -> Result<Self> {
        let mut runtime = XlaRuntime::cpu()?;
        let spec = ArtifactSpec {
            loss: loss_name.to_string(),
            batch: batch_rows,
            dim,
        };
        // Compile eagerly so construction fails fast when artifacts are
        // missing or stale.
        runtime.load(&spec)?;
        Ok(XlaLocalStep {
            runtime: Mutex::new(runtime),
            batch_rows,
            dim,
            radius,
        })
    }

    fn spec_for<L: Loss>(&self, loss: &L) -> ArtifactSpec {
        ArtifactSpec {
            loss: loss.name().to_string(),
            batch: self.batch_rows,
            dim: self.dim,
        }
    }
}

impl LocalSolver for XlaLocalStep {
    fn local_step<L: Loss, R: Regularizer>(
        &self,
        state: &mut WorkerState,
        batch: &[usize],
        loss: &L,
        _reg: &R,
        lambda_n_l: f64,
        _rng: &mut Rng,
    ) -> Delta {
        let m = self.batch_rows;
        let d = self.dim;
        assert_eq!(state.dim(), d, "artifact dim mismatch");
        let gamma = loss.gamma();
        let s = if gamma > 0.0 {
            gamma * lambda_n_l / (gamma * lambda_n_l + batch.len() as f64 * self.radius)
        } else {
            lambda_n_l / (lambda_n_l + batch.len() as f64 * self.radius)
        };
        let spec = self.spec_for(loss);

        let w_f32: Vec<f32> = state.w.iter().map(|&x| x as f32).collect();
        let mut delta_v = vec![0.0f64; d];
        let mut x_buf = vec![0.0f32; m * d];
        let mut rt = self.runtime.lock().expect("runtime poisoned");

        for chunk in batch.chunks(m) {
            state.x.pack_rows_f32(chunk, &mut x_buf[..chunk.len() * d]);
            x_buf[chunk.len() * d..].fill(0.0);
            let mut y_buf = vec![0.0f32; m];
            let mut a_buf = vec![0.0f32; m];
            for (k, &i) in chunk.iter().enumerate() {
                y_buf[k] = state.y[i] as f32;
                a_buf[k] = state.alpha[i] as f32;
            }
            let s_buf = [s as f32];
            let outputs = rt
                .execute_f32(
                    &spec,
                    &[
                        (&x_buf, &[m, d]),
                        (&y_buf, &[m]),
                        (&a_buf, &[m]),
                        (&w_f32, &[d]),
                        (&s_buf, &[]),
                    ],
                )
                .expect("XLA local step failed");
            let (alpha_new, delta_v_raw) = (&outputs[0], &outputs[1]);
            for (k, &i) in chunk.iter().enumerate() {
                state.alpha[i] = alpha_new[k] as f64;
            }
            // α was overwritten from device floats; the running dual sum
            // cannot be maintained incrementally here — mark it stale so
            // the next telemetry read rebuilds it exactly (DESIGN.md §11).
            state.conj_sum = None;
            for j in 0..d {
                delta_v[j] += delta_v_raw[j] as f64 / lambda_n_l;
            }
        }
        // The artifact computes a dense Δv_raw, but a mini-batch's
        // support may still be sparse — emit whichever form is smaller
        // on the wire, matching the native solvers.
        let nnz = delta_v.iter().filter(|x| **x != 0.0).count();
        if should_densify(nnz, d) {
            Delta::Dense(delta_v)
        } else {
            Delta::Sparse(SparseDelta::from_dense(&delta_v))
        }
    }
}

// No unit tests here: exercising this path needs built artifacts, which
// `make artifacts` produces at build time. The cross-checks against the
// native `TheoremStep` live in `rust/tests/xla_runtime.rs` and skip with
// a notice when `artifacts/` is absent.
