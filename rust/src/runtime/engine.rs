//! The unified round engine: one shared driver loop for every
//! round-structured method in the crate.
//!
//! The paper's three algorithms — DADM (Algorithm 2), Acc-DADM
//! (Algorithm 3) and the OWL-QN baseline of Figures 6–7 — share one
//! skeleton: *local step, aggregate, global step, broadcast, gap/trace
//! bookkeeping*. CoCoA+-style frameworks get their generality from
//! separating the outer driver from the local subproblem; this module is
//! that separation as a real abstraction. A [`RoundAlgorithm`] supplies
//! the per-round work and the objective hooks; the [`Driver`] owns
//! everything every method used to reimplement:
//!
//! * the stopping policy on the **normalized** duality gap `(P − D)/n`
//!   (overridable — the primal-only OWL-QN stops on its own criteria);
//! * the `gap_every` instrumentation cadence ([`GapCadence`]), including
//!   algorithm-driven cadences (Acc-DADM records on its *per-stage*
//!   schedule, not a global one);
//! * [`Trace`]/[`RoundRecord`] emission with modeled compute/comm
//!   accounting and real wall-clock;
//! * periodic [`Checkpoint`] snapshots through the
//!   [`RoundAlgorithm::snapshot`] hook ([`CheckpointPolicy`]).
//!
//! The coordinators implement `RoundAlgorithm` and keep thin
//! `solve(eps, max_rounds)` wrappers; the CLI and the experiment harness
//! construct a boxed algorithm per method and run this one loop.

use crate::coordinator::Checkpoint;
use crate::metrics::{RoundRecord, StepStats, StragglerSummary, Trace};
use std::collections::VecDeque;
use std::path::PathBuf;
use std::time::Instant;

/// Result of a [`Driver::solve`] run (uniform across methods).
#[derive(Clone, Debug)]
pub struct SolveReport {
    /// Final primal iterate.
    pub w: Vec<f64>,
    /// Final primal objective.
    pub primal: f64,
    /// Final dual objective.
    pub dual: f64,
    /// Communication rounds used.
    pub rounds: usize,
    /// Passes over the data.
    pub passes: f64,
    /// Whether the gap target was reached.
    pub converged: bool,
    /// Worker resurrections consumed over the whole solve (sum of
    /// [`RoundOutcome::retried`]; nonzero only when the fault-tolerant
    /// TCP backend re-admitted replacement workers mid-solve).
    pub retries: usize,
    /// Straggler roll-up over the recorded rounds (DESIGN.md §16):
    /// imbalance ratios and the total seconds the cluster idled behind
    /// its slowest machine. Zeros for algorithms without machine-leg
    /// timing.
    pub stragglers: StragglerSummary,
    /// Full per-round trace.
    pub trace: Trace,
}

impl SolveReport {
    /// Final normalized duality gap `(P − D)/n`.
    pub fn normalized_gap(&self) -> f64 {
        (self.primal - self.dual) / self.trace.n as f64
    }
}

/// What the driver asks of one round — the fused gap-telemetry plumbing
/// of DESIGN.md §11. Algorithms without fused telemetry
/// ([`RoundAlgorithm::fused_gap`] = false) ignore it.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundRequest {
    /// Piggyback the primal loss sum at the *entering* iterate (the
    /// previous round's synced state) in this round's fused leg and
    /// return the previous round's exact objectives in
    /// [`RoundOutcome::entering_objectives`].
    pub eval_entering_primal: bool,
    /// Piggyback the post-step dual conjugate sum in this round's fused
    /// leg (needed by the *next* round's entering record, or by a direct
    /// conj read).
    pub want_exit_conj: bool,
}

/// What one [`RoundAlgorithm::round`] reports back to the driver.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundOutcome {
    /// Under [`GapCadence::AlgorithmDriven`]: this round ends an
    /// algorithm-internal cadence window, record the gap now.
    pub record_due: bool,
    /// The algorithm has terminated on its own criteria (e.g. OWL-QN
    /// tolerance or a failed line search); the driver records a final
    /// trace entry and stops.
    pub finished: bool,
    /// Exact `(primal, dual)` of the **entering** state — the previous
    /// round's record, completed by this round's piggybacked telemetry.
    /// `Some` iff [`RoundRequest::eval_entering_primal`] asked for it
    /// and the algorithm supports fused telemetry.
    pub entering_objectives: Option<(f64, f64)>,
    /// Worker resurrections consumed while completing this round
    /// (fault-tolerant TCP backend, DESIGN.md §14). The driver sums
    /// these into [`SolveReport::retries`] — the telemetry hook that
    /// lets a caller see a solve survived worker death without parsing
    /// logs. Always `0` on the in-process backends.
    pub retried: usize,
}

/// Context handed to [`RoundAlgorithm::on_record`] after every trace
/// record (including the initial one) — the place for stage machinery
/// like Acc-DADM's prox-center updates.
#[derive(Clone, Copy, Debug)]
pub struct RecordCtx {
    /// True for the pre-loop record of the starting state.
    pub initial: bool,
    /// The (unnormalized) gap `P − D` just recorded.
    pub gap: f64,
    /// Whether the driver's stopping rule fired on this record.
    pub converged: bool,
    /// Whether the round budget is exhausted.
    pub at_round_cap: bool,
}

/// When the driver evaluates the objectives and appends to the trace.
///
/// Gap evaluation is instrumentation — excluded from modeled
/// compute/comm time — but it is a full pass over the data, so the
/// cadence matters at small sampling fractions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GapCadence {
    /// Record every `k`-th round (`k ≥ 1`); the final round always
    /// records.
    EveryRounds(usize),
    /// Record when [`RoundOutcome::record_due`] says so (Acc-DADM's
    /// per-stage schedule).
    AlgorithmDriven,
}

/// Periodic solver-state snapshots (see [`Checkpoint`]).
#[derive(Clone, Debug)]
pub struct CheckpointPolicy {
    /// Where to write the snapshot (overwritten in place each time).
    pub path: PathBuf,
    /// Snapshot every `every` rounds.
    pub every: usize,
}

/// One round-structured optimization method, as seen by the [`Driver`].
///
/// Implementations keep all their per-round state; the driver only ever
/// asks for one more round, the current objectives, and the cumulative
/// accounting. Object-safe so launchers can dispatch on a
/// `Box<dyn RoundAlgorithm>`.
pub trait RoundAlgorithm {
    /// Problem size `n` (trace normalization).
    fn n(&self) -> usize;

    /// One-time setup before the loop (initial broadcast/oracle call).
    fn prepare(&mut self) {}

    /// Run one communication round. `req` carries the driver's fused
    /// gap-telemetry requests (DESIGN.md §11); algorithms whose
    /// [`RoundAlgorithm::fused_gap`] is false may ignore it.
    fn round(&mut self, req: RoundRequest) -> RoundOutcome;

    /// Whether this algorithm supports double-buffered rounds via
    /// [`RoundAlgorithm::round_issue`]/[`RoundAlgorithm::round_complete`]
    /// (DESIGN.md §13). When true — and the cadence supports the fused
    /// lagged protocol — the driver runs the two-slot pipelined loop,
    /// keeping one round in flight while the previous one completes.
    fn overlap_capable(&self) -> bool {
        false
    }

    /// Issue one round's dispatch without consuming its results (the
    /// first half of [`RoundAlgorithm::round`] for overlap-capable
    /// algorithms). The default is a no-op: sequential algorithms do all
    /// their work in [`RoundAlgorithm::round_complete`].
    fn round_issue(&mut self, _req: &RoundRequest) {}

    /// Complete the **oldest** issued round and report its outcome. The
    /// default runs a full round, so issue-then-complete at pipeline
    /// depth one is exactly the sequential loop.
    fn round_complete(&mut self, req: RoundRequest) -> RoundOutcome {
        self.round(req)
    }

    /// Exact `(primal, dual)` objectives at the current state
    /// (instrumentation; one evaluation pass over the data). Primal-only
    /// methods report their objective as the primal and `0.0` as the
    /// dual.
    fn objectives(&mut self) -> (f64, f64);

    /// Whether this algorithm completes [`RoundRequest`] telemetry —
    /// i.e. returns [`RoundOutcome::entering_objectives`] when asked.
    /// When true and the cadence is [`GapCadence::EveryRounds`], the
    /// driver switches to the single-barrier lagged record protocol
    /// (DESIGN.md §11): steady-state records ride the next round's leg,
    /// and only the initial and final records pay a dedicated (fused)
    /// evaluation. The stopping rule then fires one round late — the
    /// telemetry for round `t` completes during round `t+1` — so a
    /// converging solve runs exactly one more round than the eager
    /// protocol would (its trace still ends at the converged record).
    fn fused_gap(&self) -> bool {
        false
    }

    /// Cumulative communication rounds.
    fn rounds(&self) -> usize;

    /// Cumulative passes over the data.
    fn passes(&self) -> f64;

    /// Cumulative modeled `(compute, comm)` seconds.
    fn modeled_secs(&self) -> (f64, f64);

    /// The final primal iterate for the report.
    fn final_w(&mut self) -> Vec<f64>;

    /// Stopping rule given the latest normalized gap. Defaults to the
    /// dual methods' `(P − D)/n ≤ eps`; primal-only methods override to
    /// `false` and stop through [`RoundOutcome::finished`] instead.
    fn gap_converged(&self, normalized_gap: f64, eps: f64) -> bool {
        normalized_gap <= eps
    }

    /// Local-step timing spread of the **last completed** round
    /// (straggler telemetry, DESIGN.md §16). The driver stamps it onto
    /// the trace record describing the state that round produced — under
    /// the lagged protocol it is captured in the same entering snapshot
    /// as the modeled-time counters, so attribution is identical across
    /// the sequential, fused-lagged, and overlap loops. Wall-clock only;
    /// excluded from cross-backend parity. Default: unmeasured (zeros).
    fn step_stats(&self) -> StepStats {
        StepStats::default()
    }

    /// Hook called after every trace record — stage transitions
    /// (Acc-DADM) live here, not in a bespoke loop.
    fn on_record(&mut self, _ctx: &RecordCtx) {}

    /// Resumable snapshot of the solver state, if the method supports
    /// checkpointing (see [`CheckpointPolicy`]).
    fn snapshot(&self) -> Option<Checkpoint> {
        None
    }
}

/// The shared solve loop (see the module docs).
#[derive(Clone, Debug)]
pub struct Driver {
    /// Target normalized gap.
    pub eps: f64,
    /// Round budget *for this run*: the driver counts rounds it issues
    /// itself, independent of the algorithm's cumulative counter. A
    /// caller resuming from a checkpoint subtracts the restored rounds
    /// to enforce a total budget (as the CLI does for `--resume`).
    pub max_rounds: usize,
    /// Instrumentation cadence.
    pub cadence: GapCadence,
    /// Optional periodic checkpointing.
    pub checkpoint: Option<CheckpointPolicy>,
}

impl Driver {
    /// Driver with the default cadence (record every round) and no
    /// checkpointing.
    pub fn new(eps: f64, max_rounds: usize) -> Self {
        Driver {
            eps,
            max_rounds,
            cadence: GapCadence::EveryRounds(1),
            checkpoint: None,
        }
    }

    /// Set the cadence.
    pub fn with_cadence(mut self, cadence: GapCadence) -> Self {
        if let GapCadence::EveryRounds(k) = cadence {
            assert!(k >= 1, "gap_every must be ≥ 1, got {k}");
        }
        self.cadence = cadence;
        self
    }

    /// Record every `k`-th round.
    pub fn with_gap_every(self, k: usize) -> Self {
        self.with_cadence(GapCadence::EveryRounds(k))
    }

    /// Snapshot to `path` every `every` rounds (methods whose
    /// [`RoundAlgorithm::snapshot`] returns `None` skip silently).
    pub fn with_checkpoint(mut self, path: PathBuf, every: usize) -> Self {
        assert!(every >= 1, "checkpoint cadence must be ≥ 1");
        self.checkpoint = Some(CheckpointPolicy { path, every });
        self
    }

    fn record(algo: &mut dyn RoundAlgorithm, trace: &mut Trace, wall_start: Instant) -> f64 {
        let (primal, dual) = algo.objectives();
        let (compute_secs, comm_secs) = algo.modeled_secs();
        trace.push(RoundRecord {
            round: algo.rounds(),
            passes: algo.passes(),
            primal,
            dual,
            compute_secs,
            comm_secs,
            wall_secs: wall_start.elapsed().as_secs_f64(),
            steps: algo.step_stats(),
        });
        primal - dual
    }

    /// Run `algo` until the stopping rule fires, the algorithm finishes,
    /// or the round budget is exhausted.
    ///
    /// With a fused-gap algorithm ([`RoundAlgorithm::fused_gap`]) under
    /// an [`GapCadence::EveryRounds`] cadence, the loop runs the
    /// single-barrier lagged protocol of DESIGN.md §11: the record for
    /// round `t` is completed by round `t+1`'s piggybacked telemetry
    /// (bit-identical values to an eager evaluation at round `t`), and
    /// only the initial record and the final close-the-books record pay
    /// a dedicated fused evaluation barrier. Stopping consequently
    /// trails by one round; when it fires, the trace already ends at the
    /// converged record and no further evaluation is issued.
    pub fn solve(&self, algo: &mut dyn RoundAlgorithm) -> SolveReport {
        let wall_start = Instant::now();
        let n = algo.n() as f64;
        let mut trace = Trace::new(algo.n());
        algo.prepare();

        let gap = Self::record(algo, &mut trace, wall_start);
        let mut converged = algo.gap_converged(gap / n, self.eps);
        algo.on_record(&RecordCtx {
            initial: true,
            gap,
            converged,
            at_round_cap: self.max_rounds == 0,
        });

        let fused_k = match self.cadence {
            GapCadence::EveryRounds(k) if algo.fused_gap() => Some(k),
            _ => None,
        };

        let mut rounds_done = 0usize;
        let mut finished = false;
        let mut lag_converged = false;
        let mut retries = 0usize;
        // Double-buffered rounds (DESIGN.md §13): when the algorithm can
        // split a round into issue/complete halves and the cadence runs
        // the fused lagged protocol, keep up to two rounds in flight —
        // round `t+1`'s dispatch is issued before round `t`'s
        // reduce/global step completes. Requests derive from the *issue*
        // index, so the flag schedule matches the sequential loop
        // exactly; completes run FIFO, so record and convergence
        // bookkeeping are unchanged. Once a lagged record converges (or
        // the algorithm finishes) issuing stops and the pipeline drains,
        // overrunning by at most the one extra in-flight round.
        let overlap_k = fused_k.filter(|_| algo.overlap_capable());
        if let Some(k) = overlap_k {
            let mut inflight: VecDeque<RoundRequest> = VecDeque::new();
            let mut issued = 0usize;
            while (!converged && !finished && issued < self.max_rounds) || !inflight.is_empty() {
                while !converged && !finished && issued < self.max_rounds && inflight.len() < 2 {
                    let req = RoundRequest {
                        eval_entering_primal: issued >= 1 && issued % k == 0,
                        want_exit_conj: (issued + 1) % k == 0,
                    };
                    algo.round_issue(&req);
                    inflight.push_back(req);
                    issued += 1;
                }
                let req = inflight.pop_front().expect("overlap loop: pipeline empty");
                // Accounting snapshot of the entering state: counters
                // advance in the complete half, so this is still the
                // state after `rounds_done` completed rounds (and
                // `step_stats` still describes the round that produced
                // that state).
                let entering = (
                    algo.rounds(),
                    algo.passes(),
                    algo.modeled_secs(),
                    algo.step_stats(),
                );
                let out = algo.round_complete(req);
                rounds_done += 1;
                retries += out.retried;
                finished = finished || out.finished;
                if let Some((primal, dual)) = out.entering_objectives {
                    // Records completing while the pipeline drains past a
                    // converged record are dropped, so the trace still
                    // ends at the converged record like the sequential
                    // protocol's.
                    if !converged {
                        let (compute_secs, comm_secs) = entering.2;
                        trace.push(RoundRecord {
                            round: entering.0,
                            passes: entering.1,
                            primal,
                            dual,
                            compute_secs,
                            comm_secs,
                            wall_secs: wall_start.elapsed().as_secs_f64(),
                            steps: entering.3,
                        });
                        let gap = primal - dual;
                        converged = algo.gap_converged(gap / n, self.eps);
                        lag_converged = converged;
                        algo.on_record(&RecordCtx {
                            initial: false,
                            gap,
                            converged,
                            at_round_cap: false,
                        });
                    }
                }
                // No checkpoint hook here: overlap-capable algorithms
                // decline snapshots while rounds are in flight.
            }
        }
        while overlap_k.is_none() && !converged && !finished && rounds_done < self.max_rounds {
            let req = match fused_k {
                // Entering state = `rounds_done` completed rounds; its
                // record is due when it sits on the cadence (round 0 was
                // recorded eagerly above). The post-step conjugate sum is
                // requested whenever *this* round will need a record.
                Some(k) => RoundRequest {
                    eval_entering_primal: rounds_done >= 1 && rounds_done % k == 0,
                    want_exit_conj: (rounds_done + 1) % k == 0,
                },
                None => RoundRequest::default(),
            };
            // Accounting snapshot of the entering state, stamped onto the
            // lagged record (its primal/dual describe this state, not the
            // round that completed them; likewise its step stats).
            let entering = (
                algo.rounds(),
                algo.passes(),
                algo.modeled_secs(),
                algo.step_stats(),
            );
            let out = algo.round(req);
            rounds_done += 1;
            retries += out.retried;
            finished = out.finished;
            if let Some((primal, dual)) = out.entering_objectives {
                let (compute_secs, comm_secs) = entering.2;
                trace.push(RoundRecord {
                    round: entering.0,
                    passes: entering.1,
                    primal,
                    dual,
                    compute_secs,
                    comm_secs,
                    wall_secs: wall_start.elapsed().as_secs_f64(),
                    steps: entering.3,
                });
                let gap = primal - dual;
                converged = algo.gap_converged(gap / n, self.eps);
                lag_converged = converged;
                algo.on_record(&RecordCtx {
                    initial: false,
                    gap,
                    converged,
                    at_round_cap: false,
                });
            }
            if fused_k.is_none() {
                let due = match self.cadence {
                    GapCadence::EveryRounds(k) => rounds_done % k == 0,
                    GapCadence::AlgorithmDriven => out.record_due,
                };
                if due || rounds_done == self.max_rounds || finished {
                    let gap = Self::record(algo, &mut trace, wall_start);
                    converged = algo.gap_converged(gap / n, self.eps);
                    algo.on_record(&RecordCtx {
                        initial: false,
                        gap,
                        converged,
                        at_round_cap: rounds_done >= self.max_rounds,
                    });
                }
            }
            if let Some(ck) = &self.checkpoint {
                if rounds_done % ck.every == 0 {
                    if let Some(snapshot) = algo.snapshot() {
                        if let Err(e) = snapshot.save_file(&ck.path) {
                            eprintln!(
                                "warning: checkpoint to {} failed: {e:#}",
                                ck.path.display()
                            );
                        }
                    }
                }
            }
        }

        // Close the books under the lagged protocol: the newest state's
        // record was never completed by a following round (round cap or
        // algorithm finish) — evaluate it now with one fused barrier.
        // Skipped when lagged stopping fired: the trace already ends at
        // the converged record, exactly like the eager protocol's.
        if fused_k.is_some() && rounds_done > 0 && !lag_converged {
            let gap = Self::record(algo, &mut trace, wall_start);
            converged = converged || algo.gap_converged(gap / n, self.eps);
            algo.on_record(&RecordCtx {
                initial: false,
                gap,
                converged,
                at_round_cap: rounds_done >= self.max_rounds,
            });
        }

        SolveReport {
            w: algo.final_w(),
            primal: trace.last().map(|r| r.primal).unwrap_or(f64::NAN),
            dual: trace.last().map(|r| r.dual).unwrap_or(f64::NAN),
            rounds: algo.rounds(),
            passes: algo.passes(),
            converged,
            retries,
            stragglers: trace.straggler_summary(),
            trace,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy algorithm: the "gap" shrinks by half each round.
    struct Halving {
        gap: f64,
        rounds: usize,
        records_at: Vec<usize>,
        finish_after: Option<usize>,
    }

    impl Halving {
        fn new(gap: f64) -> Self {
            Halving {
                gap,
                rounds: 0,
                records_at: vec![],
                finish_after: None,
            }
        }
    }

    impl RoundAlgorithm for Halving {
        fn n(&self) -> usize {
            1
        }

        fn round(&mut self, _req: RoundRequest) -> RoundOutcome {
            self.gap *= 0.5;
            self.rounds += 1;
            RoundOutcome {
                record_due: self.rounds % 3 == 0,
                finished: self.finish_after == Some(self.rounds),
                ..RoundOutcome::default()
            }
        }

        fn objectives(&mut self) -> (f64, f64) {
            self.records_at.push(self.rounds);
            (self.gap, 0.0)
        }

        fn rounds(&self) -> usize {
            self.rounds
        }

        fn passes(&self) -> f64 {
            self.rounds as f64
        }

        fn modeled_secs(&self) -> (f64, f64) {
            (0.0, 0.0)
        }

        fn final_w(&mut self) -> Vec<f64> {
            vec![self.gap]
        }
    }

    #[test]
    fn stops_on_normalized_gap() {
        let mut algo = Halving::new(1.0);
        let report = Driver::new(0.1, 100).solve(&mut algo);
        assert!(report.converged);
        // 1 → .5 → .25 → .125 → .0625 ≤ .1 after 4 rounds.
        assert_eq!(report.rounds, 4);
        assert_eq!(report.trace.rounds.len(), 5); // initial + 4
    }

    #[test]
    fn round_cap_forces_final_record() {
        let mut algo = Halving::new(1.0);
        let report = Driver::new(0.0, 7).with_gap_every(3).solve(&mut algo);
        assert!(!report.converged);
        assert_eq!(algo.records_at, vec![0, 3, 6, 7]);
        assert_eq!(report.rounds, 7);
    }

    #[test]
    fn algorithm_driven_cadence() {
        let mut algo = Halving::new(1.0);
        let report = Driver::new(0.0, 8)
            .with_cadence(GapCadence::AlgorithmDriven)
            .solve(&mut algo);
        // record_due fires every 3rd round; the cap forces round 8.
        assert_eq!(algo.records_at, vec![0, 3, 6, 8]);
        assert!(!report.converged);
    }

    #[test]
    fn finished_stops_and_records() {
        let mut algo = Halving::new(1.0);
        algo.finish_after = Some(2);
        let report = Driver::new(0.0, 100).with_gap_every(10).solve(&mut algo);
        assert!(!report.converged);
        assert_eq!(report.rounds, 2);
        // Initial record plus the forced final one at the finish.
        assert_eq!(algo.records_at, vec![0, 2]);
    }

    #[test]
    fn zero_round_budget_reports_initial_state() {
        let mut algo = Halving::new(0.5);
        let report = Driver::new(1e-9, 0).solve(&mut algo);
        assert!(!report.converged);
        assert_eq!(report.rounds, 0);
        assert_eq!(report.trace.rounds.len(), 1);
        assert_eq!(report.primal, 0.5);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_gap_cadence() {
        let _ = Driver::new(0.1, 10).with_gap_every(0);
    }

    /// Toy fused-telemetry algorithm: the same halving gap, but it
    /// completes entering objectives on request like Dadm's piggyback
    /// protocol, and counts dedicated `objectives()` barriers.
    struct FusedHalving {
        gap: f64,
        conj_ready: bool,
        rounds: usize,
        evals: usize,
    }

    impl FusedHalving {
        fn new() -> Self {
            FusedHalving {
                gap: 1.0,
                conj_ready: false,
                rounds: 0,
                evals: 0,
            }
        }
    }

    impl RoundAlgorithm for FusedHalving {
        fn n(&self) -> usize {
            1
        }
        fn fused_gap(&self) -> bool {
            true
        }
        fn round(&mut self, req: RoundRequest) -> RoundOutcome {
            let entering = req.eval_entering_primal.then(|| {
                assert!(
                    self.conj_ready,
                    "protocol: the entering conj must have been requested last round"
                );
                (self.gap, 0.0)
            });
            self.gap *= 0.5;
            self.rounds += 1;
            self.conj_ready = req.want_exit_conj;
            RoundOutcome {
                entering_objectives: entering,
                ..RoundOutcome::default()
            }
        }
        fn objectives(&mut self) -> (f64, f64) {
            self.evals += 1;
            self.conj_ready = true;
            (self.gap, 0.0)
        }
        fn rounds(&self) -> usize {
            self.rounds
        }
        fn passes(&self) -> f64 {
            self.rounds as f64
        }
        fn modeled_secs(&self) -> (f64, f64) {
            (0.0, 0.0)
        }
        fn final_w(&mut self) -> Vec<f64> {
            vec![self.gap]
        }
    }

    #[test]
    fn fused_capped_run_records_like_eager_with_two_eval_barriers() {
        // Capped fused run: same record set/values as the eager loop —
        // records at every round, gap 0.5^r — but only the initial and
        // closing records pay a dedicated evaluation.
        let mut algo = FusedHalving::new();
        let report = Driver::new(0.0, 6).solve(&mut algo);
        assert!(!report.converged);
        assert_eq!(report.rounds, 6);
        let recorded: Vec<(usize, f64)> =
            report.trace.rounds.iter().map(|r| (r.round, r.primal)).collect();
        let want: Vec<(usize, f64)> = (0..=6).map(|r| (r, 0.5f64.powi(r as i32))).collect();
        assert_eq!(recorded, want);
        assert_eq!(algo.evals, 2, "initial + closing evaluation only");
    }

    #[test]
    fn fused_cadence_skips_rounds_and_closes_at_cap() {
        let mut algo = FusedHalving::new();
        let report = Driver::new(0.0, 8).with_gap_every(3).solve(&mut algo);
        let recorded: Vec<usize> = report.trace.rounds.iter().map(|r| r.round).collect();
        // Same set as the eager cadence: 0, 3, 6, forced cap 8.
        assert_eq!(recorded, vec![0, 3, 6, 8]);
        assert_eq!(algo.evals, 2);
    }

    #[test]
    fn fused_lagged_stop_overruns_one_round_and_skips_closing_eval() {
        // Gap 0.5^r ≤ 0.1 first at record 4 — which round 5's piggyback
        // completes: the solve runs 5 rounds, the trace still ends at
        // the converged record 4 (eager semantics), and no closing
        // evaluation is issued.
        let mut algo = FusedHalving::new();
        let report = Driver::new(0.1, 100).solve(&mut algo);
        assert!(report.converged);
        assert_eq!(report.rounds, 5);
        let last = report.trace.last().unwrap();
        assert_eq!(last.round, 4);
        assert!(last.primal <= 0.1);
        assert_eq!(algo.evals, 1, "initial evaluation only");
    }

    /// Overlap-capable fused toy: queues issued requests and completes
    /// them FIFO against the inner [`FusedHalving`], recording the
    /// deepest pipeline the driver built.
    struct OverlapHalving {
        inner: FusedHalving,
        queue: VecDeque<RoundRequest>,
        max_depth: usize,
    }

    impl RoundAlgorithm for OverlapHalving {
        fn n(&self) -> usize {
            1
        }
        fn fused_gap(&self) -> bool {
            true
        }
        fn overlap_capable(&self) -> bool {
            true
        }
        fn round_issue(&mut self, req: &RoundRequest) {
            self.queue.push_back(*req);
            self.max_depth = self.max_depth.max(self.queue.len());
        }
        fn round_complete(&mut self, req: RoundRequest) -> RoundOutcome {
            let issued = self.queue.pop_front().expect("complete without issue");
            assert_eq!(issued, req, "driver must complete rounds in issue order");
            self.inner.round(issued)
        }
        fn round(&mut self, req: RoundRequest) -> RoundOutcome {
            self.inner.round(req)
        }
        fn objectives(&mut self) -> (f64, f64) {
            self.inner.objectives()
        }
        fn rounds(&self) -> usize {
            self.inner.rounds
        }
        fn passes(&self) -> f64 {
            self.inner.rounds as f64
        }
        fn modeled_secs(&self) -> (f64, f64) {
            (0.0, 0.0)
        }
        fn final_w(&mut self) -> Vec<f64> {
            vec![self.inner.gap]
        }
    }

    #[test]
    fn overlap_loop_pipelines_two_rounds_and_matches_sequential_records() {
        // Same record set/values as the sequential fused loop (FIFO
        // completes keep the telemetry schedule identical), but the
        // driver genuinely double-buffers: two rounds in flight.
        let mut algo = OverlapHalving {
            inner: FusedHalving::new(),
            queue: VecDeque::new(),
            max_depth: 0,
        };
        let report = Driver::new(0.0, 6).solve(&mut algo);
        assert_eq!(algo.max_depth, 2, "driver never double-buffered");
        assert!(algo.queue.is_empty(), "pipeline must drain");
        assert!(!report.converged);
        assert_eq!(report.rounds, 6);
        let recorded: Vec<(usize, f64)> =
            report.trace.rounds.iter().map(|r| (r.round, r.primal)).collect();
        let want: Vec<(usize, f64)> = (0..=6).map(|r| (r, 0.5f64.powi(r as i32))).collect();
        assert_eq!(recorded, want);
        assert_eq!(algo.inner.evals, 2, "initial + closing evaluation only");
    }

    #[test]
    fn overlap_lagged_stop_drains_pipeline_and_ends_at_converged_record() {
        // Gap 0.5^r ≤ 0.1 first at record 4, completed by round 5; the
        // extra in-flight round 6 drains (its record is dropped), so the
        // trace still ends at the converged record with no closing eval.
        let mut algo = OverlapHalving {
            inner: FusedHalving::new(),
            queue: VecDeque::new(),
            max_depth: 0,
        };
        let report = Driver::new(0.1, 100).solve(&mut algo);
        assert!(report.converged);
        assert_eq!(report.rounds, 6, "one-round overrun beyond the lagged stop");
        assert!(algo.queue.is_empty(), "pipeline must drain");
        let last = report.trace.last().unwrap();
        assert_eq!(last.round, 4);
        assert!(last.primal <= 0.1);
        assert_eq!(algo.inner.evals, 1, "initial evaluation only");
    }

    #[test]
    fn snapshot_hook_called_on_cadence() {
        struct Snapping(Halving);
        impl RoundAlgorithm for Snapping {
            fn n(&self) -> usize {
                1
            }
            fn round(&mut self, req: RoundRequest) -> RoundOutcome {
                self.0.round(req)
            }
            fn objectives(&mut self) -> (f64, f64) {
                self.0.objectives()
            }
            fn rounds(&self) -> usize {
                self.0.rounds
            }
            fn passes(&self) -> f64 {
                self.0.rounds as f64
            }
            fn modeled_secs(&self) -> (f64, f64) {
                (0.0, 0.0)
            }
            fn final_w(&mut self) -> Vec<f64> {
                vec![]
            }
            fn snapshot(&self) -> Option<Checkpoint> {
                Some(Checkpoint {
                    lambda: 1.0,
                    rounds: self.0.rounds,
                    passes: self.0.rounds as f64,
                    v: vec![0.0],
                    alpha: vec![vec![0.0]],
                    rng: None,
                    conj: None,
                    residual: None,
                    v_image: None,
                })
            }
        }
        let dir = std::env::temp_dir().join("dadm-engine-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.ck");
        let mut algo = Snapping(Halving::new(1.0));
        let _ = Driver::new(0.0, 5)
            .with_checkpoint(path.clone(), 2)
            .solve(&mut algo);
        let ck = Checkpoint::load_file(&path).unwrap();
        // Last snapshot at round 4 (cadence 2, budget 5).
        assert_eq!(ck.rounds, 4);
        std::fs::remove_file(&path).ok();
    }
}
