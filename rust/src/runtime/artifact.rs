//! Artifact discovery + PJRT compilation cache.

use anyhow::{Context, Result};
// dadm-lint: allow(hash-iter) — compile cache is keyed lookup/insert only, never iterated
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Identity of one AOT artifact: the local-step computation for a given
/// loss at a fixed `(batch, dim)` shape (XLA programs are shape-static).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ArtifactSpec {
    /// Loss name as used by `python/compile/aot.py` (e.g. `smooth_hinge`).
    pub loss: String,
    /// Mini-batch rows `M` baked into the artifact.
    pub batch: usize,
    /// Feature dimension `d` baked into the artifact.
    pub dim: usize,
}

impl ArtifactSpec {
    /// Conventional file name: `local_step_<loss>_<M>x<d>.hlo.txt`.
    pub fn file_name(&self) -> String {
        format!("local_step_{}_{}x{}.hlo.txt", self.loss, self.batch, self.dim)
    }
}

/// Resolve the artifacts directory: `$DADM_ARTIFACTS` or `./artifacts`.
pub fn artifact_path() -> PathBuf {
    std::env::var_os("DADM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// A PJRT CPU client plus a compile cache of loaded artifacts.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    // dadm-lint: allow(hash-iter) — keyed lookup/insert only, never iterated
    cache: HashMap<ArtifactSpec, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

// SAFETY: `PjRtClient`/`PjRtLoadedExecutable` hold `Rc`s and raw PJRT
// pointers, so they are not auto-`Send`. Every clone of those `Rc`s lives
// inside this one struct (the client and its compiled executables), and
// `XlaLocalStep` only ever accesses the runtime through a `Mutex`, so the
// whole object graph moves between threads atomically with exclusive
// access. The PJRT CPU client itself is thread-safe per the PJRT C API
// contract.
unsafe impl Send for XlaRuntime {}

impl std::fmt::Debug for XlaRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaRuntime")
            .field("platform", &self.client.platform_name())
            .field("cached", &self.cache.len())
            .field("dir", &self.dir)
            .finish()
    }
}

impl XlaRuntime {
    /// Create a CPU PJRT client rooted at the default artifacts dir.
    pub fn cpu() -> Result<Self> {
        Self::with_dir(artifact_path())
    }

    /// Create with an explicit artifacts directory.
    pub fn with_dir(dir: PathBuf) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(XlaRuntime {
            client,
            // dadm-lint: allow(hash-iter) — keyed lookup/insert only, never iterated
            cache: HashMap::new(),
            dir,
        })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Whether the artifact file for `spec` exists on disk.
    pub fn available(&self, spec: &ArtifactSpec) -> bool {
        self.dir.join(spec.file_name()).exists()
    }

    /// Load + compile (cached) the artifact for `spec`.
    pub fn load(&mut self, spec: &ArtifactSpec) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(spec) {
            let path = self.dir.join(spec.file_name());
            let exe = compile_file(&self.client, &path)
                .with_context(|| format!("load artifact {}", path.display()))?;
            self.cache.insert(spec.clone(), exe);
        }
        Ok(&self.cache[spec])
    }

    /// Execute a loaded artifact on f32 input buffers, returning the
    /// flattened f32 outputs of the (tupled) result.
    pub fn execute_f32(
        &mut self,
        spec: &ArtifactSpec,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                let lit = xla::Literal::vec1(data);
                let dims: Vec<i64> = shape.iter().map(|&s| s as i64).collect();
                lit.reshape(&dims).context("reshape input literal")
            })
            .collect::<Result<_>>()?;
        let exe = self.load(spec)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .context("PJRT execute")?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        // aot.py lowers with return_tuple=True: decompose the tuple.
        let mut result = result;
        let elements = result.decompose_tuple().context("decompose result tuple")?;
        elements
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().context("read f32 output"))
            .collect()
    }
}

fn compile_file(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    anyhow::ensure!(
        path.exists(),
        "artifact {} not found — run `make artifacts` first",
        path.display()
    );
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 artifact path")?,
    )
    .context("parse HLO text")?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).context("PJRT compile")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_file_name_convention() {
        let s = ArtifactSpec {
            loss: "smooth_hinge".into(),
            batch: 128,
            dim: 256,
        };
        assert_eq!(s.file_name(), "local_step_smooth_hinge_128x256.hlo.txt");
    }

    #[test]
    fn artifact_path_env_override() {
        // Note: tests run in parallel; use a unique var through the public
        // default path instead of mutating the environment.
        let p = artifact_path();
        assert!(p.ends_with("artifacts") || p.is_absolute());
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let mut rt = match XlaRuntime::with_dir(PathBuf::from("/nonexistent-dir")) {
            Ok(rt) => rt,
            Err(_) => return, // PJRT unavailable in this environment
        };
        let spec = ArtifactSpec {
            loss: "nope".into(),
            batch: 1,
            dim: 1,
        };
        assert!(!rt.available(&spec));
        let err = match rt.load(&spec) {
            Err(e) => e,
            Ok(_) => panic!("load of missing artifact succeeded"),
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
