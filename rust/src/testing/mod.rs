//! In-tree testing infrastructure.
//!
//! `proptest` is not available in the offline build environment, so
//! [`prop`] provides a small deterministic property-based testing harness
//! with the same workflow: generate many random cases from a seeded RNG,
//! run a check, and on failure report the case index + seed so the exact
//! failing input can be replayed.

pub mod prop;

pub use prop::{for_each_case, Gen};
