//! A minimal deterministic property-based testing harness.
//!
//! Usage (doctests run as unit tests in this crate — the doctest harness
//! cannot link the PJRT shared library, so this block is `text`):
//!
//! ```text
//! use dadm::testing::prop::{for_each_case, Gen};
//! for_each_case(0xC0FFEE, 100, |g: &mut Gen| {
//!     let x = g.f64_in(-10.0, 10.0);
//!     assert!(x.abs() <= 10.0);
//! });
//! ```
//!
//! On panic the harness re-raises with the case number and seed embedded
//! in the message so a failing case can be replayed with
//! [`replay_case`].

use crate::utils::Rng;

/// Random-input generator handed to each property case.
pub struct Gen {
    rng: Rng,
}

impl Gen {
    /// Raw access to the underlying RNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// Log-uniform positive `f64` in `[lo, hi)` (both must be > 0).
    /// Useful for regularization parameters spanning decades.
    pub fn f64_log_in(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (self.rng.uniform(lo.ln(), hi.ln())).exp()
    }

    /// Uniform `usize` in `[lo, hi)`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.rng.below(hi - lo)
    }

    /// Vector of length `n` with entries in `[lo, hi)`.
    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Vector of length `n` of standard normals.
    pub fn vec_normal(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.rng.normal()).collect()
    }

    /// A ±1 label.
    pub fn label(&mut self) -> f64 {
        if self.rng.bernoulli(0.5) {
            1.0
        } else {
            -1.0
        }
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// Bernoulli draw.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bernoulli(p)
    }

    /// `n` uniformly random bytes (wire-protocol fuzzing).
    pub fn bytes(&mut self, n: usize) -> Vec<u8> {
        (0..n).map(|_| self.rng.next_u64() as u8).collect()
    }
}

fn case_seed(seed: u64, case: usize) -> u64 {
    seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Run `cases` independent random cases of a property.
///
/// Each case gets its own RNG stream derived from `(seed, case_index)` so
/// failures are replayable in isolation.
pub fn for_each_case<F: FnMut(&mut Gen)>(seed: u64, cases: usize, mut prop: F) {
    for case in 0..cases {
        let mut g = Gen {
            rng: Rng::new(case_seed(seed, case)),
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property failed at case {case}/{cases} (seed={seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing case from the `(seed, case)` pair reported by
/// [`for_each_case`].
pub fn replay_case<F: FnOnce(&mut Gen)>(seed: u64, case: usize, prop: F) {
    let mut g = Gen {
        rng: Rng::new(case_seed(seed, case)),
    };
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_requested_cases() {
        let mut count = 0;
        for_each_case(1, 57, |_| count += 1);
        assert_eq!(count, 57);
    }

    #[test]
    fn cases_are_deterministic_and_distinct() {
        let mut first: Vec<f64> = vec![];
        for_each_case(2, 10, |g| first.push(g.f64_in(0.0, 1.0)));
        let mut second: Vec<f64> = vec![];
        for_each_case(2, 10, |g| second.push(g.f64_in(0.0, 1.0)));
        assert_eq!(first, second);
        let distinct: std::collections::HashSet<u64> =
            first.iter().map(|x| x.to_bits()).collect();
        assert!(distinct.len() > 8);
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn failure_reports_case() {
        for_each_case(3, 100, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!(x < 0.95, "x too large: {x}");
        });
    }

    #[test]
    fn replay_reproduces_case_stream() {
        let mut captured = None;
        for_each_case(4, 5, |g| {
            if captured.is_none() {
                captured = Some(g.f64_in(0.0, 1.0));
            }
        });
        replay_case(4, 0, |g| {
            assert_eq!(Some(g.f64_in(0.0, 1.0)), captured);
        });
    }

    #[test]
    fn log_uniform_spans_decades() {
        let mut lo_seen = false;
        let mut hi_seen = false;
        for_each_case(5, 200, |g| {
            let x = g.f64_log_in(1e-8, 1e-2);
            assert!((1e-8..1e-2).contains(&x));
            if x < 1e-6 {
                lo_seen = true;
            }
            if x > 1e-4 {
                hi_seen = true;
            }
        });
        assert!(lo_seen && hi_seen);
    }
}
