//! ASCII line plots for convergence curves.
//!
//! The paper's Figures 1–5 and 12–13 are log-scale duality-gap curves;
//! the benches render the same curves directly in the terminal (and the
//! CSVs remain available for external plotting). Multiple series share
//! one canvas with per-series glyphs.

/// One named series of `(x, y)` points.
#[derive(Clone, Debug)]
pub struct Series {
    /// Legend label.
    pub label: String,
    /// Points (x must be non-decreasing).
    pub points: Vec<(f64, f64)>,
}

/// Plot configuration.
#[derive(Clone, Debug)]
pub struct PlotSpec {
    /// Canvas width in characters (data area).
    pub width: usize,
    /// Canvas height in characters.
    pub height: usize,
    /// Log-scale the y axis.
    pub log_y: bool,
    /// Axis labels.
    pub x_label: String,
    /// Y-axis label.
    pub y_label: String,
}

impl Default for PlotSpec {
    fn default() -> Self {
        PlotSpec {
            width: 64,
            height: 16,
            log_y: true,
            x_label: "communications".into(),
            y_label: "normalized gap".into(),
        }
    }
}

const GLYPHS: [char; 6] = ['*', 'o', '+', 'x', '#', '@'];

/// Render series onto an ASCII canvas and return it as a string.
pub fn render(spec: &PlotSpec, series: &[Series]) -> String {
    let mut pts: Vec<(f64, f64)> = Vec::new();
    for s in series {
        for &(x, y) in &s.points {
            if y.is_finite() && (!spec.log_y || y > 0.0) {
                pts.push((x, y));
            }
        }
    }
    if pts.is_empty() {
        return "(no finite points to plot)\n".into();
    }
    let ymap = |y: f64| if spec.log_y { y.log10() } else { y };
    let (mut x_min, mut x_max) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y_min, mut y_max) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        x_min = x_min.min(x);
        x_max = x_max.max(x);
        y_min = y_min.min(ymap(y));
        y_max = y_max.max(ymap(y));
    }
    if (x_max - x_min).abs() < 1e-300 {
        x_max = x_min + 1.0;
    }
    if (y_max - y_min).abs() < 1e-300 {
        y_max = y_min + 1.0;
    }

    let (w, h) = (spec.width, spec.height);
    let mut canvas = vec![vec![' '; w]; h];
    for (si, s) in series.iter().enumerate() {
        let glyph = GLYPHS[si % GLYPHS.len()];
        for &(x, y) in &s.points {
            if !y.is_finite() || (spec.log_y && y <= 0.0) {
                continue;
            }
            let cx = ((x - x_min) / (x_max - x_min) * (w - 1) as f64).round() as usize;
            let cy = ((ymap(y) - y_min) / (y_max - y_min) * (h - 1) as f64).round() as usize;
            let row = h - 1 - cy.min(h - 1);
            canvas[row][cx.min(w - 1)] = glyph;
        }
    }

    let mut out = String::new();
    let y_hi = if spec.log_y {
        format!("1e{y_max:.1}")
    } else {
        format!("{y_max:.3}")
    };
    let y_lo = if spec.log_y {
        format!("1e{y_min:.1}")
    } else {
        format!("{y_min:.3}")
    };
    out.push_str(&format!("{} ({})\n", spec.y_label, y_hi));
    for row in &canvas {
        out.push_str("  |");
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str("  +");
    out.push_str(&"-".repeat(w));
    out.push('\n');
    out.push_str(&format!(
        "   {:<10}{:^width$}{:>10}  ({})\n",
        format!("{x_min:.0}"),
        &spec.x_label,
        format!("{x_max:.0}"),
        y_lo,
        width = w.saturating_sub(20),
    ));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("   {} {}\n", GLYPHS[si % GLYPHS.len()], s.label));
    }
    out
}

/// Convenience: gap-vs-communications series from a [`super::Trace`].
pub fn series_from_trace(label: &str, trace: &super::Trace) -> Series {
    let n = trace.n as f64;
    Series {
        label: label.to_string(),
        points: trace
            .rounds
            .iter()
            .map(|r| (r.round as f64, r.gap() / n))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> PlotSpec {
        PlotSpec {
            width: 20,
            height: 6,
            ..Default::default()
        }
    }

    #[test]
    fn renders_points_within_canvas() {
        let s = Series {
            label: "a".into(),
            points: (0..10).map(|i| (i as f64, 10f64.powi(-i))).collect(),
        };
        let out = render(&spec(), &[s]);
        assert!(out.contains('*'));
        assert!(out.contains("a"));
        // Every canvas row is prefixed and bounded.
        for line in out.lines().filter(|l| l.starts_with("  |")) {
            assert!(line.len() <= 3 + 20);
        }
    }

    #[test]
    fn multiple_series_get_distinct_glyphs() {
        let a = Series {
            label: "one".into(),
            points: vec![(0.0, 1.0), (1.0, 0.1)],
        };
        let b = Series {
            label: "two".into(),
            points: vec![(0.0, 0.5), (1.0, 0.05)],
        };
        let out = render(&spec(), &[a, b]);
        assert!(out.contains('*') && out.contains('o'));
    }

    #[test]
    fn ignores_nonpositive_on_log_scale() {
        let s = Series {
            label: "z".into(),
            points: vec![(0.0, 0.0), (1.0, -1.0)],
        };
        let out = render(&spec(), &[s]);
        assert!(out.contains("no finite points"));
    }

    #[test]
    fn linear_scale_handles_zero() {
        let mut sp = spec();
        sp.log_y = false;
        let s = Series {
            label: "lin".into(),
            points: vec![(0.0, 0.0), (1.0, 1.0)],
        };
        let out = render(&sp, &[s]);
        assert!(out.contains('*'));
    }

    #[test]
    fn trace_conversion_normalizes() {
        use crate::metrics::{RoundRecord, Trace};
        let mut t = Trace::new(100);
        t.push(RoundRecord {
            round: 1,
            passes: 0.2,
            primal: 60.0,
            dual: 10.0,
            compute_secs: 0.0,
            comm_secs: 0.0,
            wall_secs: 0.0,
            steps: crate::metrics::StepStats::default(),
        });
        let s = series_from_trace("t", &t);
        assert_eq!(s.points, vec![(1.0, 0.5)]);
    }
}
