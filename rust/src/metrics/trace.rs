//! Convergence traces — one record per communication round, carrying
//! everything the paper's figures plot: duality gap, primal objective,
//! passes over the data, modeled compute/communication time.

use std::io::Write;

/// One communication round's measurements.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    /// Communication round index (1-based; round 0 = initial state).
    pub round: usize,
    /// Cumulative passes over the data (`Σ sp` per round).
    pub passes: f64,
    /// Primal objective `P(w)` (unnormalized).
    pub primal: f64,
    /// Dual objective `D(α, β)` (unnormalized).
    pub dual: f64,
    /// Cumulative modeled compute seconds (max over machines per round).
    pub compute_secs: f64,
    /// Cumulative modeled communication seconds.
    pub comm_secs: f64,
    /// Cumulative real wall-clock seconds.
    pub wall_secs: f64,
}

impl RoundRecord {
    /// Duality gap `P − D`.
    pub fn gap(&self) -> f64 {
        self.primal - self.dual
    }

    /// Total modeled time (compute + comm).
    pub fn modeled_secs(&self) -> f64 {
        self.compute_secs + self.comm_secs
    }
}

/// A full solve trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    /// Per-round records in order.
    pub rounds: Vec<RoundRecord>,
    /// Problem size `n` (for normalized plots).
    pub n: usize,
}

impl Trace {
    /// New empty trace for a problem with `n` examples.
    pub fn new(n: usize) -> Self {
        Trace { rounds: vec![], n }
    }

    /// Append a record.
    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    /// Last record, if any.
    pub fn last(&self) -> Option<&RoundRecord> {
        self.rounds.last()
    }

    /// Normalized duality gap `(P − D)/n` per round — the y-axis of
    /// Figures 1–5, 12–13.
    pub fn normalized_gaps(&self) -> Vec<f64> {
        let n = self.n as f64;
        self.rounds.iter().map(|r| r.gap() / n).collect()
    }

    /// First round index whose normalized gap ≤ `eps`, if reached — the
    /// y-axis of the scalability Figures 8/10.
    pub fn rounds_to_gap(&self, eps: f64) -> Option<usize> {
        let n = self.n as f64;
        self.rounds
            .iter()
            .find(|r| r.gap() / n <= eps)
            .map(|r| r.round)
    }

    /// Modeled time until the normalized gap reaches `eps` — Figures 9/11.
    pub fn time_to_gap(&self, eps: f64) -> Option<f64> {
        let n = self.n as f64;
        self.rounds
            .iter()
            .find(|r| r.gap() / n <= eps)
            .map(|r| r.modeled_secs())
    }

    /// Write the trace as CSV.
    pub fn write_csv<W: Write>(&self, mut w: W) -> std::io::Result<()> {
        writeln!(
            w,
            "round,passes,primal,dual,gap,norm_gap,compute_secs,comm_secs,wall_secs"
        )?;
        let n = self.n as f64;
        for r in &self.rounds {
            writeln!(
                w,
                "{},{},{},{},{},{},{},{},{}",
                r.round,
                r.passes,
                r.primal,
                r.dual,
                r.gap(),
                r.gap() / n,
                r.compute_secs,
                r.comm_secs,
                r.wall_secs
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, gap: f64, comm: f64) -> RoundRecord {
        RoundRecord {
            round,
            passes: round as f64 * 0.2,
            primal: 10.0 + gap,
            dual: 10.0,
            compute_secs: round as f64 * 0.1,
            comm_secs: comm,
            wall_secs: round as f64 * 0.15,
        }
    }

    #[test]
    fn gap_and_normalization() {
        let mut t = Trace::new(100);
        t.push(rec(1, 50.0, 0.01));
        t.push(rec(2, 5.0, 0.02));
        assert_eq!(t.normalized_gaps(), vec![0.5, 0.05]);
    }

    #[test]
    fn rounds_to_gap_finds_first_crossing() {
        let mut t = Trace::new(10);
        t.push(rec(1, 10.0, 0.0));
        t.push(rec(2, 0.5, 0.0));
        t.push(rec(3, 0.05, 0.0));
        assert_eq!(t.rounds_to_gap(0.06), Some(2));
        assert_eq!(t.rounds_to_gap(1e-9), None);
    }

    #[test]
    fn time_to_gap_uses_modeled_time() {
        let mut t = Trace::new(10);
        t.push(rec(1, 10.0, 1.0));
        t.push(rec(2, 0.1, 2.0));
        let secs = t.time_to_gap(0.02).unwrap();
        assert!((secs - (0.2 + 2.0)).abs() < 1e-12);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut t = Trace::new(10);
        t.push(rec(1, 1.0, 0.0));
        let mut buf = Vec::new();
        t.write_csv(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("round,passes,primal"));
        assert_eq!(text.lines().count(), 2);
    }
}
