//! Convergence traces — one record per communication round, carrying
//! everything the paper's figures plot: duality gap, primal objective,
//! passes over the data, modeled compute/communication time — plus
//! per-round straggler telemetry (DESIGN.md §16).

use std::io::Write;

/// Local-step timing spread across physical machines for one round —
/// the straggler telemetry of DESIGN.md §16. Every DADM round is a
/// barrier, so its wall time is `max_ℓ` while its useful work is
/// `mean_ℓ`; the gap between the two is exactly what nnz-balanced
/// partitioning and work stealing reclaim. Wall-clock measurements
/// only: they are reported, never fed into control flow or math, so
/// they sit outside the bit-parity ("math columns") invariant.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepStats {
    /// Fastest machine's local-step seconds this round.
    pub min_secs: f64,
    /// Mean local-step seconds across machines.
    pub mean_secs: f64,
    /// Slowest machine's local-step seconds — the round's critical path.
    pub max_secs: f64,
}

impl StepStats {
    /// Aggregate per-machine local-step leg times (empty legs — e.g. an
    /// algorithm that does not measure — yield the zero stats).
    pub fn from_legs(legs: &[f64]) -> StepStats {
        if legs.is_empty() {
            return StepStats::default();
        }
        let min = legs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = legs.iter().cloned().fold(0.0, f64::max);
        // dadm-lint: allow(naive-reduction) — local timing accounting, not cross-machine float math
        let mean = legs.iter().sum::<f64>() / legs.len() as f64;
        StepStats {
            min_secs: min,
            mean_secs: mean,
            max_secs: max,
        }
    }

    /// Imbalance ratio `max/mean` — 1.0 is a perfectly balanced round,
    /// `m` is one machine doing all the work; 0.0 when unmeasured.
    pub fn imbalance(&self) -> f64 {
        if self.mean_secs > 0.0 {
            self.max_secs / self.mean_secs
        } else {
            0.0
        }
    }
}

/// Whole-solve straggler roll-up for [`SolveReport`] and bench output.
///
/// [`SolveReport`]: crate::runtime::SolveReport
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StragglerSummary {
    /// Rounds that carried non-zero step stats.
    pub rounds_measured: usize,
    /// Mean per-round imbalance ratio over measured rounds.
    pub mean_imbalance: f64,
    /// Worst per-round imbalance ratio.
    pub max_imbalance: f64,
    /// Total seconds the cluster idled behind stragglers: `Σ_rounds
    /// (max − mean)` — the wall time nnz balancing + stealing target.
    pub idle_secs: f64,
}

/// One communication round's measurements.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    /// Communication round index (1-based; round 0 = initial state).
    pub round: usize,
    /// Cumulative passes over the data (`Σ sp` per round).
    pub passes: f64,
    /// Primal objective `P(w)` (unnormalized).
    pub primal: f64,
    /// Dual objective `D(α, β)` (unnormalized).
    pub dual: f64,
    /// Cumulative modeled compute seconds (max over machines per round).
    pub compute_secs: f64,
    /// Cumulative modeled communication seconds.
    pub comm_secs: f64,
    /// Cumulative real wall-clock seconds.
    pub wall_secs: f64,
    /// This round's local-step timing spread (zeros when unmeasured —
    /// e.g. round-0 records and algorithms without machine legs).
    pub steps: StepStats,
}

impl RoundRecord {
    /// Duality gap `P − D`.
    pub fn gap(&self) -> f64 {
        self.primal - self.dual
    }

    /// Total modeled time (compute + comm).
    pub fn modeled_secs(&self) -> f64 {
        self.compute_secs + self.comm_secs
    }
}

/// A full solve trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    /// Per-round records in order.
    pub rounds: Vec<RoundRecord>,
    /// Problem size `n` (for normalized plots).
    pub n: usize,
}

impl Trace {
    /// New empty trace for a problem with `n` examples.
    pub fn new(n: usize) -> Self {
        Trace { rounds: vec![], n }
    }

    /// Append a record.
    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    /// Last record, if any.
    pub fn last(&self) -> Option<&RoundRecord> {
        self.rounds.last()
    }

    /// Normalized duality gap `(P − D)/n` per round — the y-axis of
    /// Figures 1–5, 12–13.
    pub fn normalized_gaps(&self) -> Vec<f64> {
        let n = self.n as f64;
        self.rounds.iter().map(|r| r.gap() / n).collect()
    }

    /// First round index whose normalized gap ≤ `eps`, if reached — the
    /// y-axis of the scalability Figures 8/10.
    pub fn rounds_to_gap(&self, eps: f64) -> Option<usize> {
        let n = self.n as f64;
        self.rounds
            .iter()
            .find(|r| r.gap() / n <= eps)
            .map(|r| r.round)
    }

    /// Modeled time until the normalized gap reaches `eps` — Figures 9/11.
    pub fn time_to_gap(&self, eps: f64) -> Option<f64> {
        let n = self.n as f64;
        self.rounds
            .iter()
            .find(|r| r.gap() / n <= eps)
            .map(|r| r.modeled_secs())
    }

    /// Roll up the per-round straggler telemetry (rounds with zero
    /// stats — unmeasured — are excluded).
    pub fn straggler_summary(&self) -> StragglerSummary {
        let measured: Vec<&StepStats> = self
            .rounds
            .iter()
            .map(|r| &r.steps)
            .filter(|s| s.max_secs > 0.0)
            .collect();
        if measured.is_empty() {
            return StragglerSummary::default();
        }
        let count = measured.len();
        // dadm-lint: allow(naive-reduction) — local timing accounting, not cross-machine float math
        let mean_imbalance = measured.iter().map(|s| s.imbalance()).sum::<f64>() / count as f64;
        let max_imbalance = measured
            .iter()
            .map(|s| s.imbalance())
            .fold(0.0, f64::max);
        // dadm-lint: allow(naive-reduction) — local timing accounting, not cross-machine float math
        let idle_secs = measured
            .iter()
            .map(|s| s.max_secs - s.mean_secs)
            .sum::<f64>();
        StragglerSummary {
            rounds_measured: count,
            mean_imbalance,
            max_imbalance,
            idle_secs,
        }
    }

    /// Write the trace as CSV. The first eight columns (through
    /// `comm_secs`) are the deterministic "math columns" pinned
    /// bit-identical across backends; `wall_secs` and the step-timing
    /// columns after it are wall-clock and excluded from parity checks.
    pub fn write_csv<W: Write>(&self, mut w: W) -> std::io::Result<()> {
        writeln!(
            w,
            "round,passes,primal,dual,gap,norm_gap,compute_secs,comm_secs,wall_secs,step_min_secs,step_mean_secs,step_max_secs,imbalance"
        )?;
        let n = self.n as f64;
        for r in &self.rounds {
            writeln!(
                w,
                "{},{},{},{},{},{},{},{},{},{},{},{},{}",
                r.round,
                r.passes,
                r.primal,
                r.dual,
                r.gap(),
                r.gap() / n,
                r.compute_secs,
                r.comm_secs,
                r.wall_secs,
                r.steps.min_secs,
                r.steps.mean_secs,
                r.steps.max_secs,
                r.steps.imbalance()
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, gap: f64, comm: f64) -> RoundRecord {
        RoundRecord {
            round,
            passes: round as f64 * 0.2,
            primal: 10.0 + gap,
            dual: 10.0,
            compute_secs: round as f64 * 0.1,
            comm_secs: comm,
            wall_secs: round as f64 * 0.15,
            steps: StepStats::default(),
        }
    }

    #[test]
    fn gap_and_normalization() {
        let mut t = Trace::new(100);
        t.push(rec(1, 50.0, 0.01));
        t.push(rec(2, 5.0, 0.02));
        assert_eq!(t.normalized_gaps(), vec![0.5, 0.05]);
    }

    #[test]
    fn rounds_to_gap_finds_first_crossing() {
        let mut t = Trace::new(10);
        t.push(rec(1, 10.0, 0.0));
        t.push(rec(2, 0.5, 0.0));
        t.push(rec(3, 0.05, 0.0));
        assert_eq!(t.rounds_to_gap(0.06), Some(2));
        assert_eq!(t.rounds_to_gap(1e-9), None);
    }

    #[test]
    fn time_to_gap_uses_modeled_time() {
        let mut t = Trace::new(10);
        t.push(rec(1, 10.0, 1.0));
        t.push(rec(2, 0.1, 2.0));
        let secs = t.time_to_gap(0.02).unwrap();
        assert!((secs - (0.2 + 2.0)).abs() < 1e-12);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut t = Trace::new(10);
        t.push(rec(1, 1.0, 0.0));
        let mut buf = Vec::new();
        t.write_csv(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("round,passes,primal"));
        assert!(text.lines().next().unwrap().ends_with(
            "wall_secs,step_min_secs,step_mean_secs,step_max_secs,imbalance"
        ));
        assert_eq!(text.lines().count(), 2);
        // Every row carries the same column count as the header.
        let cols = text.lines().next().unwrap().split(',').count();
        assert!(text.lines().all(|l| l.split(',').count() == cols));
    }

    #[test]
    fn step_stats_aggregate_and_imbalance() {
        let s = StepStats::from_legs(&[1.0, 2.0, 3.0]);
        assert_eq!(s.min_secs, 1.0);
        assert_eq!(s.max_secs, 3.0);
        assert!((s.mean_secs - 2.0).abs() < 1e-12);
        assert!((s.imbalance() - 1.5).abs() < 1e-12);
        // Unmeasured rounds are the additive identity, not NaN.
        assert_eq!(StepStats::from_legs(&[]), StepStats::default());
        assert_eq!(StepStats::default().imbalance(), 0.0);
    }

    #[test]
    fn straggler_summary_skips_unmeasured_rounds() {
        let mut t = Trace::new(10);
        t.push(rec(0, 10.0, 0.0)); // round-0 record: zero stats
        let mut r1 = rec(1, 5.0, 0.0);
        r1.steps = StepStats::from_legs(&[1.0, 1.0, 4.0]);
        t.push(r1);
        let mut r2 = rec(2, 1.0, 0.0);
        r2.steps = StepStats::from_legs(&[2.0, 2.0, 2.0]);
        t.push(r2);
        let s = t.straggler_summary();
        assert_eq!(s.rounds_measured, 2);
        assert!((s.max_imbalance - 2.0).abs() < 1e-12);
        assert!((s.mean_imbalance - 1.5).abs() < 1e-12);
        assert!((s.idle_secs - 2.0).abs() < 1e-12);
        assert_eq!(Trace::new(5).straggler_summary(), StragglerSummary::default());
    }
}
