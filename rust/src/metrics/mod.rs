//! Metrics: convergence traces, timing decomposition, CSV emission, and
//! the in-tree bench harness (criterion is unavailable offline).

pub mod bench;
pub mod plot;
pub mod trace;

pub use trace::{RoundRecord, StepStats, StragglerSummary, Trace};
