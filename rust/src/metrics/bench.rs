//! Minimal bench harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are plain `harness = false` binaries built on
//! this module: [`time_it`] measures a closure with warmup + repeated
//! timed runs and reports median/min/max; [`BenchTable`] accumulates rows
//! and renders both an aligned console table (mirroring the paper's
//! figures' series) and a CSV file under `target/bench_out/`.

use std::io::Write;
use std::time::Instant;

/// Timing summary over repeated runs.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    /// Median seconds per run.
    pub median: f64,
    /// Fastest run.
    pub min: f64,
    /// Slowest run.
    pub max: f64,
    /// Number of timed runs.
    pub runs: usize,
}

/// Time `f` with `warmup` untimed runs and `runs` timed runs.
pub fn time_it<F: FnMut()>(warmup: usize, runs: usize, mut f: F) -> Timing {
    assert!(runs >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = (0..runs)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Timing {
        median: samples[samples.len() / 2],
        min: samples[0],
        max: *samples.last().unwrap(),
        runs,
    }
}

/// A column-aligned results table that also writes CSV.
#[derive(Debug)]
pub struct BenchTable {
    name: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl BenchTable {
    /// Create a table with a bench name and column headers.
    pub fn new(name: &str, header: &[&str]) -> Self {
        BenchTable {
            name: name.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "ragged bench row");
        self.rows.push(cells.to_vec());
    }

    /// Render to stdout and persist CSV to `target/bench_out/<name>.csv`.
    pub fn finish(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n== {} ==", self.name);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.header));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        if let Err(e) = self.write_csv() {
            eprintln!("warning: could not write bench CSV: {e}");
        }
    }

    fn write_csv(&self) -> std::io::Result<()> {
        let dir = std::path::Path::new("target/bench_out");
        std::fs::create_dir_all(dir)?;
        let mut f = std::fs::File::create(dir.join(format!("{}.csv", self.name)))?;
        writeln!(f, "{}", self.header.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_measures_positive() {
        let mut x = 0u64;
        let t = time_it(1, 5, || {
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
        });
        assert!(t.median > 0.0);
        assert!(t.min <= t.median && t.median <= t.max);
        assert_eq!(t.runs, 5);
        assert!(x > 0 || x == 0); // keep x live
    }

    #[test]
    fn table_rejects_ragged_rows() {
        let mut t = BenchTable::new("test", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["only-one".into()])
        }));
        assert!(r.is_err());
    }

    #[test]
    fn fmt_secs_units() {
        assert!(fmt_secs(2.5).ends_with('s'));
        assert!(fmt_secs(2.5e-3).ends_with("ms"));
        assert!(fmt_secs(2.5e-6).ends_with("µs"));
        assert!(fmt_secs(2.5e-9).ends_with("ns"));
    }
}
