//! Minimal bench harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are plain `harness = false` binaries built on
//! this module: [`time_it`] measures a closure with warmup + repeated
//! timed runs and reports median/min/max; [`BenchTable`] accumulates rows
//! and renders an aligned console table (mirroring the paper's figures'
//! series), a CSV file under `target/bench_out/`, and a machine-readable
//! `target/bench_out/BENCH_<name>.json` — the artifact the CI
//! `bench-smoke` job archives so the perf trajectory accumulates across
//! commits.

use std::io::Write;
use std::time::Instant;

/// Timing summary over repeated runs.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    /// Median seconds per run.
    pub median: f64,
    /// Fastest run.
    pub min: f64,
    /// Slowest run.
    pub max: f64,
    /// Number of timed runs.
    pub runs: usize,
}

/// Time `f` with `warmup` untimed runs and `runs` timed runs.
pub fn time_it<F: FnMut()>(warmup: usize, runs: usize, mut f: F) -> Timing {
    assert!(runs >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = (0..runs)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Timing {
        median: samples[samples.len() / 2],
        min: samples[0],
        max: *samples.last().unwrap(),
        runs,
    }
}

/// A column-aligned results table that also writes CSV and JSON.
#[derive(Debug)]
pub struct BenchTable {
    name: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    meta: Vec<(String, String)>,
}

impl BenchTable {
    /// Create a table with a bench name and column headers.
    pub fn new(name: &str, header: &[&str]) -> Self {
        BenchTable {
            name: name.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
            meta: vec![],
        }
    }

    /// Attach a metadata key/value (bench scale, git describe, …) to the
    /// JSON artifact.
    pub fn meta(&mut self, key: &str, value: String) {
        self.meta.push((key.to_string(), value));
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "ragged bench row");
        self.rows.push(cells.to_vec());
    }

    /// Render to stdout and persist CSV to `target/bench_out/<name>.csv`.
    pub fn finish(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n== {} ==", self.name);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.header));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        if let Err(e) = self.write_csv() {
            eprintln!("warning: could not write bench CSV: {e}");
        }
        if let Err(e) = self.write_json() {
            eprintln!("warning: could not write bench JSON: {e}");
        }
    }

    fn write_csv(&self) -> std::io::Result<()> {
        let dir = std::path::Path::new("target/bench_out");
        std::fs::create_dir_all(dir)?;
        let mut f = std::fs::File::create(dir.join(format!("{}.csv", self.name)))?;
        writeln!(f, "{}", self.header.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }

    /// Serialize as JSON (hand-rolled — no serde offline) to the string
    /// the `BENCH_<name>.json` artifact contains.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{");
        out.push_str(&format!("\"bench\":{}", json_str(&self.name)));
        out.push_str(",\"meta\":{");
        for (i, (k, v)) in self.meta.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("{}:{}", json_str(k), json_str(v)));
        }
        out.push_str("},\"header\":[");
        for (i, h) in self.header.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&json_str(h));
        }
        out.push_str("],\"rows\":[");
        for (i, row) in self.rows.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('[');
            for (j, c) in row.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push_str(&json_str(c));
            }
            out.push(']');
        }
        out.push_str("]}");
        out
    }

    fn write_json(&self) -> std::io::Result<()> {
        let dir = std::path::Path::new("target/bench_out");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json())?;
        println!("bench JSON written to {}", path.display());
        Ok(())
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_measures_positive() {
        let mut x = 0u64;
        let t = time_it(1, 5, || {
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
        });
        assert!(t.median > 0.0);
        assert!(t.min <= t.median && t.median <= t.max);
        assert_eq!(t.runs, 5);
        assert!(x > 0 || x == 0); // keep x live
    }

    #[test]
    fn table_rejects_ragged_rows() {
        let mut t = BenchTable::new("test", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["only-one".into()])
        }));
        assert!(r.is_err());
    }

    #[test]
    fn json_artifact_shape_and_escaping() {
        let mut t = BenchTable::new("unit", &["bench", "value"]);
        t.meta("scale", "5e-5".into());
        t.row(&["round \"trip\"".into(), "1.5µs".into()]);
        t.row(&["tab\there".into(), "2".into()]);
        let json = t.to_json();
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"bench\":\"unit\""));
        assert!(json.contains("\"meta\":{\"scale\":\"5e-5\"}"));
        assert!(json.contains("\"header\":[\"bench\",\"value\"]"));
        assert!(json.contains("\\\"trip\\\""));
        assert!(json.contains("tab\\there"));
        // Balanced quoting: an even number of unescaped quotes.
        let unescaped = json.replace("\\\"", "");
        assert_eq!(unescaped.matches('"').count() % 2, 0);
    }

    #[test]
    fn fmt_secs_units() {
        assert!(fmt_secs(2.5).ends_with('s'));
        assert!(fmt_secs(2.5e-3).ends_with("ms"));
        assert!(fmt_secs(2.5e-6).ends_with("µs"));
        assert!(fmt_secs(2.5e-9).ends_with("ns"));
    }
}
