//! The Theorem-6/7 conservative mini-batch update.
//!
//! `Δα̃_i = s_ℓ (u_i − α_i)` for all `i ∈ Q_ℓ` simultaneously, where
//! `u_i = −∇φ_i(x_iᵀ w_ℓ)` and, for `(1/γ)`-smooth losses,
//!
//! ```text
//! s_ℓ = γ λ n_ℓ / (γ λ n_ℓ + M_ℓ R)           (Theorem 6)
//! ```
//!
//! For Lipschitz losses (γ = 0) Theorem 7 uses `s_ℓ = q·n_ℓ/M_ℓ` with
//! `q ∈ [0, min_ℓ M_ℓ/n_ℓ]`; we default to the largest admissible value
//! `q = M_ℓ/n_ℓ ⇒ s_ℓ = 1` damped by the same smooth-style formula with a
//! safe `γ_eff`, matching DisDCA's basic variant.
//!
//! Unlike [`super::ProxSdca`] every coordinate sees the *same* `w_ℓ` — the
//! update is embarrassingly parallel within the batch, which is exactly
//! the form the L1 Pallas kernel / PJRT path computes. The Rust and XLA
//! implementations of this step are cross-checked in integration tests.

use super::{LocalSolver, WorkerState};
use crate::comm::sparse::{should_densify, Delta, SparseDelta};
use crate::loss::Loss;
use crate::reg::Regularizer;
use crate::utils::Rng;

/// Conservative scaled mini-batch update (the analyzed variant).
#[derive(Clone, Copy, Debug)]
pub struct TheoremStep {
    /// Data radius `R ≥ max_i ‖x_i‖²` (1.0 for unit-normalized rows).
    pub radius: f64,
}

impl Default for TheoremStep {
    fn default() -> Self {
        TheoremStep { radius: 1.0 }
    }
}

impl TheoremStep {
    /// The step scale `s_ℓ` of Theorem 6.
    pub fn step_scale(&self, gamma: f64, lambda_n_l: f64, batch: usize) -> f64 {
        if gamma > 0.0 {
            gamma * lambda_n_l / (gamma * lambda_n_l + batch as f64 * self.radius)
        } else {
            // Lipschitz case: use the Theorem-7 admissible scale with the
            // damping that keeps G_ℓ bounded (DisDCA basic variant).
            lambda_n_l / (lambda_n_l + batch as f64 * self.radius)
        }
    }
}

impl LocalSolver for TheoremStep {
    fn local_step<L: Loss, R: Regularizer>(
        &self,
        state: &mut WorkerState,
        batch: &[usize],
        loss: &L,
        _reg: &R,
        lambda_n_l: f64,
        _rng: &mut Rng,
    ) -> Delta {
        let s = self.step_scale(loss.gamma(), lambda_n_l, batch.len());
        let mut delta_v = vec![0.0; state.dim()];
        for &i in batch {
            let row = state.x.row(i);
            let u_margin = row.dot(&state.w); // all coords read the same w_ℓ
            let u_i = loss.theorem_direction(u_margin, state.y[i]);
            let a_old = state.alpha[i];
            let delta = s * (u_i - a_old);
            if delta == 0.0 {
                continue;
            }
            state.alpha[i] = a_old + delta;
            // Keep the running Σ−φ*(−α) exact under this solver too
            // (new-minus-old conjugate per touched coordinate, DESIGN.md
            // §11) so gap telemetry stays O(1) regardless of the solver.
            if let Some(cs) = state.conj_sum.as_mut() {
                *cs += loss.conj_neg(a_old, state.y[i]) - loss.conj_neg(a_old + delta, state.y[i]);
            }
            row.axpy_into(delta / lambda_n_l, &mut delta_v);
        }
        // The update accumulates densely, but a mini-batch only touches
        // the sampled rows' features — emit the message in whichever
        // form is smaller on the wire (one O(d) scan).
        let nnz = delta_v.iter().filter(|x| **x != 0.0).count();
        if should_densify(nnz, delta_v.len()) {
            Delta::Dense(delta_v)
        } else {
            Delta::Sparse(SparseDelta::from_dense(&delta_v))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::tiny_classification;
    use crate::data::Partition;
    use crate::loss::{Hinge, SmoothHinge};
    use crate::reg::{ElasticNet, Regularizer};

    fn setup(seed: u64) -> WorkerState {
        let data = tiny_classification(30, 5, seed);
        let part = Partition::balanced(30, 1, seed);
        WorkerState::from_partition(&data, &part, 0)
    }

    #[test]
    fn step_scale_matches_theorem_formula() {
        let t = TheoremStep { radius: 2.0 };
        // s = γλn / (γλn + MR), γ=1, λn=10, M=5, R=2 → 10/20 = 0.5
        assert!((t.step_scale(1.0, 10.0, 5) - 0.5).abs() < 1e-12);
        // scale decreases with batch size
        assert!(t.step_scale(1.0, 10.0, 10) < t.step_scale(1.0, 10.0, 5));
        // and lies in [0, 1]
        for &(g, ln, m) in &[(1.0, 1e-4, 100), (4.0, 1e3, 1)] {
            let s = t.step_scale(g, ln, m);
            assert!((0.0..=1.0).contains(&s));
        }
    }

    #[test]
    fn update_is_order_independent() {
        let loss = SmoothHinge::default();
        let reg = ElasticNet::new(0.0);
        let mut a = setup(11);
        let mut b = a.clone();
        let mut rng = Rng::new(0);
        let fwd: Vec<usize> = (0..10).collect();
        let rev: Vec<usize> = (0..10).rev().collect();
        let dv_a = TheoremStep::default()
            .local_step(&mut a, &fwd, &loss, &reg, 0.3, &mut rng)
            .into_dense();
        let dv_b = TheoremStep::default()
            .local_step(&mut b, &rev, &loss, &reg, 0.3, &mut rng)
            .into_dense();
        for (x, y) in dv_a.iter().zip(&dv_b) {
            assert!((x - y).abs() < 1e-12);
        }
        assert_eq!(a.alpha, b.alpha);
    }

    #[test]
    fn dual_feasibility_preserved() {
        // α stays in the conjugate domain: the update is a convex
        // combination of α and the feasible point u_i when s ∈ [0,1].
        let loss = SmoothHinge::default();
        let reg = ElasticNet::new(0.1);
        let mut ws = setup(12);
        let mut rng = Rng::new(1);
        let batch: Vec<usize> = (0..ws.n_l()).collect();
        for _ in 0..5 {
            let dv = TheoremStep::default()
                .local_step(&mut ws, &batch, &loss, &reg, 0.2, &mut rng)
                .into_dense();
            ws.apply_global(&dv, &reg);
            for i in 0..ws.n_l() {
                assert!(
                    loss.conj_neg(ws.alpha[i], ws.y[i]).is_finite(),
                    "α[{i}] = {} left the dual domain",
                    ws.alpha[i]
                );
            }
        }
    }

    #[test]
    fn improves_dual_objective_smooth_case() {
        let loss = SmoothHinge::default();
        let reg = ElasticNet::new(0.0);
        let mut ws = setup(13);
        let lambda_n_l = 0.1 * ws.n_l() as f64;
        let mut rng = Rng::new(2);
        let dual = |ws: &WorkerState| -> f64 {
            let cs: f64 = (0..ws.n_l())
                .map(|i| -loss.conj_neg(ws.alpha[i], ws.y[i]))
                .sum();
            cs - lambda_n_l * reg.conj(&ws.v_tilde)
        };
        let before = dual(&ws);
        let batch: Vec<usize> = (0..ws.n_l()).collect();
        let dv = TheoremStep::default()
            .local_step(&mut ws, &batch, &loss, &reg, lambda_n_l, &mut rng)
            .into_dense();
        ws.apply_global(&dv, &reg);
        assert!(dual(&ws) > before, "no dual progress from zero start");
    }

    #[test]
    fn lipschitz_case_stays_feasible() {
        let loss = Hinge;
        let reg = ElasticNet::new(0.0);
        let mut ws = setup(14);
        let mut rng = Rng::new(3);
        let batch: Vec<usize> = (0..ws.n_l()).collect();
        for _ in 0..10 {
            let dv = TheoremStep::default()
                .local_step(&mut ws, &batch, &loss, &reg, 0.05, &mut rng)
                .into_dense();
            ws.apply_global(&dv, &reg);
        }
        for i in 0..ws.n_l() {
            assert!(loss.conj_neg(ws.alpha[i], ws.y[i]).is_finite());
        }
    }
}
