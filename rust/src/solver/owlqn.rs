//! OWL-QN (Orthant-Wise Limited-memory Quasi-Newton, Andrew & Gao 2007).
//!
//! The batch baseline of Figures 6–7: minimizes
//!
//! ```text
//! F(w) = f(w) + μ‖w‖₁,   f smooth (here (1/n)Σφ_i(x_iᵀw) + (λ/2)‖w‖²)
//! ```
//!
//! via L-BFGS on the smooth part with the orthant-wise pseudo-gradient,
//! direction alignment, orthant projection in the line search, and the
//! paper's memory parameter 10. The objective/gradient oracle is a
//! callback so the distributed bench can count data passes and charge one
//! allreduce per evaluation (each evaluation is one pass over the data).

use super::lbfgs::LbfgsHistory;
use crate::utils::math::dot;

/// OWL-QN options.
#[derive(Clone, Debug)]
pub struct OwlqnOptions {
    /// L1 weight μ.
    pub mu: f64,
    /// L-BFGS memory (paper: 10).
    pub memory: usize,
    /// Max outer iterations.
    pub max_iters: usize,
    /// Stop when the pseudo-gradient ∞-norm falls below this.
    pub tol: f64,
    /// Max line-search backtracks per iteration.
    pub max_line_search: usize,
}

impl Default for OwlqnOptions {
    fn default() -> Self {
        OwlqnOptions {
            mu: 0.0,
            memory: 10,
            max_iters: 100,
            tol: 1e-10,
            max_line_search: 30,
        }
    }
}

/// Result of an OWL-QN run.
#[derive(Clone, Debug)]
pub struct OwlqnResult {
    /// Final iterate.
    pub w: Vec<f64>,
    /// Final full objective `f(w) + μ‖w‖₁`.
    pub objective: f64,
    /// Number of oracle evaluations (== data passes == comm rounds in the
    /// distributed accounting).
    pub evals: usize,
    /// Outer iterations taken.
    pub iters: usize,
    /// Objective after every oracle evaluation (trace for Fig 6/7).
    pub eval_trace: Vec<f64>,
}

/// Explicit optimizer state, one [`Owlqn::step`] per outer iteration.
///
/// Inverting the classic "the optimizer owns the loop" control flow lets
/// the distributed driver ([`crate::coordinator::DistributedOwlqn`]) run
/// OWL-QN through the same round engine as the dual methods: one engine
/// round = one outer iteration (≥ 1 oracle evaluations). The batch
/// [`Owlqn::minimize`] is a thin loop over the same state.
#[derive(Clone, Debug)]
pub struct OwlqnState {
    /// Current iterate.
    pub w: Vec<f64>,
    /// Smooth-part value `f(w)` at the current iterate.
    pub fval: f64,
    /// `∇f(w)` at the current iterate.
    pub grad: Vec<f64>,
    /// Oracle evaluations so far (including the initial one).
    pub evals: usize,
    /// Outer iterations started.
    pub iters: usize,
    /// Full objective after every oracle evaluation (monotone envelope —
    /// the per-pass trace of Figures 6/7).
    pub eval_trace: Vec<f64>,
    /// The optimizer has terminated on its own criteria (tolerance, no
    /// descent direction, or a failed line search).
    pub done: bool,
    history: LbfgsHistory,
}

/// OWL-QN optimizer.
#[derive(Clone, Debug)]
pub struct Owlqn {
    opts: OwlqnOptions,
}

impl Owlqn {
    /// Build with options.
    pub fn new(opts: OwlqnOptions) -> Self {
        Self { opts }
    }

    /// Pseudo-gradient ⋄F(w) of `f + μ‖·‖₁`.
    fn pseudo_gradient(&self, w: &[f64], grad: &[f64]) -> Vec<f64> {
        let mu = self.opts.mu;
        w.iter()
            .zip(grad)
            .map(|(&wj, &gj)| {
                if wj > 0.0 {
                    gj + mu
                } else if wj < 0.0 {
                    gj - mu
                } else if gj + mu < 0.0 {
                    gj + mu
                } else if gj - mu > 0.0 {
                    gj - mu
                } else {
                    0.0
                }
            })
            .collect()
    }

    /// Full objective `F(w) = f(w) + μ‖w‖₁` at the state's iterate.
    pub fn objective(&self, st: &OwlqnState) -> f64 {
        st.fval + self.opts.mu * crate::utils::math::l1_norm(&st.w)
    }

    /// Start a run at `w0` (performs the initial oracle evaluation).
    pub fn begin<F>(&self, w0: Vec<f64>, f_and_grad: &mut F) -> OwlqnState
    where
        F: FnMut(&[f64]) -> (f64, Vec<f64>),
    {
        let (fval, grad) = f_and_grad(&w0);
        let mut st = OwlqnState {
            w: w0,
            fval,
            grad,
            evals: 1,
            iters: 0,
            eval_trace: Vec::new(),
            done: false,
            history: LbfgsHistory::new(self.opts.memory),
        };
        st.eval_trace.push(self.objective(&st));
        st
    }

    /// One outer iteration: pseudo-gradient, aligned quasi-Newton
    /// direction, orthant-projected backtracking line search. Returns
    /// `false` once the state is finished (tolerance reached, no descent
    /// direction, failed line search, or iteration budget exhausted) —
    /// in that case no further iterations will run.
    pub fn step<F>(&self, st: &mut OwlqnState, f_and_grad: &mut F) -> bool
    where
        F: FnMut(&[f64]) -> (f64, Vec<f64>),
    {
        if st.done || st.iters >= self.opts.max_iters {
            return false;
        }
        st.iters += 1;
        let mu = self.opts.mu;
        let pg = self.pseudo_gradient(&st.w, &st.grad);
        let pg_inf = pg.iter().fold(0.0f64, |m, x| m.max(x.abs()));
        if pg_inf < self.opts.tol {
            st.done = true;
            return false;
        }
        // Quasi-Newton direction on the pseudo-gradient…
        let mut dir: Vec<f64> = st.history.apply(&pg).iter().map(|x| -x).collect();
        // …aligned: discard components that disagree with −⋄F.
        for (dj, pgj) in dir.iter_mut().zip(&pg) {
            if *dj * -pgj <= 0.0 {
                *dj = 0.0;
            }
        }
        // Orthant ξ: sign of w, or of −⋄F where w = 0.
        let xi: Vec<f64> = st
            .w
            .iter()
            .zip(&pg)
            .map(|(&wj, &pgj)| if wj != 0.0 { wj.signum() } else { -pgj.signum() })
            .collect();
        let dir_deriv = dot(&pg, &dir);
        if dir_deriv >= 0.0 {
            st.done = true; // no descent possible
            return false;
        }
        // Backtracking line search with orthant projection.
        let f_old_full = self.objective(st);
        let mut t = if st.history.is_empty() {
            // conservative first step like the reference implementation
            1.0 / (1.0 + crate::utils::math::l2_norm_sq(&pg).sqrt())
        } else {
            1.0
        };
        let c1 = 1e-4;
        let mut accepted = false;
        for _ in 0..self.opts.max_line_search {
            let w_new: Vec<f64> = st
                .w
                .iter()
                .zip(&dir)
                .zip(&xi)
                .map(|((&wj, &dj), &xij)| {
                    let cand = wj + t * dj;
                    // Project onto the orthant: zero if sign flips.
                    if cand * xij < 0.0 {
                        0.0
                    } else {
                        cand
                    }
                })
                .collect();
            let (f_new, g_new) = f_and_grad(&w_new);
            st.evals += 1;
            let f_new_full = f_new + mu * crate::utils::math::l1_norm(&w_new);
            st.eval_trace
                .push(f_new_full.min(*st.eval_trace.last().unwrap()));
            if f_new_full <= f_old_full + c1 * t * dir_deriv {
                // Curvature pair from accepted step.
                let s: Vec<f64> = w_new.iter().zip(&st.w).map(|(a, b)| a - b).collect();
                let yv: Vec<f64> = g_new.iter().zip(&st.grad).map(|(a, b)| a - b).collect();
                st.history.push(s, yv);
                st.w = w_new;
                st.fval = f_new;
                st.grad = g_new;
                accepted = true;
                break;
            }
            t *= 0.5;
        }
        if !accepted {
            st.done = true; // line search failed — practical convergence
            return false;
        }
        st.iters < self.opts.max_iters
    }

    /// Minimize using the oracle `f_and_grad(w) -> (f(w), ∇f(w))` — the
    /// batch entry point, a loop over [`Owlqn::step`].
    pub fn minimize<F>(&self, w0: Vec<f64>, mut f_and_grad: F) -> OwlqnResult
    where
        F: FnMut(&[f64]) -> (f64, Vec<f64>),
    {
        let mut st = self.begin(w0, &mut f_and_grad);
        while self.step(&mut st, &mut f_and_grad) {}
        OwlqnResult {
            objective: self.objective(&st),
            w: st.w,
            evals: st.evals,
            iters: st.iters,
            eval_trace: st.eval_trace,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smooth quadratic oracle ½‖w − c‖².
    fn quad_oracle(c: Vec<f64>) -> impl FnMut(&[f64]) -> (f64, Vec<f64>) {
        move |w: &[f64]| {
            let f = 0.5
                * w.iter()
                    .zip(&c)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>();
            let g = w.iter().zip(&c).map(|(a, b)| a - b).collect();
            (f, g)
        }
    }

    #[test]
    fn solves_smooth_quadratic_without_l1() {
        let owlqn = Owlqn::new(OwlqnOptions::default());
        let res = owlqn.minimize(vec![0.0; 3], quad_oracle(vec![1.0, -2.0, 3.0]));
        for (wi, ci) in res.w.iter().zip(&[1.0, -2.0, 3.0]) {
            assert!((wi - ci).abs() < 1e-6, "{:?}", res.w);
        }
    }

    #[test]
    fn lasso_fixed_point_is_soft_threshold() {
        // min ½‖w − c‖² + μ‖w‖₁ has solution soft_threshold(c, μ).
        let mu = 0.8;
        let owlqn = Owlqn::new(OwlqnOptions {
            mu,
            max_iters: 200,
            ..Default::default()
        });
        let c = vec![2.0, 0.5, -1.5, -0.3];
        let res = owlqn.minimize(vec![0.0; 4], quad_oracle(c.clone()));
        let want = crate::utils::math::soft_threshold(&c, mu);
        for (got, want) in res.w.iter().zip(&want) {
            assert!((got - want).abs() < 1e-6, "{:?} vs {want}", res.w);
        }
    }

    #[test]
    fn iterates_stay_sparse_with_strong_l1() {
        let owlqn = Owlqn::new(OwlqnOptions {
            mu: 10.0,
            ..Default::default()
        });
        let res = owlqn.minimize(vec![0.0; 3], quad_oracle(vec![1.0, -2.0, 3.0]));
        assert!(res.w.iter().all(|&w| w == 0.0), "{:?}", res.w);
    }

    #[test]
    fn trace_is_monotone_nonincreasing() {
        let owlqn = Owlqn::new(OwlqnOptions {
            mu: 0.1,
            ..Default::default()
        });
        let res = owlqn.minimize(vec![5.0; 4], quad_oracle(vec![0.0; 4]));
        for pair in res.eval_trace.windows(2) {
            assert!(pair[1] <= pair[0] + 1e-12);
        }
        assert_eq!(res.eval_trace.len(), res.evals);
    }

    #[test]
    fn logistic_regression_1d_matches_grid() {
        // min f(w) = log(1+e^{−w}) + log(1+e^{w·0.5}) + 0.05 w² + 0.1|w|
        let oracle = |w: &[f64]| {
            let w0 = w[0];
            let f = crate::utils::math::log1p_exp(-w0)
                + crate::utils::math::log1p_exp(0.5 * w0)
                + 0.05 * w0 * w0;
            let g = -1.0 / (1.0 + w0.exp()) + 0.5 / (1.0 + (-0.5 * w0).exp()) + 0.1 * w0;
            (f, vec![g])
        };
        let owlqn = Owlqn::new(OwlqnOptions {
            mu: 0.1,
            max_iters: 300,
            ..Default::default()
        });
        let res = owlqn.minimize(vec![0.0], oracle);
        // grid search the full objective
        let mut best = f64::INFINITY;
        let mut arg = 0.0;
        let mut w = -5.0;
        while w <= 5.0 {
            let (f, _) = oracle(&[w]);
            let full = f + 0.1 * w.abs();
            if full < best {
                best = full;
                arg = w;
            }
            w += 1e-4;
        }
        assert!(
            (res.w[0] - arg).abs() < 1e-3,
            "owlqn {} vs grid {arg}",
            res.w[0]
        );
    }
}
