//! Sequential ProxSDCA local solver — the paper's practical variant.
//!
//! Within a mini-batch `Q_ℓ`, visit coordinates in random order and apply
//! the *exact* 1-D dual maximizer (aggressive sequential updates, as the
//! practical DisDCA variant and the CoCoA+ local solver do — §10). After
//! each coordinate step the scratch `ṽ` and the touched entries of
//! `w = ∇g*(ṽ)` are refreshed, so later coordinates in the batch see the
//! earlier updates. Cost per step is `O(nnz(x_i))`.

use super::{LocalSolver, WorkerState};
use crate::comm::sparse::{should_densify, Delta, SparseDelta};
use crate::loss::Loss;
use crate::reg::Regularizer;
use crate::utils::Rng;

/// Sequential aggressive ProxSDCA over the mini-batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct ProxSdca;

/// Hand the filled `scratch_delta` out as the dense Δv_ℓ message,
/// swapping the pre-zeroed spare in as the next round's accumulator
/// (`mem::replace`) — the message buffer leaves the worker through the
/// reduce, so the spare is replenished with a fresh zeroed vector on the
/// following dense round (a calloc, cheaper than the old clone + fill).
fn take_dense_delta(state: &mut WorkerState) -> Vec<f64> {
    let d = state.dim();
    let mut spare = std::mem::take(&mut state.scratch_delta_spare);
    if spare.len() != d {
        spare = vec![0.0; d];
    }
    debug_assert!(spare.iter().all(|&x| x == 0.0));
    std::mem::replace(&mut state.scratch_delta, spare)
}

impl LocalSolver for ProxSdca {
    fn local_step<L: Loss, R: Regularizer>(
        &self,
        state: &mut WorkerState,
        batch: &[usize],
        loss: &L,
        reg: &R,
        lambda_n_l: f64,
        rng: &mut Rng,
    ) -> Delta {
        // Allocation-free hot path (§Perf iteration 3): Δv accumulates in
        // a persistent zeroed buffer, `w` is updated *in place* so later
        // coordinates see earlier updates, and both are reverted/reset
        // from the touched-coordinate log afterwards — the synchronized
        // (ṽ_ℓ, w_ℓ) are untouched on return, as Algorithm 2 requires.
        debug_assert!(state.scratch_delta.iter().all(|&x| x == 0.0));
        // Expected touched volume decides both the restore strategy and
        // the Δv_ℓ message form up front: dense epochs skip the per-entry
        // touch log entirely and emit a dense message, mini-batch rounds
        // on sparse data emit the touched coordinates only (DESIGN.md §7).
        let avg_nnz = state.x.nnz() / state.x.rows().max(1);
        let dense_reset = batch.len().saturating_mul(avg_nnz) >= state.dim();
        // Shuffle in the persistent order buffer — no per-round
        // `batch.to_vec()` allocation (taken out of `state` so the loop
        // below can borrow the rest of the worker mutably).
        let mut order = std::mem::take(&mut state.scratch_order);
        order.clear();
        order.extend_from_slice(batch);
        rng.shuffle(&mut order);

        for &i in &order {
            let row = state.x.row(i);
            let u = row.dot(&state.w);
            // q = 0 for empty rows is handled by each loss's closed form —
            // the dual term −φ*(−α_i) still needs maximizing there or the
            // duality gap keeps a φ_i(0) floor forever.
            let q = state.row_norm_sq[i] / lambda_n_l;
            let a_old = state.alpha[i];
            let delta = loss.coordinate_delta(a_old, u, q, state.y[i]);
            if delta == 0.0 {
                continue;
            }
            state.alpha[i] = a_old + delta;
            // Incremental dual telemetry (DESIGN.md §11): the running
            // Σ−φ*(−α) moves by the new-minus-old conjugate at this one
            // coordinate — O(1) instead of the O(n_ℓ) pass a gap
            // evaluation used to pay.
            if let Some(cs) = state.conj_sum.as_mut() {
                *cs += loss.conj_neg(a_old, state.y[i]) - loss.conj_neg(a_old + delta, state.y[i]);
            }
            // Δv += x_i·δ/(λn_ℓ); refresh the touched w entries (∇g* is
            // separable for every g in this crate).
            let c = delta / lambda_n_l;
            for (&j, &xv) in row.indices.iter().zip(row.values) {
                let ju = j as usize;
                state.scratch_delta[ju] += c * xv;
                state.w[ju] =
                    reg.grad_conj_at(ju, state.v_tilde[ju] + state.scratch_delta[ju]);
                if !dense_reset {
                    state.scratch_touched.push(j);
                }
            }
        }
        state.scratch_order = order;

        // Emit Δv_ℓ and restore the synchronized state. The restore
        // strategy followed `dense_reset`; the *message form* follows the
        // wire break-even (`should_densify`), so a wide touched set still
        // goes out as the cheaper dense vector. A dense message gives the
        // accumulator itself away and swaps in the pre-zeroed spare — no
        // length-d clone + fill on the dense path.
        if dense_reset {
            let delta_v = take_dense_delta(state);
            reg.grad_conj_into(&state.v_tilde, &mut state.w);
            state.scratch_touched.clear();
            Delta::Dense(delta_v)
        } else {
            state.scratch_touched.sort_unstable();
            state.scratch_touched.dedup();
            let densify = should_densify(state.scratch_touched.len(), state.dim());
            let message = if densify {
                for &j in &state.scratch_touched {
                    let ju = j as usize;
                    state.w[ju] = reg.grad_conj_at(ju, state.v_tilde[ju]);
                }
                Delta::Dense(take_dense_delta(state))
            } else {
                let idx = state.scratch_touched.clone();
                let mut val = Vec::with_capacity(idx.len());
                for &j in &idx {
                    let ju = j as usize;
                    val.push(state.scratch_delta[ju]);
                    state.scratch_delta[ju] = 0.0;
                    state.w[ju] = reg.grad_conj_at(ju, state.v_tilde[ju]);
                }
                Delta::Sparse(SparseDelta {
                    dim: state.dim(),
                    idx,
                    val,
                })
            };
            state.scratch_touched.clear();
            message
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::tiny_classification;
    use crate::data::Partition;
    use crate::loss::{Logistic, SmoothHinge};
    use crate::reg::ElasticNet;

    fn setup(seed: u64) -> WorkerState {
        let data = tiny_classification(40, 6, seed);
        let part = Partition::balanced(40, 1, seed);
        WorkerState::from_partition(&data, &part, 0)
    }

    /// Local dual objective D̃_ℓ (up to the constant −λn_ℓ·g*(ṽ₀) shift).
    fn local_dual<L: Loss, R: Regularizer>(
        ws: &WorkerState,
        loss: &L,
        reg: &R,
        lambda_n_l: f64,
        v_tilde: &[f64],
    ) -> f64 {
        let conj_sum: f64 = (0..ws.n_l())
            .map(|i| -loss.conj_neg(ws.alpha[i], ws.y[i]))
            .sum();
        conj_sum - lambda_n_l * reg.conj(v_tilde)
    }

    #[test]
    fn dual_objective_increases_monotonically() {
        let mut ws = setup(5);
        let loss = SmoothHinge::default();
        let reg = ElasticNet::new(0.1);
        let lambda_n_l = 1e-2 * ws.n_l() as f64;
        let mut rng = Rng::new(1);
        let mut prev = local_dual(&ws, &loss, &reg, lambda_n_l, &ws.v_tilde);
        for _ in 0..10 {
            let batch: Vec<usize> = (0..ws.n_l()).collect();
            let dv = ProxSdca
                .local_step(&mut ws, &batch, &loss, &reg, lambda_n_l, &mut rng)
                .into_dense();
            // Emulate the m=1 global step: ṽ += Δv.
            ws.apply_global(&dv, &reg);
            let cur = local_dual(&ws, &loss, &reg, lambda_n_l, &ws.v_tilde);
            assert!(
                cur >= prev - 1e-10,
                "dual decreased: {prev} -> {cur}"
            );
            prev = cur;
        }
    }

    #[test]
    fn delta_v_matches_alpha_change() {
        // Invariant: Δv_ℓ == X_ℓᵀ Δα / (λn_ℓ).
        let mut ws = setup(6);
        let loss = Logistic;
        let reg = ElasticNet::new(0.05);
        let lambda_n_l = 5e-2 * ws.n_l() as f64;
        let mut rng = Rng::new(2);
        let alpha_before = ws.alpha.clone();
        let batch: Vec<usize> = (0..ws.n_l()).step_by(2).collect();
        let dv = ProxSdca
            .local_step(&mut ws, &batch, &loss, &reg, lambda_n_l, &mut rng)
            .into_dense();
        let d_alpha: Vec<f64> = ws
            .alpha
            .iter()
            .zip(&alpha_before)
            .map(|(a, b)| a - b)
            .collect();
        let want: Vec<f64> = ws
            .x
            .matvec_t(&d_alpha)
            .into_iter()
            .map(|x| x / lambda_n_l)
            .collect();
        for (got, want) in dv.iter().zip(&want) {
            assert!((got - want).abs() < 1e-12);
        }
        // Untouched coordinates keep α = 0.
        for (i, a) in ws.alpha.iter().enumerate() {
            if !batch.contains(&i) {
                assert_eq!(*a, 0.0);
            }
        }
    }

    #[test]
    fn local_step_does_not_mutate_synced_state() {
        let mut ws = setup(7);
        let loss = SmoothHinge::default();
        let reg = ElasticNet::new(0.0);
        let v_before = ws.v_tilde.clone();
        let w_before = ws.w.clone();
        let mut rng = Rng::new(3);
        let batch: Vec<usize> = (0..10).collect();
        let _ = ProxSdca.local_step(&mut ws, &batch, &loss, &reg, 0.5, &mut rng);
        assert_eq!(ws.v_tilde, v_before);
        assert_eq!(ws.w, w_before);
    }

    #[test]
    fn touched_refresh_matches_full_recompute() {
        let mut ws = setup(8);
        let loss = SmoothHinge::default();
        let reg = ElasticNet::new(0.3);
        let lambda_n_l = 1e-2 * ws.n_l() as f64;
        let mut rng = Rng::new(4);
        // Run a step, then verify w-consistency by recomputing from ṽ.
        let batch: Vec<usize> = (0..ws.n_l()).collect();
        let dv = ProxSdca
            .local_step(&mut ws, &batch, &loss, &reg, lambda_n_l, &mut rng)
            .into_dense();
        ws.apply_global(&dv, &reg);
        let full = reg.grad_conj(&ws.v_tilde);
        for (a, b) in ws.w.iter().zip(&full) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let mut ws = setup(9);
        let loss = SmoothHinge::default();
        let reg = ElasticNet::new(0.0);
        let mut rng = Rng::new(5);
        let dv = ProxSdca
            .local_step(&mut ws, &[], &loss, &reg, 1.0, &mut rng)
            .into_dense();
        assert!(dv.iter().all(|&x| x == 0.0));
        assert!(ws.alpha.iter().all(|&a| a == 0.0));
    }

    #[test]
    fn incremental_conj_tracks_exact_recomputation() {
        // With tracking armed, the O(1) new-minus-old updates must stay
        // within float-drift distance of the exact O(n) pass across many
        // mini-batch steps (DESIGN.md §11).
        let mut ws = setup(21);
        let loss = SmoothHinge::default();
        let reg = ElasticNet::new(0.1);
        let lambda_n_l = 1e-2 * ws.n_l() as f64;
        let mut rng = Rng::new(22);
        let _ = ws.conj_running(&loss); // arm
        for _ in 0..60 {
            let batch = rng.sample_indices(ws.n_l(), 8);
            let dv = ProxSdca
                .local_step(&mut ws, &batch, &loss, &reg, lambda_n_l, &mut rng)
                .into_dense();
            ws.apply_global(&dv, &reg);
        }
        let exact = ws.dual_conj_sum(&loss);
        let running = ws.conj_running(&loss);
        assert!(
            (running - exact).abs() <= 1e-9 * (1.0 + exact.abs()),
            "incremental conj drifted: {running} vs {exact}"
        );
        // An untracked worker pays nothing and stays None.
        let mut cold = setup(21);
        let batch: Vec<usize> = (0..8).collect();
        let _ = ProxSdca.local_step(&mut cold, &batch, &loss, &reg, lambda_n_l, &mut rng);
        assert!(cold.conj_sum.is_none());
    }

    #[test]
    fn dense_message_swap_leaves_zeroed_accumulator() {
        // An epoch-style batch emits a dense message by giving the
        // accumulator away; the swapped-in spare must leave the state
        // ready for the next round (all-zero scratch).
        let mut ws = setup(23);
        let loss = SmoothHinge::default();
        let reg = ElasticNet::new(0.0);
        let lambda_n_l = 1e-2 * ws.n_l() as f64;
        let mut rng = Rng::new(24);
        let batch: Vec<usize> = (0..ws.n_l()).collect();
        for round in 0..3 {
            let delta = ProxSdca.local_step(&mut ws, &batch, &loss, &reg, lambda_n_l, &mut rng);
            assert!(
                matches!(delta, Delta::Dense(_)),
                "epoch batch on dense data must emit densely (round {round})"
            );
            assert!(ws.scratch_delta.iter().all(|&x| x == 0.0));
            assert_eq!(ws.scratch_delta.len(), ws.dim());
            let dv = delta.into_dense();
            ws.apply_global(&dv, &reg);
        }
    }

    #[test]
    fn minibatch_on_sparse_data_emits_sparse_message() {
        // rcv1-style shard: a small mini-batch touches ≪ d coordinates, so
        // the Δv_ℓ message must be the sparse touched-coordinate form and
        // must agree with the dense X_ℓᵀΔα/(λn_ℓ) recompute.
        use crate::data::synthetic::SyntheticSpec;
        let data = SyntheticSpec {
            name: "sparse-msg".into(),
            n: 60,
            d: 512,
            density: 0.01,
            signal_density: 0.1,
            noise: 0.05,
            seed: 10,
        }
        .generate();
        let part = Partition::balanced(60, 1, 10);
        let mut ws = WorkerState::from_partition(&data, &part, 0);
        let loss = SmoothHinge::default();
        let reg = ElasticNet::new(0.1);
        let lambda_n_l = 1e-2 * ws.n_l() as f64;
        let mut rng = Rng::new(11);
        let alpha_before = ws.alpha.clone();
        let batch: Vec<usize> = (0..6).collect();
        let delta = ProxSdca.local_step(&mut ws, &batch, &loss, &reg, lambda_n_l, &mut rng);
        let sparse = match &delta {
            Delta::Sparse(s) => s.clone(),
            Delta::Dense(_) => panic!("mini-batch on sparse data must emit sparsely"),
        };
        assert!(sparse.nnz() < 512, "support not sparse: {}", sparse.nnz());
        assert!(sparse.idx.windows(2).all(|p| p[0] < p[1]), "unsorted idx");
        let d_alpha: Vec<f64> = ws
            .alpha
            .iter()
            .zip(&alpha_before)
            .map(|(a, b)| a - b)
            .collect();
        let want: Vec<f64> = ws
            .x
            .matvec_t(&d_alpha)
            .into_iter()
            .map(|x| x / lambda_n_l)
            .collect();
        let got = delta.into_dense();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12);
        }
        // The scratch buffers are fully restored for the next round.
        assert!(ws.scratch_delta.iter().all(|&x| x == 0.0));
        assert!(ws.scratch_touched.is_empty());
    }
}
