//! Local solvers.
//!
//! DADM's local step (Algorithm 1) may use *any* procedure that improves
//! the local dual `D̃_ℓ(α_(ℓ)|β_ℓ)` over a mini-batch `Q_ℓ`. We provide:
//!
//! * [`ProxSdca`] — the paper's practical choice (§10): sequential
//!   aggressive ProxSDCA coordinate updates within the mini-batch, exactly
//!   maximizing each 1-D dual subproblem.
//! * [`TheoremStep`] — the conservative scaled update `Δα̃_i = s_ℓ(u_i −
//!   α_i)` of Theorems 6/7 (the analyzed variant; also the batched form
//!   the L1 Pallas kernel / XLA path implements).
//! * [`owlqn`]/[`lbfgs`] — the primal OWL-QN baseline of Figures 6–7.
//!
//! All local solvers operate on a [`WorkerState`], the per-machine shard
//! of data + dual variables, and return the scaled update
//! `Δv_ℓ = Σ_{i∈Q_ℓ} X_i Δα_i / (λ n_ℓ)` as a [`Delta`] message — sparse
//! index/value pairs when the touched support is small, dense otherwise —
//! that the global step aggregates (DESIGN.md §7).

pub mod lbfgs;
pub mod owlqn;
mod prox_sdca;
mod theorem_step;
mod worker;

pub use owlqn::{Owlqn, OwlqnOptions, OwlqnState};
pub use prox_sdca::ProxSdca;
pub use theorem_step::TheoremStep;
pub use worker::{
    batch_size, machine_rng, machine_rngs, run_fused_step, run_local_step, WorkerState,
};

use crate::comm::sparse::Delta;
use crate::loss::Loss;
use crate::reg::Regularizer;
use crate::utils::Rng;

/// A local dual solver: one invocation = one local step of Algorithm 1.
pub trait LocalSolver: Send + Sync + std::fmt::Debug {
    /// Approximately maximize the local dual over the mini-batch `batch`
    /// (indices into the worker's shard), updating `state.alpha` and
    /// returning the `Δv_ℓ` message (sparse or dense over length d — the
    /// exact payload the global aggregation puts on the wire).
    ///
    /// `lambda_n_l = λ_eff · n_ℓ` is the local dual scaling (λ̃ during
    /// Acc-DADM inner solves).
    fn local_step<L: Loss, R: Regularizer>(
        &self,
        state: &mut WorkerState,
        batch: &[usize],
        loss: &L,
        reg: &R,
        lambda_n_l: f64,
        rng: &mut Rng,
    ) -> Delta;
}

/// Which local solver to run (config/CLI surface).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    /// Sequential aggressive ProxSDCA (paper's practical variant).
    ProxSdca,
    /// Theorem-6/7 conservative scaled mini-batch update.
    Theorem,
}

impl SolverKind {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "prox_sdca" | "sdca" => SolverKind::ProxSdca,
            "theorem" | "minibatch" => SolverKind::Theorem,
            other => anyhow::bail!("unknown solver `{other}`"),
        })
    }

    /// Canonical name.
    pub fn name(self) -> &'static str {
        match self {
            SolverKind::ProxSdca => "prox_sdca",
            SolverKind::Theorem => "theorem",
        }
    }
}
