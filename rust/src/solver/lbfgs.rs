//! Limited-memory BFGS direction (two-loop recursion).
//!
//! Substrate for the OWL-QN baseline (Figures 6–7): maintains the last
//! `memory` curvature pairs `(s_k, y_k)` and maps a gradient to the
//! quasi-Newton direction `−H_k·g`.

/// L-BFGS curvature history.
#[derive(Clone, Debug)]
pub struct LbfgsHistory {
    memory: usize,
    s: std::collections::VecDeque<Vec<f64>>,
    y: std::collections::VecDeque<Vec<f64>>,
    rho: std::collections::VecDeque<f64>,
}

impl LbfgsHistory {
    /// New history with the given memory (the paper uses 10 for OWL-QN).
    pub fn new(memory: usize) -> Self {
        assert!(memory >= 1);
        LbfgsHistory {
            memory,
            s: Default::default(),
            y: Default::default(),
            rho: Default::default(),
        }
    }

    /// Record a curvature pair; skipped if `sᵀy` is not sufficiently
    /// positive (preserves positive-definiteness).
    pub fn push(&mut self, s: Vec<f64>, y: Vec<f64>) {
        let sy = crate::utils::math::dot(&s, &y);
        if sy <= 1e-12 {
            return;
        }
        if self.s.len() == self.memory {
            self.s.pop_front();
            self.y.pop_front();
            self.rho.pop_front();
        }
        self.rho.push_back(1.0 / sy);
        self.s.push_back(s);
        self.y.push_back(y);
    }

    /// Number of stored pairs.
    pub fn len(&self) -> usize {
        self.s.len()
    }

    /// True if no curvature pairs are stored yet.
    pub fn is_empty(&self) -> bool {
        self.s.is_empty()
    }

    /// Two-loop recursion: returns `H_k · g` (NOT negated).
    pub fn apply(&self, grad: &[f64]) -> Vec<f64> {
        let mut q = grad.to_vec();
        if self.is_empty() {
            return q;
        }
        let k = self.len();
        let mut alpha = vec![0.0; k];
        for i in (0..k).rev() {
            alpha[i] = self.rho[i] * crate::utils::math::dot(&self.s[i], &q);
            for (qj, yj) in q.iter_mut().zip(&self.y[i]) {
                *qj -= alpha[i] * yj;
            }
        }
        // Initial Hessian scaling γ_k = sᵀy / yᵀy of the newest pair.
        let last = k - 1;
        let gamma = (1.0 / self.rho[last]) / crate::utils::math::l2_norm_sq(&self.y[last]);
        for qj in q.iter_mut() {
            *qj *= gamma;
        }
        for i in 0..k {
            let beta = self.rho[i] * crate::utils::math::dot(&self.y[i], &q);
            for (qj, sj) in q.iter_mut().zip(&self.s[i]) {
                *qj += (alpha[i] - beta) * sj;
            }
        }
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::math::dot;

    #[test]
    fn empty_history_is_identity() {
        let h = LbfgsHistory::new(5);
        assert_eq!(h.apply(&[1.0, -2.0]), vec![1.0, -2.0]);
    }

    #[test]
    fn rejects_negative_curvature() {
        let mut h = LbfgsHistory::new(5);
        h.push(vec![1.0, 0.0], vec![-1.0, 0.0]);
        assert!(h.is_empty());
    }

    #[test]
    fn memory_is_bounded() {
        let mut h = LbfgsHistory::new(2);
        for k in 1..=5 {
            h.push(vec![k as f64, 0.0], vec![k as f64, 0.0]);
        }
        assert_eq!(h.len(), 2);
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize ½ wᵀ A w − bᵀw with A = diag(1, 10): L-BFGS with exact
        // line search should reach the optimum quickly.
        let a = [1.0, 10.0];
        let b = [1.0, 1.0];
        let grad = |w: &[f64]| vec![a[0] * w[0] - b[0], a[1] * w[1] - b[1]];
        let mut h = LbfgsHistory::new(5);
        let mut w = vec![0.0, 0.0];
        for _ in 0..20 {
            let g = grad(&w);
            if crate::utils::math::l2_norm_sq(&g) < 1e-20 {
                break;
            }
            let dir: Vec<f64> = h.apply(&g).iter().map(|x| -x).collect();
            // exact line search for quadratic: t = −gᵀd / dᵀAd
            let gd = dot(&g, &dir);
            let dad = a[0] * dir[0] * dir[0] + a[1] * dir[1] * dir[1];
            let t = -gd / dad;
            let w_new: Vec<f64> = w.iter().zip(&dir).map(|(wi, di)| wi + t * di).collect();
            let g_new = grad(&w_new);
            h.push(
                w_new.iter().zip(&w).map(|(x, y)| x - y).collect(),
                g_new.iter().zip(&g).map(|(x, y)| x - y).collect(),
            );
            w = w_new;
        }
        assert!((w[0] - 1.0).abs() < 1e-8, "w0 = {}", w[0]);
        assert!((w[1] - 0.1).abs() < 1e-8, "w1 = {}", w[1]);
    }

    #[test]
    fn direction_is_descent() {
        let mut h = LbfgsHistory::new(3);
        h.push(vec![0.5, 0.1, -0.2], vec![0.4, 0.2, -0.1]);
        h.push(vec![-0.1, 0.3, 0.0], vec![-0.05, 0.25, 0.02]);
        let g = vec![1.0, -0.5, 0.25];
        let hg = h.apply(&g);
        // H is positive definite ⇒ gᵀHg > 0 ⇒ −Hg is a descent direction.
        assert!(dot(&g, &hg) > 0.0);
    }
}
