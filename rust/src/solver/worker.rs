//! Per-machine worker state: the shard of data plus the machine-local
//! optimizer variables of Algorithm 2.

use crate::data::{Dataset, Partition, SparseMatrix};
use crate::reg::Regularizer;

/// Machine-local state: `(S_ℓ, α_(ℓ), ṽ_ℓ)` plus caches.
///
/// `v_tilde` is kept at the *globally synchronized* value (Eq. 15);
/// during a local step the solver works on a scratch copy and the
/// difference becomes `Δv_ℓ`. `w` caches `∇g*(ṽ_ℓ)` and is refreshed by
/// the global step.
#[derive(Clone, Debug)]
pub struct WorkerState {
    /// Shard design matrix (rows = local examples, owned copy).
    pub x: SparseMatrix,
    /// Shard labels.
    pub y: Vec<f64>,
    /// Local dual variables `α_(ℓ)` (one scalar per local example).
    pub alpha: Vec<f64>,
    /// Synchronized `ṽ_ℓ` (length d).
    pub v_tilde: Vec<f64>,
    /// Cached `w_ℓ = ∇g*(ṽ_ℓ)` (length d).
    pub w: Vec<f64>,
    /// Precomputed `‖x_i‖²` per local example.
    pub row_norm_sq: Vec<f64>,
    /// Global indices of the shard (for debugging / trace).
    pub global_indices: Vec<usize>,
    /// Reused Δv accumulation buffer (length d, zero between local steps)
    /// — lets the mini-batch hot path run allocation-free (§Perf it. 3).
    pub scratch_delta: Vec<f64>,
    /// Reused touched-coordinate log for reverting the in-place `w`
    /// updates after a local step.
    pub scratch_touched: Vec<u32>,
}

impl WorkerState {
    /// Build worker `l`'s state from a dataset and partition.
    pub fn from_partition(data: &Dataset, part: &Partition, l: usize) -> Self {
        let idx = part.shard(l);
        let x = data.x.select_rows(idx);
        let y: Vec<f64> = idx.iter().map(|&i| data.y[i]).collect();
        let row_norm_sq: Vec<f64> = (0..x.rows()).map(|i| x.row(i).norm_sq()).collect();
        let d = data.dim();
        WorkerState {
            x,
            y,
            alpha: vec![0.0; idx.len()],
            v_tilde: vec![0.0; d],
            w: vec![0.0; d],
            row_norm_sq,
            global_indices: idx.to_vec(),
            scratch_delta: vec![0.0; d],
            scratch_touched: Vec::new(),
        }
    }

    /// Local shard size `n_ℓ`.
    pub fn n_l(&self) -> usize {
        self.y.len()
    }

    /// Feature dimension `d`.
    pub fn dim(&self) -> usize {
        self.v_tilde.len()
    }

    /// Apply the broadcast global update `ṽ_ℓ += Δṽ` and refresh `w`.
    pub fn apply_global<R: Regularizer>(&mut self, delta_v_tilde: &[f64], reg: &R) {
        for (v, &dv) in self.v_tilde.iter_mut().zip(delta_v_tilde) {
            *v += dv;
        }
        reg.grad_conj_into(&self.v_tilde, &mut self.w);
    }

    /// Overwrite the *touched* coordinates of `ṽ_ℓ` with their new
    /// global values and refresh the matching entries of `w`. This is
    /// the broadcast-apply of the fused round (DESIGN.md §4/§7): the
    /// message carries the changed coordinates of `ṽ` as values, not
    /// increments, so the worker replica stays **bit-identical** to the
    /// coordinator's `ṽ` (incremental `a + (Δ)` application accumulates
    /// ulp drift, which would break exact checkpoint resumption).
    pub fn set_v_tilde_sparse_parts<R: Regularizer>(&mut self, idx: &[u32], val: &[f64], reg: &R) {
        for (&j, &vj) in idx.iter().zip(val) {
            let ju = j as usize;
            self.v_tilde[ju] = vj;
            self.w[ju] = reg.grad_conj_at(ju, vj);
        }
    }

    /// Overwrite `ṽ_ℓ` (Acc-DADM stage transitions) and refresh `w`.
    pub fn set_v_tilde<R: Regularizer>(&mut self, v_tilde: &[f64], reg: &R) {
        self.v_tilde.copy_from_slice(v_tilde);
        reg.grad_conj_into(&self.v_tilde, &mut self.w);
    }

    /// Reset dual variables (fresh solve on the same shard).
    pub fn reset(&mut self) {
        self.alpha.iter_mut().for_each(|a| *a = 0.0);
        self.v_tilde.iter_mut().for_each(|v| *v = 0.0);
        self.w.iter_mut().for_each(|w| *w = 0.0);
    }

    /// `v_ℓ`-side contribution `Σ_{i∈S_ℓ} X_i α_i` (unscaled) — used by
    /// invariants tests to validate `ṽ` bookkeeping.
    pub fn raw_dual_combination(&self) -> Vec<f64> {
        self.x.matvec_t(&self.alpha)
    }

    /// Local primal sum `Σ_{i∈S_ℓ} φ_i(x_iᵀ w_global)`.
    pub fn primal_loss_sum<L: crate::loss::Loss>(&self, loss: &L, w: &[f64]) -> f64 {
        (0..self.n_l())
            .map(|i| loss.phi(self.x.row(i).dot(w), self.y[i]))
            .sum()
    }

    /// Local dual sum `Σ_{i∈S_ℓ} −φ_i*(−α_i)`.
    pub fn dual_conj_sum<L: crate::loss::Loss>(&self, loss: &L) -> f64 {
        (0..self.n_l())
            .map(|i| -loss.conj_neg(self.alpha[i], self.y[i]))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::tiny_classification;
    use crate::loss::{Loss, SmoothHinge};
    use crate::reg::ElasticNet;

    #[test]
    fn from_partition_shards_data() {
        let data = tiny_classification(20, 4, 3);
        let part = Partition::balanced(20, 3, 7);
        let total: usize = (0..3)
            .map(|l| WorkerState::from_partition(&data, &part, l).n_l())
            .sum();
        assert_eq!(total, 20);
        let w0 = WorkerState::from_partition(&data, &part, 0);
        assert_eq!(w0.dim(), 4);
        assert_eq!(w0.alpha.len(), w0.n_l());
        // Shard rows match the original data.
        for (local, &gi) in w0.global_indices.iter().enumerate() {
            assert_eq!(w0.x.row(local).to_dense(4), data.x.row(gi).to_dense(4));
            assert_eq!(w0.y[local], data.y[gi]);
        }
    }

    #[test]
    fn apply_global_refreshes_w() {
        let data = tiny_classification(10, 3, 1);
        let part = Partition::balanced(10, 2, 1);
        let mut ws = WorkerState::from_partition(&data, &part, 0);
        let reg = ElasticNet::new(0.5);
        ws.apply_global(&[1.0, -2.0, 0.2], &reg);
        assert_eq!(ws.v_tilde, vec![1.0, -2.0, 0.2]);
        assert_eq!(ws.w, vec![0.5, -1.5, 0.0]);
        // Incremental second application accumulates.
        ws.apply_global(&[0.5, 0.0, 0.0], &reg);
        assert_eq!(ws.v_tilde[0], 1.5);
        assert_eq!(ws.w[0], 1.0);
    }

    #[test]
    fn sparse_value_set_matches_dense_set() {
        // The sparse broadcast apply (values at touched coordinates)
        // must land on exactly the state a full `set_v_tilde` produces
        // when only those coordinates changed — the bit-identical
        // worker-replica property of DESIGN.md §7.
        let data = tiny_classification(10, 5, 2);
        let part = Partition::balanced(10, 2, 2);
        let reg = ElasticNet::new(0.2);
        let mut dense_ws = WorkerState::from_partition(&data, &part, 0);
        let mut sparse_ws = dense_ws.clone();
        // Establish a nonzero synced state first.
        let v0 = vec![0.5, -1.0, 0.0, 2.0, -0.3];
        dense_ws.set_v_tilde(&v0, &reg);
        sparse_ws.set_v_tilde(&v0, &reg);
        // The next global ṽ differs at coordinates 1 and 3 only.
        let v1 = vec![0.5, -0.25, 0.0, 1.5, -0.3];
        dense_ws.set_v_tilde(&v1, &reg);
        sparse_ws.set_v_tilde_sparse_parts(&[1, 3], &[v1[1], v1[3]], &reg);
        assert_eq!(dense_ws.v_tilde, sparse_ws.v_tilde);
        assert_eq!(dense_ws.w, sparse_ws.w);
    }

    #[test]
    fn sums_match_direct_computation() {
        let data = tiny_classification(12, 3, 9);
        let part = Partition::balanced(12, 2, 2);
        let mut ws = WorkerState::from_partition(&data, &part, 1);
        let loss = SmoothHinge::default();
        ws.alpha = (0..ws.n_l()).map(|i| ws.y[i] * 0.3).collect();
        let w = vec![0.1, -0.2, 0.4];
        let p: f64 = (0..ws.n_l())
            .map(|i| loss.phi(ws.x.row(i).dot(&w), ws.y[i]))
            .sum();
        assert!((ws.primal_loss_sum(&loss, &w) - p).abs() < 1e-12);
        let d: f64 = (0..ws.n_l())
            .map(|i| -loss.conj_neg(ws.alpha[i], ws.y[i]))
            .sum();
        assert!((ws.dual_conj_sum(&loss) - d).abs() < 1e-12);
    }
}
