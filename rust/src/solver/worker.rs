//! Per-machine worker state: the shard of data plus the machine-local
//! optimizer variables of Algorithm 2.

use crate::data::{Dataset, Partition, SparseMatrix};
use crate::reg::Regularizer;
use crate::utils::Rng;

/// Machine `l`'s private mini-batch RNG stream, exactly as
/// `Dadm::new` derives it: a seed generator forked once per machine in
/// index order. Replaying the fork sequence makes the stream computable
/// for a *single* machine — which is how a remote TCP worker (hosting
/// only machine `l`) reproduces the coordinator's draws bit for bit.
pub fn machine_rng(seed: u64, l: usize) -> Rng {
    let mut seed_rng = Rng::new(seed);
    let mut rng = seed_rng.fork(0);
    for i in 1..=l as u64 {
        rng = seed_rng.fork(i);
    }
    rng
}

/// The `count` consecutive logical-machine RNG streams starting at index
/// `first`, with one replay of the fork sequence (instead of `count`
/// O(first) replays of [`machine_rng`]). Under hierarchical parallelism
/// (DESIGN.md §10) machine `l` hosts logical sub-solvers
/// `l·T .. l·T + T`, so a remote TCP worker calls
/// `machine_rngs(seed, l * t, t)` and gets streams bit-identical to the
/// coordinator's flat `machine_rng(seed, l·T + k)` forks.
pub fn machine_rngs(seed: u64, first: usize, count: usize) -> Vec<Rng> {
    let mut seed_rng = Rng::new(seed);
    let mut out = Vec::with_capacity(count);
    for i in 0..(first + count) as u64 {
        let rng = seed_rng.fork(i);
        if i >= first as u64 {
            out.push(rng);
        }
    }
    out
}

/// Mini-batch size `M_ℓ = ⌈sp · n_ℓ⌉`, clamped into `[1, n_ℓ]` — the one
/// formula both the coordinator and remote TCP workers must share.
pub fn batch_size(sp: f64, n_l: usize) -> usize {
    ((sp * n_l as f64).ceil() as usize).clamp(1, n_l)
}

/// One machine's local-step leg, exactly as every backend must run it:
/// draw the mini-batch from the machine's private RNG stream, then run
/// the solver with the `λ·n_ℓ` dual scaling. Shared by `Dadm::round`'s
/// in-process closure and the TCP worker's `LocalStep` handler so the
/// two can never drift apart (the bit-parity contract of DESIGN.md §9).
pub fn run_local_step<L, R, S>(
    solver: &S,
    state: &mut WorkerState,
    rng: &mut Rng,
    batch: usize,
    loss: &L,
    reg: &R,
    lambda: f64,
) -> crate::comm::sparse::Delta
where
    L: crate::loss::Loss,
    R: Regularizer,
    S: super::LocalSolver,
{
    let n_l = state.n_l();
    let batch_idx = rng.sample_indices(n_l, batch);
    solver.local_step(state, &batch_idx, loss, reg, lambda * n_l as f64, rng)
}

/// [`run_local_step`] plus the fused gap telemetry of DESIGN.md §11, in
/// the one canonical order every backend must follow: entering loss sum
/// (at the just-synced replica, *before* the step), local step, exact
/// conjugate resummation, post-step running-conjugate read. The caller
/// applies its pending broadcast first (the broadcast types differ per
/// backend). Shared by `Dadm::round_fused`'s in-process leg and the TCP
/// worker's `LocalStep` handler so the telemetry points can never drift
/// apart between backends.
#[allow(clippy::too_many_arguments)]
pub fn run_fused_step<L, R, S>(
    solver: &S,
    state: &mut WorkerState,
    rng: &mut Rng,
    batch: usize,
    loss: &L,
    reg: &R,
    lambda: f64,
    eval_loss: bool,
    want_conj: bool,
    resum_conj: bool,
) -> (crate::comm::sparse::Delta, Option<f64>, Option<f64>)
where
    L: crate::loss::Loss,
    R: Regularizer,
    S: super::LocalSolver,
{
    let loss_sum = eval_loss.then(|| state.primal_loss_sum(loss, &state.w));
    let delta = run_local_step(solver, state, rng, batch, loss, reg, lambda);
    if resum_conj {
        state.resum_conj(loss);
    }
    let conj = want_conj.then(|| state.conj_running(loss));
    (delta, loss_sum, conj)
}

/// Machine-local state: `(S_ℓ, α_(ℓ), ṽ_ℓ)` plus caches.
///
/// `v_tilde` is kept at the *globally synchronized* value (Eq. 15);
/// during a local step the solver works on a scratch copy and the
/// difference becomes `Δv_ℓ`. `w` caches `∇g*(ṽ_ℓ)` and is refreshed by
/// the global step.
#[derive(Clone, Debug)]
pub struct WorkerState {
    /// Shard design matrix (rows = local examples, owned copy).
    pub x: SparseMatrix,
    /// Shard labels.
    pub y: Vec<f64>,
    /// Local dual variables `α_(ℓ)` (one scalar per local example).
    pub alpha: Vec<f64>,
    /// Synchronized `ṽ_ℓ` (length d).
    pub v_tilde: Vec<f64>,
    /// Cached `w_ℓ = ∇g*(ṽ_ℓ)` (length d).
    pub w: Vec<f64>,
    /// Precomputed `‖x_i‖²` per local example.
    pub row_norm_sq: Vec<f64>,
    /// Global indices of the shard (for debugging / trace).
    pub global_indices: Vec<usize>,
    /// Reused Δv accumulation buffer (length d, zero between local steps)
    /// — lets the mini-batch hot path run allocation-free (§Perf it. 3).
    pub scratch_delta: Vec<f64>,
    /// Reused touched-coordinate log for reverting the in-place `w`
    /// updates after a local step.
    pub scratch_touched: Vec<u32>,
    /// Reused mini-batch visit-order buffer ([`crate::solver::ProxSdca`]
    /// shuffles here instead of allocating a `batch.to_vec()` per round).
    pub scratch_order: Vec<usize>,
    /// Spare pre-zeroed Δv buffer: a dense-message round gives its
    /// `scratch_delta` away as the outgoing message and swaps this in
    /// (`mem::replace`) so the next round starts from zeros without a
    /// length-d clone + fill; subsequent dense rounds replenish it with
    /// a fresh zeroed vector (calloc — still cheaper than clone + fill).
    pub scratch_delta_spare: Vec<f64>,
    /// Running local dual sum `Σ_{i∈S_ℓ} −φ*(−α_i)` (DESIGN.md §11),
    /// maintained in O(1) per touched coordinate by the local solvers.
    /// `None` = stale: the value has not been requested yet, or `α` was
    /// mutated by a path that cannot maintain it (reset, a non-tracking
    /// solver, a v1/v2 checkpoint restore); the next
    /// [`WorkerState::conj_running`] read rebuilds it exactly.
    pub conj_sum: Option<f64>,
    /// Error-feedback residual of the machine's outgoing Δv compression
    /// (DESIGN.md §13): the per-coordinate quantization error still owed
    /// to the coordinator, folded back into the next round's delta by
    /// [`crate::comm::sparse::compress_delta`]. Empty until the first
    /// compressed round (and always empty in exact-f64 mode). Under
    /// hierarchical parallelism the residual lives on the machine's
    /// *lead* sub-solver only — quantization happens once per machine,
    /// after the wire-free sub-merge. Solver state: checkpointed (v4)
    /// so a resumed compressed run replays bit-identically.
    pub residual: Vec<f64>,
}

/// `Some(start..end)` when `idx` is a non-empty ascending run of
/// consecutive indices — the shape every [`Partition::contiguous`]
/// shard has.
fn contiguous_run(idx: &[usize]) -> Option<std::ops::Range<usize>> {
    let first = *idx.first()?;
    for (k, &i) in idx.iter().enumerate() {
        if i != first + k {
            return None;
        }
    }
    Some(first..first + idx.len())
}

impl WorkerState {
    /// Build worker `l`'s state from a dataset and partition.
    ///
    /// A contiguous shard of a mapped dataset (the `--cache` +
    /// contiguous-partition path) is taken as a zero-copy row-range
    /// view; anything else is an owned copy. The values are identical
    /// either way, so solves don't depend on the storage backend.
    pub fn from_partition(data: &Dataset, part: &Partition, l: usize) -> Self {
        let idx = part.shard(l);
        let x = match contiguous_run(idx) {
            Some(range) if data.x.is_mapped() => data.x.slice_rows(range),
            _ => data.x.select_rows(idx),
        };
        let y: Vec<f64> = idx.iter().map(|&i| data.y[i]).collect();
        WorkerState::from_matrix(x, y, idx.to_vec())
    }

    /// Build a worker state directly from an explicit shard (the TCP
    /// `DataSpec::Shard` path: rows already selected by the coordinator).
    /// Produces exactly the state [`WorkerState::from_partition`] would
    /// for the same shard.
    pub fn from_shard(
        rows: Vec<Vec<(u32, f64)>>,
        y: Vec<f64>,
        global_indices: Vec<usize>,
        dim: usize,
    ) -> Self {
        let x = SparseMatrix::from_rows(rows, dim);
        WorkerState::from_matrix(x, y, global_indices)
    }

    /// Build a worker state from an already-built shard matrix — the
    /// shared tail of [`WorkerState::from_partition`] /
    /// [`WorkerState::from_shard`], and the entry point of the mapped
    /// cache path (`DataSpec::Cache`): the matrix may be a zero-copy
    /// row range of an mmapped cache file, in which case no shard data
    /// is copied at all.
    pub fn from_matrix(x: SparseMatrix, y: Vec<f64>, global_indices: Vec<usize>) -> Self {
        assert_eq!(x.rows(), y.len(), "shard rows/labels mismatch");
        assert_eq!(x.rows(), global_indices.len(), "shard rows/indices mismatch");
        let dim = x.cols();
        let n_l = x.rows();
        let row_norm_sq: Vec<f64> = (0..x.rows()).map(|i| x.row(i).norm_sq()).collect();
        WorkerState {
            x,
            y,
            alpha: vec![0.0; n_l],
            v_tilde: vec![0.0; dim],
            w: vec![0.0; dim],
            row_norm_sq,
            global_indices,
            scratch_delta: vec![0.0; dim],
            scratch_touched: Vec::new(),
            scratch_order: Vec::new(),
            scratch_delta_spare: vec![0.0; dim],
            conj_sum: None,
            residual: Vec::new(),
        }
    }

    /// Local shard size `n_ℓ`.
    pub fn n_l(&self) -> usize {
        self.y.len()
    }

    /// Feature dimension `d`.
    pub fn dim(&self) -> usize {
        self.v_tilde.len()
    }

    /// Apply the broadcast global update `ṽ_ℓ += Δṽ` and refresh `w`.
    pub fn apply_global<R: Regularizer>(&mut self, delta_v_tilde: &[f64], reg: &R) {
        for (v, &dv) in self.v_tilde.iter_mut().zip(delta_v_tilde) {
            *v += dv;
        }
        reg.grad_conj_into(&self.v_tilde, &mut self.w);
    }

    /// Overwrite the *touched* coordinates of `ṽ_ℓ` with their new
    /// global values and refresh the matching entries of `w`. This is
    /// the broadcast-apply of the fused round (DESIGN.md §4/§7): the
    /// message carries the changed coordinates of `ṽ` as values, not
    /// increments, so the worker replica stays **bit-identical** to the
    /// coordinator's `ṽ` (incremental `a + (Δ)` application accumulates
    /// ulp drift, which would break exact checkpoint resumption).
    pub fn set_v_tilde_sparse_parts<R: Regularizer>(&mut self, idx: &[u32], val: &[f64], reg: &R) {
        for (&j, &vj) in idx.iter().zip(val) {
            let ju = j as usize;
            self.v_tilde[ju] = vj;
            self.w[ju] = reg.grad_conj_at(ju, vj);
        }
    }

    /// Add the broadcast increment at the listed coordinates and refresh
    /// the matching entries of `w` — the compressed-broadcast apply
    /// (DESIGN.md §13). Unlike [`WorkerState::set_v_tilde_sparse_parts`]
    /// the message carries *increments* (quantized Δṽ images carrying the
    /// coordinator's error feedback); every replica applies the same
    /// f64 adds in the same coordinate order, so all replicas — and the
    /// coordinator's `v_image` shadow — stay bit-identical to each other.
    pub fn add_v_tilde_sparse_parts<R: Regularizer>(&mut self, idx: &[u32], val: &[f64], reg: &R) {
        for (&j, &dv) in idx.iter().zip(val) {
            let ju = j as usize;
            self.v_tilde[ju] += dv;
            self.w[ju] = reg.grad_conj_at(ju, self.v_tilde[ju]);
        }
    }

    /// Overwrite `ṽ_ℓ` (Acc-DADM stage transitions) and refresh `w`.
    pub fn set_v_tilde<R: Regularizer>(&mut self, v_tilde: &[f64], reg: &R) {
        self.v_tilde.copy_from_slice(v_tilde);
        reg.grad_conj_into(&self.v_tilde, &mut self.w);
    }

    /// Reset dual variables (fresh solve on the same shard).
    pub fn reset(&mut self) {
        self.alpha.iter_mut().for_each(|a| *a = 0.0);
        self.v_tilde.iter_mut().for_each(|v| *v = 0.0);
        self.w.iter_mut().for_each(|w| *w = 0.0);
        self.conj_sum = None;
        self.residual.clear();
    }

    /// `v_ℓ`-side contribution `Σ_{i∈S_ℓ} X_i α_i` (unscaled) — used by
    /// invariants tests to validate `ṽ` bookkeeping.
    pub fn raw_dual_combination(&self) -> Vec<f64> {
        self.x.matvec_t(&self.alpha)
    }

    /// Local primal sum `Σ_{i∈S_ℓ} φ_i(x_iᵀ w_global)`.
    pub fn primal_loss_sum<L: crate::loss::Loss>(&self, loss: &L, w: &[f64]) -> f64 {
        (0..self.n_l())
            .map(|i| loss.phi(self.x.row(i).dot(w), self.y[i]))
            .sum()
    }

    /// Local dual sum `Σ_{i∈S_ℓ} −φ_i*(−α_i)`, recomputed exactly with
    /// one O(n_ℓ) pass — the reference the running [`WorkerState::conj_sum`]
    /// is initialized from, resummed against, and drift-tested against.
    pub fn dual_conj_sum<L: crate::loss::Loss>(&self, loss: &L) -> f64 {
        (0..self.n_l())
            .map(|i| -loss.conj_neg(self.alpha[i], self.y[i]))
            .sum()
    }

    /// The running local dual sum `Σ −φ*(−α_i)` — an O(1) read once
    /// initialized (DESIGN.md §11). A stale sum (`conj_sum == None`) is
    /// rebuilt exactly here, which is also what arms the incremental
    /// maintenance in the tracking local solvers.
    pub fn conj_running<L: crate::loss::Loss>(&mut self, loss: &L) -> f64 {
        match self.conj_sum {
            Some(c) => c,
            None => {
                let c = self.dual_conj_sum(loss);
                self.conj_sum = Some(c);
                c
            }
        }
    }

    /// Exact resummation of the running dual sum — bounds the float
    /// drift of the incremental O(1) updates. A no-op while the sum is
    /// not being tracked (a later first read is exact anyway).
    pub fn resum_conj<L: crate::loss::Loss>(&mut self, loss: &L) {
        if self.conj_sum.is_some() {
            self.conj_sum = Some(self.dual_conj_sum(loss));
        }
    }

    /// The OWL-QN smooth-part oracle's per-shard raw sums at `w`:
    /// `(Σ x_i·φ'_i ‖ Σ φ_i)` as a `d + 1` vector — one fused pass over
    /// the shard. Shared by the in-process oracle and the TCP worker's
    /// `GradOracle` handler so the two traversals can never drift apart
    /// (the bit-parity contract of DESIGN.md §9).
    pub fn grad_oracle_sums<L: crate::loss::Loss>(&self, loss: &L, w: &[f64]) -> Vec<f64> {
        let d = self.dim();
        debug_assert_eq!(w.len(), d);
        let mut grad = vec![0.0; d + 1];
        for i in 0..self.n_l() {
            let row = self.x.row(i);
            let u = row.dot(w);
            grad[d] += loss.phi(u, self.y[i]);
            let gi = loss.grad(u, self.y[i]);
            if gi != 0.0 {
                row.axpy_into(gi, &mut grad[..d]);
            }
        }
        grad
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::tiny_classification;
    use crate::loss::{Loss, SmoothHinge};
    use crate::reg::ElasticNet;

    #[test]
    fn machine_rng_replays_sequential_forks() {
        // The helper must reproduce the coordinator's fork-in-index-order
        // streams exactly — the property remote TCP workers rely on.
        let seed = 0xDA_DA;
        let mut seq = Rng::new(seed);
        let direct: Vec<Rng> = (0..5).map(|l| seq.fork(l as u64)).collect();
        for (l, mut want) in direct.into_iter().enumerate() {
            let mut got = machine_rng(seed, l);
            for _ in 0..50 {
                assert_eq!(got.next_u64(), want.next_u64(), "stream {l} diverged");
            }
        }
    }

    #[test]
    fn machine_rngs_match_per_index_replay() {
        let seed = 0xF0_0D;
        for (first, count) in [(0usize, 4usize), (3, 2), (6, 1), (2, 0)] {
            let got = machine_rngs(seed, first, count);
            assert_eq!(got.len(), count);
            for (k, mut rng) in got.into_iter().enumerate() {
                let mut want = machine_rng(seed, first + k);
                for _ in 0..40 {
                    assert_eq!(
                        rng.next_u64(),
                        want.next_u64(),
                        "stream {} diverged",
                        first + k
                    );
                }
            }
        }
    }

    #[test]
    fn batch_size_matches_coordinator_formula() {
        assert_eq!(batch_size(0.2, 25), 5);
        assert_eq!(batch_size(1.0, 25), 25);
        assert_eq!(batch_size(1e-9, 25), 1); // clamped up
        assert_eq!(batch_size(0.3, 10), 3);
    }

    #[test]
    fn from_shard_matches_from_partition() {
        let data = tiny_classification(20, 4, 3);
        let part = Partition::balanced(20, 3, 7);
        for l in 0..3 {
            let want = WorkerState::from_partition(&data, &part, l);
            let shard = part.shard(l);
            let rows: Vec<Vec<(u32, f64)>> = shard
                .iter()
                .map(|&i| {
                    let r = data.x.row(i);
                    r.indices.iter().copied().zip(r.values.iter().copied()).collect()
                })
                .collect();
            let y: Vec<f64> = shard.iter().map(|&i| data.y[i]).collect();
            let got = WorkerState::from_shard(rows, y, shard.to_vec(), data.dim());
            assert_eq!(got.y, want.y);
            assert_eq!(got.alpha, want.alpha);
            assert_eq!(got.row_norm_sq, want.row_norm_sq);
            assert_eq!(got.global_indices, want.global_indices);
            for i in 0..got.n_l() {
                assert_eq!(got.x.row(i).indices, want.x.row(i).indices);
                assert_eq!(got.x.row(i).values, want.x.row(i).values);
            }
        }
    }

    #[test]
    fn from_partition_shards_data() {
        let data = tiny_classification(20, 4, 3);
        let part = Partition::balanced(20, 3, 7);
        let total: usize = (0..3)
            .map(|l| WorkerState::from_partition(&data, &part, l).n_l())
            .sum();
        assert_eq!(total, 20);
        let w0 = WorkerState::from_partition(&data, &part, 0);
        assert_eq!(w0.dim(), 4);
        assert_eq!(w0.alpha.len(), w0.n_l());
        // Shard rows match the original data.
        for (local, &gi) in w0.global_indices.iter().enumerate() {
            assert_eq!(w0.x.row(local).to_dense(4), data.x.row(gi).to_dense(4));
            assert_eq!(w0.y[local], data.y[gi]);
        }
    }

    #[test]
    fn apply_global_refreshes_w() {
        let data = tiny_classification(10, 3, 1);
        let part = Partition::balanced(10, 2, 1);
        let mut ws = WorkerState::from_partition(&data, &part, 0);
        let reg = ElasticNet::new(0.5);
        ws.apply_global(&[1.0, -2.0, 0.2], &reg);
        assert_eq!(ws.v_tilde, vec![1.0, -2.0, 0.2]);
        assert_eq!(ws.w, vec![0.5, -1.5, 0.0]);
        // Incremental second application accumulates.
        ws.apply_global(&[0.5, 0.0, 0.0], &reg);
        assert_eq!(ws.v_tilde[0], 1.5);
        assert_eq!(ws.w[0], 1.0);
    }

    #[test]
    fn sparse_value_set_matches_dense_set() {
        // The sparse broadcast apply (values at touched coordinates)
        // must land on exactly the state a full `set_v_tilde` produces
        // when only those coordinates changed — the bit-identical
        // worker-replica property of DESIGN.md §7.
        let data = tiny_classification(10, 5, 2);
        let part = Partition::balanced(10, 2, 2);
        let reg = ElasticNet::new(0.2);
        let mut dense_ws = WorkerState::from_partition(&data, &part, 0);
        let mut sparse_ws = dense_ws.clone();
        // Establish a nonzero synced state first.
        let v0 = vec![0.5, -1.0, 0.0, 2.0, -0.3];
        dense_ws.set_v_tilde(&v0, &reg);
        sparse_ws.set_v_tilde(&v0, &reg);
        // The next global ṽ differs at coordinates 1 and 3 only.
        let v1 = vec![0.5, -0.25, 0.0, 1.5, -0.3];
        dense_ws.set_v_tilde(&v1, &reg);
        sparse_ws.set_v_tilde_sparse_parts(&[1, 3], &[v1[1], v1[3]], &reg);
        assert_eq!(dense_ws.v_tilde, sparse_ws.v_tilde);
        assert_eq!(dense_ws.w, sparse_ws.w);
    }

    #[test]
    fn sparse_add_applies_increments_and_refreshes_w() {
        // The compressed-broadcast apply (increments at touched
        // coordinates) must land on the state a value-set would produce
        // when the increments are exactly representable — and must
        // refresh `w` at exactly the touched coordinates.
        let data = tiny_classification(10, 5, 2);
        let part = Partition::balanced(10, 2, 2);
        let reg = ElasticNet::new(0.2);
        let mut set_ws = WorkerState::from_partition(&data, &part, 0);
        let mut add_ws = set_ws.clone();
        let v0 = vec![0.5, -1.0, 0.0, 2.0, -0.3];
        set_ws.set_v_tilde(&v0, &reg);
        add_ws.set_v_tilde(&v0, &reg);
        // Increments at coordinates 1 and 3; powers of two keep the f64
        // adds exact so the two paths must agree bit for bit.
        add_ws.add_v_tilde_sparse_parts(&[1, 3], &[0.75, -0.5], &reg);
        set_ws.set_v_tilde_sparse_parts(&[1, 3], &[-0.25, 1.5], &reg);
        assert_eq!(set_ws.v_tilde, add_ws.v_tilde);
        assert_eq!(set_ws.w, add_ws.w);
        // A second add accumulates on top of the first.
        add_ws.add_v_tilde_sparse_parts(&[1], &[0.25], &reg);
        assert_eq!(add_ws.v_tilde[1], 0.0);
        assert_eq!(add_ws.w[1], reg.grad_conj_at(1, 0.0));
    }

    #[test]
    fn conj_running_initializes_exactly_and_invalidates() {
        let data = tiny_classification(16, 3, 4);
        let part = Partition::balanced(16, 2, 4);
        let mut ws = WorkerState::from_partition(&data, &part, 0);
        let loss = SmoothHinge::default();
        assert!(ws.conj_sum.is_none(), "lazy: no cost before the first read");
        // resum_conj is a no-op while untracked.
        ws.resum_conj(&loss);
        assert!(ws.conj_sum.is_none());
        // First read = exact recomputation, bit for bit.
        let got = ws.conj_running(&loss);
        assert_eq!(got.to_bits(), ws.dual_conj_sum(&loss).to_bits());
        assert_eq!(ws.conj_sum, Some(got));
        // reset() marks the sum stale along with the duals.
        ws.reset();
        assert!(ws.conj_sum.is_none());
    }

    #[test]
    fn sums_match_direct_computation() {
        let data = tiny_classification(12, 3, 9);
        let part = Partition::balanced(12, 2, 2);
        let mut ws = WorkerState::from_partition(&data, &part, 1);
        let loss = SmoothHinge::default();
        ws.alpha = (0..ws.n_l()).map(|i| ws.y[i] * 0.3).collect();
        let w = vec![0.1, -0.2, 0.4];
        let p: f64 = (0..ws.n_l())
            .map(|i| loss.phi(ws.x.row(i).dot(&w), ws.y[i]))
            .sum();
        assert!((ws.primal_loss_sum(&loss, &w) - p).abs() < 1e-12);
        let d: f64 = (0..ws.n_l())
            .map(|i| -loss.conj_neg(ws.alpha[i], ws.y[i]))
            .sum();
        assert!((ws.dual_conj_sum(&loss) - d).abs() < 1e-12);
    }
}
