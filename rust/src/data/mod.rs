//! Data substrate: design matrices, dataset I/O, synthetic generators,
//! and the balanced partitioner.
//!
//! The paper evaluates on four LIBSVM datasets (covtype, rcv1, HIGGS,
//! kdd2010 — Table 1). Real data is not shipped with this repository, so
//! [`synthetic`] provides generators matched to each dataset's (n, d,
//! sparsity, label balance) profile at a configurable scale, while
//! [`libsvm`] parses the real files unchanged if the user supplies them.

pub mod cache;
pub mod dense;
pub mod libsvm;
pub mod partition;
pub mod sparse;
pub mod synthetic;

pub use cache::{CacheError, CsrCache};
pub use partition::{Balance, Partition};
pub use sparse::{SparseMatrix, SparseRow};

/// A binary-classification / regression dataset in row-major sparse form.
///
/// `X` is stored row-wise (one [`SparseRow`] per example, matching the
/// paper's `X_i` columns of the design matrix with `q = 1`), labels are
/// `±1` for classification or reals for regression.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Design matrix, one row per example.
    pub x: SparseMatrix,
    /// Labels, `y.len() == x.rows()`.
    pub y: Vec<f64>,
    /// Human-readable name (used by bench output).
    pub name: String,
}

impl Dataset {
    /// Number of examples `n`.
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    /// Feature dimension `d`.
    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// `R = max_i ‖x_i‖²` — the data-radius constant in Theorems 6/7/11.
    pub fn max_row_norm_sq(&self) -> f64 {
        (0..self.n())
            .map(|i| self.x.row(i).norm_sq())
            .fold(0.0, f64::max)
    }

    /// Fraction of structurally non-zero entries.
    pub fn density(&self) -> f64 {
        self.x.nnz() as f64 / (self.n() as f64 * self.dim() as f64)
    }

    /// Basic sanity checks used by loaders and generators.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.y.len() == self.x.rows(),
            "label count {} != row count {}",
            self.y.len(),
            self.x.rows()
        );
        anyhow::ensure!(self.x.rows() > 0, "empty dataset");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let x = SparseMatrix::from_dense(&[vec![1.0, 0.0], vec![0.0, 2.0], vec![3.0, 4.0]]);
        Dataset {
            x,
            y: vec![1.0, -1.0, 1.0],
            name: "tiny".into(),
        }
    }

    #[test]
    fn dims() {
        let d = tiny();
        assert_eq!(d.n(), 3);
        assert_eq!(d.dim(), 2);
        d.validate().unwrap();
    }

    #[test]
    fn radius() {
        let d = tiny();
        assert_eq!(d.max_row_norm_sq(), 25.0);
    }

    #[test]
    fn density_counts_structural_nnz() {
        let d = tiny();
        assert!((d.density() - 4.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn validate_rejects_mismatch() {
        let mut d = tiny();
        d.y.pop();
        assert!(d.validate().is_err());
    }
}
