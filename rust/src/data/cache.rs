//! Binary CSR shard cache: compile LIBSVM text once, mmap it forever.
//!
//! The paper's premise is data parallelism over shards that never move
//! (§10 runs kdd2010-class datasets), yet text-parsing LIBSVM on every
//! run makes worker startup O(dataset) and caps the trainable problem
//! at RAM. This module compiles a LIBSVM file into a versioned binary
//! CSR image (`dadm compile-cache`), then serves [`SparseRow`] views
//! zero-copy straight out of a read-only memory mapping: opening a
//! cache is O(1) in data size, the OS pages rows in on demand, and a
//! resurrected worker (DESIGN.md §14) re-mmaps in milliseconds instead
//! of re-parsing gigabytes. On-disk layout, alignment rules, and the
//! mmap safety argument live in DESIGN.md §15.
//!
//! # On-disk layout (all integers little-endian)
//!
//! ```text
//! offset  size  field
//!      0     8  magic  b"DADMCSR1"
//!      8     4  format_version (= 1)
//!     12     4  reserved (= 0)
//!     16     8  content_hash (FNV-1a-64, see below)
//!     24     8  n      (rows)
//!     32     8  d      (columns)
//!     40     8  nnz    (stored entries)
//!     48     8  labels_off   (= 88)
//!     56     8  indptr_off   (= labels_off + 8·n)
//!     64     8  indices_off  (= indptr_off + 8·(n+1))
//!     72     8  values_off   (= indices_off + 4·nnz, padded to 8)
//!     80     8  file_len     (= values_off + 8·nnz)
//!     88        labels   n × f64
//!             indptr   (n+1) × u64   (absolute entry offsets, [0] = 0)
//!             indices  nnz × u32     (+ zero pad to 8-byte boundary)
//!             values   nnz × f64
//! ```
//!
//! Every section offset is 8-byte aligned by construction (the
//! `indices` section only needs 4), so reinterpreting mapped bytes as
//! `u64`/`f64`/`u32` slices is layout-sound on any little-endian host;
//! big-endian hosts are rejected at open. Decoding is **total**:
//! corrupt, truncated, misaligned, or hash-mismatched caches surface as
//! typed [`CacheError`]s — never panics, never count-driven giant
//! allocations (nothing is allocated from header counts; all sections
//! stay in the mapping).
//!
//! # Content hash = cache identity
//!
//! `content_hash` is FNV-1a-64 (same function as the `wire.schema`
//! fingerprint) over `format_version ‖ n ‖ d ‖ nnz ‖ h(labels) ‖
//! h(indptr) ‖ h(indices) ‖ h(values)` where each `h(·)` is FNV-1a-64
//! of that section's logical payload bytes. It is computed once at
//! compile time and **recorded as the cache's identity**: the wire-v6
//! `DataSpec::Cache` hashes it into the problem spec so a resurrected
//! worker provably re-mmaps the same bytes ("state is a pure function
//! of (spec, frame bytes)"). Opening does *not* rehash the data — that
//! would make open O(dataset) again; [`CsrCache::verify_content`] does
//! the full O(data) check on demand.

use super::libsvm::{parse_line, uses_zero_one_labels};
use super::sparse::append_normalized_row;
use super::{Dataset, SparseMatrix};
use crate::utils::mmap::{map_readonly, Mmap};
use std::fs::File;
use std::io::{BufRead, BufReader, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// First 8 bytes of every cache file.
pub const CACHE_MAGIC: [u8; 8] = *b"DADMCSR1";
/// On-disk format version; bump on any layout change.
pub const CACHE_FORMAT_VERSION: u32 = 1;
/// Fixed header size in bytes (the labels section starts here).
pub const CACHE_HEADER_BYTES: u64 = 88;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_update(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Typed, total error surface of the cache layer (DESIGN.md §12: no
/// panic, no unwrap, no unbounded allocation on attacker-controlled
/// counts).
#[derive(Debug)]
pub enum CacheError {
    /// Underlying file I/O failed.
    Io(std::io::Error),
    /// LIBSVM input failed to parse during `compile`.
    Parse(String),
    /// The first 8 bytes are not `DADMCSR1`.
    BadMagic,
    /// Known magic, unknown format version.
    BadVersion { got: u32, want: u32 },
    /// The file is shorter than its header claims.
    Truncated { need: u64, have: u64 },
    /// A section offset violates the alignment rules.
    Misaligned { section: &'static str, offset: u64 },
    /// The cache identity does not match what the caller expected
    /// (resurrection safety: a worker must never train on different
    /// bytes than the coordinator partitioned).
    HashMismatch { got: u64, want: u64 },
    /// Structurally invalid contents (bad offsets, non-monotone row
    /// pointers, out-of-range columns, input changed mid-compile, ...).
    Malformed(String),
}

impl std::fmt::Display for CacheError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CacheError::Io(e) => write!(f, "cache i/o error: {e}"),
            CacheError::Parse(m) => write!(f, "cache compile parse error: {m}"),
            CacheError::BadMagic => write!(f, "not a dadm cache file (bad magic)"),
            CacheError::BadVersion { got, want } => {
                write!(f, "unsupported cache format version {got} (expected {want})")
            }
            CacheError::Truncated { need, have } => {
                write!(f, "truncated cache file: need {need} bytes, have {have}")
            }
            CacheError::Misaligned { section, offset } => {
                write!(f, "misaligned cache section `{section}` at offset {offset}")
            }
            CacheError::HashMismatch { got, want } => write!(
                f,
                "cache identity mismatch: file has {got:016x}, expected {want:016x}"
            ),
            CacheError::Malformed(m) => write!(f, "malformed cache: {m}"),
        }
    }
}

impl std::error::Error for CacheError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CacheError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CacheError {
    fn from(e: std::io::Error) -> Self {
        CacheError::Io(e)
    }
}

/// What `compile` produced — printed by `dadm compile-cache`.
#[derive(Clone, Copy, Debug)]
pub struct CompileReport {
    /// Rows compiled.
    pub n: u64,
    /// Feature dimension.
    pub d: u64,
    /// Stored non-zeros after per-row normalization.
    pub nnz: u64,
    /// The cache identity (header `content_hash`).
    pub content_hash: u64,
    /// Total output size in bytes.
    pub bytes: u64,
}

/// Pad `len` up to the next multiple of 8.
fn pad8(len: u64) -> u64 {
    len.div_ceil(8) * 8
}

/// One output section written incrementally at a fixed file region:
/// bytes are buffered, hashed, and flushed with an explicit seek so
/// four sections can interleave over a single descriptor without ever
/// materializing a section in memory (satellite: streaming compile).
struct SectionWriter {
    off: u64,
    buf: Vec<u8>,
    hash: u64,
    written: u64,
}

const FLUSH_CHUNK: usize = 1 << 20;

impl SectionWriter {
    fn new(off: u64) -> Self {
        SectionWriter {
            off,
            buf: Vec::new(),
            hash: FNV_OFFSET,
            written: 0,
        }
    }

    fn push(&mut self, file: &mut File, bytes: &[u8]) -> Result<(), CacheError> {
        self.hash = fnv_update(self.hash, bytes);
        self.written += bytes.len() as u64;
        self.buf.extend_from_slice(bytes);
        if self.buf.len() >= FLUSH_CHUNK {
            self.flush(file)?;
        }
        Ok(())
    }

    /// Raw pad bytes: written but not part of the logical payload hash.
    fn push_pad(&mut self, file: &mut File, bytes: &[u8]) -> Result<(), CacheError> {
        self.buf.extend_from_slice(bytes);
        self.flush(file)
    }

    fn flush(&mut self, file: &mut File) -> Result<(), CacheError> {
        if self.buf.is_empty() {
            return Ok(());
        }
        file.seek(SeekFrom::Start(self.off))?;
        file.write_all(&self.buf)?;
        self.off += self.buf.len() as u64;
        self.buf.clear();
        Ok(())
    }
}

/// One streaming pass over the LIBSVM input: counts and (optionally)
/// per-example callbacks, with per-row normalization identical to
/// [`SparseMatrix::from_rows`] by construction (shared helper).
struct ScanStats {
    n: u64,
    nnz: u64,
    max_col: usize,
    all_zero_one: bool,
    any_zero: bool,
}

fn scan_input<F>(path: &Path, mut per_row: F) -> Result<ScanStats, CacheError>
where
    F: FnMut(f64, &[u32], &[f64]) -> Result<(), CacheError>,
{
    let file = File::open(path)?;
    let reader = BufReader::new(file);
    let mut stats = ScanStats {
        n: 0,
        nnz: 0,
        max_col: 0,
        all_zero_one: true,
        any_zero: false,
    };
    let mut scratch_idx: Vec<u32> = Vec::new();
    let mut scratch_val: Vec<f64> = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let parsed = parse_line(&line, lineno, &mut stats.max_col)
            .map_err(|e| CacheError::Parse(format!("{e:#}")))?;
        let Some((label, feats)) = parsed else {
            continue;
        };
        scratch_idx.clear();
        scratch_val.clear();
        // `usize::MAX` disables the helper's column assert; the caller
        // does its own typed bound check against the final dimension.
        append_normalized_row(feats, usize::MAX, &mut scratch_idx, &mut scratch_val);
        stats.n += 1;
        stats.nnz += scratch_idx.len() as u64;
        stats.all_zero_one &= label == 0.0 || label == 1.0;
        stats.any_zero |= label == 0.0;
        per_row(label, &scratch_idx, &scratch_val)?;
    }
    Ok(stats)
}

/// Compile `input` (LIBSVM text) into the binary cache at `output`.
///
/// Two streaming passes: the first counts rows/nnz and detects the
/// `{0,1}` label convention, the second writes all four sections
/// incrementally — no `Vec<Vec<(u32, f64)>>` is ever materialized, so
/// peak memory is O(longest row), not O(dataset).
pub fn compile(input: &Path, output: &Path) -> Result<CompileReport, CacheError> {
    // Pass 1: sizes and label convention.
    let stats = scan_input(input, |_, _, _| Ok(()))?;
    if stats.n == 0 {
        return Err(CacheError::Malformed("empty dataset".into()));
    }
    let n = stats.n;
    let d = (stats.max_col.max(1)) as u64;
    let nnz = stats.nnz;
    let zero_one = uses_zero_one_labels(stats.all_zero_one, stats.any_zero);

    let labels_off = CACHE_HEADER_BYTES;
    let indptr_off = labels_off + 8 * n;
    let indices_off = indptr_off + 8 * (n + 1);
    let values_off = indices_off + pad8(4 * nnz);
    let file_len = values_off + 8 * nnz;

    let mut out = File::create(output)?;
    out.write_all(&[0u8; CACHE_HEADER_BYTES as usize])?;

    let mut labels = SectionWriter::new(labels_off);
    let mut indptr = SectionWriter::new(indptr_off);
    let mut indices = SectionWriter::new(indices_off);
    let mut values = SectionWriter::new(values_off);
    indptr.push(&mut out, &0u64.to_le_bytes())?;

    // Pass 2: write sections. The borrow checker won't let the closure
    // capture `out` and the writers at once mutably through `scan_input`,
    // so collect the per-row work through a RefCell-free split: do the
    // pass inline here.
    let mut running: u64 = 0;
    let pass2 = {
        let out = &mut out;
        let labels = &mut labels;
        let indptr = &mut indptr;
        let indices = &mut indices;
        let values = &mut values;
        let running = &mut running;
        scan_input(input, move |label, idx, val| {
            let y = if zero_one {
                if label == 1.0 {
                    1.0
                } else {
                    -1.0
                }
            } else {
                label
            };
            labels.push(out, &y.to_le_bytes())?;
            for &j in idx {
                if (j as u64) >= d {
                    return Err(CacheError::Malformed(
                        "input changed during compile (column out of range)".into(),
                    ));
                }
                indices.push(out, &j.to_le_bytes())?;
            }
            for &v in val {
                values.push(out, &v.to_le_bytes())?;
            }
            *running += idx.len() as u64;
            indptr.push(out, &running.to_le_bytes())?;
            Ok(())
        })?
    };
    if pass2.n != n || pass2.nnz != nnz || pass2.max_col != stats.max_col {
        return Err(CacheError::Malformed(
            "input changed during compile (pass disagreement)".into(),
        ));
    }

    labels.flush(&mut out)?;
    indptr.flush(&mut out)?;
    let pad_len = (values_off - (indices_off + 4 * nnz)) as usize;
    indices.push_pad(&mut out, &vec![0u8; pad_len])?;
    values.flush(&mut out)?;
    out.set_len(file_len)?;

    let mut h = FNV_OFFSET;
    h = fnv_update(h, &CACHE_FORMAT_VERSION.to_le_bytes());
    h = fnv_update(h, &n.to_le_bytes());
    h = fnv_update(h, &d.to_le_bytes());
    h = fnv_update(h, &nnz.to_le_bytes());
    for s in [&labels, &indptr, &indices, &values] {
        h = fnv_update(h, &s.hash.to_le_bytes());
    }
    let content_hash = h;

    let mut header = Vec::with_capacity(CACHE_HEADER_BYTES as usize);
    header.extend_from_slice(&CACHE_MAGIC);
    header.extend_from_slice(&CACHE_FORMAT_VERSION.to_le_bytes());
    header.extend_from_slice(&0u32.to_le_bytes());
    header.extend_from_slice(&content_hash.to_le_bytes());
    for v in [n, d, nnz, labels_off, indptr_off, indices_off, values_off, file_len] {
        header.extend_from_slice(&v.to_le_bytes());
    }
    debug_assert_eq!(header.len() as u64, CACHE_HEADER_BYTES);
    out.seek(SeekFrom::Start(0))?;
    out.write_all(&header)?;
    out.sync_all()?;

    Ok(CompileReport {
        n,
        d,
        nnz,
        content_hash,
        bytes: file_len,
    })
}

fn rd_u32(bytes: &[u8], off: usize) -> u32 {
    let mut b = [0u8; 4];
    b.copy_from_slice(&bytes[off..off + 4]);
    u32::from_le_bytes(b)
}

fn rd_u64(bytes: &[u8], off: usize) -> u64 {
    let mut b = [0u8; 8];
    b.copy_from_slice(&bytes[off..off + 8]);
    u64::from_le_bytes(b)
}

/// An opened, structurally-validated cache file.
///
/// Holding one of these keeps the mapping alive; matrices produced by
/// [`CsrCache::matrix_range`] share it via `Arc`, so the cache handle
/// itself may be dropped once shards are built.
#[derive(Clone, Debug)]
pub struct CsrCache {
    map: Arc<Mmap>,
    path: PathBuf,
    n: usize,
    d: usize,
    nnz: usize,
    content_hash: u64,
    labels_off: usize,
    indptr_off: usize,
    indices_off: usize,
    values_off: usize,
}

impl CsrCache {
    /// Open and structurally validate a cache file: O(1) in data size
    /// plus one O(n) scan of the row-offset section (the part whose
    /// corruption could break the `get_unchecked` hot-path contract).
    /// Column indices are validated per row range in
    /// [`CsrCache::matrix_range`] — a worker only pays for its shard.
    pub fn open(path: &Path) -> Result<CsrCache, CacheError> {
        if cfg!(target_endian = "big") {
            return Err(CacheError::Malformed(
                "cache files are little-endian; big-endian hosts are unsupported".into(),
            ));
        }
        let file = File::open(path)?;
        let map = Arc::new(map_readonly(&file)?);
        let bytes = map.as_slice();
        if (bytes.len() as u64) < CACHE_HEADER_BYTES {
            return Err(CacheError::Truncated {
                need: CACHE_HEADER_BYTES,
                have: bytes.len() as u64,
            });
        }
        if bytes[..8] != CACHE_MAGIC {
            return Err(CacheError::BadMagic);
        }
        let version = rd_u32(bytes, 8);
        if version != CACHE_FORMAT_VERSION {
            return Err(CacheError::BadVersion {
                got: version,
                want: CACHE_FORMAT_VERSION,
            });
        }
        let content_hash = rd_u64(bytes, 16);
        let n = rd_u64(bytes, 24);
        let d = rd_u64(bytes, 32);
        let nnz = rd_u64(bytes, 40);
        let labels_off = rd_u64(bytes, 48);
        let indptr_off = rd_u64(bytes, 56);
        let indices_off = rd_u64(bytes, 64);
        let values_off = rd_u64(bytes, 72);
        let file_len = rd_u64(bytes, 80);

        if n == 0 {
            return Err(CacheError::Malformed("zero rows".into()));
        }
        if d == 0 || d > (u32::MAX as u64) + 1 {
            return Err(CacheError::Malformed(format!("dimension {d} out of range")));
        }
        // Alignment first (so a hand-mangled offset reports as such) …
        for (name, off, align) in [
            ("labels", labels_off, 8u64),
            ("indptr", indptr_off, 8),
            ("indices", indices_off, 4),
            ("values", values_off, 8),
        ] {
            if off % align != 0 {
                return Err(CacheError::Misaligned {
                    section: name,
                    offset: off,
                });
            }
        }
        // … then exact layout recomputation with overflow-checked
        // arithmetic: counts can't drive allocations (there are none)
        // but they also can't place sections outside the mapping.
        let want_indptr = (|| {
            let o = labels_off.checked_add(n.checked_mul(8)?)?;
            Some(o)
        })();
        let want_indices =
            want_indptr.and_then(|o| o.checked_add(n.checked_add(1)?.checked_mul(8)?));
        let want_values =
            want_indices.and_then(|o| o.checked_add(pad8(nnz.checked_mul(4)?)));
        let want_len = want_values.and_then(|o| o.checked_add(nnz.checked_mul(8)?));
        let (want_indptr, want_indices, want_values, want_len) =
            match (want_indptr, want_indices, want_values, want_len) {
                (Some(a), Some(b), Some(c), Some(e)) => (a, b, c, e),
                _ => return Err(CacheError::Malformed("section offsets overflow".into())),
            };
        if labels_off != CACHE_HEADER_BYTES
            || indptr_off != want_indptr
            || indices_off != want_indices
            || values_off != want_values
            || file_len != want_len
        {
            return Err(CacheError::Malformed(
                "section offsets disagree with counts".into(),
            ));
        }
        let have = bytes.len() as u64;
        if have < file_len {
            return Err(CacheError::Truncated {
                need: file_len,
                have,
            });
        }
        if have > file_len {
            return Err(CacheError::Malformed(format!(
                "trailing bytes: file is {have}, header says {file_len}"
            )));
        }
        if bytes.as_ptr() as usize % 8 != 0 {
            // Real mappings are page-aligned; this guards the fallback.
            return Err(CacheError::Misaligned {
                section: "mapping base",
                offset: bytes.as_ptr() as u64,
            });
        }
        let (n, d, nnz) = match (
            usize::try_from(n),
            usize::try_from(d),
            usize::try_from(nnz),
        ) {
            (Ok(n), Ok(d), Ok(z)) => (n, d, z),
            _ => return Err(CacheError::Malformed("counts exceed address space".into())),
        };
        let cache = CsrCache {
            map,
            path: path.to_path_buf(),
            n,
            d,
            nnz,
            content_hash,
            labels_off: labels_off as usize,
            indptr_off: indptr_off as usize,
            indices_off: indices_off as usize,
            values_off: values_off as usize,
        };
        // O(n) structural scan of indptr — the bound every mapped row
        // view trusts. Columns are checked lazily per range.
        let indptr = cache.indptr_section();
        if indptr[0] != 0 {
            return Err(CacheError::Malformed("indptr[0] != 0".into()));
        }
        for w in indptr.windows(2) {
            if w[1] < w[0] {
                return Err(CacheError::Malformed("indptr not monotone".into()));
            }
        }
        if indptr[cache.n] as u64 != cache.nnz as u64 {
            return Err(CacheError::Malformed(format!(
                "indptr[n] = {} but header nnz = {}",
                indptr[cache.n], cache.nnz
            )));
        }
        Ok(cache)
    }

    /// Open and require a specific cache identity — the resurrection
    /// path: a worker must refuse to train on bytes other than the
    /// ones the coordinator partitioned.
    pub fn open_expecting(path: &Path, want_hash: u64) -> Result<CsrCache, CacheError> {
        let cache = CsrCache::open(path)?;
        if cache.content_hash != want_hash {
            return Err(CacheError::HashMismatch {
                got: cache.content_hash,
                want: want_hash,
            });
        }
        Ok(cache)
    }

    /// Rows `n`.
    pub fn rows(&self) -> usize {
        self.n
    }

    /// Feature dimension `d`.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Total stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// The cache identity recorded at compile time.
    pub fn content_hash(&self) -> u64 {
        self.content_hash
    }

    /// The file this cache was opened from.
    pub fn path(&self) -> &Path {
        &self.path
    }

    fn indptr_section(&self) -> &[u64] {
        // SAFETY: `open` validated that the section lies inside the
        // mapping, is 8-byte aligned (base + offset), and holds
        // exactly n+1 u64s; the mapping is immutable and outlives
        // `self`.
        unsafe {
            std::slice::from_raw_parts(
                self.map.as_slice().as_ptr().add(self.indptr_off) as *const u64,
                self.n + 1,
            )
        }
    }

    fn indices_section(&self) -> &[u32] {
        // SAFETY: as in `indptr_section` (4-byte alignment, nnz u32s).
        unsafe {
            std::slice::from_raw_parts(
                self.map.as_slice().as_ptr().add(self.indices_off) as *const u32,
                self.nnz,
            )
        }
    }

    /// The nnz prefix sum over all rows — the `indptr` section verbatim
    /// (`n + 1` entries, `[0] = 0`), zero-copy out of the mapping. This
    /// is what makes `--balance nnz` O(1) per row on the cache path: the
    /// cut-point search reads these offsets directly, no counting pass
    /// (DESIGN.md §16).
    pub fn nnz_prefix(&self) -> &[u64] {
        self.indptr_section()
    }

    /// All labels, zero-copy out of the mapping.
    pub fn labels(&self) -> &[f64] {
        // SAFETY: as in `indptr_section` (8-byte alignment, n f64s; any
        // bit pattern is a valid f64).
        unsafe {
            std::slice::from_raw_parts(
                self.map.as_slice().as_ptr().add(self.labels_off) as *const f64,
                self.n,
            )
        }
    }

    /// A zero-copy matrix over rows `[range.start, range.end)`.
    ///
    /// Validates every stored column index in the range: each must be
    /// `< d` (upholds the `get_unchecked` contract of
    /// [`crate::data::SparseRow::dot`]) and strictly increasing within
    /// its row (the dense fast path in `dot`/`axpy_into` assumes a row
    /// with `nnz == d` has indices exactly `0..d`; without
    /// monotonicity a corrupt cache could hit it with permuted or
    /// duplicated columns and silently compute wrong answers). O(range
    /// nnz) — each worker pays only for its own shard, never the whole
    /// file.
    pub fn matrix_range(&self, range: std::ops::Range<usize>) -> Result<SparseMatrix, CacheError> {
        if range.start > range.end || range.end > self.n {
            return Err(CacheError::Malformed(format!(
                "row range {range:?} out of bounds ({} rows)",
                self.n
            )));
        }
        let indptr = self.indptr_section();
        let indices = self.indices_section();
        for r in range.clone() {
            let (lo, hi) = (indptr[r] as usize, indptr[r + 1] as usize);
            let mut prev: Option<u32> = None;
            for &j in &indices[lo..hi] {
                if (j as usize) >= self.d {
                    return Err(CacheError::Malformed(format!(
                        "column {j} out of bounds ({} columns)",
                        self.d
                    )));
                }
                if let Some(p) = prev {
                    if p >= j {
                        return Err(CacheError::Malformed(format!(
                            "non-monotone column indices in row {r}: {p} then {j}"
                        )));
                    }
                }
                prev = Some(j);
            }
        }
        let base = self.map.as_slice().as_ptr();
        // SAFETY: `open` validated section bounds/alignment and the
        // monotone indptr; the loop above validated the columns of this
        // range (bounds and per-row strict monotonicity); the Arc keeps
        // the mapping alive for the matrix.
        Ok(unsafe {
            SparseMatrix::from_mapped_sections(
                Arc::clone(&self.map),
                (base.add(self.indptr_off) as *const u64).add(range.start),
                range.end - range.start,
                base.add(self.indices_off) as *const u32,
                base.add(self.values_off) as *const f64,
                self.nnz,
                self.d,
            )
        })
    }

    /// The whole file as a zero-copy [`Dataset`] (labels are copied —
    /// they're O(n), not O(nnz); rows stay mapped).
    pub fn dataset(&self) -> Result<Dataset, CacheError> {
        let x = self.matrix_range(0..self.n)?;
        let name = self
            .path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "cache".into());
        Ok(Dataset {
            x,
            y: self.labels().to_vec(),
            name,
        })
    }

    /// Recompute the content hash from the mapped sections (O(data))
    /// and compare against the header — on-demand integrity check for
    /// tooling and tests; deliberately not part of `open`.
    pub fn verify_content(&self) -> Result<(), CacheError> {
        let bytes = self.map.as_slice();
        let sections = [
            (self.labels_off, 8 * self.n),
            (self.indptr_off, 8 * (self.n + 1)),
            (self.indices_off, 4 * self.nnz),
            (self.values_off, 8 * self.nnz),
        ];
        let mut h = FNV_OFFSET;
        h = fnv_update(h, &CACHE_FORMAT_VERSION.to_le_bytes());
        h = fnv_update(h, &(self.n as u64).to_le_bytes());
        h = fnv_update(h, &(self.d as u64).to_le_bytes());
        h = fnv_update(h, &(self.nnz as u64).to_le_bytes());
        for (off, len) in sections {
            let sh = fnv_update(FNV_OFFSET, &bytes[off..off + len]);
            h = fnv_update(h, &sh.to_le_bytes());
        }
        if h != self.content_hash {
            return Err(CacheError::HashMismatch {
                got: h,
                want: self.content_hash,
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::libsvm;
    use std::io::Cursor;
    use std::sync::atomic::{AtomicU64, Ordering};

    static UNIQ: AtomicU64 = AtomicU64::new(0);

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "dadm_cache_{tag}_{}_{}",
            std::process::id(),
            UNIQ.fetch_add(1, Ordering::Relaxed)
        ))
    }

    fn write_text(tag: &str, text: &str) -> PathBuf {
        let p = tmp(&format!("{tag}_txt"));
        std::fs::write(&p, text).unwrap();
        p
    }

    const SMALL: &str = "+1 1:0.5 3:1.25\n-1 2:2.0\n# comment\n\n+1 1:-0.25 2:0.5 3:0.75\n";

    fn compiled(tag: &str, text: &str) -> (PathBuf, CompileReport) {
        let input = write_text(tag, text);
        let out = tmp(&format!("{tag}_cache"));
        let report = compile(&input, &out).unwrap();
        std::fs::remove_file(&input).ok();
        (out, report)
    }

    #[test]
    fn compile_then_open_matches_text_parse_row_for_row() {
        let (path, report) = compiled("roundtrip", SMALL);
        let cache = CsrCache::open(&path).unwrap();
        let text = libsvm::parse(Cursor::new(SMALL)).unwrap();
        assert_eq!(report.n as usize, text.n());
        assert_eq!(report.d as usize, text.dim());
        assert_eq!(cache.rows(), text.n());
        assert_eq!(cache.dim(), text.dim());
        assert_eq!(cache.nnz(), text.x.nnz());
        assert_eq!(cache.labels(), &text.y[..]);
        let mapped = cache.dataset().unwrap();
        assert!(mapped.x.is_mapped());
        for i in 0..text.n() {
            let (a, b) = (mapped.x.row(i), text.x.row(i));
            assert_eq!(a.indices, b.indices, "row {i} indices");
            assert_eq!(a.values, b.values, "row {i} values");
        }
        cache.verify_content().unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn nnz_prefix_matches_text_parse_and_mapped_matrix() {
        let (path, _) = compiled("nnz_prefix", SMALL);
        let cache = CsrCache::open(&path).unwrap();
        let text = libsvm::parse(Cursor::new(SMALL)).unwrap();
        // The cache's indptr section IS the nnz prefix of the text parse
        // — the identity `--balance nnz` relies on for cache/text cut
        // parity.
        assert_eq!(cache.nnz_prefix(), &text.x.nnz_prefix()[..]);
        assert_eq!(cache.nnz_prefix()[0], 0);
        assert_eq!(*cache.nnz_prefix().last().unwrap() as usize, cache.nnz());
        // And the mapped full-range matrix reports the same prefix.
        let mapped = cache.matrix_range(0..cache.rows()).unwrap();
        assert_eq!(mapped.nnz_prefix(), cache.nnz_prefix());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn prop_parse_compile_mmap_row_parity() {
        // Property pin: text parse → libsvm::write → compile → mmap is
        // row-for-row and label-for-label identical to the in-memory
        // parse, across random shapes, sparsities, and label schemes.
        crate::testing::prop::for_each_case(0xCACE, 25, |g| {
            let rows = g.usize_in(1, 20);
            let cols = g.usize_in(1, 15);
            let zero_one = g.bool(0.3);
            let mut text = String::new();
            for _ in 0..rows {
                let y = if zero_one {
                    if g.bool(0.5) {
                        "1".to_string()
                    } else {
                        "0".to_string()
                    }
                } else {
                    format!("{}", g.f64_in(-2.0, 2.0))
                };
                text.push_str(&y);
                for j in 0..cols {
                    if g.bool(0.4) {
                        text.push_str(&format!(" {}:{}", j + 1, g.f64_in(-3.0, 3.0)));
                    }
                }
                text.push('\n');
            }
            let parsed = match libsvm::parse(Cursor::new(text.as_str())) {
                Ok(d) => d,
                // All-empty rows with max_col 0 etc. stay valid; parse
                // only fails on validate() edge cases we don't emit.
                Err(e) => panic!("parse failed: {e:#}"),
            };
            let input = write_text("prop", &text);
            let out = tmp("prop_cache");
            let report = compile(&input, &out).unwrap();
            let cache = CsrCache::open(&out).unwrap();
            assert_eq!(cache.rows(), parsed.n());
            assert_eq!(cache.dim(), parsed.dim());
            assert_eq!(report.nnz as usize, parsed.x.nnz());
            assert_eq!(cache.labels(), &parsed.y[..]);
            let mapped = cache.matrix_range(0..cache.rows()).unwrap();
            for i in 0..parsed.n() {
                let (a, b) = (mapped.row(i), parsed.x.row(i));
                assert_eq!(a.indices, b.indices);
                assert_eq!(a.values, b.values);
            }
            // Ranged views agree with full-view slices.
            let s = g.usize_in(0, parsed.n());
            let e = g.usize_in(s, parsed.n() + 1);
            let sub = cache.matrix_range(s..e).unwrap();
            for (k, i) in (s..e).enumerate() {
                assert_eq!(sub.row(k).indices, parsed.x.row(i).indices);
                assert_eq!(sub.row(k).values, parsed.x.row(i).values);
            }
            std::fs::remove_file(&input).ok();
            std::fs::remove_file(&out).ok();
        });
    }

    #[test]
    fn reopen_is_identity_stable_and_slices_are_zero_copy() {
        let (path, report) = compiled("stable", SMALL);
        let a = CsrCache::open(&path).unwrap();
        let b = CsrCache::open(&path).unwrap();
        assert_eq!(a.content_hash(), b.content_hash());
        assert_eq!(a.content_hash(), report.content_hash);
        CsrCache::open_expecting(&path, report.content_hash).unwrap();
        let m = a.matrix_range(0..a.rows()).unwrap();
        let s = m.slice_rows(1..3);
        assert!(s.is_mapped());
        assert_eq!(s.rows(), 2);
        assert_eq!(s.row(0).values, m.row(1).values);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_expected_hash_is_typed_mismatch() {
        let (path, report) = compiled("hash", SMALL);
        let err = CsrCache::open_expecting(&path, report.content_hash ^ 1).unwrap_err();
        assert!(matches!(err, CacheError::HashMismatch { .. }), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_cache_is_typed_error_not_panic() {
        let (path, _) = compiled("trunc", SMALL);
        let full = std::fs::read(&path).unwrap();
        // Truncate to a dozen prefixes, including mid-header and
        // mid-section; every one must be a typed error.
        for keep in [1usize, 8, 40, 87, 88, 100, full.len() - 1] {
            if keep >= full.len() {
                continue;
            }
            std::fs::write(&path, &full[..keep]).unwrap();
            let err = CsrCache::open(&path).unwrap_err();
            assert!(
                matches!(err, CacheError::Truncated { .. } | CacheError::Malformed(_)),
                "keep={keep}: {err}"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_and_bad_version_are_typed() {
        let (path, _) = compiled("magic", SMALL);
        let mut bytes = std::fs::read(&path).unwrap();
        let orig = bytes.clone();
        bytes[0] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            CsrCache::open(&path).unwrap_err(),
            CacheError::BadMagic
        ));
        bytes = orig;
        bytes[8] = 99; // format_version
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            CsrCache::open(&path).unwrap_err(),
            CacheError::BadVersion { got: 99, .. }
        ));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn misaligned_section_offset_is_typed() {
        let (path, _) = compiled("align", SMALL);
        let mut bytes = std::fs::read(&path).unwrap();
        // labels_off lives at header offset 48; nudge it off 8-byte
        // alignment.
        bytes[48] = bytes[48].wrapping_add(4);
        std::fs::write(&path, &bytes).unwrap();
        let err = CsrCache::open(&path).unwrap_err();
        assert!(matches!(err, CacheError::Misaligned { .. }), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_indptr_and_columns_are_typed() {
        let (path, _) = compiled("corrupt", SMALL);
        let orig = std::fs::read(&path).unwrap();
        let cache = CsrCache::open(&path).unwrap();
        let (indptr_off, indices_off) = (cache.indptr_off, cache.indices_off);
        drop(cache);

        // Non-monotone indptr → rejected at open.
        let mut bytes = orig.clone();
        bytes[indptr_off + 8] = 0xFF; // second entry becomes huge
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            CsrCache::open(&path).unwrap_err(),
            CacheError::Malformed(_)
        ));

        // Out-of-range column → rejected at matrix_range.
        let mut bytes = orig.clone();
        bytes[indices_off..indices_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let cache = CsrCache::open(&path).unwrap();
        assert!(matches!(
            cache.matrix_range(0..cache.rows()).unwrap_err(),
            CacheError::Malformed(_)
        ));
        // …and the content check flags the flip too.
        assert!(matches!(
            cache.verify_content().unwrap_err(),
            CacheError::HashMismatch { .. }
        ));

        // In-bounds but non-monotone column within a row (row 0 becomes
        // [0, 0]): every index is < d, but a row whose nnz happens to
        // equal d would hit the dense fast path in dot/axpy with
        // permuted or duplicated columns — silent wrong answers, not a
        // crash — so matrix_range must reject it.
        drop(cache); // don't rewrite the file under a live mapping
        let mut bytes = orig.clone();
        bytes[indices_off + 4..indices_off + 8].copy_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let cache = CsrCache::open(&path).unwrap();
        assert!(matches!(
            cache.matrix_range(0..cache.rows()).unwrap_err(),
            CacheError::Malformed(_)
        ));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn counts_cannot_drive_allocations_or_out_of_bounds() {
        let (path, _) = compiled("bounds", SMALL);
        let mut bytes = std::fs::read(&path).unwrap();
        // Claim an absurd n with unchanged offsets: offsets disagree →
        // typed error before anything is allocated or dereferenced.
        bytes[24..32].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = CsrCache::open(&path).unwrap_err();
        assert!(matches!(err, CacheError::Malformed(_)), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
