//! Synthetic dataset generators matched to the paper's Table 1.
//!
//! | Paper dataset | n          | d          | sparsity | analogue default |
//! |---------------|------------|------------|----------|------------------|
//! | covtype       | 581,012    | 54         | 22.12%   | n/29 ≈ 20k       |
//! | rcv1          | 677,399    | 47,236     | 0.16%    | 20k × 2,048      |
//! | HIGGS         | 11,000,000 | 28         | 92.11%   | 40k × 28         |
//! | kdd2010       | 19,264,097 | 29,890,095 | ~1e-6    | 40k × 8,192      |
//!
//! The substitution rationale (DESIGN.md §3): dual coordinate method
//! behaviour is governed by (n, d, sparsity, R = max‖x_i‖², label noise,
//! λ); the generators preserve those while scaling n so laptop-scale
//! benches finish. Each generator draws a ground-truth sparse predictor
//! `w*`, emits features with the target density, and labels
//! `y = sign(x·w* + noise)`, giving a realistic margin distribution.

use super::{Dataset, SparseMatrix};
use crate::utils::Rng;

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    /// Dataset name (bench output key).
    pub name: String,
    /// Number of examples.
    pub n: usize,
    /// Feature dimension.
    pub d: usize,
    /// Target fraction of non-zeros per row (1.0 = dense).
    pub density: f64,
    /// Fraction of features active in the ground-truth predictor.
    pub signal_density: f64,
    /// Label flip probability (Bayes noise).
    pub noise: f64,
    /// RNG seed.
    pub seed: u64,
}

impl SyntheticSpec {
    /// covtype analogue: small dense-ish d, moderately sparse rows.
    pub fn covtype(scale: f64) -> Self {
        SyntheticSpec {
            name: "synth-covtype".into(),
            n: ((581_012.0 * scale) as usize).max(64),
            d: 54,
            density: 0.2212,
            signal_density: 0.5,
            noise: 0.1,
            seed: 0xC0F_7359E,
        }
    }

    /// rcv1 analogue: high-dimensional, very sparse text-like features.
    pub fn rcv1(scale: f64) -> Self {
        SyntheticSpec {
            name: "synth-rcv1".into(),
            n: ((677_399.0 * scale) as usize).max(64),
            d: 2_048,
            density: 0.016, // scaled-up from 0.0016 so rows keep ≥ a few nnz at d=2048
            signal_density: 0.05,
            noise: 0.05,
            seed: 0x9C41,
        }
    }

    /// HIGGS analogue: low-dimensional fully dense physics features.
    pub fn higgs(scale: f64) -> Self {
        SyntheticSpec {
            name: "synth-higgs".into(),
            n: ((11_000_000.0 * scale) as usize).max(64),
            d: 28,
            density: 0.9211,
            signal_density: 1.0,
            noise: 0.2,
            seed: 0x8166_5,
        }
    }

    /// kdd2010 analogue: extreme dimension/sparsity ratio.
    pub fn kdd2010(scale: f64) -> Self {
        SyntheticSpec {
            name: "synth-kdd2010".into(),
            n: ((19_264_097.0 * scale) as usize).max(64),
            d: 8_192,
            density: 0.002,
            signal_density: 0.02,
            noise: 0.05,
            seed: 0x6DD2010,
        }
    }

    /// Generate the dataset.
    pub fn generate(&self) -> Dataset {
        let mut rng = Rng::new(self.seed);
        // Ground-truth predictor on a random support.
        let k = ((self.d as f64 * self.signal_density).ceil() as usize).clamp(1, self.d);
        let support = rng.sample_indices(self.d, k);
        let mut w_star = vec![0.0; self.d];
        for &j in &support {
            w_star[j] = rng.normal();
        }
        let nnz_per_row = ((self.d as f64 * self.density).round() as usize).clamp(1, self.d);
        let mut rows: Vec<Vec<(u32, f64)>> = Vec::with_capacity(self.n);
        let mut y = Vec::with_capacity(self.n);
        for _ in 0..self.n {
            let cols = rng.sample_indices(self.d, nnz_per_row);
            // Normalize rows to unit norm like common LIBSVM preprocessing —
            // this pins R = max‖x_i‖² = 1, matching how the paper's λ grid
            // (1e-6..1e-8) maps onto condition numbers.
            let mut vals: Vec<f64> = (0..nnz_per_row).map(|_| rng.normal()).collect();
            let norm = vals.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-12);
            for v in &mut vals {
                *v /= norm;
            }
            let margin: f64 = cols
                .iter()
                .zip(&vals)
                .map(|(&j, &v)| v * w_star[j])
                .sum();
            let mut label = if margin >= 0.0 { 1.0 } else { -1.0 };
            if rng.bernoulli(self.noise) {
                label = -label;
            }
            y.push(label);
            rows.push(cols.into_iter().map(|j| (j as u32, 0.0)).zip(vals).map(|((j, _), v)| (j, v)).collect());
        }
        let x = SparseMatrix::from_rows(rows, self.d);
        Dataset {
            x,
            y,
            name: self.name.clone(),
        }
    }
}

/// The paper's four datasets at a given scale factor (fraction of the
/// original n). `scale = 3.5e-5` gives the quick defaults used by tests;
/// benches use larger scales.
pub fn paper_suite(scale: f64) -> Vec<SyntheticSpec> {
    vec![
        SyntheticSpec::covtype(scale * 10.0), // covtype is small; keep it bigger
        SyntheticSpec::rcv1(scale * 10.0),
        SyntheticSpec::higgs(scale),
        SyntheticSpec::kdd2010(scale),
    ]
}

/// A tiny well-conditioned classification problem for unit tests.
pub fn tiny_classification(n: usize, d: usize, seed: u64) -> Dataset {
    SyntheticSpec {
        name: "tiny".into(),
        n,
        d,
        density: 1.0,
        signal_density: 1.0,
        noise: 0.05,
        seed,
    }
    .generate()
}

/// A tiny regression problem (`y = x·w* + ε`, unnormalized labels) for the
/// squared-loss / ridge closed-form cross-checks.
pub fn tiny_regression(n: usize, d: usize, noise: f64, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let w_star: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let mut rows = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let x: Vec<f64> = (0..d).map(|_| rng.normal() / (d as f64).sqrt()).collect();
        let target: f64 = x.iter().zip(&w_star).map(|(a, b)| a * b).sum::<f64>()
            + noise * rng.normal();
        y.push(target);
        rows.push(x.iter().enumerate().map(|(j, &v)| (j as u32, v)).collect());
    }
    Dataset {
        x: SparseMatrix::from_rows(rows, d),
        y,
        name: "tiny-reg".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covtype_profile() {
        let d = SyntheticSpec::covtype(0.002).generate();
        assert_eq!(d.dim(), 54);
        assert!(d.n() >= 1000);
        let density = d.density();
        assert!(
            (density - 0.2212).abs() < 0.03,
            "density {density} far from covtype's 22.12%"
        );
        d.validate().unwrap();
    }

    #[test]
    fn rows_are_unit_norm() {
        let d = SyntheticSpec::higgs(2e-5).generate();
        for i in 0..d.n() {
            let ns = d.x.row(i).norm_sq();
            assert!((ns - 1.0).abs() < 1e-9, "row {i} norm² = {ns}");
        }
        assert!((d.max_row_norm_sq() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn labels_are_pm1_and_balanced_ish() {
        let d = tiny_classification(2000, 10, 42);
        assert!(d.y.iter().all(|&y| y == 1.0 || y == -1.0));
        let pos = d.y.iter().filter(|&&y| y > 0.0).count() as f64 / d.n() as f64;
        assert!((0.3..0.7).contains(&pos), "positive fraction {pos}");
    }

    #[test]
    fn labels_mostly_agree_with_signal() {
        // With 5% flip noise a linear model should fit well; check that the
        // generator's labels are actually learnable by measuring agreement
        // between the margin sign implied by regenerating with zero noise.
        let spec = SyntheticSpec {
            noise: 0.0,
            ..SyntheticSpec::covtype(0.001)
        };
        let a = spec.generate();
        let spec_noisy = SyntheticSpec {
            noise: 0.3,
            ..spec.clone()
        };
        let b = spec_noisy.generate();
        // Same seed ⇒ same features; labels differ only by flips ≈ 30%.
        let flips = a
            .y
            .iter()
            .zip(&b.y)
            .filter(|(p, q)| p != q)
            .count() as f64
            / a.n() as f64;
        assert!((0.2..0.4).contains(&flips), "flip rate {flips}");
    }

    #[test]
    fn deterministic_generation() {
        let a = SyntheticSpec::rcv1(2e-5).generate();
        let b = SyntheticSpec::rcv1(2e-5).generate();
        assert_eq!(a.y, b.y);
        assert_eq!(a.x.to_dense(), b.x.to_dense());
    }

    #[test]
    fn regression_targets_correlate() {
        let d = tiny_regression(500, 8, 0.01, 7);
        assert_eq!(d.n(), 500);
        // Targets should have non-trivial variance (signal present).
        let mean = d.y.iter().sum::<f64>() / 500.0;
        let var = d.y.iter().map(|y| (y - mean) * (y - mean)).sum::<f64>() / 500.0;
        assert!(var > 0.1);
    }

    #[test]
    fn paper_suite_has_four() {
        let suite = paper_suite(1e-5);
        assert_eq!(suite.len(), 4);
        let names: Vec<_> = suite.iter().map(|s| s.name.as_str()).collect();
        assert!(names.contains(&"synth-covtype"));
        assert!(names.contains(&"synth-kdd2010"));
    }
}
