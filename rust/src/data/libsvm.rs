//! LIBSVM text format I/O.
//!
//! The paper's datasets (covtype, rcv1, HIGGS, kdd2010) are distributed in
//! this format. We parse it so real data drops into the benches unchanged
//! (`--data path.libsvm`); the synthetic generators are only the default.
//!
//! Format: one example per line, `label idx:val idx:val ...` with
//! **1-based** indices, `#` comments allowed at end of line.

use super::{Dataset, SparseMatrix};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Parse one LIBSVM line into `(label, features)` with 0-based `u32`
/// columns, updating `max_col` (1-based max index seen). Returns
/// `Ok(None)` for blank / comment-only lines.
///
/// Shared by the in-memory [`parse`] and the streaming cache compiler
/// (`data/cache.rs`), so the two paths cannot drift.
pub(crate) fn parse_line(
    raw: &str,
    lineno: usize,
    max_col: &mut usize,
) -> Result<Option<(f64, Vec<(u32, f64)>)>> {
    let line = raw.split('#').next().unwrap_or("").trim();
    if line.is_empty() {
        return Ok(None);
    }
    let mut parts = line.split_ascii_whitespace();
    let label: f64 = parts
        .next()
        .context("missing label")?
        .parse()
        .with_context(|| format!("line {}: bad label", lineno + 1))?;
    let mut feats = Vec::new();
    for tok in parts {
        let (idx, val) = tok
            .split_once(':')
            .with_context(|| format!("line {}: bad feature `{tok}`", lineno + 1))?;
        let idx: usize = idx
            .parse()
            .with_context(|| format!("line {}: bad index `{idx}`", lineno + 1))?;
        anyhow::ensure!(idx >= 1, "line {}: LIBSVM indices are 1-based", lineno + 1);
        let val: f64 = val
            .parse()
            .with_context(|| format!("line {}: bad value `{val}`", lineno + 1))?;
        *max_col = (*max_col).max(idx);
        feats.push(((idx - 1) as u32, val));
    }
    Ok(Some((label, feats)))
}

/// True when `labels` uses the rcv1-style `{0, 1}` convention that
/// [`parse`] (and the cache compiler) remaps to `±1`.
pub(crate) fn uses_zero_one_labels(all_zero_one: bool, any_zero: bool) -> bool {
    all_zero_one && any_zero
}

/// Parse LIBSVM text from a reader. Labels are kept as parsed, except that
/// `0/1` labels are mapped to `±1` (rcv1-style convention).
pub fn parse<R: BufRead>(reader: R) -> Result<Dataset> {
    let mut rows: Vec<Vec<(u32, f64)>> = Vec::new();
    let mut labels: Vec<f64> = Vec::new();
    let mut max_col = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if let Some((label, feats)) = parse_line(&line, lineno, &mut max_col)? {
            labels.push(label);
            rows.push(feats);
        }
    }
    // Map {0,1} labels to ±1 if the file uses that convention.
    let zero_one = uses_zero_one_labels(
        labels.iter().all(|&y| y == 0.0 || y == 1.0),
        labels.iter().any(|&y| y == 0.0),
    );
    if zero_one {
        for y in &mut labels {
            *y = if *y == 1.0 { 1.0 } else { -1.0 };
        }
    }
    let x = SparseMatrix::from_rows(rows, max_col.max(1));
    let d = Dataset {
        x,
        y: labels,
        name: "libsvm".into(),
    };
    d.validate()?;
    Ok(d)
}

/// Load a LIBSVM file from disk.
pub fn load(path: &Path) -> Result<Dataset> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut d = parse(BufReader::new(f))?;
    d.name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "libsvm".into());
    Ok(d)
}

/// Serialize a dataset back to LIBSVM text (round-trip tested).
pub fn write<W: Write>(d: &Dataset, mut w: W) -> Result<()> {
    for i in 0..d.n() {
        write!(w, "{}", d.y[i])?;
        let row = d.x.row(i);
        for (&j, &v) in row.indices.iter().zip(row.values) {
            write!(w, " {}:{}", j + 1, v)?;
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_basic_file() {
        let text = "+1 1:0.5 3:1.25\n-1 2:2.0\n";
        let d = parse(Cursor::new(text)).unwrap();
        assert_eq!(d.n(), 2);
        assert_eq!(d.dim(), 3);
        assert_eq!(d.y, vec![1.0, -1.0]);
        assert_eq!(d.x.row(0).to_dense(3), vec![0.5, 0.0, 1.25]);
        assert_eq!(d.x.row(1).to_dense(3), vec![0.0, 2.0, 0.0]);
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let text = "\n# full comment\n1 1:1.0 # trailing\n\n-1 1:2.0\n";
        let d = parse(Cursor::new(text)).unwrap();
        assert_eq!(d.n(), 2);
    }

    #[test]
    fn maps_zero_one_labels() {
        let d = parse(Cursor::new("1 1:1\n0 1:2\n")).unwrap();
        assert_eq!(d.y, vec![1.0, -1.0]);
    }

    #[test]
    fn keeps_pm1_labels() {
        let d = parse(Cursor::new("1 1:1\n-1 1:2\n")).unwrap();
        assert_eq!(d.y, vec![1.0, -1.0]);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse(Cursor::new("1 0:1.0\n")).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse(Cursor::new("abc 1:1.0\n")).is_err());
        assert!(parse(Cursor::new("1 1-1.0\n")).is_err());
    }

    #[test]
    fn roundtrip() {
        let text = "1 1:0.5 3:1.25\n-1 2:2\n";
        let d = parse(Cursor::new(text)).unwrap();
        let mut buf = Vec::new();
        write(&d, &mut buf).unwrap();
        let d2 = parse(Cursor::new(buf)).unwrap();
        assert_eq!(d.y, d2.y);
        assert_eq!(d.x.to_dense(), d2.x.to_dense());
    }
}
