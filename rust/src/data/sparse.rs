//! Compressed sparse row (CSR) matrix.
//!
//! The single most important data structure on the Layer-3 hot path: every
//! ProxSDCA coordinate step does one sparse dot `x_iᵀ w` and one sparse
//! axpy `v += c·x_i` against a row of this matrix. Rows are contiguous
//! `(indices, values)` slices so the inner loops are cache-friendly and
//! allocation-free.

/// Borrowed view of one CSR row.
#[derive(Clone, Copy, Debug)]
pub struct SparseRow<'a> {
    /// Column indices (strictly increasing).
    pub indices: &'a [u32],
    /// Matching values.
    pub values: &'a [f64],
}

impl<'a> SparseRow<'a> {
    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Sparse dot product against a dense vector.
    ///
    /// The innermost loop of every coordinate step. Column indices are
    /// validated once at construction (`SparseMatrix::from_rows`), so the
    /// gather skips per-element bounds checks (§Perf iteration 1: +35%
    /// epoch throughput). The gather accumulates into four independent
    /// streams: a single accumulator chains every add behind the previous
    /// one (4–5 cycle FP-add latency per nnz), while four break the
    /// dependence and let the loads and adds overlap — the
    /// `sparse_dot_unrolled` row of `perf_hotpath` pins the win on long
    /// rows. The combine order `(a0+a1)+(a2+a3)` is fixed, so results are
    /// deterministic (though not bit-identical to a serial fold).
    #[inline]
    pub fn dot(&self, w: &[f64]) -> f64 {
        debug_assert!(self
            .indices
            .iter()
            .all(|&j| (j as usize) < w.len()));
        // Fully-dense row (covtype/HIGGS-like data): indices are exactly
        // 0..d, so the gather degenerates to a contiguous dot product that
        // LLVM auto-vectorizes (§Perf iteration 2).
        if self.indices.len() == w.len() {
            return self.values.iter().zip(w).map(|(v, x)| v * x).sum();
        }
        let idx = self.indices;
        let val = self.values;
        let head = idx.len() & !3;
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        let mut k = 0usize;
        while k < head {
            // SAFETY: k + 3 < idx.len() == val.len(), and every stored
            // index j < cols ≤ w.len() — enforced at matrix construction
            // and checked above in debug builds.
            unsafe {
                a0 += *val.get_unchecked(k) * *w.get_unchecked(*idx.get_unchecked(k) as usize);
                a1 += *val.get_unchecked(k + 1)
                    * *w.get_unchecked(*idx.get_unchecked(k + 1) as usize);
                a2 += *val.get_unchecked(k + 2)
                    * *w.get_unchecked(*idx.get_unchecked(k + 2) as usize);
                a3 += *val.get_unchecked(k + 3)
                    * *w.get_unchecked(*idx.get_unchecked(k + 3) as usize);
            }
            k += 4;
        }
        let mut acc = (a0 + a1) + (a2 + a3);
        while k < idx.len() {
            // SAFETY: as above.
            acc += unsafe {
                *val.get_unchecked(k) * *w.get_unchecked(*idx.get_unchecked(k) as usize)
            };
            k += 1;
        }
        acc
    }

    /// `out += c · x_i` (sparse axpy).
    #[inline]
    pub fn axpy_into(&self, c: f64, out: &mut [f64]) {
        debug_assert!(self
            .indices
            .iter()
            .all(|&j| (j as usize) < out.len()));
        if self.indices.len() == out.len() {
            for (o, &v) in out.iter_mut().zip(self.values) {
                *o += c * v;
            }
            return;
        }
        for (&j, &v) in self.indices.iter().zip(self.values) {
            // SAFETY: as in `dot`.
            unsafe { *out.get_unchecked_mut(j as usize) += c * v };
        }
    }

    /// `‖x_i‖₂²`.
    #[inline]
    pub fn norm_sq(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum()
    }

    /// Materialize as a dense vector of length `dim`.
    pub fn to_dense(&self, dim: usize) -> Vec<f64> {
        let mut out = vec![0.0; dim];
        self.axpy_into(1.0, &mut out);
        out
    }
}

/// Normalize one `(col, value)` row list — sort by column, sum
/// duplicate columns, drop explicit zeros — and append the result to
/// the CSR `indices`/`values` arrays.
///
/// This is the single definition of row normalization: both
/// [`SparseMatrix::from_rows`] and the streaming cache compiler
/// (`data/cache.rs`) call it, so a compiled cache is row-for-row
/// identical to the in-memory parse by construction.
pub(crate) fn append_normalized_row(
    mut row: Vec<(u32, f64)>,
    cols: usize,
    indices: &mut Vec<u32>,
    values: &mut Vec<f64>,
) {
    row.sort_unstable_by_key(|&(j, _)| j);
    let mut last: Option<u32> = None;
    for (j, v) in row {
        assert!((j as usize) < cols, "column {j} out of bounds ({cols})");
        if last == Some(j) {
            *values.last_mut().unwrap() += v;
        } else if v != 0.0 {
            indices.push(j);
            values.push(v);
            last = Some(j);
        }
    }
}

/// Row storage backend for [`SparseMatrix`].
///
/// `Owned` is the classic heap CSR triple. `Mapped` serves rows
/// zero-copy out of a read-only memory mapping (the binary cache of
/// DESIGN.md §15): same `SparseRow` views, same `dot`/`axpy` unsafe
/// contract, but opening is O(1) in data size and the OS pages rows in
/// on demand.
#[derive(Clone)]
enum Storage {
    Owned {
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f64>,
    },
    Mapped(MappedCsr),
}

impl Default for Storage {
    fn default() -> Self {
        Storage::Owned {
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
        }
    }
}

impl std::fmt::Debug for Storage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Storage::Owned { indptr, indices, .. } => f
                .debug_struct("Owned")
                .field("rows", &(indptr.len().saturating_sub(1)))
                .field("nnz", &indices.len())
                .finish(),
            Storage::Mapped(m) => f
                .debug_struct("Mapped")
                .field("rows", &m.n_rows)
                .field("section_nnz", &m.nnz)
                .finish(),
        }
    }
}

/// A window of rows over a memory-mapped CSR file.
///
/// `indptr` points at `n_rows + 1` little-endian `u64` offsets that are
/// *absolute* positions into the file's full `indices`/`values`
/// sections (whose starts the other two pointers hold), so slicing a
/// row range is pointer arithmetic on `indptr` alone. The `Arc<Mmap>`
/// keeps the pages mapped for as long as any view (or clone) lives.
#[derive(Clone)]
struct MappedCsr {
    map: std::sync::Arc<crate::utils::mmap::Mmap>,
    indptr: *const u64,
    n_rows: usize,
    indices: *const u32,
    values: *const f64,
    /// Total entries in the file's indices/values sections — the upper
    /// bound every `indptr` entry was validated against at open.
    nnz: usize,
}

// SAFETY: the pointed-to mapping is immutable (`PROT_READ`) for the
// lifetime of the `Arc<Mmap>` this struct holds, so aliased reads from
// any thread are data-race free; the raw pointers are derived from that
// mapping and never written through.
unsafe impl Send for MappedCsr {}
unsafe impl Sync for MappedCsr {}

impl MappedCsr {
    #[inline]
    fn row(&self, i: usize) -> SparseRow<'_> {
        assert!(i < self.n_rows, "row {i} out of bounds ({})", self.n_rows);
        // SAFETY: `i + 1 <= n_rows`, and the constructor contract
        // (`from_mapped_sections`) guarantees `indptr` holds `n_rows + 1`
        // readable, monotone entries bounded by `nnz`, with `indices`/
        // `values` sections of at least `nnz` elements — all validated
        // by the cache opener before this struct exists.
        unsafe {
            let lo = *self.indptr.add(i) as usize;
            let hi = *self.indptr.add(i + 1) as usize;
            debug_assert!(lo <= hi && hi <= self.nnz);
            SparseRow {
                indices: std::slice::from_raw_parts(self.indices.add(lo), hi - lo),
                values: std::slice::from_raw_parts(self.values.add(lo), hi - lo),
            }
        }
    }

    fn local_nnz(&self) -> usize {
        // SAFETY: constructor contract — `n_rows + 1` readable entries.
        unsafe { (*self.indptr.add(self.n_rows) - *self.indptr) as usize }
    }
}

/// CSR sparse matrix with `u32` column indices.
///
/// Rows live either in owned heap vectors or zero-copy in a read-only
/// memory mapping ([`Storage`]); every consumer sees the same
/// [`SparseRow`] views either way.
#[derive(Clone, Debug, Default)]
pub struct SparseMatrix {
    storage: Storage,
    cols: usize,
}

impl SparseMatrix {
    /// Build from per-row `(col, value)` lists. Columns within a row are
    /// sorted and duplicate columns are summed. Index/value buffers are
    /// pre-sized with a counted pass so large loads don't reallocate
    /// per row.
    pub fn from_rows(rows: Vec<Vec<(u32, f64)>>, cols: usize) -> Self {
        let total: usize = rows.iter().map(Vec::len).sum();
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::with_capacity(total);
        let mut values = Vec::with_capacity(total);
        indptr.push(0usize);
        for row in rows {
            append_normalized_row(row, cols, &mut indices, &mut values);
            indptr.push(indices.len());
        }
        SparseMatrix {
            storage: Storage::Owned {
                indptr,
                indices,
                values,
            },
            cols,
        }
    }

    /// Wrap already-validated sections of a memory-mapped cache file as
    /// a zero-copy matrix over rows `[0, n_rows)` of the mapping.
    ///
    /// # Safety
    ///
    /// The caller (the cache opener, `data/cache.rs`) must guarantee,
    /// for the lifetime of `map`:
    /// * `indptr` points at `n_rows + 1` aligned, readable `u64`s inside
    ///   the mapping, monotonically non-decreasing, each `<= nnz`;
    /// * `indices` / `values` point at aligned, readable sections of at
    ///   least `nnz` elements inside the mapping;
    /// * every stored column index in rows `[0, n_rows)` is `< cols` —
    ///   this upholds the `get_unchecked` contract of [`SparseRow::dot`].
    pub(crate) unsafe fn from_mapped_sections(
        map: std::sync::Arc<crate::utils::mmap::Mmap>,
        indptr: *const u64,
        n_rows: usize,
        indices: *const u32,
        values: *const f64,
        nnz: usize,
        cols: usize,
    ) -> SparseMatrix {
        SparseMatrix {
            storage: Storage::Mapped(MappedCsr {
                map,
                indptr,
                n_rows,
                indices,
                values,
                nnz,
            }),
            cols,
        }
    }

    /// True when rows are served from a memory mapping (no heap copy).
    pub fn is_mapped(&self) -> bool {
        matches!(self.storage, Storage::Mapped(_))
    }

    /// Build from a dense row-major matrix (zeros dropped).
    pub fn from_dense(rows: &[Vec<f64>]) -> Self {
        let cols = rows.first().map(|r| r.len()).unwrap_or(0);
        let sparse_rows = rows
            .iter()
            .map(|r| {
                assert_eq!(r.len(), cols, "ragged dense input");
                r.iter()
                    .enumerate()
                    .filter(|(_, &v)| v != 0.0)
                    .map(|(j, &v)| (j as u32, v))
                    .collect()
            })
            .collect();
        SparseMatrix::from_rows(sparse_rows, cols)
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        match &self.storage {
            Storage::Owned { indptr, .. } => indptr.len() - 1,
            Storage::Mapped(m) => m.n_rows,
        }
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total stored non-zeros.
    pub fn nnz(&self) -> usize {
        match &self.storage {
            Storage::Owned { values, .. } => values.len(),
            Storage::Mapped(m) => m.local_nnz(),
        }
    }

    /// The nnz prefix sum: `rows() + 1` values starting at 0 whose
    /// consecutive differences are the per-row stored non-zeros — the
    /// input `split_nnz` cuts on (`--balance nnz`, DESIGN.md §16).
    /// O(1) per row either way: a copy of the owned `indptr`, or a
    /// rebased read of the mapped cache's `indptr` section.
    pub fn nnz_prefix(&self) -> Vec<u64> {
        match &self.storage {
            Storage::Owned { indptr, .. } => indptr.iter().map(|&p| p as u64).collect(),
            Storage::Mapped(m) => {
                // SAFETY: constructor contract — `n_rows + 1` readable
                // monotone entries (see `MappedCsr::row`).
                let base = unsafe { *m.indptr };
                (0..=m.n_rows)
                    .map(|i| unsafe { *m.indptr.add(i) } - base)
                    .collect()
            }
        }
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> SparseRow<'_> {
        match &self.storage {
            Storage::Owned {
                indptr,
                indices,
                values,
            } => {
                let (lo, hi) = (indptr[i], indptr[i + 1]);
                SparseRow {
                    indices: &indices[lo..hi],
                    values: &values[lo..hi],
                }
            }
            Storage::Mapped(m) => m.row(i),
        }
    }

    /// Dense mat-vec `X w`.
    pub fn matvec(&self, w: &[f64]) -> Vec<f64> {
        assert_eq!(w.len(), self.cols);
        (0..self.rows()).map(|i| self.row(i).dot(w)).collect()
    }

    /// Transposed mat-vec `Xᵀ a`.
    pub fn matvec_t(&self, a: &[f64]) -> Vec<f64> {
        assert_eq!(a.len(), self.rows());
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows() {
            if a[i] != 0.0 {
                self.row(i).axpy_into(a[i], &mut out);
            }
        }
        out
    }

    /// Materialize a subset of rows as a new matrix (used by the
    /// partitioner to give each simulated machine an owned shard).
    pub fn select_rows(&self, rows: &[usize]) -> SparseMatrix {
        let total: usize = rows.iter().map(|&i| self.row(i).nnz()).sum();
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::with_capacity(total);
        let mut values = Vec::with_capacity(total);
        indptr.push(0usize);
        for &i in rows {
            let r = self.row(i);
            indices.extend_from_slice(r.indices);
            values.extend_from_slice(r.values);
            indptr.push(indices.len());
        }
        SparseMatrix {
            storage: Storage::Owned {
                indptr,
                indices,
                values,
            },
            cols: self.cols,
        }
    }

    /// A contiguous row range `[range.start, range.end)` as a matrix.
    ///
    /// Zero-copy for mapped storage (pointer arithmetic on the shared
    /// mapping — this is how each worker gets its shard out-of-core);
    /// an owned copy otherwise. Either way the values are identical, so
    /// solves over the two are bit-for-bit the same.
    pub fn slice_rows(&self, range: std::ops::Range<usize>) -> SparseMatrix {
        assert!(
            range.start <= range.end && range.end <= self.rows(),
            "row range {range:?} out of bounds ({} rows)",
            self.rows()
        );
        match &self.storage {
            Storage::Owned { .. } => {
                let idx: Vec<usize> = range.collect();
                self.select_rows(&idx)
            }
            Storage::Mapped(m) => SparseMatrix {
                storage: Storage::Mapped(MappedCsr {
                    map: std::sync::Arc::clone(&m.map),
                    // SAFETY: `range.start <= n_rows` (asserted above),
                    // so the shifted pointer still addresses valid
                    // `indptr` entries: `(n_rows - start) + 1` of them.
                    indptr: unsafe { m.indptr.add(range.start) },
                    n_rows: range.end - range.start,
                    indices: m.indices,
                    values: m.values,
                    nnz: m.nnz,
                }),
                cols: self.cols,
            },
        }
    }

    /// Dense row-major copy (tests / XLA path staging).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        (0..self.rows()).map(|i| self.row(i).to_dense(self.cols)).collect()
    }

    /// Pack rows `rows` into a dense row-major `f32` buffer of shape
    /// `(rows.len(), cols)` — the staging format for the PJRT batched
    /// local step.
    pub fn pack_rows_f32(&self, rows: &[usize], out: &mut [f32]) {
        assert_eq!(out.len(), rows.len() * self.cols);
        out.fill(0.0);
        for (k, &i) in rows.iter().enumerate() {
            let r = self.row(i);
            let base = k * self.cols;
            for (&j, &v) in r.indices.iter().zip(r.values) {
                out[base + j as usize] = v as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::for_each_case;

    fn sample() -> SparseMatrix {
        SparseMatrix::from_dense(&[
            vec![1.0, 0.0, 2.0],
            vec![0.0, 0.0, 0.0],
            vec![-1.0, 3.0, 0.0],
        ])
    }

    #[test]
    fn shape_and_nnz() {
        let m = sample();
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row(1).nnz(), 0);
    }

    #[test]
    fn matvec_matches_dense() {
        let m = sample();
        let w = vec![1.0, 2.0, 3.0];
        assert_eq!(m.matvec(&w), vec![7.0, 0.0, 5.0]);
    }

    #[test]
    fn matvec_t_matches_dense() {
        let m = sample();
        let a = vec![1.0, 5.0, 2.0];
        assert_eq!(m.matvec_t(&a), vec![-1.0, 6.0, 2.0]);
    }

    #[test]
    fn nnz_prefix_matches_per_row_counts() {
        let m = sample();
        assert_eq!(m.nnz_prefix(), vec![0, 2, 2, 4]);
        // Differences are exactly the per-row nnz, on a row-range view too.
        let s = m.slice_rows(1..3);
        let p = s.nnz_prefix();
        assert_eq!(p[0], 0);
        for i in 0..s.rows() {
            assert_eq!((p[i + 1] - p[i]) as usize, s.row(i).nnz());
        }
    }

    #[test]
    fn duplicate_columns_are_summed() {
        let m = SparseMatrix::from_rows(vec![vec![(0, 1.0), (0, 2.0), (2, 1.0)]], 3);
        assert_eq!(m.row(0).to_dense(3), vec![3.0, 0.0, 1.0]);
    }

    #[test]
    fn select_rows_copies() {
        let m = sample();
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.rows(), 2);
        assert_eq!(s.row(0).to_dense(3), vec![-1.0, 3.0, 0.0]);
        assert_eq!(s.row(1).to_dense(3), vec![1.0, 0.0, 2.0]);
    }

    #[test]
    fn slice_rows_matches_select_rows_on_owned_storage() {
        let m = sample();
        let s = m.slice_rows(1..3);
        let sel = m.select_rows(&[1, 2]);
        assert_eq!(s.rows(), 2);
        assert!(!s.is_mapped());
        assert_eq!(s.to_dense(), sel.to_dense());
        // Empty and full ranges are valid.
        assert_eq!(m.slice_rows(0..0).rows(), 0);
        assert_eq!(m.slice_rows(0..3).to_dense(), m.to_dense());
    }

    #[test]
    #[should_panic]
    fn slice_rows_rejects_out_of_bounds_range() {
        sample().slice_rows(1..4);
    }

    #[test]
    fn pack_rows_f32_layout() {
        let m = sample();
        let mut buf = vec![0f32; 6];
        m.pack_rows_f32(&[0, 2], &mut buf);
        assert_eq!(buf, vec![1.0, 0.0, 2.0, -1.0, 3.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_column_rejected() {
        SparseMatrix::from_rows(vec![vec![(5, 1.0)]], 3);
    }

    #[test]
    fn unrolled_dot_matches_serial_reference() {
        // The 4-accumulator gather must agree with a plain serial fold to
        // fp tolerance at every remainder length (0–3 tail elements), and
        // exactly on integer-valued data.
        use crate::utils::Rng;
        let mut rng = Rng::new(0xD07);
        for nnz in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 31, 100, 257] {
            let d = (nnz * 3).max(8);
            let mut cols: Vec<usize> = rng.sample_indices(d, nnz);
            cols.sort_unstable();
            let row: Vec<(u32, f64)> = cols
                .iter()
                .map(|&j| (j as u32, rng.uniform(-2.0, 2.0)))
                .collect();
            let m = SparseMatrix::from_rows(vec![row], d);
            let w: Vec<f64> = (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let r = m.row(0);
            let serial: f64 = r
                .indices
                .iter()
                .zip(r.values)
                .map(|(&j, &v)| v * w[j as usize])
                .sum();
            let got = r.dot(&w);
            assert!(
                (got - serial).abs() <= 1e-12 * (1.0 + serial.abs()),
                "nnz={nnz}: {got} vs {serial}"
            );
        }
        // Integer values: every partial sum is exact, so the reassociated
        // result must be bit-equal to the serial one.
        let m = SparseMatrix::from_rows(
            vec![(0..9).map(|j| (j as u32, (j + 1) as f64)).collect()],
            16,
        );
        let w: Vec<f64> = (0..16).map(|j| j as f64).collect();
        let want: f64 = (0..9).map(|j| ((j + 1) * j) as f64).sum();
        assert_eq!(m.row(0).dot(&w), want);
    }

    #[test]
    fn prop_roundtrip_and_matvec_agree_with_dense() {
        for_each_case(0xDA7A, 50, |g| {
            let rows = g.usize_in(1, 12);
            let cols = g.usize_in(1, 12);
            let dense: Vec<Vec<f64>> = (0..rows)
                .map(|_| {
                    (0..cols)
                        .map(|_| {
                            if g.bool(0.4) {
                                g.f64_in(-2.0, 2.0)
                            } else {
                                0.0
                            }
                        })
                        .collect()
                })
                .collect();
            let m = SparseMatrix::from_dense(&dense);
            assert_eq!(m.to_dense(), dense);
            let w = g.vec_f64(cols, -1.0, 1.0);
            let got = m.matvec(&w);
            for i in 0..rows {
                let want: f64 = dense[i].iter().zip(&w).map(|(a, b)| a * b).sum();
                assert!((got[i] - want).abs() < 1e-12);
            }
            let a = g.vec_f64(rows, -1.0, 1.0);
            let got_t = m.matvec_t(&a);
            for j in 0..cols {
                let want: f64 = (0..rows).map(|i| dense[i][j] * a[i]).sum();
                assert!((got_t[j] - want).abs() < 1e-12);
            }
        });
    }
}
