//! Compressed sparse row (CSR) matrix.
//!
//! The single most important data structure on the Layer-3 hot path: every
//! ProxSDCA coordinate step does one sparse dot `x_iᵀ w` and one sparse
//! axpy `v += c·x_i` against a row of this matrix. Rows are contiguous
//! `(indices, values)` slices so the inner loops are cache-friendly and
//! allocation-free.

/// Borrowed view of one CSR row.
#[derive(Clone, Copy, Debug)]
pub struct SparseRow<'a> {
    /// Column indices (strictly increasing).
    pub indices: &'a [u32],
    /// Matching values.
    pub values: &'a [f64],
}

impl<'a> SparseRow<'a> {
    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Sparse dot product against a dense vector.
    ///
    /// The innermost loop of every coordinate step. Column indices are
    /// validated once at construction (`SparseMatrix::from_rows`), so the
    /// gather skips per-element bounds checks (§Perf iteration 1: +35%
    /// epoch throughput). The gather accumulates into four independent
    /// streams: a single accumulator chains every add behind the previous
    /// one (4–5 cycle FP-add latency per nnz), while four break the
    /// dependence and let the loads and adds overlap — the
    /// `sparse_dot_unrolled` row of `perf_hotpath` pins the win on long
    /// rows. The combine order `(a0+a1)+(a2+a3)` is fixed, so results are
    /// deterministic (though not bit-identical to a serial fold).
    #[inline]
    pub fn dot(&self, w: &[f64]) -> f64 {
        debug_assert!(self
            .indices
            .iter()
            .all(|&j| (j as usize) < w.len()));
        // Fully-dense row (covtype/HIGGS-like data): indices are exactly
        // 0..d, so the gather degenerates to a contiguous dot product that
        // LLVM auto-vectorizes (§Perf iteration 2).
        if self.indices.len() == w.len() {
            return self.values.iter().zip(w).map(|(v, x)| v * x).sum();
        }
        let idx = self.indices;
        let val = self.values;
        let head = idx.len() & !3;
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        let mut k = 0usize;
        while k < head {
            // SAFETY: k + 3 < idx.len() == val.len(), and every stored
            // index j < cols ≤ w.len() — enforced at matrix construction
            // and checked above in debug builds.
            unsafe {
                a0 += *val.get_unchecked(k) * *w.get_unchecked(*idx.get_unchecked(k) as usize);
                a1 += *val.get_unchecked(k + 1)
                    * *w.get_unchecked(*idx.get_unchecked(k + 1) as usize);
                a2 += *val.get_unchecked(k + 2)
                    * *w.get_unchecked(*idx.get_unchecked(k + 2) as usize);
                a3 += *val.get_unchecked(k + 3)
                    * *w.get_unchecked(*idx.get_unchecked(k + 3) as usize);
            }
            k += 4;
        }
        let mut acc = (a0 + a1) + (a2 + a3);
        while k < idx.len() {
            // SAFETY: as above.
            acc += unsafe {
                *val.get_unchecked(k) * *w.get_unchecked(*idx.get_unchecked(k) as usize)
            };
            k += 1;
        }
        acc
    }

    /// `out += c · x_i` (sparse axpy).
    #[inline]
    pub fn axpy_into(&self, c: f64, out: &mut [f64]) {
        debug_assert!(self
            .indices
            .iter()
            .all(|&j| (j as usize) < out.len()));
        if self.indices.len() == out.len() {
            for (o, &v) in out.iter_mut().zip(self.values) {
                *o += c * v;
            }
            return;
        }
        for (&j, &v) in self.indices.iter().zip(self.values) {
            // SAFETY: as in `dot`.
            unsafe { *out.get_unchecked_mut(j as usize) += c * v };
        }
    }

    /// `‖x_i‖₂²`.
    #[inline]
    pub fn norm_sq(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum()
    }

    /// Materialize as a dense vector of length `dim`.
    pub fn to_dense(&self, dim: usize) -> Vec<f64> {
        let mut out = vec![0.0; dim];
        self.axpy_into(1.0, &mut out);
        out
    }
}

/// CSR sparse matrix with `u32` column indices.
#[derive(Clone, Debug, Default)]
pub struct SparseMatrix {
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
    cols: usize,
}

impl SparseMatrix {
    /// Build from per-row `(col, value)` lists. Columns within a row are
    /// sorted and duplicate columns are summed.
    pub fn from_rows(rows: Vec<Vec<(u32, f64)>>, cols: usize) -> Self {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0usize);
        for mut row in rows {
            row.sort_unstable_by_key(|&(j, _)| j);
            let mut last: Option<u32> = None;
            for (j, v) in row {
                assert!((j as usize) < cols, "column {j} out of bounds ({cols})");
                if last == Some(j) {
                    *values.last_mut().unwrap() += v;
                } else if v != 0.0 {
                    indices.push(j);
                    values.push(v);
                    last = Some(j);
                }
            }
            indptr.push(indices.len());
        }
        SparseMatrix {
            indptr,
            indices,
            values,
            cols,
        }
    }

    /// Build from a dense row-major matrix (zeros dropped).
    pub fn from_dense(rows: &[Vec<f64>]) -> Self {
        let cols = rows.first().map(|r| r.len()).unwrap_or(0);
        let sparse_rows = rows
            .iter()
            .map(|r| {
                assert_eq!(r.len(), cols, "ragged dense input");
                r.iter()
                    .enumerate()
                    .filter(|(_, &v)| v != 0.0)
                    .map(|(j, &v)| (j as u32, v))
                    .collect()
            })
            .collect();
        SparseMatrix::from_rows(sparse_rows, cols)
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.indptr.len() - 1
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> SparseRow<'_> {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        SparseRow {
            indices: &self.indices[lo..hi],
            values: &self.values[lo..hi],
        }
    }

    /// Dense mat-vec `X w`.
    pub fn matvec(&self, w: &[f64]) -> Vec<f64> {
        assert_eq!(w.len(), self.cols);
        (0..self.rows()).map(|i| self.row(i).dot(w)).collect()
    }

    /// Transposed mat-vec `Xᵀ a`.
    pub fn matvec_t(&self, a: &[f64]) -> Vec<f64> {
        assert_eq!(a.len(), self.rows());
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows() {
            if a[i] != 0.0 {
                self.row(i).axpy_into(a[i], &mut out);
            }
        }
        out
    }

    /// Materialize a subset of rows as a new matrix (used by the
    /// partitioner to give each simulated machine an owned shard).
    pub fn select_rows(&self, rows: &[usize]) -> SparseMatrix {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0usize);
        for &i in rows {
            let r = self.row(i);
            indices.extend_from_slice(r.indices);
            values.extend_from_slice(r.values);
            indptr.push(indices.len());
        }
        SparseMatrix {
            indptr,
            indices,
            values,
            cols: self.cols,
        }
    }

    /// Dense row-major copy (tests / XLA path staging).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        (0..self.rows()).map(|i| self.row(i).to_dense(self.cols)).collect()
    }

    /// Pack rows `rows` into a dense row-major `f32` buffer of shape
    /// `(rows.len(), cols)` — the staging format for the PJRT batched
    /// local step.
    pub fn pack_rows_f32(&self, rows: &[usize], out: &mut [f32]) {
        assert_eq!(out.len(), rows.len() * self.cols);
        out.fill(0.0);
        for (k, &i) in rows.iter().enumerate() {
            let r = self.row(i);
            let base = k * self.cols;
            for (&j, &v) in r.indices.iter().zip(r.values) {
                out[base + j as usize] = v as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::for_each_case;

    fn sample() -> SparseMatrix {
        SparseMatrix::from_dense(&[
            vec![1.0, 0.0, 2.0],
            vec![0.0, 0.0, 0.0],
            vec![-1.0, 3.0, 0.0],
        ])
    }

    #[test]
    fn shape_and_nnz() {
        let m = sample();
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row(1).nnz(), 0);
    }

    #[test]
    fn matvec_matches_dense() {
        let m = sample();
        let w = vec![1.0, 2.0, 3.0];
        assert_eq!(m.matvec(&w), vec![7.0, 0.0, 5.0]);
    }

    #[test]
    fn matvec_t_matches_dense() {
        let m = sample();
        let a = vec![1.0, 5.0, 2.0];
        assert_eq!(m.matvec_t(&a), vec![-1.0, 6.0, 2.0]);
    }

    #[test]
    fn duplicate_columns_are_summed() {
        let m = SparseMatrix::from_rows(vec![vec![(0, 1.0), (0, 2.0), (2, 1.0)]], 3);
        assert_eq!(m.row(0).to_dense(3), vec![3.0, 0.0, 1.0]);
    }

    #[test]
    fn select_rows_copies() {
        let m = sample();
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.rows(), 2);
        assert_eq!(s.row(0).to_dense(3), vec![-1.0, 3.0, 0.0]);
        assert_eq!(s.row(1).to_dense(3), vec![1.0, 0.0, 2.0]);
    }

    #[test]
    fn pack_rows_f32_layout() {
        let m = sample();
        let mut buf = vec![0f32; 6];
        m.pack_rows_f32(&[0, 2], &mut buf);
        assert_eq!(buf, vec![1.0, 0.0, 2.0, -1.0, 3.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_column_rejected() {
        SparseMatrix::from_rows(vec![vec![(5, 1.0)]], 3);
    }

    #[test]
    fn unrolled_dot_matches_serial_reference() {
        // The 4-accumulator gather must agree with a plain serial fold to
        // fp tolerance at every remainder length (0–3 tail elements), and
        // exactly on integer-valued data.
        use crate::utils::Rng;
        let mut rng = Rng::new(0xD07);
        for nnz in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 31, 100, 257] {
            let d = (nnz * 3).max(8);
            let mut cols: Vec<usize> = rng.sample_indices(d, nnz);
            cols.sort_unstable();
            let row: Vec<(u32, f64)> = cols
                .iter()
                .map(|&j| (j as u32, rng.uniform(-2.0, 2.0)))
                .collect();
            let m = SparseMatrix::from_rows(vec![row], d);
            let w: Vec<f64> = (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let r = m.row(0);
            let serial: f64 = r
                .indices
                .iter()
                .zip(r.values)
                .map(|(&j, &v)| v * w[j as usize])
                .sum();
            let got = r.dot(&w);
            assert!(
                (got - serial).abs() <= 1e-12 * (1.0 + serial.abs()),
                "nnz={nnz}: {got} vs {serial}"
            );
        }
        // Integer values: every partial sum is exact, so the reassociated
        // result must be bit-equal to the serial one.
        let m = SparseMatrix::from_rows(
            vec![(0..9).map(|j| (j as u32, (j + 1) as f64)).collect()],
            16,
        );
        let w: Vec<f64> = (0..16).map(|j| j as f64).collect();
        let want: f64 = (0..9).map(|j| ((j + 1) * j) as f64).sum();
        assert_eq!(m.row(0).dot(&w), want);
    }

    #[test]
    fn prop_roundtrip_and_matvec_agree_with_dense() {
        for_each_case(0xDA7A, 50, |g| {
            let rows = g.usize_in(1, 12);
            let cols = g.usize_in(1, 12);
            let dense: Vec<Vec<f64>> = (0..rows)
                .map(|_| {
                    (0..cols)
                        .map(|_| {
                            if g.bool(0.4) {
                                g.f64_in(-2.0, 2.0)
                            } else {
                                0.0
                            }
                        })
                        .collect()
                })
                .collect();
            let m = SparseMatrix::from_dense(&dense);
            assert_eq!(m.to_dense(), dense);
            let w = g.vec_f64(cols, -1.0, 1.0);
            let got = m.matvec(&w);
            for i in 0..rows {
                let want: f64 = dense[i].iter().zip(&w).map(|(a, b)| a * b).sum();
                assert!((got[i] - want).abs() < 1e-12);
            }
            let a = g.vec_f64(rows, -1.0, 1.0);
            let got_t = m.matvec_t(&a);
            for j in 0..cols {
                let want: f64 = (0..rows).map(|i| dense[i][j] * a[i]).sum();
                assert!((got_t[j] - want).abs() < 1e-12);
            }
        });
    }
}
