//! Balanced random partitioning of examples across `m` simulated machines.
//!
//! Mirrors the paper's experimental protocol (§10: "we use same balanced
//! data partitions and random seeds"): a seeded shuffle of `{0..n}` split
//! into `m` contiguous chunks whose sizes differ by at most one.

use crate::utils::Rng;

/// A partition of `{0, …, n−1}` into `m` machine-local index sets `S_ℓ`.
#[derive(Clone, Debug)]
pub struct Partition {
    shards: Vec<Vec<usize>>,
    n: usize,
}

impl Partition {
    /// Balanced random partition with a seeded shuffle.
    pub fn balanced(n: usize, m: usize, seed: u64) -> Self {
        assert!(m >= 1, "need at least one machine");
        assert!(n >= m, "need at least one example per machine (n={n}, m={m})");
        let mut idx: Vec<usize> = (0..n).collect();
        Rng::new(seed).shuffle(&mut idx);
        let base = n / m;
        let extra = n % m;
        let mut shards = Vec::with_capacity(m);
        let mut cursor = 0usize;
        for l in 0..m {
            let size = base + usize::from(l < extra);
            shards.push(idx[cursor..cursor + size].to_vec());
            cursor += size;
        }
        Partition { shards, n }
    }

    /// Deterministic round-robin partition (no shuffle) — used by tests
    /// that need a fixed assignment.
    pub fn round_robin(n: usize, m: usize) -> Self {
        assert!(m >= 1 && n >= m);
        let mut shards = vec![Vec::new(); m];
        for i in 0..n {
            shards[i % m].push(i);
        }
        Partition { shards, n }
    }

    /// Number of machines `m`.
    pub fn machines(&self) -> usize {
        self.shards.len()
    }

    /// Total number of examples `n`.
    pub fn total(&self) -> usize {
        self.n
    }

    /// Index set `S_ℓ`.
    pub fn shard(&self, l: usize) -> &[usize] {
        &self.shards[l]
    }

    /// `n_ℓ = |S_ℓ|`.
    pub fn shard_size(&self, l: usize) -> usize {
        self.shards[l].len()
    }

    /// `max_ℓ n_ℓ / M_ℓ` term of Theorems 6/7 for a fixed sampling
    /// fraction `sp` (`M_ℓ = ⌈sp · n_ℓ⌉`).
    pub fn max_epoch_ratio(&self, sp: f64) -> f64 {
        (0..self.machines())
            .map(|l| {
                let nl = self.shard_size(l) as f64;
                let ml = (sp * nl).ceil().max(1.0);
                nl / ml
            })
            .fold(0.0, f64::max)
    }

    /// Verify partition invariants: disjoint cover of `{0..n}` with shard
    /// sizes differing by ≤ 1 (balanced variants only).
    pub fn check_invariants(&self, balanced: bool) -> anyhow::Result<()> {
        let mut seen = vec![false; self.n];
        for shard in &self.shards {
            for &i in shard {
                anyhow::ensure!(i < self.n, "index {i} out of range");
                anyhow::ensure!(!seen[i], "index {i} appears twice");
                seen[i] = true;
            }
        }
        anyhow::ensure!(seen.iter().all(|&s| s), "partition does not cover all indices");
        if balanced {
            let min = self.shards.iter().map(Vec::len).min().unwrap();
            let max = self.shards.iter().map(Vec::len).max().unwrap();
            anyhow::ensure!(max - min <= 1, "unbalanced shards: {min}..{max}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::for_each_case;

    #[test]
    fn balanced_invariants_hold() {
        for &(n, m) in &[(10, 3), (100, 8), (7, 7), (1000, 20)] {
            let p = Partition::balanced(n, m, 42);
            assert_eq!(p.machines(), m);
            p.check_invariants(true).unwrap();
        }
    }

    #[test]
    fn same_seed_same_partition() {
        let a = Partition::balanced(100, 4, 7);
        let b = Partition::balanced(100, 4, 7);
        for l in 0..4 {
            assert_eq!(a.shard(l), b.shard(l));
        }
    }

    #[test]
    fn different_seed_different_partition() {
        let a = Partition::balanced(100, 4, 7);
        let b = Partition::balanced(100, 4, 8);
        assert!((0..4).any(|l| a.shard(l) != b.shard(l)));
    }

    #[test]
    fn round_robin_deterministic() {
        let p = Partition::round_robin(7, 3);
        assert_eq!(p.shard(0), &[0, 3, 6]);
        assert_eq!(p.shard(1), &[1, 4]);
        assert_eq!(p.shard(2), &[2, 5]);
        p.check_invariants(true).unwrap();
    }

    #[test]
    fn epoch_ratio_matches_theorem_term() {
        let p = Partition::balanced(100, 4, 1); // n_ℓ = 25
        // sp = 0.2 ⇒ M_ℓ = 5 ⇒ n_ℓ/M_ℓ = 5
        assert!((p.max_epoch_ratio(0.2) - 5.0).abs() < 1e-12);
        // sp = 1.0 ⇒ ratio 1
        assert!((p.max_epoch_ratio(1.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn prop_invariants_random_shapes() {
        for_each_case(0x9A27, 60, |g| {
            let m = g.usize_in(1, 12);
            let n = g.usize_in(m, m * 40);
            let seed = g.rng().next_u64();
            let p = Partition::balanced(n, m, seed);
            p.check_invariants(true).unwrap();
            let total: usize = (0..m).map(|l| p.shard_size(l)).sum();
            assert_eq!(total, n);
        });
    }

    #[test]
    #[should_panic]
    fn rejects_more_machines_than_examples() {
        Partition::balanced(3, 5, 0);
    }
}
