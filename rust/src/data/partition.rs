//! Balanced random partitioning of examples across `m` simulated machines.
//!
//! Mirrors the paper's experimental protocol (§10: "we use same balanced
//! data partitions and random seeds"): a seeded shuffle of `{0..n}` split
//! into `m` contiguous chunks whose sizes differ by at most one.
//!
//! Two chunking formulas exist (DESIGN.md §16): [`split_ranges`] balances
//! **row counts**, [`split_nnz`] balances **stored non-zeros** — on skewed
//! sparse data the per-round barrier waits on the densest shard, so
//! equalizing nnz is what equalizes local-step time. Both are pure
//! functions of their inputs, so every backend derives identical cuts.

use crate::utils::Rng;

/// How shard cut points are chosen (`--balance {rows,nnz}`): balance row
/// counts (the default, and the historical parity pin) or stored
/// non-zeros ([`split_nnz`]). Shipped to remote TCP workers in the
/// `ProblemSpec` so their locally derived sub-shards use the same
/// formula as the coordinator's (DESIGN.md §16).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Balance {
    /// Shard sizes differ by at most one row ([`split_ranges`]).
    #[default]
    Rows,
    /// Contiguous cuts minimizing the max shard nnz ([`split_nnz`]).
    Nnz,
}

/// A partition of `{0, …, n−1}` into `m` machine-local index sets `S_ℓ`.
#[derive(Clone, Debug)]
pub struct Partition {
    shards: Vec<Vec<usize>>,
    n: usize,
}

impl Partition {
    /// Balanced random partition with a seeded shuffle.
    pub fn balanced(n: usize, m: usize, seed: u64) -> Self {
        assert!(m >= 1, "need at least one machine");
        assert!(n >= m, "need at least one example per machine (n={n}, m={m})");
        let mut idx: Vec<usize> = (0..n).collect();
        Rng::new(seed).shuffle(&mut idx);
        // One chunking formula in the crate (§10): the shuffled sequence
        // is cut exactly like every other contiguous balanced split.
        let shards = split_ranges(n, m).into_iter().map(|r| idx[r].to_vec()).collect();
        Partition { shards, n }
    }

    /// Contiguous balanced partition: machine `ℓ` owns the `ℓ`-th range
    /// of [`split_ranges`]`(n, m)` — no shuffle, no seed.
    ///
    /// This is the partition the binary cache path uses (`--cache`):
    /// each worker's shard is a contiguous row range of the mapped
    /// file, so shards are served zero-copy. A text-parsed run with
    /// `partition = contiguous` produces the *same* index sets, which
    /// is what makes cache-vs-text solves bit-identical.
    pub fn contiguous(n: usize, m: usize) -> Self {
        let shards = split_ranges(n, m).into_iter().map(|r| r.collect()).collect();
        Partition { shards, n }
    }

    /// Contiguous **nnz-balanced** partition: machine `ℓ` owns the `ℓ`-th
    /// range of [`split_nnz`]`(nnz_prefix, m)` — contiguous cut points
    /// minimizing the maximum shard nnz (`--balance nnz`, DESIGN.md §16).
    ///
    /// `nnz_prefix` holds `n + 1` non-decreasing values with
    /// `nnz_prefix[i+1] − nnz_prefix[i]` = row `i`'s stored non-zeros
    /// (the cache's `indptr` section verbatim, or one counting pass for
    /// text/synthetic data). The cuts are a pure function of the data —
    /// no seed, no tie randomness — so TCP workers, checkpoint resume
    /// and §14 resurrection all reconstruct the same shards.
    pub fn contiguous_nnz(nnz_prefix: &[u64], m: usize) -> Self {
        let n = nnz_prefix.len().checked_sub(1).expect("nnz prefix needs ≥ 1 entry");
        let shards = split_nnz(nnz_prefix, m).into_iter().map(|r| r.collect()).collect();
        Partition { shards, n }
    }

    /// Deterministic round-robin partition (no shuffle) — used by tests
    /// that need a fixed assignment.
    pub fn round_robin(n: usize, m: usize) -> Self {
        assert!(m >= 1 && n >= m);
        let mut shards = vec![Vec::new(); m];
        for i in 0..n {
            shards[i % m].push(i);
        }
        Partition { shards, n }
    }

    /// Number of machines `m`.
    pub fn machines(&self) -> usize {
        self.shards.len()
    }

    /// Total number of examples `n`.
    pub fn total(&self) -> usize {
        self.n
    }

    /// Index set `S_ℓ`.
    pub fn shard(&self, l: usize) -> &[usize] {
        &self.shards[l]
    }

    /// `n_ℓ = |S_ℓ|`.
    pub fn shard_size(&self, l: usize) -> usize {
        self.shards[l].len()
    }

    /// `max_ℓ n_ℓ / M_ℓ` term of Theorems 6/7 for a fixed sampling
    /// fraction `sp` (`M_ℓ = ⌈sp · n_ℓ⌉`).
    pub fn max_epoch_ratio(&self, sp: f64) -> f64 {
        (0..self.machines())
            .map(|l| {
                let nl = self.shard_size(l) as f64;
                let ml = (sp * nl).ceil().max(1.0);
                nl / ml
            })
            .fold(0.0, f64::max)
    }

    /// Smallest shard size `min_ℓ n_ℓ` — the upper bound on how many
    /// sub-shards a machine can be split into ([`Partition::split`]).
    pub fn min_shard(&self) -> usize {
        self.shards.iter().map(Vec::len).min().unwrap_or(0)
    }

    /// Sub-partition every machine's shard into `t` contiguous balanced
    /// sub-shards (hierarchical parallelism, DESIGN.md §10): the result
    /// has `m·t` *logical* machines where logical shard `ℓ·t + k` is the
    /// `k`-th contiguous chunk of machine `ℓ`'s shard, chunk sizes
    /// differing by at most one within each machine.
    ///
    /// Because [`Partition::balanced`] splits one seeded shuffle into
    /// contiguous chunks, `balanced(n, m, s).split(t)` is **identical**
    /// to `balanced(n, m·t, s)` whenever `m·t` divides `n` — the property
    /// that lets an `(m, t)` hierarchical solve reproduce a flat `m·t`
    /// solve bit for bit (pinned in `rust/tests/local_threads.rs`).
    pub fn split(&self, t: usize) -> Partition {
        assert!(t >= 1, "need at least one sub-shard per machine");
        let mut shards = Vec::with_capacity(self.shards.len() * t);
        for shard in &self.shards {
            assert!(
                shard.len() >= t,
                "cannot split a shard of {} examples into {t} sub-shards",
                shard.len()
            );
            for r in split_ranges(shard.len(), t) {
                shards.push(shard[r].to_vec());
            }
        }
        Partition { shards, n: self.n }
    }

    /// Sub-partition every machine's shard into `t` contiguous
    /// **nnz-balanced** sub-shards — the `--balance nnz` analog of
    /// [`Partition::split`] (hierarchical parallelism, DESIGN.md §10/§16).
    /// `row_nnz[i]` is global row `i`'s stored non-zeros; each shard's
    /// local prefix sum feeds [`split_nnz`], the same formula a remote
    /// TCP worker applies to its own rows, so the coordinator's logical
    /// sub-shards and a worker's locally derived ones can never disagree.
    pub fn split_nnz(&self, t: usize, row_nnz: &[u64]) -> Partition {
        assert!(t >= 1, "need at least one sub-shard per machine");
        assert_eq!(row_nnz.len(), self.n, "row_nnz must cover every example");
        let mut shards = Vec::with_capacity(self.shards.len() * t);
        for shard in &self.shards {
            assert!(
                shard.len() >= t,
                "cannot split a shard of {} examples into {t} sub-shards",
                shard.len()
            );
            let mut prefix = Vec::with_capacity(shard.len() + 1);
            prefix.push(0u64);
            for &i in shard {
                prefix.push(prefix.last().unwrap() + row_nnz[i]);
            }
            for r in split_nnz(&prefix, t) {
                shards.push(shard[r].to_vec());
            }
        }
        Partition { shards, n: self.n }
    }

    /// Verify partition invariants: disjoint cover of `{0..n}` with shard
    /// sizes differing by ≤ 1 (balanced variants only).
    pub fn check_invariants(&self, balanced: bool) -> anyhow::Result<()> {
        let mut seen = vec![false; self.n];
        for shard in &self.shards {
            for &i in shard {
                anyhow::ensure!(i < self.n, "index {i} out of range");
                anyhow::ensure!(!seen[i], "index {i} appears twice");
                seen[i] = true;
            }
        }
        anyhow::ensure!(seen.iter().all(|&s| s), "partition does not cover all indices");
        if balanced {
            let min = self.shards.iter().map(Vec::len).min().unwrap();
            let max = self.shards.iter().map(Vec::len).max().unwrap();
            anyhow::ensure!(max - min <= 1, "unbalanced shards: {min}..{max}");
        }
        Ok(())
    }
}

/// The contiguous balanced chunking `{0..n} → t` ranges (sizes differ by
/// at most one, larger chunks first) shared by [`Partition::split`] and
/// the TCP worker's local sub-shard reconstruction — one formula, so the
/// coordinator's logical partition and a remote worker's locally-derived
/// sub-shards can never disagree (DESIGN.md §10).
pub fn split_ranges(n: usize, t: usize) -> Vec<std::ops::Range<usize>> {
    assert!(t >= 1 && n >= t, "cannot split {n} examples into {t} chunks");
    let base = n / t;
    let extra = n % t;
    let mut out = Vec::with_capacity(t);
    let mut cursor = 0usize;
    for k in 0..t {
        let size = base + usize::from(k < extra);
        out.push(cursor..cursor + size);
        cursor += size;
    }
    out
}

/// The contiguous **nnz-balanced** chunking `{0..n} → t` ranges
/// (DESIGN.md §16): cut points minimizing the maximum chunk nnz, every
/// chunk non-empty. The dual formula to [`split_ranges`] — used by
/// machine-level `--balance nnz` partitioning ([`Partition::contiguous_nnz`]),
/// sub-machine splitting ([`Partition::split_nnz`]) and the TCP worker's
/// local sub-shard reconstruction, so cuts derived from the same nnz
/// values agree everywhere.
///
/// `prefix` holds `n + 1` non-decreasing values whose consecutive
/// differences are the per-row nnz; an arbitrary base offset is allowed
/// (a mapped cache's absolute `indptr` entries work verbatim).
///
/// The optimum is found by bisecting on the answer `W` (a chunking with
/// max-nnz ≤ W exists iff the deterministic greedy one below stays
/// within `W`), then emitting the greedy cuts at the minimal feasible
/// `W`: each chunk takes the longest row run with nnz ≤ W that still
/// leaves one row per remaining chunk. O(n log Σnnz), deterministic —
/// and never worse than row balancing, because [`split_ranges`]'s cuts
/// are one feasible candidate.
pub fn split_nnz(prefix: &[u64], t: usize) -> Vec<std::ops::Range<usize>> {
    let n = prefix.len().checked_sub(1).expect("nnz prefix needs ≥ 1 entry");
    assert!(t >= 1 && n >= t, "cannot split {n} examples into {t} chunks");
    assert!(
        prefix.windows(2).all(|w| w[0] <= w[1]),
        "nnz prefix must be non-decreasing"
    );
    let nnz = |lo: usize, hi: usize| prefix[hi] - prefix[lo];
    // Greedy cuts at budget w; returns (ranges, max chunk nnz realized).
    let cuts = |w: u64| -> (Vec<std::ops::Range<usize>>, u64) {
        let mut out = Vec::with_capacity(t);
        let mut worst = 0u64;
        let mut start = 0usize;
        for k in 0..t {
            let left = t - k - 1; // chunks still owed one row each
            let mut end = start + 1;
            while end < n - left && nnz(start, end + 1) <= w {
                end += 1;
            }
            if k + 1 == t {
                end = n; // last chunk takes the tail
            }
            worst = worst.max(nnz(start, end));
            out.push(start..end);
            start = end;
        }
        (out, worst)
    };
    // Feasibility is monotone in w: bisect the minimal budget. A chunk
    // holds ≥ 1 row and some chunk holds ≥ ⌈total/t⌉ nnz, so:
    let total = nnz(0, n);
    let max_row = (0..n).map(|i| nnz(i, i + 1)).max().unwrap_or(0);
    let mut lo = max_row.max(total.div_ceil(t as u64));
    let mut hi = total;
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if cuts(mid).1 <= mid {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    cuts(lo).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::for_each_case;

    #[test]
    fn balanced_invariants_hold() {
        for &(n, m) in &[(10, 3), (100, 8), (7, 7), (1000, 20)] {
            let p = Partition::balanced(n, m, 42);
            assert_eq!(p.machines(), m);
            p.check_invariants(true).unwrap();
        }
    }

    #[test]
    fn same_seed_same_partition() {
        let a = Partition::balanced(100, 4, 7);
        let b = Partition::balanced(100, 4, 7);
        for l in 0..4 {
            assert_eq!(a.shard(l), b.shard(l));
        }
    }

    #[test]
    fn different_seed_different_partition() {
        let a = Partition::balanced(100, 4, 7);
        let b = Partition::balanced(100, 4, 8);
        assert!((0..4).any(|l| a.shard(l) != b.shard(l)));
    }

    #[test]
    fn contiguous_matches_split_ranges() {
        for &(n, m) in &[(10, 3), (100, 8), (7, 7), (1000, 20)] {
            let p = Partition::contiguous(n, m);
            p.check_invariants(true).unwrap();
            let rs = split_ranges(n, m);
            for l in 0..m {
                let want: Vec<usize> = rs[l].clone().collect();
                assert_eq!(p.shard(l), &want[..], "machine {l}");
            }
        }
    }

    #[test]
    fn round_robin_deterministic() {
        let p = Partition::round_robin(7, 3);
        assert_eq!(p.shard(0), &[0, 3, 6]);
        assert_eq!(p.shard(1), &[1, 4]);
        assert_eq!(p.shard(2), &[2, 5]);
        p.check_invariants(true).unwrap();
    }

    #[test]
    fn epoch_ratio_matches_theorem_term() {
        let p = Partition::balanced(100, 4, 1); // n_ℓ = 25
        // sp = 0.2 ⇒ M_ℓ = 5 ⇒ n_ℓ/M_ℓ = 5
        assert!((p.max_epoch_ratio(0.2) - 5.0).abs() < 1e-12);
        // sp = 1.0 ⇒ ratio 1
        assert!((p.max_epoch_ratio(1.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn prop_invariants_random_shapes() {
        for_each_case(0x9A27, 60, |g| {
            let m = g.usize_in(1, 12);
            let n = g.usize_in(m, m * 40);
            let seed = g.rng().next_u64();
            let p = Partition::balanced(n, m, seed);
            p.check_invariants(true).unwrap();
            let total: usize = (0..m).map(|l| p.shard_size(l)).sum();
            assert_eq!(total, n);
        });
    }

    #[test]
    #[should_panic]
    fn rejects_more_machines_than_examples() {
        Partition::balanced(3, 5, 0);
    }

    #[test]
    fn split_ranges_are_balanced_and_cover() {
        for &(n, t) in &[(10, 3), (12, 4), (7, 7), (100, 1), (5, 2)] {
            let rs = split_ranges(n, t);
            assert_eq!(rs.len(), t);
            assert_eq!(rs[0].start, 0);
            assert_eq!(rs.last().unwrap().end, n);
            for pair in rs.windows(2) {
                assert_eq!(pair[0].end, pair[1].start, "ranges must be contiguous");
            }
            let min = rs.iter().map(|r| r.len()).min().unwrap();
            let max = rs.iter().map(|r| r.len()).max().unwrap();
            assert!(max - min <= 1, "unbalanced chunks: {min}..{max}");
            assert!(min >= 1);
        }
    }

    #[test]
    fn split_preserves_cover_and_order() {
        let p = Partition::balanced(100, 4, 7);
        let s = p.split(3);
        assert_eq!(s.machines(), 12);
        assert_eq!(s.total(), 100);
        s.check_invariants(false).unwrap();
        // Sub-shards of machine ℓ concatenate back to ℓ's shard in order.
        for l in 0..4 {
            let rebuilt: Vec<usize> = (0..3).flat_map(|k| s.shard(l * 3 + k).to_vec()).collect();
            assert_eq!(rebuilt, p.shard(l));
        }
    }

    #[test]
    fn split_one_is_identity() {
        let p = Partition::balanced(57, 5, 9);
        let s = p.split(1);
        for l in 0..5 {
            assert_eq!(s.shard(l), p.shard(l));
        }
    }

    #[test]
    fn split_matches_flat_balanced_when_divisible() {
        // The bit-parity anchor: when m·t | n, splitting the m-machine
        // partition reproduces the flat m·t-machine partition exactly.
        for &(n, m, t) in &[(240, 2, 2), (240, 3, 4), (64, 4, 4), (96, 2, 8)] {
            assert_eq!(n % (m * t), 0);
            let nested = Partition::balanced(n, m, 11).split(t);
            let flat = Partition::balanced(n, m * t, 11);
            assert_eq!(nested.machines(), flat.machines());
            for k in 0..m * t {
                assert_eq!(nested.shard(k), flat.shard(k), "shard {k} diverged");
            }
        }
    }

    #[test]
    fn min_shard_reports_smallest() {
        let p = Partition::balanced(10, 3, 0); // sizes 4, 3, 3
        assert_eq!(p.min_shard(), 3);
    }

    #[test]
    #[should_panic]
    fn split_rejects_oversized_t() {
        Partition::balanced(10, 3, 0).split(4); // min shard is 3
    }

    fn prefix_of(row_nnz: &[u64]) -> Vec<u64> {
        let mut p = vec![0u64];
        for &c in row_nnz {
            p.push(p.last().unwrap() + c);
        }
        p
    }

    fn max_chunk_nnz(prefix: &[u64], ranges: &[std::ops::Range<usize>]) -> u64 {
        ranges.iter().map(|r| prefix[r.end] - prefix[r.start]).max().unwrap()
    }

    #[test]
    fn split_nnz_basic_shapes() {
        // One heavy row dominates: it gets its own chunk, the light rows
        // spread over the rest.
        let prefix = prefix_of(&[100, 1, 1, 1, 1, 1]);
        let rs = split_nnz(&prefix, 3);
        assert_eq!(rs[0], 0..1, "the heavy row is isolated");
        assert_eq!(max_chunk_nnz(&prefix, &rs), 100);
        // Uniform rows: max chunk nnz matches the row-balanced split.
        let prefix = prefix_of(&[3; 12]);
        let rs = split_nnz(&prefix, 4);
        assert_eq!(max_chunk_nnz(&prefix, &rs), 9);
    }

    #[test]
    fn split_nnz_accepts_absolute_offset_prefixes() {
        // A mapped cache hands over absolute indptr entries; cuts must
        // depend only on the differences.
        let rel = prefix_of(&[5, 1, 9, 2, 2, 7]);
        let abs: Vec<u64> = rel.iter().map(|&x| x + 1000).collect();
        assert_eq!(split_nnz(&rel, 3), split_nnz(&abs, 3));
    }

    #[test]
    fn prop_split_nnz_covers_and_never_beats_optimal_bound() {
        for_each_case(0x57A7, 80, |g| {
            let t = g.usize_in(1, 8);
            let n = g.usize_in(t, t * 25);
            // Zipf-ish skew: most rows tiny, a few huge.
            let row_nnz: Vec<u64> = (0..n)
                .map(|_| {
                    if g.bool(0.15) {
                        g.usize_in(50, 400) as u64
                    } else {
                        g.usize_in(0, 8) as u64
                    }
                })
                .collect();
            let prefix = prefix_of(&row_nnz);
            let rs = split_nnz(&prefix, t);
            // Disjoint contiguous cover, every chunk non-empty.
            assert_eq!(rs.len(), t);
            assert_eq!(rs[0].start, 0);
            assert_eq!(rs.last().unwrap().end, n);
            for pair in rs.windows(2) {
                assert_eq!(pair[0].end, pair[1].start, "ranges must be contiguous");
            }
            assert!(rs.iter().all(|r| !r.is_empty()));
            // Deterministic: same inputs, same cuts.
            assert_eq!(rs, split_nnz(&prefix, t));
            // Never worse than row balancing.
            let row_balanced = max_chunk_nnz(&prefix, &split_ranges(n, t));
            assert!(
                max_chunk_nnz(&prefix, &rs) <= row_balanced,
                "nnz cuts worse than row cuts: {} > {row_balanced}",
                max_chunk_nnz(&prefix, &rs)
            );
        });
    }

    #[test]
    fn split_nnz_is_optimal_on_small_cases() {
        // Brute-force every contiguous t-chunking of small inputs and
        // check the bisection finds the true minimal max-chunk nnz.
        fn brute(prefix: &[u64], t: usize) -> u64 {
            let n = prefix.len() - 1;
            fn rec(prefix: &[u64], start: usize, t: usize) -> u64 {
                let n = prefix.len() - 1;
                if t == 1 {
                    return prefix[n] - prefix[start];
                }
                (start + 1..=n - (t - 1))
                    .map(|cut| (prefix[cut] - prefix[start]).max(rec(prefix, cut, t - 1)))
                    .min()
                    .unwrap()
            }
            assert!(n >= t);
            rec(prefix, 0, t)
        }
        for_each_case(0x0B57, 60, |g| {
            let t = g.usize_in(1, 4);
            let n = g.usize_in(t, 10);
            let row_nnz: Vec<u64> = (0..n).map(|_| g.usize_in(0, 30) as u64).collect();
            let prefix = prefix_of(&row_nnz);
            let got = max_chunk_nnz(&prefix, &split_nnz(&prefix, t));
            let want = brute(&prefix, t);
            assert_eq!(got, want, "suboptimal cuts for nnz {row_nnz:?}, t={t}");
        });
    }

    #[test]
    fn contiguous_nnz_invariants_and_degenerate_rows() {
        let prefix = prefix_of(&[0, 0, 40, 1, 1, 0, 7, 7]);
        let p = Partition::contiguous_nnz(&prefix, 3);
        assert_eq!(p.machines(), 3);
        assert_eq!(p.total(), 8);
        p.check_invariants(false).unwrap();
        // Shards are ascending contiguous runs (the zero-copy cache
        // contract of WorkerState::from_partition).
        for l in 0..3 {
            let s = p.shard(l);
            assert!(s.windows(2).all(|w| w[1] == w[0] + 1));
        }
    }

    #[test]
    fn split_nnz_method_refines_each_shard_in_order() {
        let row_nnz: Vec<u64> = (0..60).map(|i| if i % 9 == 0 { 120 } else { 2 }).collect();
        let p = Partition::balanced(60, 4, 5);
        let s = p.split_nnz(3, &row_nnz);
        assert_eq!(s.machines(), 12);
        s.check_invariants(false).unwrap();
        for l in 0..4 {
            let rebuilt: Vec<usize> = (0..3).flat_map(|k| s.shard(l * 3 + k).to_vec()).collect();
            assert_eq!(rebuilt, p.shard(l), "sub-shards must concatenate in order");
            // Within each machine, the nnz split is no worse than the
            // row split.
            let row_split = p.split(3);
            let nnz_of = |part: &Partition, k: usize| -> u64 {
                part.shard(l * 3 + k).iter().map(|&i| row_nnz[i]).sum()
            };
            let got = (0..3).map(|k| nnz_of(&s, k)).max().unwrap();
            let via_rows = (0..3).map(|k| nnz_of(&row_split, k)).max().unwrap();
            assert!(got <= via_rows, "machine {l}: {got} > {via_rows}");
        }
    }

    #[test]
    fn split_nnz_one_is_identity() {
        let row_nnz = vec![1u64; 30];
        let p = Partition::balanced(30, 3, 2);
        let s = p.split_nnz(1, &row_nnz);
        for l in 0..3 {
            assert_eq!(s.shard(l), p.shard(l));
        }
    }
}
