//! Balanced random partitioning of examples across `m` simulated machines.
//!
//! Mirrors the paper's experimental protocol (§10: "we use same balanced
//! data partitions and random seeds"): a seeded shuffle of `{0..n}` split
//! into `m` contiguous chunks whose sizes differ by at most one.

use crate::utils::Rng;

/// A partition of `{0, …, n−1}` into `m` machine-local index sets `S_ℓ`.
#[derive(Clone, Debug)]
pub struct Partition {
    shards: Vec<Vec<usize>>,
    n: usize,
}

impl Partition {
    /// Balanced random partition with a seeded shuffle.
    pub fn balanced(n: usize, m: usize, seed: u64) -> Self {
        assert!(m >= 1, "need at least one machine");
        assert!(n >= m, "need at least one example per machine (n={n}, m={m})");
        let mut idx: Vec<usize> = (0..n).collect();
        Rng::new(seed).shuffle(&mut idx);
        let base = n / m;
        let extra = n % m;
        let mut shards = Vec::with_capacity(m);
        let mut cursor = 0usize;
        for l in 0..m {
            let size = base + usize::from(l < extra);
            shards.push(idx[cursor..cursor + size].to_vec());
            cursor += size;
        }
        Partition { shards, n }
    }

    /// Contiguous balanced partition: machine `ℓ` owns the `ℓ`-th range
    /// of [`split_ranges`]`(n, m)` — no shuffle, no seed.
    ///
    /// This is the partition the binary cache path uses (`--cache`):
    /// each worker's shard is a contiguous row range of the mapped
    /// file, so shards are served zero-copy. A text-parsed run with
    /// `partition = contiguous` produces the *same* index sets, which
    /// is what makes cache-vs-text solves bit-identical.
    pub fn contiguous(n: usize, m: usize) -> Self {
        let shards = split_ranges(n, m).into_iter().map(|r| r.collect()).collect();
        Partition { shards, n }
    }

    /// Deterministic round-robin partition (no shuffle) — used by tests
    /// that need a fixed assignment.
    pub fn round_robin(n: usize, m: usize) -> Self {
        assert!(m >= 1 && n >= m);
        let mut shards = vec![Vec::new(); m];
        for i in 0..n {
            shards[i % m].push(i);
        }
        Partition { shards, n }
    }

    /// Number of machines `m`.
    pub fn machines(&self) -> usize {
        self.shards.len()
    }

    /// Total number of examples `n`.
    pub fn total(&self) -> usize {
        self.n
    }

    /// Index set `S_ℓ`.
    pub fn shard(&self, l: usize) -> &[usize] {
        &self.shards[l]
    }

    /// `n_ℓ = |S_ℓ|`.
    pub fn shard_size(&self, l: usize) -> usize {
        self.shards[l].len()
    }

    /// `max_ℓ n_ℓ / M_ℓ` term of Theorems 6/7 for a fixed sampling
    /// fraction `sp` (`M_ℓ = ⌈sp · n_ℓ⌉`).
    pub fn max_epoch_ratio(&self, sp: f64) -> f64 {
        (0..self.machines())
            .map(|l| {
                let nl = self.shard_size(l) as f64;
                let ml = (sp * nl).ceil().max(1.0);
                nl / ml
            })
            .fold(0.0, f64::max)
    }

    /// Smallest shard size `min_ℓ n_ℓ` — the upper bound on how many
    /// sub-shards a machine can be split into ([`Partition::split`]).
    pub fn min_shard(&self) -> usize {
        self.shards.iter().map(Vec::len).min().unwrap_or(0)
    }

    /// Sub-partition every machine's shard into `t` contiguous balanced
    /// sub-shards (hierarchical parallelism, DESIGN.md §10): the result
    /// has `m·t` *logical* machines where logical shard `ℓ·t + k` is the
    /// `k`-th contiguous chunk of machine `ℓ`'s shard, chunk sizes
    /// differing by at most one within each machine.
    ///
    /// Because [`Partition::balanced`] splits one seeded shuffle into
    /// contiguous chunks, `balanced(n, m, s).split(t)` is **identical**
    /// to `balanced(n, m·t, s)` whenever `m·t` divides `n` — the property
    /// that lets an `(m, t)` hierarchical solve reproduce a flat `m·t`
    /// solve bit for bit (pinned in `rust/tests/local_threads.rs`).
    pub fn split(&self, t: usize) -> Partition {
        assert!(t >= 1, "need at least one sub-shard per machine");
        let mut shards = Vec::with_capacity(self.shards.len() * t);
        for shard in &self.shards {
            assert!(
                shard.len() >= t,
                "cannot split a shard of {} examples into {t} sub-shards",
                shard.len()
            );
            for r in split_ranges(shard.len(), t) {
                shards.push(shard[r].to_vec());
            }
        }
        Partition { shards, n: self.n }
    }

    /// Verify partition invariants: disjoint cover of `{0..n}` with shard
    /// sizes differing by ≤ 1 (balanced variants only).
    pub fn check_invariants(&self, balanced: bool) -> anyhow::Result<()> {
        let mut seen = vec![false; self.n];
        for shard in &self.shards {
            for &i in shard {
                anyhow::ensure!(i < self.n, "index {i} out of range");
                anyhow::ensure!(!seen[i], "index {i} appears twice");
                seen[i] = true;
            }
        }
        anyhow::ensure!(seen.iter().all(|&s| s), "partition does not cover all indices");
        if balanced {
            let min = self.shards.iter().map(Vec::len).min().unwrap();
            let max = self.shards.iter().map(Vec::len).max().unwrap();
            anyhow::ensure!(max - min <= 1, "unbalanced shards: {min}..{max}");
        }
        Ok(())
    }
}

/// The contiguous balanced chunking `{0..n} → t` ranges (sizes differ by
/// at most one, larger chunks first) shared by [`Partition::split`] and
/// the TCP worker's local sub-shard reconstruction — one formula, so the
/// coordinator's logical partition and a remote worker's locally-derived
/// sub-shards can never disagree (DESIGN.md §10).
pub fn split_ranges(n: usize, t: usize) -> Vec<std::ops::Range<usize>> {
    assert!(t >= 1 && n >= t, "cannot split {n} examples into {t} chunks");
    let base = n / t;
    let extra = n % t;
    let mut out = Vec::with_capacity(t);
    let mut cursor = 0usize;
    for k in 0..t {
        let size = base + usize::from(k < extra);
        out.push(cursor..cursor + size);
        cursor += size;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::for_each_case;

    #[test]
    fn balanced_invariants_hold() {
        for &(n, m) in &[(10, 3), (100, 8), (7, 7), (1000, 20)] {
            let p = Partition::balanced(n, m, 42);
            assert_eq!(p.machines(), m);
            p.check_invariants(true).unwrap();
        }
    }

    #[test]
    fn same_seed_same_partition() {
        let a = Partition::balanced(100, 4, 7);
        let b = Partition::balanced(100, 4, 7);
        for l in 0..4 {
            assert_eq!(a.shard(l), b.shard(l));
        }
    }

    #[test]
    fn different_seed_different_partition() {
        let a = Partition::balanced(100, 4, 7);
        let b = Partition::balanced(100, 4, 8);
        assert!((0..4).any(|l| a.shard(l) != b.shard(l)));
    }

    #[test]
    fn contiguous_matches_split_ranges() {
        for &(n, m) in &[(10, 3), (100, 8), (7, 7), (1000, 20)] {
            let p = Partition::contiguous(n, m);
            p.check_invariants(true).unwrap();
            let rs = split_ranges(n, m);
            for l in 0..m {
                let want: Vec<usize> = rs[l].clone().collect();
                assert_eq!(p.shard(l), &want[..], "machine {l}");
            }
        }
    }

    #[test]
    fn round_robin_deterministic() {
        let p = Partition::round_robin(7, 3);
        assert_eq!(p.shard(0), &[0, 3, 6]);
        assert_eq!(p.shard(1), &[1, 4]);
        assert_eq!(p.shard(2), &[2, 5]);
        p.check_invariants(true).unwrap();
    }

    #[test]
    fn epoch_ratio_matches_theorem_term() {
        let p = Partition::balanced(100, 4, 1); // n_ℓ = 25
        // sp = 0.2 ⇒ M_ℓ = 5 ⇒ n_ℓ/M_ℓ = 5
        assert!((p.max_epoch_ratio(0.2) - 5.0).abs() < 1e-12);
        // sp = 1.0 ⇒ ratio 1
        assert!((p.max_epoch_ratio(1.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn prop_invariants_random_shapes() {
        for_each_case(0x9A27, 60, |g| {
            let m = g.usize_in(1, 12);
            let n = g.usize_in(m, m * 40);
            let seed = g.rng().next_u64();
            let p = Partition::balanced(n, m, seed);
            p.check_invariants(true).unwrap();
            let total: usize = (0..m).map(|l| p.shard_size(l)).sum();
            assert_eq!(total, n);
        });
    }

    #[test]
    #[should_panic]
    fn rejects_more_machines_than_examples() {
        Partition::balanced(3, 5, 0);
    }

    #[test]
    fn split_ranges_are_balanced_and_cover() {
        for &(n, t) in &[(10, 3), (12, 4), (7, 7), (100, 1), (5, 2)] {
            let rs = split_ranges(n, t);
            assert_eq!(rs.len(), t);
            assert_eq!(rs[0].start, 0);
            assert_eq!(rs.last().unwrap().end, n);
            for pair in rs.windows(2) {
                assert_eq!(pair[0].end, pair[1].start, "ranges must be contiguous");
            }
            let min = rs.iter().map(|r| r.len()).min().unwrap();
            let max = rs.iter().map(|r| r.len()).max().unwrap();
            assert!(max - min <= 1, "unbalanced chunks: {min}..{max}");
            assert!(min >= 1);
        }
    }

    #[test]
    fn split_preserves_cover_and_order() {
        let p = Partition::balanced(100, 4, 7);
        let s = p.split(3);
        assert_eq!(s.machines(), 12);
        assert_eq!(s.total(), 100);
        s.check_invariants(false).unwrap();
        // Sub-shards of machine ℓ concatenate back to ℓ's shard in order.
        for l in 0..4 {
            let rebuilt: Vec<usize> = (0..3).flat_map(|k| s.shard(l * 3 + k).to_vec()).collect();
            assert_eq!(rebuilt, p.shard(l));
        }
    }

    #[test]
    fn split_one_is_identity() {
        let p = Partition::balanced(57, 5, 9);
        let s = p.split(1);
        for l in 0..5 {
            assert_eq!(s.shard(l), p.shard(l));
        }
    }

    #[test]
    fn split_matches_flat_balanced_when_divisible() {
        // The bit-parity anchor: when m·t | n, splitting the m-machine
        // partition reproduces the flat m·t-machine partition exactly.
        for &(n, m, t) in &[(240, 2, 2), (240, 3, 4), (64, 4, 4), (96, 2, 8)] {
            assert_eq!(n % (m * t), 0);
            let nested = Partition::balanced(n, m, 11).split(t);
            let flat = Partition::balanced(n, m * t, 11);
            assert_eq!(nested.machines(), flat.machines());
            for k in 0..m * t {
                assert_eq!(nested.shard(k), flat.shard(k), "shard {k} diverged");
            }
        }
    }

    #[test]
    fn min_shard_reports_smallest() {
        let p = Partition::balanced(10, 3, 0); // sizes 4, 3, 3
        assert_eq!(p.min_shard(), 3);
    }

    #[test]
    #[should_panic]
    fn split_rejects_oversized_t() {
        Partition::balanced(10, 3, 0).split(4); // min shard is 3
    }
}
