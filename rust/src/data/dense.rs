//! Dense row-major matrix — staging buffers for the XLA batched path and
//! small test fixtures. The solve path proper works on [`super::sparse`].

/// Dense row-major `f64` matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    data: Vec<f64>,
    rows: usize,
    cols: usize,
}

impl DenseMatrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DenseMatrix {
            data: vec![0.0; rows * cols],
            rows,
            cols,
        }
    }

    /// From a flat row-major buffer.
    pub fn from_flat(data: Vec<f64>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols);
        DenseMatrix { data, rows, cols }
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Flat row-major view.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// `X w`.
    pub fn matvec(&self, w: &[f64]) -> Vec<f64> {
        assert_eq!(w.len(), self.cols);
        (0..self.rows)
            .map(|i| crate::utils::math::dot(self.row(i), w))
            .collect()
    }

    /// `Xᵀ a`.
    pub fn matvec_t(&self, a: &[f64]) -> Vec<f64> {
        assert_eq!(a.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            crate::utils::math::axpy(a[i], self.row(i), &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_matvec() {
        let m = DenseMatrix::from_flat(vec![1.0, 2.0, 3.0, 4.0], 2, 2);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        assert_eq!(m.matvec_t(&[1.0, 1.0]), vec![4.0, 6.0]);
    }

    #[test]
    fn zeros_shape() {
        let m = DenseMatrix::zeros(3, 4);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 4);
        assert!(m.as_slice().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn row_mut_writes() {
        let mut m = DenseMatrix::zeros(2, 2);
        m.row_mut(1)[0] = 5.0;
        assert_eq!(m.row(1), &[5.0, 0.0]);
    }
}
