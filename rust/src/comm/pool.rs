//! Persistent worker pool: long-lived OS threads driven over channels,
//! with a second tier of per-worker *sub-worker* threads for nested
//! parallel sections (hierarchical intra-machine parallelism, DESIGN.md
//! §4/§10).
//!
//! The previous `Cluster::Threads` backend spawned one fresh OS thread
//! per machine per round through `std::thread::scope`, which puts a
//! thread create/join pair on every simulated communication round — at
//! mini-batch sampling fractions (`sp ≪ 1`, thousands of rounds) the
//! spawn overhead dwarfs the local step itself. This pool spawns each
//! worker thread once, parks it on an `mpsc` job queue, and reuses it for
//! every subsequent parallel section (see DESIGN.md §4). Worker `l` of a
//! parallel section always runs on pool thread `l`, so a solve's
//! per-machine state stays on the same thread round after round.
//!
//! **Nested sections.** A [`WorkerPool::run`] issued from *inside* a pool
//! job used to degrade to inline serial execution (dispatching to the
//! global queues would deadlock the issuing worker behind itself). It now
//! dispatches to the issuing worker's own lazily-spawned sub-queue
//! threads: a machine's `T` sub-shard solvers run genuinely concurrently,
//! with sub-job `0` executed inline on the issuing worker so a `T = 1`
//! nested section costs nothing and a `T`-wide one occupies exactly `T`
//! threads. Sub-workers belong to one pool worker and that worker's jobs
//! are serialized FIFO, so concurrent solves time-sharing the pool can
//! never contend for the same sub-queues. Nesting is bounded at two
//! levels — machine × sub-shard, DADM's hierarchy — every sub-shard leg
//! (queued sub-worker jobs *and* the inline job 0, which runs at
//! sub-worker tier for its duration) executes further parallel sections
//! inline serially.
//!
//! The pool is process-global and grows lazily to the widest machine
//! count requested; idle workers block on their queue and cost nothing.
//! Concurrent parallel sections (e.g. two solves in one process)
//! time-share the same workers — jobs queue FIFO per worker rather than
//! spawning extra threads.

use super::cluster::ParallelRun;
use std::cell::{Cell, RefCell};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// How deep in the pool hierarchy the current thread sits: 0 = not a
/// pool thread, 1 = worker, 2 = sub-worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Tier {
    Outside,
    Worker,
    SubWorker,
}

thread_local! {
    /// Set for the lifetime of every pool (sub-)worker thread; selects
    /// between top-level dispatch, sub-queue dispatch, and inline
    /// execution in [`WorkerPool::run`].
    static TIER: Cell<Tier> = const { Cell::new(Tier::Outside) };

    /// The issuing worker's private sub-worker queues (lazily spawned;
    /// only ever populated on `Tier::Worker` threads).
    static SUB_SENDERS: RefCell<Vec<Sender<Job>>> = const { RefCell::new(Vec::new()) };
}

/// A type-erased unit of work shipped to a pool thread.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Scoped tier override restoring the previous tier on drop. The inline
/// job-0 leg of a nested section runs at `SubWorker` tier so that *its*
/// nested sections degrade inline too — the two-level bound (machine ×
/// sub-shard) holds for every leg, not just the queued ones.
struct TierGuard(Tier);

impl TierGuard {
    fn enter(tier: Tier) -> TierGuard {
        TierGuard(TIER.with(|t| t.replace(tier)))
    }
}

impl Drop for TierGuard {
    fn drop(&mut self) {
        TIER.with(|t| t.set(self.0));
    }
}

/// Process-global pool of persistent worker threads.
pub struct WorkerPool {
    /// One job queue per worker thread, in spawn order.
    senders: Mutex<Vec<Sender<Job>>>,
}

static POOL: OnceLock<WorkerPool> = OnceLock::new();

/// Spawn one parked queue-driven thread at the given tier.
fn spawn_queue_thread(name: String, tier: Tier) -> Sender<Job> {
    let (tx, rx) = channel::<Job>();
    #[allow(clippy::expect_used)]
    std::thread::Builder::new()
        .name(name)
        .spawn(move || {
            TIER.with(|t| t.set(tier));
            while let Ok(job) = rx.recv() {
                // A panicking job must not take down the pool thread; the
                // panic is re-raised on the submitting side when the
                // job's result slot comes back empty.
                let _ = catch_unwind(AssertUnwindSafe(job));
            }
        })
        // dadm-lint: allow(total-decoding) — OS thread-spawn failure at pool growth is unrecoverable; abort loudly
        .expect("failed to spawn pool worker");
    tx
}

impl WorkerPool {
    /// The process-global pool (created empty on first use).
    pub fn global() -> &'static WorkerPool {
        POOL.get_or_init(|| WorkerPool {
            senders: Mutex::new(Vec::new()),
        })
    }

    /// Number of worker threads currently alive (top tier only).
    ///
    /// A poisoned registry lock is recovered rather than propagated: the
    /// registry (a grow-only `Vec` of queue senders) is never left
    /// half-mutated by a panicking round, and `Drop`-driven teardown
    /// still needs to count workers.
    pub fn workers(&self) -> usize {
        self.senders
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .len()
    }

    /// Grow the pool to at least `m` workers and hand back their queues.
    /// Poison recovery as in [`WorkerPool::workers`].
    fn ensure_workers(&self, m: usize) -> Vec<Sender<Job>> {
        let mut senders = self
            .senders
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        while senders.len() < m {
            let id = senders.len();
            senders.push(spawn_queue_thread(format!("dadm-worker-{id}"), Tier::Worker));
        }
        senders[..m].to_vec()
    }

    /// Run `f(l, &mut states[l])` for every `l` concurrently, blocking
    /// until all have finished. Semantics and timing accounting match
    /// [`super::Cluster::run`]. Issued from a pool worker, the section
    /// runs on that worker's sub-queues (job 0 inline); issued from a
    /// sub-worker, it runs inline serially (two-level nesting bound).
    pub fn run<S, T, F>(&self, states: &mut [S], f: F) -> ParallelRun<T>
    where
        S: Send,
        T: Send,
        F: Fn(usize, &mut S) -> T + Sync,
    {
        let m = states.len();
        if m == 0 {
            return ParallelRun {
                results: Vec::new(),
                parallel_secs: 0.0,
                total_secs: 0.0,
            };
        }
        match TIER.with(|t| t.get()) {
            Tier::Outside => {
                let senders = self.ensure_workers(m);
                dispatch(&senders, 0, states, &f)
            }
            Tier::Worker => {
                if m == 1 {
                    return run_inline(states, &f);
                }
                // Sub-queue dispatch: jobs 1.. go to this worker's private
                // sub-workers, job 0 runs inline on the worker itself —
                // a T-wide section occupies exactly T threads.
                let senders = SUB_SENDERS.with(|subs| {
                    let mut subs = subs.borrow_mut();
                    while subs.len() < m - 1 {
                        let id = subs.len();
                        subs.push(spawn_queue_thread(format!("dadm-sub-{id}"), Tier::SubWorker));
                    }
                    subs[..m - 1].to_vec()
                });
                dispatch(&senders, 1, states, &f)
            }
            // A section issued from a sub-worker: the hierarchy is two
            // levels deep by design; run inline with Serial timing
            // semantics rather than growing threads without bound.
            Tier::SubWorker => run_inline(states, &f),
        }
    }
}

/// Inline serial execution with the same timing semantics as
/// `Cluster::Serial` (per-leg elapsed, parallel = max, total = sum) —
/// the one shared serial loop, also behind
/// [`super::cluster::run_subgroup`]'s non-parallel path.
pub(crate) fn run_inline<S, T, F>(states: &mut [S], f: &F) -> ParallelRun<T>
where
    F: Fn(usize, &mut S) -> T,
{
    let mut results = Vec::with_capacity(states.len());
    let mut parallel_secs = 0.0f64;
    let mut total_secs = 0.0f64;
    for (l, s) in states.iter_mut().enumerate() {
        let t0 = Instant::now();
        results.push(f(l, s));
        let t = t0.elapsed().as_secs_f64();
        parallel_secs = parallel_secs.max(t);
        total_secs += t;
    }
    ParallelRun {
        results,
        parallel_secs,
        total_secs,
    }
}

/// Ship jobs `inline_from..` to `senders` (one each, in order), run jobs
/// `0..inline_from` on the calling thread, and drain all results.
/// `inline_from` is 0 for top-level sections (all queued) and 1 for
/// nested ones (job 0 on the issuing worker).
fn dispatch<S, T, F>(
    senders: &[Sender<Job>],
    inline_from: usize,
    states: &mut [S],
    f: &F,
) -> ParallelRun<T>
where
    S: Send,
    T: Send,
    F: Fn(usize, &mut S) -> T + Sync,
{
    let m = states.len();
    debug_assert_eq!(senders.len(), m - inline_from);
    // Each job reports either its (result, elapsed) or the panic payload
    // it caught, so a panicking local step re-raises with the original
    // message on the submitting side.
    let (tx, rx) = channel::<(usize, std::thread::Result<(T, f64)>)>();
    let (inline_states, queued_states) = states.split_at_mut(inline_from);
    for (k, (s, sender)) in queued_states.iter_mut().zip(senders).enumerate() {
        let l = inline_from + k;
        let tx = tx.clone();
        let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
            let t0 = Instant::now();
            let outcome = catch_unwind(AssertUnwindSafe(|| f(l, s)))
                .map(|r| (r, t0.elapsed().as_secs_f64()));
            let _ = tx.send((l, outcome));
        });
        // SAFETY: the job borrows `states` and `f`, which outlive this
        // call frame, and this function does not return until every job
        // has run to completion (or been dropped unrun): the drain loop
        // below blocks until all clones of `tx` are gone, and each clone
        // lives inside exactly one job. Erasing the borrow lifetime to
        // 'static is therefore sound — the referents are live for the
        // whole time any job can observe them.
        let job: Job = unsafe { std::mem::transmute(job) };
        // A send can only fail if the worker thread is gone (process
        // teardown); the undelivered job — and its `tx` clone — are
        // dropped with the error, so the drain below still terminates
        // and the empty slot reports the dead worker.
        let _ = sender.send(job);
    }
    // Inline legs run on the calling thread while the queued jobs are
    // already in flight — at sub-worker tier when this is a nested
    // section, so their own nested sections run inline like every other
    // sub-shard leg's would.
    if !inline_states.is_empty() {
        let _tier = (inline_from > 0).then(|| TierGuard::enter(Tier::SubWorker));
        for (l, s) in inline_states.iter_mut().enumerate() {
            let t0 = Instant::now();
            let outcome = catch_unwind(AssertUnwindSafe(|| f(l, s)))
                .map(|r| (r, t0.elapsed().as_secs_f64()));
            let _ = tx.send((l, outcome));
        }
    }
    drop(tx);

    let mut slots: Vec<Option<std::thread::Result<(T, f64)>>> = (0..m).map(|_| None).collect();
    while let Ok((l, outcome)) = rx.recv() {
        slots[l] = Some(outcome);
    }
    // All senders are gone ⇒ every job has finished or been dropped;
    // only now is it safe to unwind past the borrowed state.
    let mut results = Vec::with_capacity(m);
    let mut parallel_secs = 0.0f64;
    let mut total_secs = 0.0f64;
    for slot in slots {
        match slot {
            Some(Ok((r, t))) => {
                results.push(r);
                parallel_secs = parallel_secs.max(t);
                total_secs += t;
            }
            Some(Err(payload)) => std::panic::resume_unwind(payload),
            // dadm-lint: allow(total-decoding) — a dead worker dropped a job unrun; the synchronous barrier cannot fill its slot
            None => panic!("pool worker thread died"),
        }
    }
    ParallelRun {
        results,
        parallel_secs,
        total_secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_preserves_order() {
        let mut s: Vec<u64> = (0..6).collect();
        let r = WorkerPool::global().run(&mut s, |l, x| {
            *x += 100;
            *x * 10 + l as u64
        });
        assert_eq!(s, vec![100, 101, 102, 103, 104, 105]);
        assert_eq!(r.results, vec![1000, 1011, 1022, 1033, 1044, 1055]);
        assert!(r.total_secs >= r.parallel_secs);
    }

    #[test]
    fn threads_persist_across_runs() {
        let pool = WorkerPool::global();
        let collect_ids = |pool: &WorkerPool| -> Vec<std::thread::ThreadId> {
            let mut s = vec![(); 3];
            pool.run(&mut s, |_, _| std::thread::current().id()).results
        };
        let a = collect_ids(pool);
        let b = collect_ids(pool);
        // Same workers serve consecutive parallel sections: no per-round
        // spawning.
        assert_eq!(a, b);
        assert!(pool.workers() >= 3);
    }

    #[test]
    fn grows_to_widest_request() {
        let pool = WorkerPool::global();
        let mut s = vec![0u8; 9];
        let r = pool.run(&mut s, |l, _| l);
        assert_eq!(r.results, (0..9).collect::<Vec<_>>());
        assert!(pool.workers() >= 9);
    }

    #[test]
    fn empty_input() {
        let mut s: Vec<u8> = vec![];
        let r = WorkerPool::global().run(&mut s, |_, _| 0u8);
        assert!(r.results.is_empty());
        assert_eq!(r.parallel_secs, 0.0);
        assert_eq!(r.total_secs, 0.0);
    }

    #[test]
    fn nested_run_is_parallel_and_correct() {
        // A run issued from inside a pool job dispatches to the issuing
        // worker's sub-queues (no deadlock on its own queue) and must
        // preserve the result order and state mutations of the old
        // inline fallback.
        let pool = WorkerPool::global();
        let mut outer = vec![(); 3];
        let r = pool.run(&mut outer, |l, _| {
            let mut inner = vec![0usize; 2];
            let rr = pool.run(&mut inner, |k, _| k + l);
            rr.results.iter().sum::<usize>()
        });
        // Inner sums are (0+l) + (1+l) = 2l + 1.
        assert_eq!(r.results, vec![1, 3, 5]);
    }

    #[test]
    fn nested_run_overlaps_sub_jobs() {
        // Two machines × three 60 ms sub-sleeps: run serially that is
        // ≥ 360 ms of wall clock. Sleeps need no CPU, so even a loaded
        // box overlaps them; assert a generous wall bound (ideal ≈ 60 ms)
        // that still proves the sub-shard legs run concurrently.
        let pool = WorkerPool::global();
        let mut outer = vec![(); 2];
        let t0 = Instant::now();
        let r = pool.run(&mut outer, |_, _| {
            let mut inner = vec![(); 3];
            let rr = pool.run(&mut inner, |_, _| {
                std::thread::sleep(std::time::Duration::from_millis(60));
            });
            rr.parallel_secs
        });
        let wall = t0.elapsed().as_secs_f64();
        assert!(
            wall < 0.75 * 0.36,
            "nested sections did not overlap: wall {wall}s for six 60 ms sleeps"
        );
        assert_eq!(r.results.len(), 2);
    }

    #[test]
    fn doubly_nested_run_degrades_to_inline() {
        // Machine → sub-shard is the whole hierarchy; a third-level
        // section must run inline (bounded threads), not deadlock.
        let pool = WorkerPool::global();
        let mut outer = vec![(); 2];
        let r = pool.run(&mut outer, |l, _| {
            let mut mid = vec![(); 2];
            let rm = pool.run(&mut mid, |k, _| {
                let mut inner = vec![0usize; 2];
                let ri = pool.run(&mut inner, |j, _| j + k + l);
                ri.results.iter().sum::<usize>()
            });
            rm.results.iter().sum::<usize>()
        });
        // Σ_k Σ_j (j + k + l) = Σ_k (2k + 2l + 1) = 4l + 4.
        assert_eq!(r.results, vec![4, 8]);
    }

    #[test]
    fn pool_survives_a_panicking_job() {
        let pool = WorkerPool::global();
        let panicked = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut s = vec![(); 2];
            pool.run(&mut s, |l, _| {
                if l == 1 {
                    panic!("boom");
                }
                l
            });
        }));
        // The original payload is re-raised, not a generic pool message.
        let payload = panicked.expect_err("panic must propagate to the caller");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "boom");
        // The surviving workers keep serving jobs afterwards.
        let mut s = vec![0usize; 2];
        let r = pool.run(&mut s, |l, _| l + 1);
        assert_eq!(r.results, vec![1, 2]);
    }

    #[test]
    fn nested_panic_propagates_to_the_outer_caller() {
        let pool = WorkerPool::global();
        let panicked = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut outer = vec![(); 2];
            pool.run(&mut outer, |_, _| {
                let mut inner = vec![(); 2];
                pool.run(&mut inner, |k, _| {
                    if k == 1 {
                        panic!("sub boom");
                    }
                });
            });
        }));
        let payload = panicked.expect_err("nested panic must propagate");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "sub boom");
        // Workers and sub-workers keep serving afterwards.
        let mut outer = vec![(); 2];
        let r = pool.run(&mut outer, |l, _| {
            let mut inner = vec![0usize; 2];
            pool.run(&mut inner, |k, _| k + l).results.iter().sum::<usize>()
        });
        assert_eq!(r.results, vec![1, 3]);
    }
}
