//! Persistent work-stealing worker pool: long-lived OS threads that pull
//! type-erased jobs from a process-global injector queue, so a thread
//! that finishes its work early picks up whatever sub-machine is still
//! pending instead of idling behind the section's straggler (DESIGN.md
//! §16).
//!
//! The previous pool pinned job `l` of every parallel section to pool
//! thread `l` and gave each worker a private set of lazily-spawned
//! sub-queues for nested sections. That fixed assignment is exactly
//! wrong on skewed sparse data: when one machine's shard carries most of
//! the nonzeros, its sub-solvers queue behind that one worker's private
//! threads while every other worker idles at the round barrier. Here
//! every job — top-level machine legs and nested sub-machine legs alike
//! — goes through one shared injector, and any free pool thread may
//! execute it.
//!
//! **Determinism.** Scheduling freedom cannot perturb the math: each
//! section's results land in index-addressed slots (`slots[l]`), every
//! reduction downstream consumes them in fixed machine order
//! (`tree_allreduce_delta`/`tree_sum`, DESIGN.md §3), and each job's
//! closure reads and writes only its own `states[l]`. Which OS thread
//! runs a job, and in what order jobs complete, is therefore
//! unobservable in the outputs — property-pinned by
//! `stealing_results_bit_match_inline_serial`.
//!
//! **Scheduling.** [`WorkerPool::run`] wraps its jobs in a [`Section`]
//! (one FIFO of pending jobs), pushes one *ticket* per job onto the
//! global injector, and then participates: the calling thread drains its
//! own section until the queue is empty, then blocks until stolen jobs
//! finish. A worker pops a ticket, takes one job from that ticket's
//! section (tickets whose section the issuer already drained are
//! discarded), and runs it. Because every issuer drains its own queue,
//! progress never depends on a pool thread being free — the pool can be
//! arbitrarily busy and a section still completes on its caller, which
//! is the deadlock-freedom argument for nested sections.
//!
//! **Nesting** stays bounded at two levels: machine × sub-machine is
//! DADM's whole hierarchy, so sections issued at depth ≥ 2 run inline
//! serially rather than growing threads without bound. The pool grows
//! lazily to the number of live jobs minus the participating caller and
//! never shrinks; idle workers block on the injector's condvar and cost
//! nothing.

use super::cluster::ParallelRun;
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

/// A type-erased unit of work run by a pool thread or a participating
/// caller.
type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// Nesting depth of the parallel section the current thread is
    /// executing a job for: 0 = not inside any section, 1 = machine
    /// leg, 2 = sub-machine leg. Sections issued at depth ≥ 2 run
    /// inline.
    static DEPTH: Cell<u8> = const { Cell::new(0) };
}

/// Scoped depth override restoring the previous depth on drop; wrapped
/// around every job execution — worker-side and caller-side alike — so
/// a job's own nested sections see the right depth no matter which
/// thread stole it.
struct DepthGuard(u8);

impl DepthGuard {
    fn enter(depth: u8) -> DepthGuard {
        DepthGuard(DEPTH.with(|d| d.replace(depth)))
    }
}

impl Drop for DepthGuard {
    fn drop(&mut self) {
        DEPTH.with(|d| d.set(self.0));
    }
}

/// One parallel section's pending jobs. Workers reach it through ticket
/// clones on the injector; the issuing thread drains it directly.
struct Section {
    jobs: Mutex<VecDeque<Job>>,
    /// Depth the section's jobs execute at (issuer's depth + 1).
    depth: u8,
}

/// Poison recovery for every pool lock: jobs are wrapped in
/// `catch_unwind`, so a poisoned guard only ever protects consistent
/// state (a grow-only counter and pop-only queues), and teardown paths
/// still need the data.
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Process-global work-stealing pool.
pub struct WorkerPool {
    /// One ticket per pending job. A ticket is a handle to the section
    /// that owns the job, not the job itself, so the issuer can drain
    /// its own section without racing ticket delivery.
    injector: Mutex<VecDeque<Arc<Section>>>,
    /// Signalled whenever tickets are pushed.
    available: Condvar,
    /// Worker threads spawned so far (grow-only).
    spawned: Mutex<usize>,
    /// Jobs pushed but not yet completed, across all sections; sizes
    /// the pool.
    live_jobs: AtomicUsize,
}

static POOL: OnceLock<WorkerPool> = OnceLock::new();

/// Body of every pool thread: pop a ticket, take one job from its
/// section (if the issuer hasn't drained it already), run it, repeat.
fn worker_loop() {
    let pool = WorkerPool::global();
    loop {
        let ticket = {
            let mut tickets = relock(&pool.injector);
            loop {
                if let Some(t) = tickets.pop_front() {
                    break t;
                }
                tickets = pool
                    .available
                    .wait(tickets)
                    .unwrap_or_else(PoisonError::into_inner);
            }
        };
        // Bind the popped job OUTSIDE the `if let` so the section lock
        // drops before the job runs — holding it would serialize the
        // issuer's own drain against this (possibly long) job.
        let job = relock(&ticket.jobs).pop_front();
        if let Some(job) = job {
            let _depth = DepthGuard::enter(ticket.depth);
            // A panicking job must not take down the pool thread; the
            // panic is re-raised on the submitting side through the
            // job's result slot.
            let _ = catch_unwind(AssertUnwindSafe(job));
            pool.live_jobs.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

impl WorkerPool {
    /// The process-global pool (created empty on first use).
    pub fn global() -> &'static WorkerPool {
        POOL.get_or_init(|| WorkerPool {
            injector: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            spawned: Mutex::new(0),
            live_jobs: AtomicUsize::new(0),
        })
    }

    /// Number of worker threads currently alive.
    pub fn workers(&self) -> usize {
        *relock(&self.spawned)
    }

    /// Grow the pool to at least `target` worker threads.
    fn ensure_workers(&self, target: usize) {
        let mut spawned = relock(&self.spawned);
        while *spawned < target {
            let id = *spawned;
            #[allow(clippy::expect_used)]
            std::thread::Builder::new()
                .name(format!("dadm-worker-{id}"))
                .spawn(worker_loop)
                // dadm-lint: allow(total-decoding) — OS thread-spawn failure at pool growth is unrecoverable; abort loudly
                .expect("failed to spawn pool worker");
            *spawned += 1;
        }
    }

    /// Run `f(l, &mut states[l])` for every `l` concurrently, blocking
    /// until all have finished. Semantics and timing accounting match
    /// [`super::Cluster::run`]. Jobs are scheduled by work stealing —
    /// any pool thread (or the caller) may run any leg — but results
    /// are slot-addressed by `l`, so outputs are bit-identical to the
    /// serial loop regardless of execution order. Sections issued at
    /// depth ≥ 2 (below machine × sub-machine) run inline serially.
    pub fn run<S, T, F>(&self, states: &mut [S], f: F) -> ParallelRun<T>
    where
        S: Send,
        T: Send,
        F: Fn(usize, &mut S) -> T + Sync,
    {
        let m = states.len();
        if m == 0 {
            return ParallelRun {
                results: Vec::new(),
                parallel_secs: 0.0,
                total_secs: 0.0,
            };
        }
        let depth = DEPTH.with(|d| d.get());
        if depth >= 2 {
            return run_inline(states, &f);
        }
        if m == 1 {
            // A 1-wide section needs no dispatch; run it on the caller
            // at the depth its job would have had, so the job's own
            // nested sections still parallelize (and still bound at two
            // levels).
            let _depth = DepthGuard::enter(depth + 1);
            return run_inline(states, &f);
        }
        self.dispatch(depth + 1, states, &f)
    }

    /// Work-stealing dispatch: queue all jobs in a fresh [`Section`],
    /// publish one ticket per job, then help drain our own section and
    /// collect slot-ordered results.
    fn dispatch<S, T, F>(&self, depth: u8, states: &mut [S], f: &F) -> ParallelRun<T>
    where
        S: Send,
        T: Send,
        F: Fn(usize, &mut S) -> T + Sync,
    {
        let m = states.len();
        // Each job reports either its (result, elapsed) or the panic
        // payload it caught, so a panicking local step re-raises with
        // the original message on the submitting side.
        let (tx, rx) = channel::<(usize, std::thread::Result<(T, f64)>)>();
        let section = Arc::new(Section {
            jobs: Mutex::new(VecDeque::with_capacity(m)),
            depth,
        });
        {
            let mut jobs = relock(&section.jobs);
            for (l, s) in states.iter_mut().enumerate() {
                let tx = tx.clone();
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    let t0 = Instant::now();
                    let outcome = catch_unwind(AssertUnwindSafe(|| f(l, s)))
                        .map(|r| (r, t0.elapsed().as_secs_f64()));
                    let _ = tx.send((l, outcome));
                });
                // SAFETY: the job borrows `states` and `f`, which
                // outlive this call frame, and this function does not
                // return until every job has run: the collect loop
                // below blocks until all clones of `tx` are gone, each
                // clone lives inside exactly one job, and a job leaves
                // the section queue solely to be executed — by a worker
                // or by the caller's drain below (tickets orphaned by
                // the drain carry no job). Erasing the borrow lifetime
                // to 'static is therefore sound — the referents are
                // live for the whole time any job can observe them.
                let job: Job =
                    unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job) };
                jobs.push_back(job);
            }
        }
        drop(tx);

        self.live_jobs.fetch_add(m, Ordering::Relaxed);
        // The caller participates below, so full overlap needs one
        // thread per live job minus this one.
        self.ensure_workers(self.live_jobs.load(Ordering::Relaxed).saturating_sub(1));
        {
            let mut tickets = relock(&self.injector);
            for _ in 0..m {
                tickets.push_back(Arc::clone(&section));
            }
        }
        self.available.notify_all();

        // Help with our own section: pop jobs until workers have stolen
        // the rest. Every issuer drains its own queue, so a section
        // completes even when every pool thread is busy — the
        // deadlock-freedom argument for nested sections.
        loop {
            let job = relock(&section.jobs).pop_front();
            let Some(job) = job else { break };
            let _depth = DepthGuard::enter(depth);
            job();
            self.live_jobs.fetch_sub(1, Ordering::Relaxed);
        }

        let mut slots: Vec<Option<std::thread::Result<(T, f64)>>> = (0..m).map(|_| None).collect();
        while let Ok((l, outcome)) = rx.recv() {
            slots[l] = Some(outcome);
        }
        // All senders are gone ⇒ every job has finished; only now is it
        // safe to unwind past the borrowed state.
        let mut results = Vec::with_capacity(m);
        let mut parallel_secs = 0.0f64;
        let mut total_secs = 0.0f64;
        for slot in slots {
            match slot {
                Some(Ok((r, t))) => {
                    results.push(r);
                    parallel_secs = parallel_secs.max(t);
                    total_secs += t;
                }
                Some(Err(payload)) => std::panic::resume_unwind(payload),
                // dadm-lint: allow(total-decoding) — unreachable: every queued job runs exactly once and fills its slot
                None => panic!("pool job lost without a result"),
            }
        }
        ParallelRun {
            results,
            parallel_secs,
            total_secs,
        }
    }
}

/// Inline serial execution with the same timing semantics as
/// `Cluster::Serial` (per-leg elapsed, parallel = max, total = sum) —
/// the one shared serial loop, also behind
/// [`super::cluster::run_subgroup`]'s non-parallel path.
pub(crate) fn run_inline<S, T, F>(states: &mut [S], f: &F) -> ParallelRun<T>
where
    F: Fn(usize, &mut S) -> T,
{
    let mut results = Vec::with_capacity(states.len());
    let mut parallel_secs = 0.0f64;
    let mut total_secs = 0.0f64;
    for (l, s) in states.iter_mut().enumerate() {
        let t0 = Instant::now();
        results.push(f(l, s));
        let t = t0.elapsed().as_secs_f64();
        parallel_secs = parallel_secs.max(t);
        total_secs += t;
    }
    ParallelRun {
        results,
        parallel_secs,
        total_secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_preserves_order() {
        let mut s: Vec<u64> = (0..6).collect();
        let r = WorkerPool::global().run(&mut s, |l, x| {
            *x += 100;
            *x * 10 + l as u64
        });
        assert_eq!(s, vec![100, 101, 102, 103, 104, 105]);
        assert_eq!(r.results, vec![1000, 1011, 1022, 1033, 1044, 1055]);
        assert!(r.total_secs >= r.parallel_secs);
    }

    #[test]
    fn repeated_runs_reuse_threads() {
        // A hundred narrow sections must not spawn a hundred threads —
        // the pool is sized by peak live jobs, not run count. Other
        // tests share the global pool concurrently, so bound generously
        // instead of asserting exact stability.
        let pool = WorkerPool::global();
        for _ in 0..100 {
            let mut s = vec![0u64; 3];
            let r = pool.run(&mut s, |l, x| {
                *x = l as u64 + 1;
                *x
            });
            assert_eq!(r.results, vec![1, 2, 3]);
        }
        assert!(
            pool.workers() < 64,
            "pool grew per-run: {} workers",
            pool.workers()
        );
    }

    #[test]
    fn grows_to_widest_request() {
        let pool = WorkerPool::global();
        let mut s = vec![0u8; 9];
        let r = pool.run(&mut s, |l, _| l);
        assert_eq!(r.results, (0..9).collect::<Vec<_>>());
        // The caller participates, so a 9-wide section needs ≥ 8
        // workers.
        assert!(pool.workers() >= 8);
    }

    #[test]
    fn empty_input() {
        let mut s: Vec<u8> = vec![];
        let r = WorkerPool::global().run(&mut s, |_, _| 0u8);
        assert!(r.results.is_empty());
        assert_eq!(r.parallel_secs, 0.0);
        assert_eq!(r.total_secs, 0.0);
    }

    #[test]
    fn stealing_results_bit_match_inline_serial() {
        // Work stealing may run legs on any thread in any order; the
        // results must nonetheless be bit-identical to the serial loop,
        // because each leg's computation and its result slot are fixed
        // by `l`. Leg costs are deliberately skewed so completions
        // interleave differently from issue order.
        let leg = |l: usize, acc: &mut f64| -> f64 {
            let mut s = 0.0f64;
            for i in 1..(400 * (l + 1)) {
                s += ((l as f64 + 1.0) / i as f64).sin();
            }
            *acc = s;
            s * 2.0
        };
        let pool = WorkerPool::global();
        for _ in 0..4 {
            let mut a = vec![0.0f64; 6];
            let mut b = vec![0.0f64; 6];
            let ra = pool.run(&mut a, |l, s| leg(l, s));
            let rb = run_inline(&mut b, &|l, s: &mut f64| leg(l, s));
            assert_eq!(
                ra.results.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                rb.results.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(a, b);
        }
    }

    #[test]
    fn nested_run_is_parallel_and_correct() {
        // A run issued from inside a pool job publishes to the shared
        // injector (no deadlock: the issuer drains its own section) and
        // must preserve the result order and state mutations of a
        // serial loop.
        let pool = WorkerPool::global();
        let mut outer = vec![(); 3];
        let r = pool.run(&mut outer, |l, _| {
            let mut inner = vec![0usize; 2];
            let rr = pool.run(&mut inner, |k, _| k + l);
            rr.results.iter().sum::<usize>()
        });
        // Inner sums are (0+l) + (1+l) = 2l + 1.
        assert_eq!(r.results, vec![1, 3, 5]);
    }

    #[test]
    fn nested_run_overlaps_sub_jobs() {
        // Two machines × three 60 ms sub-sleeps: run serially that is
        // ≥ 360 ms of wall clock. Sleeps need no CPU, so even a loaded
        // box overlaps them; assert a generous wall bound (ideal ≈ 60 ms)
        // that still proves the sub-shard legs run concurrently.
        let pool = WorkerPool::global();
        let mut outer = vec![(); 2];
        let t0 = Instant::now();
        let r = pool.run(&mut outer, |_, _| {
            let mut inner = vec![(); 3];
            let rr = pool.run(&mut inner, |_, _| {
                std::thread::sleep(std::time::Duration::from_millis(60));
            });
            rr.parallel_secs
        });
        let wall = t0.elapsed().as_secs_f64();
        assert!(
            wall < 0.75 * 0.36,
            "nested sections did not overlap: wall {wall}s for six 60 ms sleeps"
        );
        assert_eq!(r.results.len(), 2);
    }

    #[test]
    fn idle_threads_steal_the_stragglers_sub_jobs() {
        // One outer leg finishes instantly; the other fans out four
        // 50 ms sub-sleeps. Under the old fixed assignment a machine's
        // sub-jobs were confined to its private sub-queues; with a
        // shared injector any idle pool thread helps, so the whole
        // section completes in roughly one sleep.
        let pool = WorkerPool::global();
        let mut outer = vec![0usize; 2];
        let t0 = Instant::now();
        pool.run(&mut outer, |l, _| {
            if l == 1 {
                let mut inner = vec![(); 4];
                pool.run(&mut inner, |_, _| {
                    std::thread::sleep(std::time::Duration::from_millis(50));
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        assert!(
            wall < 0.75 * 0.20,
            "sub-jobs were not stolen: wall {wall}s for four 50 ms sleeps"
        );
    }

    #[test]
    fn doubly_nested_run_degrades_to_inline() {
        // Machine → sub-shard is the whole hierarchy; a third-level
        // section must run inline (bounded threads), not deadlock.
        let pool = WorkerPool::global();
        let mut outer = vec![(); 2];
        let r = pool.run(&mut outer, |l, _| {
            let mut mid = vec![(); 2];
            let rm = pool.run(&mut mid, |k, _| {
                let mut inner = vec![0usize; 2];
                let ri = pool.run(&mut inner, |j, _| j + k + l);
                ri.results.iter().sum::<usize>()
            });
            rm.results.iter().sum::<usize>()
        });
        // Σ_k Σ_j (j + k + l) = Σ_k (2k + 2l + 1) = 4l + 4.
        assert_eq!(r.results, vec![4, 8]);
    }

    #[test]
    fn stress_nested_sections_from_concurrent_issuers() {
        // Several machine legs repeatedly issuing nested sections
        // through the one shared injector: no deadlock, no cross-talk
        // between sections, results always slot-correct.
        let pool = WorkerPool::global();
        for round in 0..10usize {
            let mut outer = vec![0usize; 5];
            let r = pool.run(&mut outer, |l, slot| {
                let mut inner = vec![0usize; 4];
                let ri = pool.run(&mut inner, |k, s| {
                    *s = 10 * l + k + round;
                    *s
                });
                assert_eq!(ri.results, inner);
                *slot = ri.results.iter().sum();
                *slot
            });
            let expect: Vec<usize> = (0..5)
                .map(|l| (0..4).map(|k| 10 * l + k + round).sum())
                .collect();
            assert_eq!(r.results, expect);
            assert_eq!(outer, expect);
        }
    }

    #[test]
    fn pool_survives_a_panicking_job() {
        let pool = WorkerPool::global();
        let panicked = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut s = vec![(); 2];
            pool.run(&mut s, |l, _| {
                if l == 1 {
                    panic!("boom");
                }
                l
            });
        }));
        // The original payload is re-raised, not a generic pool message.
        let payload = panicked.expect_err("panic must propagate to the caller");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "boom");
        // The surviving workers keep serving jobs afterwards.
        let mut s = vec![0usize; 2];
        let r = pool.run(&mut s, |l, _| l + 1);
        assert_eq!(r.results, vec![1, 2]);
    }

    #[test]
    fn nested_panic_propagates_to_the_outer_caller() {
        let pool = WorkerPool::global();
        let panicked = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut outer = vec![(); 2];
            pool.run(&mut outer, |_, _| {
                let mut inner = vec![(); 2];
                pool.run(&mut inner, |k, _| {
                    if k == 1 {
                        panic!("sub boom");
                    }
                });
            });
        }));
        let payload = panicked.expect_err("nested panic must propagate");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "sub boom");
        // Workers keep serving afterwards.
        let mut outer = vec![(); 2];
        let r = pool.run(&mut outer, |l, _| {
            let mut inner = vec![0usize; 2];
            pool.run(&mut inner, |k, _| k + l)
                .results
                .iter()
                .sum::<usize>()
        });
        assert_eq!(r.results, vec![1, 3]);
    }
}
