//! Persistent worker pool: long-lived OS threads driven over channels.
//!
//! The previous `Cluster::Threads` backend spawned one fresh OS thread
//! per machine per round through `std::thread::scope`, which puts a
//! thread create/join pair on every simulated communication round — at
//! mini-batch sampling fractions (`sp ≪ 1`, thousands of rounds) the
//! spawn overhead dwarfs the local step itself. This pool spawns each
//! worker thread once, parks it on an `mpsc` job queue, and reuses it for
//! every subsequent parallel section (see DESIGN.md §4). Worker `l` of a
//! parallel section always runs on pool thread `l`, so a solve's
//! per-machine state stays on the same thread round after round.
//!
//! The pool is process-global and grows lazily to the widest machine
//! count requested; idle workers block on their queue and cost nothing.
//! Two consequences of the global design: concurrent parallel sections
//! (e.g. two solves in one process) time-share the same workers — jobs
//! queue FIFO per worker rather than spawning extra threads — and a
//! nested [`WorkerPool::run`] issued from inside a pool job degrades to
//! inline serial execution (dispatching it to the pool would have the
//! issuing worker deadlock waiting on its own queue).

use super::cluster::ParallelRun;
use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

thread_local! {
    /// Set for the lifetime of every pool worker thread; guards against
    /// re-entrant dispatch (see [`WorkerPool::run`]).
    static IS_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// A type-erased unit of work shipped to a pool thread.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Process-global pool of persistent worker threads.
pub struct WorkerPool {
    /// One job queue per worker thread, in spawn order.
    senders: Mutex<Vec<Sender<Job>>>,
}

static POOL: OnceLock<WorkerPool> = OnceLock::new();

impl WorkerPool {
    /// The process-global pool (created empty on first use).
    pub fn global() -> &'static WorkerPool {
        POOL.get_or_init(|| WorkerPool {
            senders: Mutex::new(Vec::new()),
        })
    }

    /// Number of worker threads currently alive.
    pub fn workers(&self) -> usize {
        self.senders.lock().expect("pool lock poisoned").len()
    }

    /// Grow the pool to at least `m` workers and hand back their queues.
    fn ensure_workers(&self, m: usize) -> Vec<Sender<Job>> {
        let mut senders = self.senders.lock().expect("pool lock poisoned");
        while senders.len() < m {
            let (tx, rx) = channel::<Job>();
            let id = senders.len();
            std::thread::Builder::new()
                .name(format!("dadm-worker-{id}"))
                .spawn(move || {
                    IS_POOL_WORKER.with(|flag| flag.set(true));
                    while let Ok(job) = rx.recv() {
                        // A panicking job must not take down the pool
                        // thread; the panic is re-raised on the submitting
                        // side when the job's result slot comes back empty.
                        let _ = catch_unwind(AssertUnwindSafe(job));
                    }
                })
                .expect("failed to spawn pool worker");
            senders.push(tx);
        }
        senders[..m].to_vec()
    }

    /// Run `f(l, &mut states[l])` for every `l` concurrently, one pool
    /// worker per state, blocking until all have finished. Semantics and
    /// timing accounting match [`super::Cluster::run`].
    pub fn run<S, T, F>(&self, states: &mut [S], f: F) -> ParallelRun<T>
    where
        S: Send,
        T: Send,
        F: Fn(usize, &mut S) -> T + Sync,
    {
        let m = states.len();
        if m == 0 {
            return ParallelRun {
                results: Vec::new(),
                parallel_secs: 0.0,
                total_secs: 0.0,
            };
        }
        if IS_POOL_WORKER.with(|flag| flag.get()) {
            // Nested parallel section issued from inside a pool job:
            // dispatching it would have this worker wait on a job queued
            // behind itself — a guaranteed deadlock. Run inline instead,
            // with the same timing semantics as `Cluster::Serial`.
            let mut results = Vec::with_capacity(m);
            let mut parallel_secs = 0.0f64;
            let mut total_secs = 0.0f64;
            for (l, s) in states.iter_mut().enumerate() {
                let t0 = Instant::now();
                results.push(f(l, s));
                let t = t0.elapsed().as_secs_f64();
                parallel_secs = parallel_secs.max(t);
                total_secs += t;
            }
            return ParallelRun {
                results,
                parallel_secs,
                total_secs,
            };
        }
        let senders = self.ensure_workers(m);
        // Each job reports either its (result, elapsed) or the panic
        // payload it caught, so a panicking local step re-raises with the
        // original message on the submitting side.
        let (tx, rx) = channel::<(usize, std::thread::Result<(T, f64)>)>();
        for (l, (s, sender)) in states.iter_mut().zip(&senders).enumerate() {
            let tx = tx.clone();
            let f = &f;
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let t0 = Instant::now();
                let outcome = catch_unwind(AssertUnwindSafe(|| f(l, s)))
                    .map(|r| (r, t0.elapsed().as_secs_f64()));
                let _ = tx.send((l, outcome));
            });
            // SAFETY: the job borrows `states` and `f`, which outlive this
            // call frame, and this function does not return until every
            // job has run to completion (or been dropped unrun): the drain
            // loop below blocks until all clones of `tx` are gone, and
            // each clone lives inside exactly one job. Erasing the borrow
            // lifetime to 'static is therefore sound — the referents are
            // live for the whole time any job can observe them.
            let job: Job = unsafe { std::mem::transmute(job) };
            // A send can only fail if the worker thread is gone (process
            // teardown); the undelivered job — and its `tx` clone — are
            // dropped with the error, so the drain below still terminates
            // and the empty slot reports the dead worker.
            let _ = sender.send(job);
        }
        drop(tx);

        let mut slots: Vec<Option<std::thread::Result<(T, f64)>>> =
            (0..m).map(|_| None).collect();
        while let Ok((l, outcome)) = rx.recv() {
            slots[l] = Some(outcome);
        }
        // All senders are gone ⇒ every job has finished or been dropped;
        // only now is it safe to unwind past the borrowed state.
        let mut results = Vec::with_capacity(m);
        let mut parallel_secs = 0.0f64;
        let mut total_secs = 0.0f64;
        for slot in slots {
            match slot {
                Some(Ok((r, t))) => {
                    results.push(r);
                    parallel_secs = parallel_secs.max(t);
                    total_secs += t;
                }
                Some(Err(payload)) => std::panic::resume_unwind(payload),
                None => panic!("pool worker thread died"),
            }
        }
        ParallelRun {
            results,
            parallel_secs,
            total_secs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_preserves_order() {
        let mut s: Vec<u64> = (0..6).collect();
        let r = WorkerPool::global().run(&mut s, |l, x| {
            *x += 100;
            *x * 10 + l as u64
        });
        assert_eq!(s, vec![100, 101, 102, 103, 104, 105]);
        assert_eq!(
            r.results,
            vec![1000, 1011, 1022, 1033, 1044, 1055]
        );
        assert!(r.total_secs >= r.parallel_secs);
    }

    #[test]
    fn threads_persist_across_runs() {
        let pool = WorkerPool::global();
        let collect_ids = |pool: &WorkerPool| -> Vec<std::thread::ThreadId> {
            let mut s = vec![(); 3];
            pool.run(&mut s, |_, _| std::thread::current().id()).results
        };
        let a = collect_ids(pool);
        let b = collect_ids(pool);
        // Same workers serve consecutive parallel sections: no per-round
        // spawning.
        assert_eq!(a, b);
        assert!(pool.workers() >= 3);
    }

    #[test]
    fn grows_to_widest_request() {
        let pool = WorkerPool::global();
        let mut s = vec![0u8; 9];
        let r = pool.run(&mut s, |l, _| l);
        assert_eq!(r.results, (0..9).collect::<Vec<_>>());
        assert!(pool.workers() >= 9);
    }

    #[test]
    fn empty_input() {
        let mut s: Vec<u8> = vec![];
        let r = WorkerPool::global().run(&mut s, |_, _| 0u8);
        assert!(r.results.is_empty());
        assert_eq!(r.parallel_secs, 0.0);
        assert_eq!(r.total_secs, 0.0);
    }

    #[test]
    fn nested_run_degrades_to_inline_execution() {
        // A run issued from inside a pool job must not deadlock on the
        // issuing worker's own queue.
        let pool = WorkerPool::global();
        let mut outer = vec![(); 3];
        let r = pool.run(&mut outer, |l, _| {
            let mut inner = vec![0usize; 2];
            let rr = pool.run(&mut inner, |k, _| k + l);
            rr.results.iter().sum::<usize>()
        });
        // Inner sums are (0+l) + (1+l) = 2l + 1.
        assert_eq!(r.results, vec![1, 3, 5]);
    }

    #[test]
    fn pool_survives_a_panicking_job() {
        let pool = WorkerPool::global();
        let panicked = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut s = vec![(); 2];
            pool.run(&mut s, |l, _| {
                if l == 1 {
                    panic!("boom");
                }
                l
            });
        }));
        // The original payload is re-raised, not a generic pool message.
        let payload = panicked.expect_err("panic must propagate to the caller");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "boom");
        // The surviving workers keep serving jobs afterwards.
        let mut s = vec![0usize; 2];
        let r = pool.run(&mut s, |l, _| l + 1);
        assert_eq!(r.results, vec![1, 2]);
    }
}
